"""L1 correctness: Pallas kernels vs pure-jnp oracles, hypothesis-swept over
shapes and dtypes, plus gradient checks of the custom VJPs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    kron_pair,
    kron_pair_rank_sum,
    kron_tree_ranked,
    layernorm,
    luong_attention,
    xs_reconstruct_rows,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=dtype)


# ---------------------------------------------------------------------------
# forward agreement, hypothesis-swept shapes
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 33),
    da=st.integers(1, 9),
    db=st.integers(1, 9),
)
def test_kron_pair_matches_ref(b, da, db):
    a = rand(b * 31 + da, (b, da))
    c = rand(b * 17 + db, (b, db))
    np.testing.assert_allclose(kron_pair(a, c), ref.kron_pair_ref(a, c), rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 17),
    r=st.integers(1, 5),
    da=st.integers(1, 6),
    db=st.integers(1, 6),
)
def test_kron_rank_sum_matches_ref(b, r, da, db):
    a = rand(b + r, (b, r, da))
    c = rand(b * r + 3, (b, r, db))
    np.testing.assert_allclose(
        kron_pair_rank_sum(a, c), ref.kron_pair_rank_sum_ref(a, c), rtol=1e-5, atol=1e-5
    )


@settings(**SETTINGS)
@given(
    b=st.integers(1, 9),
    r=st.integers(1, 4),
    n=st.integers(1, 4),
    q=st.integers(2, 5),
    ln=st.booleans(),
)
def test_kron_tree_matches_ref(b, r, n, q, ln):
    leaves = rand(b * n + q, (b, r, n, q))
    got = kron_tree_ranked(leaves, layernorm_nodes=ln)
    want = ref.kron_tree_ranked_ref(leaves, layernorm_nodes=ln)
    assert got.shape == (b, q**n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 9),
    r=st.integers(1, 4),
    n=st.integers(1, 4),
    q=st.integers(2, 5),
)
def test_xs_rows_matches_ref(b, r, n, q):
    cols = rand(b + 7 * q, (b, r, n, q))
    got = xs_reconstruct_rows(cols)
    want = ref.xs_reconstruct_rows_ref(cols)
    assert got.shape == (b, q**n)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(b=st.integers(1, 33), d=st.integers(2, 65))
def test_layernorm_matches_ref(b, d):
    x = rand(b * d, (b, d))
    np.testing.assert_allclose(layernorm(x), ref.layernorm_ref(x), rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(b=st.integers(1, 9), t=st.integers(1, 12), h=st.integers(1, 16), valid=st.integers(1, 12))
def test_attention_matches_ref(b, t, h, valid):
    hq = rand(b + h, (b, h))
    enc = rand(t + h, (b, t, h))
    mask = jnp.zeros((b, t)).at[:, : min(valid, t)].set(1.0)
    c1, p1 = luong_attention(hq, enc, mask)
    c2, p2 = ref.luong_attention_ref(hq, enc, mask)
    np.testing.assert_allclose(c1, c2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------


def test_kron_norm_multiplicative():
    a = rand(0, (4, 6))
    b = rand(1, (4, 5))
    kp = kron_pair(a, b)
    na = jnp.linalg.norm(a, axis=1)
    nb = jnp.linalg.norm(b, axis=1)
    np.testing.assert_allclose(jnp.linalg.norm(kp, axis=1), na * nb, rtol=1e-5)


def test_attention_probs_normalized_and_masked():
    h = rand(2, (5, 8))
    enc = rand(3, (5, 7, 8))
    mask = jnp.zeros((5, 7)).at[:, :3].set(1.0)
    _, probs = luong_attention(h, enc, mask)
    np.testing.assert_allclose(probs.sum(axis=1), np.ones(5), rtol=1e-5)
    assert float(jnp.abs(probs[:, 3:]).max()) < 1e-7


def test_layernorm_row_stats():
    x = rand(4, (6, 32)) * 5.0 + 3.0
    y = layernorm(x)
    np.testing.assert_allclose(y.mean(axis=1), np.zeros(6), atol=1e-5)
    np.testing.assert_allclose(y.std(axis=1), np.ones(6), atol=1e-2)


def test_tree_equals_chain_without_ln():
    # Balanced tree (kernel) == left chain (ref) by associativity.
    leaves = rand(9, (3, 2, 4, 3))
    got = kron_tree_ranked(leaves, layernorm_nodes=False)
    want = ref.xs_reconstruct_rows_ref(leaves)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# gradients (custom VJPs vs jnp autodiff of the refs)
# ---------------------------------------------------------------------------


def _gradcheck(f, fr, args, tol=5e-3):
    g1 = jax.grad(lambda *a: (f(*a) ** 2).sum())(*args)
    g2 = jax.grad(lambda *a: (fr(*a) ** 2).sum())(*args)
    np.testing.assert_allclose(g1, g2, rtol=tol, atol=tol)


def test_grad_kron_pair():
    _gradcheck(kron_pair, ref.kron_pair_ref, (rand(0, (4, 5)), rand(1, (4, 3))))


def test_grad_rank_sum():
    _gradcheck(
        kron_pair_rank_sum,
        ref.kron_pair_rank_sum_ref,
        (rand(2, (3, 2, 4)), rand(3, (3, 2, 5))),
    )


def test_grad_layernorm():
    _gradcheck(layernorm, ref.layernorm_ref, (rand(4, (5, 16)),))


@settings(**SETTINGS)
@given(n=st.integers(1, 4), q=st.integers(2, 4), r=st.integers(1, 3))
def test_grad_xs_rows_swept(n, q, r):
    cols = rand(n * q + r, (3, r, n, q))
    _gradcheck(xs_reconstruct_rows, ref.xs_reconstruct_rows_ref, (cols,))


def test_grad_tree_with_layernorm():
    leaves = rand(7, (2, 2, 4, 3))
    _gradcheck(
        lambda l: kron_tree_ranked(l, True),
        lambda l: ref.kron_tree_ranked_ref(l, True),
        (leaves,),
    )


def test_grad_attention():
    h, enc = rand(0, (3, 6)), rand(1, (3, 5, 6))
    mask = jnp.ones((3, 5)).at[:, 4:].set(0.0)
    _gradcheck(
        lambda h, e: luong_attention(h, e, mask)[0],
        lambda h, e: ref.luong_attention_ref(h, e, mask)[0],
        (h, enc),
    )


def test_grad_finite_differences_spot():
    # Independent FD check, not via ref autodiff.
    cols = np.array(rand(5, (1, 1, 2, 3)))
    f = lambda c: float((xs_reconstruct_rows(jnp.array(c)) ** 2).sum())
    g = np.array(jax.grad(lambda c: (xs_reconstruct_rows(c) ** 2).sum())(jnp.array(cols)))
    eps = 1e-3
    for idx in [(0, 0, 0, 0), (0, 0, 1, 2)]:
        cp = cols.copy()
        cp[idx] += eps
        cm = cols.copy()
        cm[idx] -= eps
        fd = (f(cp) - f(cm)) / (2 * eps)
        assert abs(fd - g[idx]) < 5e-2, f"fd {fd} vs grad {g[idx]} at {idx}"


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
