"""AOT pipeline: manifest integrity, HLO text well-formedness, variant
registry coverage of the paper's tables, incremental-build hash."""

import json
import os

import pytest

from compile.aot import kernel_artifacts, source_hash, spec_manifest, variants
from compile.hlo import lower_to_text

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_variant_registry_covers_paper_tables():
    v = variants()
    # Table 1 mirror
    for name in ["sum_regular", "sum_w2k_o4r1", "sum_xs_o2r10", "sum_xs_o4r1"]:
        assert name in v
    # Table 2 mirror
    for name in ["mt_regular", "mt_xs_o2r30", "mt_xs_o2r10", "mt_xs_o3r10"]:
        assert name in v
    # Table 3 mirror
    for name in ["qa_regular", "qa_xs_o2r2", "qa_xs_o4r1"]:
        assert name in v
    assert len(v) == 11


def test_spec_manifest_structure():
    v = variants()
    task, spec = v["sum_xs_o2r10"]
    m = spec_manifest(task, spec)
    assert m["dims"]["task"] == "sum"
    assert m["embedding"]["kind"] == "xs"
    assert m["embedding"]["rank"] == 10
    names = [p["name"] for p in m["params"]]
    assert "emb/factors" in names
    assert "out/w" in names
    for p in m["params"]:
        assert p["init"]["dist"] in ("uniform", "zeros", "ones")
        if p["init"]["dist"] == "uniform":
            assert p["init"]["a"] > 0


def test_lowering_produces_parseable_hlo_text():
    import jax.numpy as jnp
    import jax

    def fn(x, y):
        return (jnp.dot(x, y),)

    text = lower_to_text(fn, [jax.ShapeDtypeStruct((2, 3), jnp.float32),
                              jax.ShapeDtypeStruct((3, 2), jnp.float32)])
    assert "ENTRY" in text
    assert "f32[2,3]" in text
    assert "dot" in text


def test_kernel_artifacts_registry():
    arts = kernel_artifacts()
    assert set(arts) == {
        "kernel_kron_pair",
        "kernel_xs_rows",
        "kernel_layernorm",
        "kernel_attention",
    }


def test_source_hash_stable():
    assert source_hash() == source_hash()
    assert len(source_hash()) == 64


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_manifest_consistent_with_registry():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        m = json.load(f)
    v = variants()
    assert set(m["variants"]) == set(v)
    for name, entry in m["variants"].items():
        task, spec = v[name]
        # Shapes in the manifest must match the current registry.
        fresh = spec_manifest(task, spec)
        assert entry["dims"] == fresh["dims"], f"{name} dims drift"
        assert entry["params"] == fresh["params"], f"{name} params drift"
        for fname, finfo in entry["functions"].items():
            path = os.path.join(ART_DIR, finfo["file"])
            assert os.path.exists(path), f"missing {finfo['file']}"
            with open(path) as fh:
                head = fh.read(4096)
            assert "ENTRY" in head or "HloModule" in head


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
    reason="artifacts not built",
)
def test_built_manifest_hash_current():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        m = json.load(f)
    assert m["source_hash"] == source_hash(), (
        "artifacts stale vs python/compile sources — run `make artifacts`"
    )


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
