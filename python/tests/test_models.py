"""L2 model graphs: shapes, masking semantics, loss behaviour (overfit a
fixed batch), decode-step consistency with the training path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, model_qa
from compile.aot import qa_functions, seq2seq_functions, variants

VAR = variants()


def mk_inputs(ex_shapes, rng):
    args = []
    for s in ex_shapes:
        if s.dtype == jnp.int32:
            args.append(jnp.array(rng.integers(4, 20, s.shape), jnp.int32))
        else:
            args.append(jnp.array(rng.normal(0, 0.05, s.shape), jnp.float32))
    return args


@pytest.mark.parametrize("vname", ["sum_regular", "sum_xs_o2r10", "sum_w2k_o4r1"])
def test_seq2seq_train_step_shapes_and_finite(vname):
    task, spec = VAR[vname]
    fns = seq2seq_functions(spec)
    fn, ex, _, _ = fns["train_step"]
    rng = np.random.default_rng(0)
    args = mk_inputs(ex, rng)
    # Proper teacher-forcing batch: mask in {0,1}, step=1, lr small.
    b, tt = spec.batch, spec.tgt_len
    args[-3] = jnp.ones((b, tt), jnp.float32)
    args[-2] = jnp.float32(1.0)
    args[-1] = jnp.float32(1e-3)
    out = jax.jit(fn)(*args)
    nparams = len(model.param_specs(spec))
    assert len(out) == 3 * nparams + 1
    loss = float(out[-1])
    assert np.isfinite(loss) and loss > 0
    # Initial loss ≈ ln(V) for random init.
    assert abs(loss - np.log(spec.vocab)) < 1.5


def test_seq2seq_overfits_fixed_batch():
    task, spec = VAR["sum_xs_o2r10"]
    names = [n for n, _, _ in model.param_specs(spec)]
    fns = seq2seq_functions(spec)
    fn, ex, _, _ = fns["train_step"]
    rng = np.random.default_rng(1)
    args = mk_inputs(ex, rng)
    b, tt = spec.batch, spec.tgt_len
    # Fixed, learnable batch: target = copy of first src tokens.
    src = jnp.array(rng.integers(4, 40, (b, spec.src_len)), jnp.int32)
    tgt = jnp.concatenate(
        [jnp.full((b, 1), 2, jnp.int32), src[:, : tt - 2], jnp.full((b, 1), 3, jnp.int32)],
        axis=1,
    )
    mask = jnp.ones((b, tt), jnp.float32)
    np_ = len(names)
    # params random, Adam moments start at zero
    state = list(args[:np_]) + [jnp.zeros_like(a) for a in args[np_ : 3 * np_]]
    step_fn = jax.jit(fn)
    losses = []
    for step in range(30):
        out = step_fn(*state, src, tgt, mask, jnp.float32(step + 1), jnp.float32(5e-3))
        state = list(out[:-1])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.5, f"no overfit: {losses[0]} → {losses[-1]}"


def test_encode_mask_semantics():
    task, spec = VAR["sum_regular"]
    names = [n for n, _, _ in model.param_specs(spec)]
    fns = seq2seq_functions(spec)
    fn, ex, _, _ = fns["encode"]
    rng = np.random.default_rng(2)
    args = mk_inputs(ex, rng)
    src = np.array(rng.integers(4, 30, (spec.batch, spec.src_len)), np.int32)
    src[:, 10:] = 0  # PAD tail
    out = jax.jit(fn)(*args[: len(names)], jnp.array(src))
    enc_proj, mask, h0 = out
    assert enc_proj.shape == (spec.batch, spec.src_len, spec.hidden)
    assert h0.shape == (spec.batch, spec.hidden)
    np.testing.assert_allclose(np.array(mask[:, :10]), 1.0)
    np.testing.assert_allclose(np.array(mask[:, 10:]), 0.0)


def test_decode_step_argmax_consistent_with_logits():
    task, spec = VAR["sum_regular"]
    names = [n for n, _, _ in model.param_specs(spec)]
    fns = seq2seq_functions(spec)
    enc_fn, enc_ex, _, _ = fns["encode"]
    dec_fn, dec_ex, _, _ = fns["decode_step"]
    rng = np.random.default_rng(3)
    enc_args = mk_inputs(enc_ex, rng)
    enc_out = jax.jit(enc_fn)(*enc_args)
    params = enc_args[: len(names)]
    prev = jnp.full((spec.batch,), 2, jnp.int32)
    h = enc_out[2]
    next_tok, h2, logits = jax.jit(dec_fn)(*params, enc_out[0], enc_out[1], prev, h)
    assert next_tok.shape == (spec.batch,)
    assert h2.shape == (spec.batch, spec.hidden)
    np.testing.assert_array_equal(np.array(next_tok), np.argmax(np.array(logits), axis=-1))


@pytest.mark.parametrize("vname", ["qa_regular", "qa_xs_o2r2", "qa_xs_o4r1"])
def test_qa_train_and_predict(vname):
    task, spec = VAR[vname]
    names = [n for n, _, _ in model_qa.param_specs(spec)]
    fns = qa_functions(spec)
    fn, ex, _, _ = fns["train_step"]
    rng = np.random.default_rng(4)
    args = mk_inputs(ex, rng)
    b = spec.batch
    args[-4] = jnp.array(rng.integers(0, spec.ctx_len // 2, (b,)), jnp.int32)  # start
    args[-3] = args[-4] + 1  # end
    args[-2] = jnp.float32(1.0)
    args[-1] = jnp.float32(1e-3)
    out = jax.jit(fn)(*args)
    loss = float(out[-1])
    # Initial loss ≈ 2·ln(ctx_len).
    assert abs(loss - 2 * np.log(spec.ctx_len)) < 1.5

    pfn, pex, _, _ = fns["predict"]
    pargs = args[: len(names)] + [args[3 * len(names)], args[3 * len(names) + 1]]
    start, end = jax.jit(pfn)(*pargs)
    s, e = np.array(start), np.array(end)
    assert ((s >= 0) & (s < spec.ctx_len)).all()
    assert ((e >= s) & (e < s + spec.max_answer_len)).all()


def test_qa_overfits_fixed_batch():
    task, spec = VAR["qa_xs_o4r1"]
    names = [n for n, _, _ in model_qa.param_specs(spec)]
    fns = qa_functions(spec)
    fn, ex, _, _ = fns["train_step"]
    rng = np.random.default_rng(5)
    args = mk_inputs(ex, rng)
    np_ = len(names)
    state = list(args[:np_]) + [jnp.zeros_like(a) for a in args[np_ : 3 * np_]]
    ctx = args[3 * np_]
    q = args[3 * np_ + 1]
    start = jnp.array(rng.integers(0, 10, (spec.batch,)), jnp.int32)
    end = start + 1
    step_fn = jax.jit(fn)
    losses = []
    # The 72-parameter order-4 embedding learns slowly on unstructured random
    # contexts (the real corpus has fact structure); 60 steps suffice to show
    # a clear descent.
    for step in range(60):
        out = step_fn(*state, ctx, q, start, end, jnp.float32(step + 1), jnp.float32(5e-3))
        state = list(out[:-1])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.7, f"no overfit: {losses[0]} → {losses[-1]}"


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
