"""L2 embedding modules: lookup semantics per eq. 3 / eq. 4, param-count
formulas, and agreement with manual reconstruction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.embeddings import EmbSpec, ceil_root, lookup

SETTINGS = dict(max_examples=20, deadline=None)


def init_params(spec, seed=0):
    params = {}
    key = jax.random.PRNGKey(seed)
    for name, shape, init in spec.param_specs():
        key, sub = jax.random.split(key)
        if init["dist"] == "uniform":
            params[name] = jax.random.uniform(sub, shape, minval=-init["a"], maxval=init["a"])
        else:
            params[name] = jnp.zeros(shape)
    return params


def test_ceil_root_matches_paper():
    assert ceil_root(118_655, 2) == 345
    assert ceil_root(118_655, 4) == 19
    assert ceil_root(300, 4) == 5
    assert ceil_root(300, 2) == 18
    assert ceil_root(30_428, 4) == 14


def test_param_counts_match_paper_formulas():
    # Table 3: XS 4/1 over 118,655×300 → 380 params (four 19×5 matrices).
    spec = EmbSpec("xs", 118_655, 300, 4, 1)
    assert spec.num_params() == 380
    spec = EmbSpec("xs", 118_655, 300, 2, 2)
    assert spec.num_params() == 24_840
    # Table 1: w2k 4/1 over 30,428×256 → 486,848.
    spec = EmbSpec("w2k", 30_428, 256, 4, 1)
    assert spec.num_params() == 486_848


@settings(**SETTINGS)
@given(
    vocab=st.integers(4, 200),
    dim=st.sampled_from([4, 8, 16, 27]),
    order=st.integers(2, 3),
    rank=st.integers(1, 3),
)
def test_xs_lookup_matches_manual_kron(vocab, dim, order, rank):
    spec = EmbSpec("xs", vocab, dim, order, rank)
    params = init_params(spec)
    factors = np.array(params["emb/factors"])  # (r, n, t, q)
    t, q, n = spec.t, spec.q, spec.order
    ids = np.array([0, vocab - 1, vocab // 2], dtype=np.int32)
    got = np.array(lookup(spec, params, jnp.array(ids)))
    for bi, wid in enumerate(ids):
        # big-endian digit decode
        digits = []
        x = int(wid)
        for j in range(n):
            w = t ** (n - 1 - j)
            digits.append((x // w) % t)
        expect = np.zeros(q**n, dtype=np.float64)
        for k in range(rank):
            acc = np.array([1.0])
            for j in range(n):
                acc = np.kron(acc, factors[k, j, digits[j], :])
            expect += acc
        np.testing.assert_allclose(got[bi], expect, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(vocab=st.integers(4, 60), order=st.integers(2, 3), rank=st.integers(1, 3))
def test_w2k_lookup_matches_manual(vocab, order, rank):
    dim = 3**order
    spec = EmbSpec("w2k", vocab, dim, order, rank)
    object.__setattr__(spec, "layernorm", False) if False else None
    spec = EmbSpec("w2k", vocab, dim, order, rank, layernorm=False)
    params = init_params(spec)
    leaves = np.array(params["emb/leaves"])  # (V, r, n, q)
    wid = vocab // 3
    got = np.array(lookup(spec, params, jnp.array([wid], dtype=jnp.int32)))[0]
    expect = np.zeros(dim)
    for k in range(rank):
        acc = np.array([1.0])
        for j in range(order):
            acc = np.kron(acc, leaves[wid, k, j, :])
        expect += acc
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-5)


def test_regular_lookup_is_row_select():
    spec = EmbSpec("regular", 10, 8)
    params = init_params(spec)
    ids = jnp.array([3, 7], dtype=jnp.int32)
    got = lookup(spec, params, ids)
    np.testing.assert_allclose(got[0], params["emb/table"][3])
    np.testing.assert_allclose(got[1], params["emb/table"][7])


def test_lookup_preserves_leading_shape():
    spec = EmbSpec("xs", 100, 16, 2, 2)
    params = init_params(spec)
    ids = jnp.zeros((4, 7), dtype=jnp.int32)
    out = lookup(spec, params, ids)
    assert out.shape == (4, 7, spec.effective_dim)


def test_lookup_differentiable():
    spec = EmbSpec("xs", 50, 16, 2, 2)
    params = init_params(spec)
    ids = jnp.array([1, 2, 3], dtype=jnp.int32)

    def loss(p):
        return (lookup(spec, p, ids) ** 2).sum()

    g = jax.grad(loss)(params)
    assert g["emb/factors"].shape == params["emb/factors"].shape
    assert float(jnp.abs(g["emb/factors"]).sum()) > 0.0


def test_w2k_layernorm_changes_output():
    base = EmbSpec("w2k", 20, 16, 4, 2, layernorm=False)
    ln = EmbSpec("w2k", 20, 16, 4, 2, layernorm=True)
    params = init_params(base)
    ids = jnp.array([5], dtype=jnp.int32)
    a = lookup(base, params, ids)
    b = lookup(ln, params, ids)
    assert not np.allclose(np.array(a), np.array(b))
    assert np.isfinite(np.array(b)).all()


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
