"""HLO-text lowering helper.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. Lower with return_tuple=True and unwrap with
to_tuple1()/tupled outputs on the Rust side.
"""

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_text(fn, example_args) -> str:
    """jit-lower fn at the example argument shapes and render HLO text.

    keep_unused=True: the Rust runtime feeds every manifest input
    positionally, so argument pruning (jit's default) would desynchronize
    the call signature (e.g. `encode` uses only 11 of 18 param tensors).
    """
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    return to_hlo_text(lowered)
