# L2 build-time package: JAX models + Pallas kernels, AOT-lowered to HLO text
# by aot.py. Never imported at runtime — the Rust coordinator executes the
# lowered artifacts through PJRT.
