"""L2 QA reader: DrQA-style extractive span model (paper Table 3 / Fig. 2),
scaled for CPU — biGRU context and question encoders, masked-mean question
pooling, bilinear start/end span scorers.

Lowered entry points per variant:
  train_step : params, m, v, ctx, q, start, end, step, lr → updated, loss
  predict    : params, ctx, q → (start_idx (B,), end_idx (B,))
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from . import adam, gru
from .embeddings import EmbSpec, lookup

PAD = 0
NEG_BIG = -1e9


@dataclasses.dataclass(frozen=True)
class QaSpec:
    emb: EmbSpec
    hidden: int
    batch: int
    ctx_len: int
    q_len: int
    max_answer_len: int = 4
    clip: float = 1.0

    @property
    def vocab(self) -> int:
        return self.emb.vocab


def param_specs(spec: QaSpec):
    h = spec.hidden
    e = spec.emb.effective_dim
    a = lambda fan_in: {"dist": "uniform", "a": math.sqrt(3.0 / fan_in)}
    out = []
    out += spec.emb.param_specs()
    out += gru.cell_specs("ctx_fwd", e, h)
    out += gru.cell_specs("ctx_bwd", e, h)
    out += gru.cell_specs("q_fwd", e, h)
    out += gru.cell_specs("q_bwd", e, h)
    # bilinear span scorers: score = ctx_h · W · q_vec
    out += [("span_start/w", (2 * h, 2 * h), a(2 * h))]
    out += [("span_end/w", (2 * h, 2 * h), a(2 * h))]
    return out


def _encode(spec: QaSpec, params: dict, ctx: jax.Array, q: jax.Array):
    """→ (ctx_h (B,Tc,2H), ctx_mask, q_vec (B,2H))."""
    ctx_mask = (ctx != PAD).astype(jnp.float32)
    q_mask = (q != PAD).astype(jnp.float32)
    b = ctx.shape[0]
    h0 = jnp.zeros((b, spec.hidden), jnp.float32)

    ce = lookup(spec.emb, params, ctx)
    cf, _ = gru.run(params, "ctx_fwd", ce, h0, ctx_mask)
    cb, _ = gru.run(params, "ctx_bwd", ce, h0, ctx_mask, reverse=True)
    ctx_h = jnp.concatenate([cf, cb], axis=-1)  # (B, Tc, 2H)

    qe = lookup(spec.emb, params, q)
    qf, _ = gru.run(params, "q_fwd", qe, h0, q_mask)
    qb, _ = gru.run(params, "q_bwd", qe, h0, q_mask, reverse=True)
    q_h = jnp.concatenate([qf, qb], axis=-1)  # (B, Tq, 2H)
    denom = jnp.maximum(q_mask.sum(axis=1, keepdims=True), 1.0)
    q_vec = (q_h * q_mask[:, :, None]).sum(axis=1) / denom  # (B, 2H)
    return ctx_h, ctx_mask, q_vec


def _span_logits(spec: QaSpec, params: dict, ctx_h, ctx_mask, q_vec):
    s = jnp.einsum("bth,hk,bk->bt", ctx_h, params["span_start/w"], q_vec)
    e = jnp.einsum("bth,hk,bk->bt", ctx_h, params["span_end/w"], q_vec)
    s = jnp.where(ctx_mask > 0.5, s, NEG_BIG)
    e = jnp.where(ctx_mask > 0.5, e, NEG_BIG)
    return s, e


def loss_fn(spec: QaSpec, params, ctx, q, start, end):
    ctx_h, ctx_mask, q_vec = _encode(spec, params, ctx, q)
    s_logits, e_logits = _span_logits(spec, params, ctx_h, ctx_mask, q_vec)
    s_logp = jax.nn.log_softmax(s_logits, axis=-1)
    e_logp = jax.nn.log_softmax(e_logits, axis=-1)
    s_nll = -jnp.take_along_axis(s_logp, start[:, None], axis=-1)[:, 0]
    e_nll = -jnp.take_along_axis(e_logp, end[:, None], axis=-1)[:, 0]
    return (s_nll + e_nll).mean()


def train_step(spec: QaSpec, params, m, v, ctx, q, start, end, step, lr):
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(spec, p, ctx, q, start, end)
    )(params)
    new_params, new_m, new_v = adam.update(params, grads, m, v, step, lr, spec.clip)
    return new_params, new_m, new_v, loss


def predict(spec: QaSpec, params, ctx, q):
    """Greedy constrained span: best start, then best end within
    [start, start + max_answer_len)."""
    ctx_h, ctx_mask, q_vec = _encode(spec, params, ctx, q)
    s_logits, e_logits = _span_logits(spec, params, ctx_h, ctx_mask, q_vec)
    start = jnp.argmax(s_logits, axis=-1).astype(jnp.int32)  # (B,)
    t = ctx.shape[1]
    pos = jnp.arange(t)[None, :]
    window = (pos >= start[:, None]) & (pos < start[:, None] + spec.max_answer_len)
    e_masked = jnp.where(window, e_logits, NEG_BIG)
    end = jnp.argmax(e_masked, axis=-1).astype(jnp.int32)
    return start, end
