"""Luong dot attention kernel (Luong et al., 2015), the decoder's per-step
hot loop in the paper's seq2seq models.

For one decoder step: scores over encoder outputs, masked softmax, context.

    score[b, t] = <h[b, :], enc[b, t, :]>
    probs       = softmax(score + (mask - 1) * BIG)
    ctx[b, :]   = Σ_t probs[b, t] * enc[b, t, :]

One batch tile holds enc (B_blk, T, H), h (B_blk, H) in VMEM → ctx (B_blk, H).
The two contractions are MXU-shaped (batched matvec); on TPU this is where
the decode-path FLOPs live.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_BLOCK = 8
NEG_BIG = -1e9


def _attention_kernel(h_ref, enc_ref, mask_ref, ctx_ref, probs_ref):
    h = h_ref[...]  # (B, H)
    enc = enc_ref[...]  # (B, T, H)
    mask = mask_ref[...]  # (B, T) 1.0 = valid
    scores = jnp.einsum("bh,bth->bt", h, enc)
    scores = jnp.where(mask > 0.5, scores, NEG_BIG)
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - m) * mask
    z = e.sum(axis=-1, keepdims=True)
    probs = e / jnp.maximum(z, 1e-9)
    ctx_ref[...] = jnp.einsum("bt,bth->bh", probs, enc)
    probs_ref[...] = probs


@jax.custom_vjp
def luong_attention(h: jax.Array, enc: jax.Array, mask: jax.Array):
    """One attention step.

    h:    (B, H) decoder hidden state
    enc:  (B, T, H) encoder outputs
    mask: (B, T) 1.0 on real source tokens
    Returns (context (B, H), probs (B, T)).

    Forward is the Pallas kernel; backward is the analytic masked-softmax
    attention gradient (mask is treated as non-differentiable).
    """
    return _attention_impl(h, enc, mask)


def _attention_fwd(h, enc, mask):
    ctx, probs = _attention_impl(h, enc, mask)
    return (ctx, probs), (h, enc, probs)


def _attention_bwd(res, grads):
    h, enc, probs = res
    g_ctx, g_probs = grads
    # ctx = Σ_t P[t]·enc[t]
    d_enc_from_ctx = probs[:, :, None] * g_ctx[:, None, :]  # (B, T, H)
    dP = jnp.einsum("bh,bth->bt", g_ctx, enc) + g_probs
    # softmax backward (P already zero on masked positions)
    ds = probs * (dP - (probs * dP).sum(axis=-1, keepdims=True))
    dh = jnp.einsum("bt,bth->bh", ds, enc)
    d_enc = d_enc_from_ctx + ds[:, :, None] * h[:, None, :]
    d_mask = jnp.zeros_like(probs)
    return dh, d_enc, d_mask


def _attention_impl(h: jax.Array, enc: jax.Array, mask: jax.Array):
    assert h.ndim == 2 and enc.ndim == 3 and mask.ndim == 2
    bsz, hdim = h.shape
    t = enc.shape[1]
    blk = min(BATCH_BLOCK, bsz)
    pad = (-bsz) % blk
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        enc = jnp.pad(enc, ((0, pad), (0, 0), (0, 0)))
        # Padded rows get an all-invalid mask; softmax degrades to uniform-0
        # but those rows are sliced away below.
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    ctx, probs = pl.pallas_call(
        _attention_kernel,
        grid=(h.shape[0] // blk,),
        in_specs=[
            pl.BlockSpec((blk, hdim), lambda i: (i, 0)),
            pl.BlockSpec((blk, t, hdim), lambda i: (i, 0, 0)),
            pl.BlockSpec((blk, t), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk, hdim), lambda i: (i, 0)),
            pl.BlockSpec((blk, t), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h.shape[0], hdim), h.dtype),
            jax.ShapeDtypeStruct((h.shape[0], t), h.dtype),
        ],
        interpret=True,
    )(h, enc, mask)
    return ctx[:bsz], probs[:bsz]


luong_attention.defvjp(_attention_fwd, _attention_bwd)
