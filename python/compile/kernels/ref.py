"""Pure-jnp oracles for every Pallas kernel — the correctness contract.

pytest (python/tests/test_kernels.py) sweeps shapes/dtypes with hypothesis and
asserts allclose(kernel, ref). The Rust integration tests independently check
the same identities against the pure-Rust kron module, closing the loop:

    Pallas kernel == jnp oracle == Rust kron mirror
"""

import jax
import jax.numpy as jnp

EPS = 1e-5
NEG_BIG = -1e9


def kron_pair_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """(B, Da) ⊗ (B, Db) → (B, Da·Db)."""
    return (a[:, :, None] * b[:, None, :]).reshape(a.shape[0], -1)


def kron_pair_rank_sum_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """(B, R, Da) ⊗ (B, R, Db) summed over R → (B, Da·Db)."""
    prod = a[:, :, :, None] * b[:, :, None, :]
    return prod.sum(axis=1).reshape(a.shape[0], -1)


def layernorm_ref(x: jax.Array) -> jax.Array:
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + EPS)


def kron_chain_ref(vecs) -> jax.Array:
    """Left-associated batched Kronecker chain over a list of (B, q) arrays."""
    acc = vecs[0]
    for v in vecs[1:]:
        acc = kron_pair_ref(acc, v)
    return acc


def kron_tree_ranked_ref(leaves: jax.Array, layernorm_nodes: bool = False) -> jax.Array:
    """(B, R, n, q) CP leaves → (B, q^n); balanced tree + rank sum.

    Mirrors kernels.kron_tree.kron_tree_ranked including optional per-node
    LayerNorm (which breaks the plain-chain identity, hence reimplemented).
    """
    bsz, r, n, q = leaves.shape
    level = [leaves[:, :, j, :] for j in range(n)]
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level) - 1, 2):
            a, c = level[i], level[i + 1]
            prod = (a[:, :, :, None] * c[:, :, None, :]).reshape(bsz, r, -1)
            if layernorm_nodes and len(level) > 2:
                # internal node (not the fused root)
                prod = layernorm_ref(prod.reshape(bsz * r, -1)).reshape(prod.shape)
            nxt.append(prod)
        if len(level) % 2 == 1:
            nxt.append(level[-1])
        level = nxt
    return level[0].sum(axis=1)


def xs_reconstruct_rows_ref(cols: jax.Array) -> jax.Array:
    """(B, R, n, q) gathered columns → (B, q^n) via plain chain + rank sum."""
    bsz, r, n, q = cols.shape
    flat = cols.reshape(bsz * r, n, q)
    acc = flat[:, 0, :]
    for j in range(1, n):
        acc = kron_pair_ref(acc, flat[:, j, :])
    return acc.reshape(bsz, r, -1).sum(axis=1)


def luong_attention_ref(h: jax.Array, enc: jax.Array, mask: jax.Array):
    scores = jnp.einsum("bh,bth->bt", h, enc)
    scores = jnp.where(mask > 0.5, scores, NEG_BIG)
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - m) * mask
    z = e.sum(axis=-1, keepdims=True)
    probs = e / jnp.maximum(z, 1e-9)
    ctx = jnp.einsum("bt,bth->bh", probs, enc)
    return ctx, probs
