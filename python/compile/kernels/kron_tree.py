"""Balanced-tree Kronecker product kernels (paper Fig. 1, §2.3).

The reconstruction hot-spot of word2ket embeddings is the batched Kronecker
product at each balanced-tree node:

    out[b, i * Db + j] = a[b, i] * c[b, j]

TPU thinking (DESIGN.md §Hardware-Adaptation): one grid step holds a
(B_blk, Da) left tile and (B_blk, Db) right tile in VMEM and emits the
(B_blk, Da*Db) node output — an elementwise outer product, bandwidth-bound,
never touching the MXU. The rank dimension is fused into the final tree level
(`kron_pair_rank_sum`) so intermediate rank copies are never materialized in
HBM: VMEM saving of (r-1)·p floats per row.

interpret=True everywhere: CPU PJRT cannot run Mosaic custom-calls; the
lowered HLO is plain elementwise code that XLA:CPU fuses well.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: 8 rows per grid step keeps the node output tile below
# 8 * 1024 * 4B = 32 KiB VMEM even for p = 1024 embeddings.
BATCH_BLOCK = 8


def _kron_pair_kernel(a_ref, b_ref, o_ref):
    """One batch tile: outer product flattened to the Kronecker layout."""
    a = a_ref[...]  # (B_blk, Da)
    b = b_ref[...]  # (B_blk, Db)
    # (B, Da, 1) * (B, 1, Db) -> (B, Da, Db) -> (B, Da*Db)
    prod = a[:, :, None] * b[:, None, :]
    o_ref[...] = prod.reshape(a.shape[0], -1)


def _kron_pair_impl(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched Kronecker product of vectors: (B, Da) ⊗ (B, Db) → (B, Da·Db)."""
    assert a.ndim == 2 and b.ndim == 2 and a.shape[0] == b.shape[0], (a.shape, b.shape)
    bsz, da = a.shape
    db = b.shape[1]
    blk = min(BATCH_BLOCK, bsz)
    # Pad batch to a multiple of the block.
    pad = (-bsz) % blk
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0)))
    grid = (a.shape[0] // blk,)
    out = pl.pallas_call(
        _kron_pair_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, da), lambda i: (i, 0)),
            pl.BlockSpec((blk, db), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((blk, da * db), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], da * db), a.dtype),
        interpret=True,
    )(a, b)
    return out[:bsz]


# pallas_call has no autodiff rule (and interpret-mode Mosaic never will on
# CPU), so the training graph needs explicit VJPs: forward runs the Pallas
# kernel, backward is the analytic jnp expression. This is also the honest
# TPU story — backward of an outer product is two reductions, MXU-free.


@jax.custom_vjp
def kron_pair(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched Kronecker product of vectors: (B, Da) ⊗ (B, Db) → (B, Da·Db)."""
    return _kron_pair_impl(a, b)


def _kron_pair_fwd(a, b):
    return _kron_pair_impl(a, b), (a, b)


def _kron_pair_bwd(res, g):
    a, b = res
    g3 = g.reshape(a.shape[0], a.shape[1], b.shape[1])
    da = (g3 * b[:, None, :]).sum(axis=2)
    db = (g3 * a[:, :, None]).sum(axis=1)
    return da, db


kron_pair.defvjp(_kron_pair_fwd, _kron_pair_bwd)


def _kron_rank_sum_kernel(a_ref, b_ref, o_ref):
    """Final tree level fused with the rank summation (eq. 3's Σ_k)."""
    a = a_ref[...]  # (B_blk, R, Da)
    b = b_ref[...]  # (B_blk, R, Db)
    prod = a[:, :, :, None] * b[:, :, None, :]  # (B, R, Da, Db)
    o_ref[...] = prod.sum(axis=1).reshape(a.shape[0], -1)


def _kron_pair_rank_sum_impl(a: jax.Array, b: jax.Array) -> jax.Array:
    """Rank-fused root node: (B, R, Da) ⊗ (B, R, Db) summed over R → (B, Da·Db)."""
    assert a.ndim == 3 and b.ndim == 3, (a.shape, b.shape)
    assert a.shape[:2] == b.shape[:2], (a.shape, b.shape)
    bsz, r, da = a.shape
    db = b.shape[2]
    blk = min(BATCH_BLOCK, bsz)
    pad = (-bsz) % blk
    if pad:
        a = jnp.pad(a, ((0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, pad), (0, 0), (0, 0)))
    grid = (a.shape[0] // blk,)
    out = pl.pallas_call(
        _kron_rank_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk, r, da), lambda i: (i, 0, 0)),
            pl.BlockSpec((blk, r, db), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((blk, da * db), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((a.shape[0], da * db), a.dtype),
        interpret=True,
    )(a, b)
    return out[:bsz]


@jax.custom_vjp
def kron_pair_rank_sum(a: jax.Array, b: jax.Array) -> jax.Array:
    """Rank-fused root node: (B, R, Da) ⊗ (B, R, Db) summed over R → (B, Da·Db)."""
    return _kron_pair_rank_sum_impl(a, b)


def _kron_rank_fwd(a, b):
    return _kron_pair_rank_sum_impl(a, b), (a, b)


def _kron_rank_bwd(res, g):
    a, b = res
    bsz, r, da = a.shape
    db = b.shape[2]
    g4 = g.reshape(bsz, 1, da, db)
    dga = (g4 * b[:, :, None, :]).sum(axis=3)  # (B, R, Da)
    dgb = (g4 * a[:, :, :, None]).sum(axis=2)  # (B, R, Db)
    return dga, dgb


kron_pair_rank_sum.defvjp(_kron_rank_fwd, _kron_rank_bwd)


def kron_tree_ranked(leaves: jax.Array, layernorm_nodes: bool = False) -> jax.Array:
    """Full balanced-tree reconstruction with fused rank sum at the root.

    leaves: (B, R, n, q) — per-example rank-R order-n CP leaves.
    Returns (B, q**n).

    Internal nodes optionally LayerNorm their output (paper §2.3). The rank
    axis rides along through internal levels and is contracted by
    `kron_pair_rank_sum` at the root (or by a plain sum when n == 1).
    """
    from .layernorm import layernorm

    bsz, r, n, q = leaves.shape
    # Current level: list of (B, R, width) arrays.
    level = [leaves[:, :, j, :] for j in range(n)]
    while len(level) > 2:
        nxt = []
        for i in range(0, len(level) - 1, 2):
            a, c = level[i], level[i + 1]
            da, db = a.shape[2], c.shape[2]
            # Treat (B, R) as one batch axis for the pair kernel.
            flat = kron_pair(a.reshape(bsz * r, da), c.reshape(bsz * r, db))
            node = flat.reshape(bsz, r, da * db)
            if layernorm_nodes:
                node = layernorm(node.reshape(bsz * r, -1)).reshape(node.shape)
            nxt.append(node)
        if len(level) % 2 == 1:
            nxt.append(level[-1])
        level = nxt
    if len(level) == 1:
        return level[0].sum(axis=1)
    return kron_pair_rank_sum(level[0], level[1])
