# L1: Pallas kernels for the word2ket / word2ketXS reconstruction hot path.
#
# All kernels run with interpret=True — the CPU PJRT plugin cannot execute
# Mosaic custom-calls, so interpret mode is the correctness path and the
# BlockSpec structure documents the intended TPU HBM<->VMEM schedule
# (DESIGN.md "Hardware adaptation").

from .kron_tree import kron_pair, kron_pair_rank_sum, kron_tree_ranked
from .xs_rows import xs_reconstruct_rows
from .layernorm import layernorm
from .attention import luong_attention

__all__ = [
    "kron_pair",
    "kron_pair_rank_sum",
    "kron_tree_ranked",
    "xs_reconstruct_rows",
    "layernorm",
    "luong_attention",
]
