"""Pallas LayerNorm kernel (no learned affine), used at the internal nodes of
the word2ket balanced tree (paper §2.3: LayerNorm tames the gradient Lipschitz
constant of chained tensor products).

One grid step normalizes a (B_blk, D) tile held in VMEM — mean/variance are
per-row reductions over the minor axis, ideal for the TPU VPU; no MXU use.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_BLOCK = 8
EPS = 1e-5


def _layernorm_kernel(x_ref, o_ref):
    x = x_ref[...]
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    o_ref[...] = (x - mean) * jax.lax.rsqrt(var + EPS)


@jax.custom_vjp
def layernorm(x: jax.Array) -> jax.Array:
    """Row-wise LayerNorm of a (B, D) array (eps=1e-5, no affine).

    Forward runs the Pallas kernel; backward is the analytic LN gradient
    (pallas_call has no autodiff rule in interpret mode).
    """
    return _layernorm_impl(x)


def _layernorm_fwd(x):
    return _layernorm_impl(x), x


def _layernorm_bwd(x, g):
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    xhat = (x - mean) * inv
    gm = g.mean(axis=-1, keepdims=True)
    gx = (g * xhat).mean(axis=-1, keepdims=True)
    return (inv * (g - gm - xhat * gx),)


def _layernorm_impl(x: jax.Array) -> jax.Array:
    assert x.ndim == 2, x.shape
    bsz, d = x.shape
    blk = min(BATCH_BLOCK, bsz)
    pad = (-bsz) % blk
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        _layernorm_kernel,
        grid=(x.shape[0] // blk,),
        in_specs=[pl.BlockSpec((blk, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((blk, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)
    return out[:bsz]


layernorm.defvjp(_layernorm_fwd, _layernorm_bwd)
