"""word2ketXS lazy row reconstruction kernel (paper §3.2).

Given the per-factor *columns* already gathered for a batch of token ids
(the gather is cheap data movement done in the surrounding jax graph; the
digit decode happens on the Rust side or via integer ops in L2), the kernel
computes the balanced-tree Kronecker product across the order axis and sums
ranks:

    rows[b] = Σ_k ⊗_j cols[b, k, j]     ∈ R^{q^n}

TPU thinking: `cols` for one batch tile is (B_blk, R, n, q) — a few KiB —
and the output tile is (B_blk, q^n). Both sit comfortably in VMEM; the kernel
is a chain of elementwise outer products (VPU work). This replaces the
paper's CUDA lazy-tensor row kernels (KeOps-style) with a BlockSpec-scheduled
VMEM pipeline.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BATCH_BLOCK = 8


def _xs_rows_kernel(cols_ref, o_ref):
    cols = cols_ref[...]  # (B_blk, R, n, q)
    b, r, n, q = cols.shape
    # Balanced tree over the order axis, rank axis riding along.
    level = [cols[:, :, j, :] for j in range(n)]
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level) - 1, 2):
            a, c = level[i], level[i + 1]
            prod = a[:, :, :, None] * c[:, :, None, :]
            nxt.append(prod.reshape(b, r, -1))
        if len(level) % 2 == 1:
            nxt.append(level[-1])
        level = nxt
    o_ref[...] = level[0].sum(axis=1)


@jax.custom_vjp
def xs_reconstruct_rows(cols: jax.Array) -> jax.Array:
    """(B, R, n, q) gathered factor columns → (B, q**n) embedding rows.

    Forward is the Pallas kernel; backward is the analytic product-rule
    gradient (∂/∂cols_j = g contracted with the Kronecker product of the
    other factors), expressed with jnp reshapes.
    """
    return _xs_rows_impl(cols)


def _xs_rows_fwd(cols):
    return _xs_rows_impl(cols), cols


def _xs_rows_bwd(cols, g):
    bsz, r, n, q = cols.shape
    # View g as an order-n tensor (B, q, q, ..., q).
    g_nd = g.reshape((bsz,) + (q,) * n)
    dcols = []
    for j in range(n):
        # Kron product of all factors except j, contracted against g.
        # other[b, r, (prod of q over axes != j)] built by sequential kron.
        others = [cols[:, :, i, :] for i in range(n) if i != j]
        if others:
            acc = others[0]
            for o in others[1:]:
                acc = (acc[:, :, :, None] * o[:, :, None, :]).reshape(bsz, r, -1)
        else:
            acc = jnp.ones((bsz, r, 1), cols.dtype)
        # Move axis j of g to the end: (B, rest..., q_j) then flatten rest.
        perm = (0,) + tuple(1 + i for i in range(n) if i != j) + (1 + j,)
        g_perm = jnp.transpose(g_nd, perm).reshape(bsz, -1, q)  # (B, prod_rest, q)
        # dcols[:, r, j, :] = Σ_rest acc[b,r,rest] * g_perm[b,rest,q]
        dj = jnp.einsum("brk,bkq->brq", acc, g_perm)
        dcols.append(dj)
    return (jnp.stack(dcols, axis=2),)


def _xs_rows_impl(cols: jax.Array) -> jax.Array:
    assert cols.ndim == 4, cols.shape
    bsz, r, n, q = cols.shape
    p = q**n
    blk = min(BATCH_BLOCK, bsz)
    pad = (-bsz) % blk
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _xs_rows_kernel,
        grid=(cols.shape[0] // blk,),
        in_specs=[pl.BlockSpec((blk, r, n, q), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((blk, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cols.shape[0], p), cols.dtype),
        interpret=True,
    )(cols)
    return out[:bsz]


xs_reconstruct_rows.defvjp(_xs_rows_fwd, _xs_rows_bwd)
