"""AOT compiler: lowers every (task × embedding-variant) model function to
HLO text plus a manifest.json the Rust runtime consumes.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts [--only sum_regular] [--list]

Python runs exactly once per source change (`make artifacts` checks a source
hash); the request path is pure Rust + PJRT.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp

from . import model, model_qa
from .embeddings import EmbSpec
from .hlo import lower_to_text
from .model import Seq2SeqSpec
from .model_qa import QaSpec

# ---------------------------------------------------------------------------
# Scenario registry — dims chosen for CPU-scale end-to-end runs; the paper's
# full-scale parameter accounting is reproduced exactly in rust (stats.rs).
# Table 1 mirror: regular / w2k 4/1 / XS 2/10 / XS 4/1.
# Table 2 mirror: regular / XS 2/30 / XS 2/10 / XS 3/10.
# Table 3 mirror: regular / XS 2/2 / XS 4/1.
# ---------------------------------------------------------------------------

SUM = dict(vocab=1024, hidden=64, batch=16, src_len=24, tgt_len=8, dim=64)
MT = dict(vocab=2048, hidden=64, batch=16, src_len=20, tgt_len=14, dim=64)
QA = dict(vocab=1024, hidden=48, batch=16, ctx_len=48, q_len=8, dim=64)


def _emb(kind, vocab, dim, order=1, rank=1):
    return EmbSpec(kind=kind, vocab=vocab, dim=dim, order=order, rank=rank)


def variants():
    """name → (task, spec) for every lowered model variant."""
    out = {}
    v, d = SUM["vocab"], SUM["dim"]
    for name, emb in [
        ("regular", _emb("regular", v, d)),
        ("w2k_o4r1", _emb("w2k", v, d, 4, 1)),
        ("xs_o2r10", _emb("xs", v, d, 2, 10)),
        ("xs_o4r1", _emb("xs", v, d, 4, 1)),
    ]:
        out[f"sum_{name}"] = (
            "sum",
            Seq2SeqSpec(emb=emb, hidden=SUM["hidden"], batch=SUM["batch"],
                        src_len=SUM["src_len"], tgt_len=SUM["tgt_len"]),
        )
    v, d = MT["vocab"], MT["dim"]
    for name, emb in [
        ("regular", _emb("regular", v, d)),
        ("xs_o2r30", _emb("xs", v, d, 2, 30)),
        ("xs_o2r10", _emb("xs", v, d, 2, 10)),
        ("xs_o3r10", _emb("xs", v, d, 3, 10)),
    ]:
        out[f"mt_{name}"] = (
            "mt",
            Seq2SeqSpec(emb=emb, hidden=MT["hidden"], batch=MT["batch"],
                        src_len=MT["src_len"], tgt_len=MT["tgt_len"]),
        )
    v, d = QA["vocab"], QA["dim"]
    for name, emb in [
        ("regular", _emb("regular", v, d)),
        ("xs_o2r2", _emb("xs", v, d, 2, 2)),
        ("xs_o4r1", _emb("xs", v, d, 4, 1)),
    ]:
        out[f"qa_{name}"] = (
            "qa",
            QaSpec(emb=emb, hidden=QA["hidden"], batch=QA["batch"],
                   ctx_len=QA["ctx_len"], q_len=QA["q_len"]),
        )
    return out


# ---------------------------------------------------------------------------
# Flat-argument wrappers (HLO entry takes positional parameters; the manifest
# records the order).
# ---------------------------------------------------------------------------


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def seq2seq_functions(spec: Seq2SeqSpec):
    """name → (fn, example_args, extra_input_descr, output_descr)."""
    pspecs = model.param_specs(spec)
    names = [n for n, _, _ in pspecs]
    shapes = {n: s for n, s, _ in pspecs}
    np_ = len(names)
    b, ts, tt, h = spec.batch, spec.src_len, spec.tgt_len, spec.hidden

    def split_pmv(args):
        params = dict(zip(names, args[:np_]))
        m = dict(zip(names, args[np_:2 * np_]))
        v = dict(zip(names, args[2 * np_:3 * np_]))
        return params, m, v, args[3 * np_:]

    def train_fn(*args):
        params, m, v, rest = split_pmv(args)
        src, tgt, tgt_mask, step, lr = rest
        p2, m2, v2, loss = model.train_step(spec, params, m, v, src, tgt, tgt_mask, step, lr)
        return (
            tuple(p2[n] for n in names)
            + tuple(m2[n] for n in names)
            + tuple(v2[n] for n in names)
            + (loss,)
        )

    def encode_fn(*args):
        params = dict(zip(names, args[:np_]))
        (src,) = args[np_:]
        enc_proj, mask, h0 = model.encode(spec, params, src)
        return enc_proj, mask, h0

    def decode_fn(*args):
        params = dict(zip(names, args[:np_]))
        enc_proj, src_mask, prev_tok, hstate = args[np_:]
        return model.decode_step(spec, params, enc_proj, src_mask, prev_tok, hstate)

    pm = [_sds(shapes[n]) for n in names]
    train_extra = [
        ("src", (b, ts), "i32"),
        ("tgt", (b, tt), "i32"),
        ("tgt_mask", (b, tt), "f32"),
        ("step", (), "f32"),
        ("lr", (), "f32"),
    ]
    enc_extra = [("src", (b, ts), "i32")]
    dec_extra = [
        ("enc_proj", (b, ts, h), "f32"),
        ("src_mask", (b, ts), "f32"),
        ("prev_tok", (b,), "i32"),
        ("h", (b, h), "f32"),
    ]
    return {
        "train_step": (
            train_fn,
            pm * 3 + [_example(e) for e in train_extra],
            {"param_copies": 3, "extra": train_extra},
            [("loss", (), "f32")],  # params/m/v outputs implied by order
        ),
        "encode": (
            encode_fn,
            pm + [_example(e) for e in enc_extra],
            {"param_copies": 1, "extra": enc_extra},
            [("enc_proj", (b, ts, h), "f32"), ("src_mask", (b, ts), "f32"), ("h0", (b, h), "f32")],
        ),
        "decode_step": (
            decode_fn,
            pm + [_example(e) for e in dec_extra],
            {"param_copies": 1, "extra": dec_extra},
            [("next_tok", (b,), "i32"), ("h", (b, h), "f32"), ("logits", (b, spec.vocab), "f32")],
        ),
    }


def qa_functions(spec: QaSpec):
    pspecs = model_qa.param_specs(spec)
    names = [n for n, _, _ in pspecs]
    shapes = {n: s for n, s, _ in pspecs}
    np_ = len(names)
    b, tc, tq = spec.batch, spec.ctx_len, spec.q_len

    def train_fn(*args):
        params = dict(zip(names, args[:np_]))
        m = dict(zip(names, args[np_:2 * np_]))
        v = dict(zip(names, args[2 * np_:3 * np_]))
        ctx, q, start, end, step, lr = args[3 * np_:]
        p2, m2, v2, loss = model_qa.train_step(spec, params, m, v, ctx, q, start, end, step, lr)
        return (
            tuple(p2[n] for n in names)
            + tuple(m2[n] for n in names)
            + tuple(v2[n] for n in names)
            + (loss,)
        )

    def predict_fn(*args):
        params = dict(zip(names, args[:np_]))
        ctx, q = args[np_:]
        return model_qa.predict(spec, params, ctx, q)

    pm = [_sds(shapes[n]) for n in names]
    train_extra = [
        ("ctx", (b, tc), "i32"),
        ("q", (b, tq), "i32"),
        ("start", (b,), "i32"),
        ("end", (b,), "i32"),
        ("step", (), "f32"),
        ("lr", (), "f32"),
    ]
    pred_extra = [("ctx", (b, tc), "i32"), ("q", (b, tq), "i32")]
    return {
        "train_step": (
            train_fn,
            pm * 3 + [_example(e) for e in train_extra],
            {"param_copies": 3, "extra": train_extra},
            [("loss", (), "f32")],
        ),
        "predict": (
            predict_fn,
            pm + [_example(e) for e in pred_extra],
            {"param_copies": 1, "extra": pred_extra},
            [("start", (b,), "i32"), ("end", (b,), "i32")],
        ),
    }


_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def _example(descr):
    name, shape, dt = descr
    return _sds(shape, _DTYPES[dt])


# ---------------------------------------------------------------------------
# Kernel smoke artifacts: standalone Pallas kernels for Rust integration tests
# and the lookup-throughput bench.
# ---------------------------------------------------------------------------


def kernel_artifacts():
    from .kernels import kron_pair, layernorm, luong_attention, xs_reconstruct_rows

    arts = {}
    arts["kernel_kron_pair"] = (
        lambda a, b: (kron_pair(a, b),),
        [_sds((16, 8)), _sds((16, 8))],
        [("a", (16, 8), "f32"), ("b", (16, 8), "f32")],
        [("out", (16, 64), "f32")],
    )
    arts["kernel_xs_rows"] = (
        lambda c: (xs_reconstruct_rows(c),),
        [_sds((16, 2, 2, 8))],
        [("cols", (16, 2, 2, 8), "f32")],
        [("rows", (16, 64), "f32")],
    )
    arts["kernel_layernorm"] = (
        lambda x: (layernorm(x),),
        [_sds((16, 64))],
        [("x", (16, 64), "f32")],
        [("out", (16, 64), "f32")],
    )
    arts["kernel_attention"] = (
        lambda h, e, m: luong_attention(h, e, m),
        [_sds((16, 64)), _sds((16, 24, 64)), _sds((16, 24))],
        [("h", (16, 64), "f32"), ("enc", (16, 24, 64), "f32"), ("mask", (16, 24), "f32")],
        [("ctx", (16, 64), "f32"), ("probs", (16, 24), "f32")],
    )
    return arts


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def source_hash() -> str:
    """Hash of every .py under compile/ — staleness key for make."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _dirs, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def spec_manifest(task, spec):
    emb = spec.emb
    dims = {
        "task": task,
        "hidden": spec.hidden,
        "batch": spec.batch,
        "vocab": emb.vocab,
        "emb_dim": emb.effective_dim,
    }
    if task in ("sum", "mt"):
        dims.update(src_len=spec.src_len, tgt_len=spec.tgt_len)
    else:
        dims.update(ctx_len=spec.ctx_len, q_len=spec.q_len, max_answer_len=spec.max_answer_len)
    pspecs = model.param_specs(spec) if task in ("sum", "mt") else model_qa.param_specs(spec)
    return {
        "dims": dims,
        "embedding": {
            "kind": emb.kind,
            "order": emb.order,
            "rank": emb.rank,
            "q": emb.q if emb.kind != "regular" else emb.dim,
            "t": emb.t if emb.kind != "regular" else emb.vocab,
            "num_params": emb.num_params(),
        },
        "params": [
            {"name": n, "shape": list(s), "init": init} for n, s, init in pspecs
        ],
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", action="append", default=None,
                    help="lower only variants whose name contains this substring")
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    var = variants()
    if args.list:
        for name in var:
            print(name)
        return 0

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"source_hash": source_hash(), "variants": {}, "kernels": {}}

    selected = {
        name: tv
        for name, tv in var.items()
        if args.only is None or any(sub in name for sub in args.only)
    }
    for name, (task, spec) in selected.items():
        fns = seq2seq_functions(spec) if task in ("sum", "mt") else qa_functions(spec)
        entry = spec_manifest(task, spec)
        entry["functions"] = {}
        for fname, (fn, ex_args, input_descr, out_descr) in fns.items():
            fname_file = f"{name}.{fname}.hlo.txt"
            path = os.path.join(args.out_dir, fname_file)
            print(f"[aot] lowering {name}.{fname} ...", flush=True)
            text = lower_to_text(fn, ex_args)
            with open(path, "w") as f:
                f.write(text)
            entry["functions"][fname] = {
                "file": fname_file,
                "param_copies": input_descr["param_copies"],
                "extra_inputs": [
                    {"name": n, "shape": list(s), "dtype": d}
                    for n, s, d in input_descr["extra"]
                ],
                "outputs": [
                    {"name": n, "shape": list(s), "dtype": d} for n, s, d in out_descr
                ],
            }
            print(f"[aot]   wrote {path} ({len(text)} chars)", flush=True)
        manifest["variants"][name] = entry

    if not args.skip_kernels:
        for kname, (fn, ex_args, in_descr, out_descr) in kernel_artifacts().items():
            path = os.path.join(args.out_dir, f"{kname}.hlo.txt")
            print(f"[aot] lowering {kname} ...", flush=True)
            text = lower_to_text(fn, ex_args)
            with open(path, "w") as f:
                f.write(text)
            manifest["kernels"][kname] = {
                "file": f"{kname}.hlo.txt",
                "inputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in in_descr],
                "outputs": [{"name": n, "shape": list(s), "dtype": d} for n, s, d in out_descr],
            }

    mpath = os.path.join(args.out_dir, "manifest.json")
    # Merge with an existing manifest when lowering a subset.
    if args.only is not None and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old_vars = old.get("variants", {})
        old_vars.update(manifest["variants"])
        manifest["variants"] = old_vars
        if not manifest["kernels"]:
            manifest["kernels"] = old.get("kernels", {})
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] manifest → {mpath} ({len(manifest['variants'])} variants)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
