"""L2 seq2seq model: bidirectional GRU encoder + Luong-attention GRU decoder
(the paper's GIGAWORD / IWSLT architecture, Texar-style, scaled for CPU).

Three lowered entry points per (task, embedding) variant:
  train_step : params, m, v, src, tgt, tgt_mask, step, lr
               → new params/m/v, loss
  encode     : params, src → enc_proj (B,T,H), src_mask (B,T), h0 (B,H)
  decode_step: params, enc_proj, src_mask, prev_tok, h
               → next_tok (argmax), h', logits

The source/target share one vocabulary and one (possibly compressed)
embedding table — matching the paper's single-#Params accounting per model.
The pre-softmax output projection stays dense (§4: "the matrix of word
probabilities prior to the last softmax ... not compressed by our method").
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from . import adam, gru
from .embeddings import EmbSpec, lookup
from .kernels import luong_attention

PAD = 0
BOS = 2
EOS = 3


@dataclasses.dataclass(frozen=True)
class Seq2SeqSpec:
    emb: EmbSpec
    hidden: int
    batch: int
    src_len: int
    tgt_len: int
    clip: float = 1.0

    @property
    def vocab(self) -> int:
        return self.emb.vocab


def param_specs(spec: Seq2SeqSpec):
    """Ordered [(name, shape, init)] for every trainable tensor."""
    h = spec.hidden
    e = spec.emb.effective_dim
    a = lambda fan_in: {"dist": "uniform", "a": math.sqrt(3.0 / fan_in)}
    out = []
    out += spec.emb.param_specs()
    out += gru.cell_specs("enc_fwd", e, h)
    out += gru.cell_specs("enc_bwd", e, h)
    # encoder output projection 2H → H (attention memory)
    out += [("enc_proj/w", (2 * h, h), a(2 * h)), ("enc_proj/b", (h,), {"dist": "zeros"})]
    # decoder initial state from final fwd/bwd states
    out += [("dec_init/w", (2 * h, h), a(2 * h)), ("dec_init/b", (h,), {"dist": "zeros"})]
    # decoder GRU input = [emb, prev context]
    out += gru.cell_specs("dec", e + h, h)
    # attentional combine [h, ctx] → h
    out += [("combine/w", (2 * h, h), a(2 * h)), ("combine/b", (h,), {"dist": "zeros"})]
    # output projection (dense, uncompressed per the paper)
    out += [("out/w", (h, spec.vocab), a(h)), ("out/b", (spec.vocab,), {"dist": "zeros"})]
    return out


def encode(spec: Seq2SeqSpec, params: dict, src: jax.Array):
    """src (B, T) int32 → (enc_proj (B,T,H), src_mask (B,T) f32, h0 (B,H))."""
    mask = (src != PAD).astype(jnp.float32)
    emb = lookup(spec.emb, params, src)  # (B, T, E)
    b = src.shape[0]
    h_init = jnp.zeros((b, spec.hidden), emb.dtype)
    fwd, h_fwd = gru.run(params, "enc_fwd", emb, h_init, mask)
    bwd, h_bwd = gru.run(params, "enc_bwd", emb, h_init, mask, reverse=True)
    enc = jnp.concatenate([fwd, bwd], axis=-1)  # (B, T, 2H)
    enc_proj = jnp.tanh(enc @ params["enc_proj/w"] + params["enc_proj/b"])
    h0 = jnp.tanh(
        jnp.concatenate([h_fwd, h_bwd], axis=-1) @ params["dec_init/w"]
        + params["dec_init/b"]
    )
    return enc_proj, mask, h0


def _decoder_step(spec: Seq2SeqSpec, params: dict, tok_emb, h, enc_proj, src_mask):
    """Shared per-step decoder computation → (h', attn_h)."""
    ctx, _probs = luong_attention(h, enc_proj, src_mask)
    x = jnp.concatenate([tok_emb, ctx], axis=-1)
    h_new = gru.cell_step(params, "dec", x, h)
    ctx2, _ = luong_attention(h_new, enc_proj, src_mask)
    attn_h = jnp.tanh(
        jnp.concatenate([h_new, ctx2], axis=-1) @ params["combine/w"] + params["combine/b"]
    )
    return h_new, attn_h


def logits_from_attn(params: dict, attn_h: jax.Array) -> jax.Array:
    return attn_h @ params["out/w"] + params["out/b"]


def loss_fn(spec: Seq2SeqSpec, params: dict, src, tgt, tgt_mask):
    """Teacher-forced masked cross-entropy.

    tgt (B, Tt) includes BOS...EOS; positions predicting tgt[:, 1:] are live
    where tgt_mask[:, :-1] is 1.
    """
    enc_proj, src_mask, h0 = encode(spec, params, src)
    tgt_in = tgt[:, :-1]  # (B, Tt-1)
    tgt_out = tgt[:, 1:]
    emb = lookup(spec.emb, params, tgt_in)  # (B, Tt-1, E)
    emb_t = jnp.swapaxes(emb, 0, 1)  # (Tt-1, B, E)

    def step(h, e_t):
        h_new, attn_h = _decoder_step(spec, params, e_t, h, enc_proj, src_mask)
        return h_new, attn_h

    _, attn_seq = jax.lax.scan(step, h0, emb_t)  # (Tt-1, B, H)
    attn_seq = jnp.swapaxes(attn_seq, 0, 1)  # (B, Tt-1, H)
    logits = logits_from_attn(params, attn_seq)  # (B, Tt-1, V)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt_out[:, :, None], axis=-1)[:, :, 0]
    mask = tgt_mask[:, : nll.shape[1]]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def train_step(spec: Seq2SeqSpec, params, m, v, src, tgt, tgt_mask, step, lr):
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(spec, p, src, tgt, tgt_mask)
    )(params)
    new_params, new_m, new_v = adam.update(params, grads, m, v, step, lr, spec.clip)
    return new_params, new_m, new_v, loss


def decode_step(spec: Seq2SeqSpec, params, enc_proj, src_mask, prev_tok, h):
    """Greedy decode one step: returns (next_tok (B,) int32, h', logits)."""
    emb = lookup(spec.emb, params, prev_tok)  # (B, E)
    h_new, attn_h = _decoder_step(spec, params, emb, h, enc_proj, src_mask)
    logits = logits_from_attn(params, attn_h)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return next_tok, h_new, logits
