"""Minimal GRU layers (scan-based) shared by the seq2seq and QA models.

Parameter layout per cell (name prefix + suffixes):
    <p>/wx (in_dim, 3H), <p>/wh (H, 3H), <p>/b (3H)
Gate order along the 3H axis: [reset | update | candidate].
"""

import math

import jax
import jax.numpy as jnp


def cell_specs(prefix: str, in_dim: int, hidden: int):
    ax = math.sqrt(3.0 / in_dim)
    ah = math.sqrt(3.0 / hidden)
    return [
        (f"{prefix}/wx", (in_dim, 3 * hidden), {"dist": "uniform", "a": ax}),
        (f"{prefix}/wh", (hidden, 3 * hidden), {"dist": "uniform", "a": ah}),
        (f"{prefix}/b", (3 * hidden,), {"dist": "zeros"}),
    ]


def cell_step(params: dict, prefix: str, x: jax.Array, h: jax.Array) -> jax.Array:
    """One GRU step: x (B, in), h (B, H) → h' (B, H)."""
    hidden = h.shape[-1]
    gx = x @ params[f"{prefix}/wx"] + params[f"{prefix}/b"]
    gh = h @ params[f"{prefix}/wh"]
    rx, zx, nx = jnp.split(gx, 3, axis=-1)
    rh, zh, nh = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(rx + rh)
    z = jax.nn.sigmoid(zx + zh)
    n = jnp.tanh(nx + r * nh)
    del hidden
    return (1.0 - z) * n + z * h


def run(params: dict, prefix: str, xs: jax.Array, h0: jax.Array, mask: jax.Array,
        reverse: bool = False) -> tuple[jax.Array, jax.Array]:
    """Run a GRU over time.

    xs (B, T, in), h0 (B, H), mask (B, T) 1.0 on real tokens.
    Returns (outputs (B, T, H), final hidden (B, H)). Masked positions carry
    the previous hidden state through (standard packed-sequence semantics).
    """
    xs_t = jnp.swapaxes(xs, 0, 1)  # (T, B, in)
    mask_t = jnp.swapaxes(mask, 0, 1)[:, :, None]  # (T, B, 1)

    def step(h, inp):
        x, m = inp
        h_new = cell_step(params, prefix, x, h)
        h = m * h_new + (1.0 - m) * h
        return h, h

    hT, outs = jax.lax.scan(step, h0, (xs_t, mask_t), reverse=reverse)
    return jnp.swapaxes(outs, 0, 1), hT
