"""Adam with global-norm gradient clipping, fused into the train_step HLO.

The optimizer state (m, v) flows through the executable as explicit inputs/
outputs — the Rust ParamStore owns the buffers; Python never runs at training
time. Bias correction uses the step counter passed as a scalar input.
"""

import jax
import jax.numpy as jnp

BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def clip_by_global_norm(grads: dict, max_norm: float) -> dict:
    """Scale all grads so the global L2 norm is at most max_norm (0 = off)."""
    if max_norm <= 0:
        return grads
    total = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in grads.values())
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(total, 1e-12))
    return {k: g * scale for k, g in grads.items()}


def update(params: dict, grads: dict, m: dict, v: dict, step: jax.Array,
           lr: jax.Array, clip: float = 1.0):
    """One Adam step. step is the 1-based iteration count (f32 scalar)."""
    grads = clip_by_global_norm(grads, clip)
    b1t = BETA1**step
    b2t = BETA2**step
    new_params, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        mk = BETA1 * m[k] + (1.0 - BETA1) * g
        vk = BETA2 * v[k] + (1.0 - BETA2) * g * g
        mhat = mk / (1.0 - b1t)
        vhat = vk / (1.0 - b2t)
        new_params[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + EPS)
        new_m[k] = mk
        new_v[k] = vk
    return new_params, new_m, new_v
