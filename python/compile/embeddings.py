"""L2 embedding modules: regular, word2ket, word2ketXS lookups.

Each embedding kind is a (param-spec, lookup-fn) pair. Parameters are plain
arrays initialized on the Rust side from manifest init specs; lookups call
the L1 Pallas kernels so the whole reconstruction lowers into the AOT HLO.

Dimension conventions (mirroring rust/src/embedding/*):
  regular : table  (V, p)
  word2ket: leaves (V, r, n, q), p = q**n           (paper eq. 3, per-word)
  word2ketXS: factors (r, n, t, q), q**n >= p,
              t**n >= V, digits base-t big-endian    (paper eq. 4, lazy rows)
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import kron_tree_ranked, xs_reconstruct_rows


def ceil_root(x: int, n: int) -> int:
    """Smallest t with t**n >= x (matches rust util::ceil_root)."""
    if x <= 1:
        return 1
    t = int(math.floor(x ** (1.0 / n)))
    while t**n < x:
        t += 1
    while t > 1 and (t - 1) ** n >= x:
        t -= 1
    return t


@dataclasses.dataclass(frozen=True)
class EmbSpec:
    """Embedding hyper-parameters for one model variant."""

    kind: str  # 'regular' | 'w2k' | 'xs'
    vocab: int
    dim: int  # requested p; effective dim is q**n for tensorized kinds
    order: int = 1
    rank: int = 1
    layernorm: bool = True

    @property
    def q(self) -> int:
        return ceil_root(self.dim, self.order) if self.kind != "regular" else self.dim

    @property
    def t(self) -> int:
        return ceil_root(self.vocab, self.order)

    @property
    def effective_dim(self) -> int:
        """Embedding width actually produced (q**n for tensorized kinds)."""
        if self.kind == "regular":
            return self.dim
        return self.q**self.order

    def param_specs(self):
        """[(name, shape, init)] — init mirrored by rust ParamStore."""
        if self.kind == "regular":
            a = math.sqrt(3.0 / self.dim)
            return [("emb/table", (self.vocab, self.dim), {"dist": "uniform", "a": a})]
        if self.kind == "w2k":
            a = math.sqrt(3.0 / (self.q * self.rank ** (1.0 / self.order)))
            return [(
                "emb/leaves",
                (self.vocab, self.rank, self.order, self.q),
                {"dist": "uniform", "a": a},
            )]
        if self.kind == "xs":
            target = math.sqrt(3.0 / self.effective_dim)
            a = (target / math.sqrt(self.rank)) ** (1.0 / self.order)
            return [(
                "emb/factors",
                (self.rank, self.order, self.t, self.q),
                {"dist": "uniform", "a": a},
            )]
        raise ValueError(f"unknown embedding kind {self.kind}")

    def num_params(self) -> int:
        return sum(math.prod(s) for _, s, _ in self.param_specs())


def lookup(spec: EmbSpec, params: dict, ids: jax.Array) -> jax.Array:
    """ids (...,) int32 → embeddings (..., effective_dim)."""
    flat = ids.reshape(-1)
    if spec.kind == "regular":
        out = params["emb/table"][flat]
    elif spec.kind == "w2k":
        leaves = params["emb/leaves"][flat]  # (B, r, n, q)
        out = kron_tree_ranked(leaves, layernorm_nodes=spec.layernorm)
    elif spec.kind == "xs":
        factors = params["emb/factors"]  # (r, n, t, q)
        n, t = spec.order, spec.t
        # Big-endian base-t digit decode (mirrors rust kron::MixedRadix).
        cols = []
        for j in range(n):
            weight = t ** (n - 1 - j)
            dj = (flat // weight) % t  # (B,)
            # factors[:, j, dj, :] → (r, B, q) → (B, r, q)
            cj = jnp.transpose(factors[:, j, :, :][:, dj, :], (1, 0, 2))
            cols.append(cj)
        stacked = jnp.stack(cols, axis=2)  # (B, r, n, q)
        out = xs_reconstruct_rows(stacked)
    else:
        raise ValueError(spec.kind)
    return out.reshape(*ids.shape, spec.effective_dim)
