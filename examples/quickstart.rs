//! Quickstart: the paper's core idea in a few dozen lines.
//!
//! Builds the Fig. 3 configuration — a 118,655-word, 300-dimensional
//! embedding table stored in **380 parameters** (four 19×5 matrices,
//! word2ketXS order 4 rank 1) — looks up rows lazily, compares against a
//! regular table and the paper's baselines, and demonstrates the factored
//! inner product of §2.3.
//!
//! Run: cargo run --release --example quickstart

use word2ket::embedding::{
    EmbeddingStore, HashedEmbedding, LowRankEmbedding, QuantizedEmbedding, RegularEmbedding,
    Word2Ket, Word2KetXS,
};
use word2ket::util::{fmt_count, Rng, Table, Timer};

fn main() {
    let mut rng = Rng::new(2020);

    // --- The paper's Fig. 3 setting ---------------------------------------
    let vocab = 118_655;
    let dim = 300;
    let xs41 = Word2KetXS::random(vocab, dim, 4, 1, &mut rng);
    println!("{}", xs41.describe());
    assert_eq!(xs41.num_params(), 380);

    let t = Timer::start();
    let v = xs41.lookup(42_000);
    println!(
        "lazy row reconstruction of word 42,000: {} dims in {:.1}µs (first 4: {:?})",
        v.len(),
        t.elapsed_us(),
        &v[..4]
    );

    // --- Compare storage across representations ---------------------------
    let regular = RegularEmbedding::random(vocab, dim, &mut rng);
    let w2k = Word2Ket::random(vocab, dim, 4, 1, &mut rng);
    let xs22 = Word2KetXS::random(vocab, dim, 2, 2, &mut rng);
    let quant = QuantizedEmbedding::random(1000, dim, 8, &mut rng); // small demo table
    let lowrank = LowRankEmbedding::random(vocab, dim, 1, &mut rng);
    let hashed = HashedEmbedding::random(vocab, dim, 1 << 16, &mut rng);

    let mut table = Table::new(vec!["Representation", "#Params", "Space saving"])
        .with_title("SQuAD-scale embedding table (118,655 × 300), paper Table 3 setting");
    let stores: Vec<(&str, &dyn EmbeddingStore)> = vec![
        ("Regular", &regular),
        ("word2ket 4/1", &w2k),
        ("word2ketXS 2/2", &xs22),
        ("word2ketXS 4/1 (Fig. 3)", &xs41),
        ("LowRank k=1 (PCA bound)", &lowrank),
        ("Hashed 64k buckets", &hashed),
    ];
    for (name, s) in stores {
        table.add_row(vec![
            name.to_string(),
            fmt_count(s.num_params() as u64),
            format!("{:.0}×", s.space_saving_rate()),
        ]);
    }
    table.add_row(vec![
        "Quantized 8-bit (32/b bound)".to_string(),
        format!("{} (per 1k words)", fmt_count(quant.num_params() as u64)),
        format!("{:.1}×", quant.space_saving_rate()),
    ]);
    println!("\n{}", table.render());

    // --- Factored inner product (§2.3): O(r²·n·q), no reconstruction ------
    let small = Word2Ket::random(100, 64, 2, 3, &mut rng); // p = 8² = 64
    let (a, b) = (7usize, 19usize);
    let dense: f32 = small
        .lookup(a)
        .iter()
        .zip(small.lookup(b).iter())
        .map(|(x, y)| x * y)
        .sum();
    let factored = small.inner(a, b);
    println!(
        "\nfactored inner product ⟨v_{a}, v_{b}⟩ = {factored:.6} (dense: {dense:.6}, \
         diff {:.2e})",
        (dense - factored).abs()
    );
    assert!((dense - factored).abs() < 1e-3 * dense.abs().max(1.0));

    println!("\nquickstart OK");
}
