//! End-to-end driver (deliverable e2e validation): train the seq2seq
//! summarization model on the synthetic GIGAWORD-like corpus through the
//! full three-layer stack — Rust coordinator → AOT HLO artifacts (JAX L2 +
//! Pallas L1) → PJRT CPU — for two embedding variants (regular and
//! word2ketXS 2/10), logging the loss curve and ROUGE, proving all layers
//! compose. Results are recorded in EXPERIMENTS.md.
//!
//! Run: make artifacts && cargo run --release --example train_summarization
//! Options: --steps N --variant regular|xs (default: both) --json out.json

use word2ket::cli::{App, CommandSpec, OptSpec};
use word2ket::config::{EmbeddingKind, ExperimentConfig, TaskKind};
use word2ket::coordinator::experiment::{run_experiment, Report};
use word2ket::util::{Json, Table};

fn cfg_for(kind: EmbeddingKind, steps: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("e2e-summarization-{}", kind.name());
    cfg.task = TaskKind::Summarization;
    cfg.embedding.kind = kind;
    if kind == EmbeddingKind::Word2KetXS {
        cfg.embedding.order = 2;
        cfg.embedding.rank = 10;
    }
    cfg.train.steps = steps;
    cfg.train.eval_every = (steps / 4).max(1);
    cfg.train.warmup = 0;
    cfg.train.lr = 5e-3;
    cfg.corpus.train = 2000;
    cfg.corpus.valid = 100;
    cfg.corpus.test = 100;
    cfg
}

fn main() -> word2ket::Result<()> {
    let app = App {
        name: "train_summarization",
        about: "end-to-end summarization training through the 3-layer stack",
        commands: vec![CommandSpec {
            name: "run",
            about: "train + evaluate",
            opts: vec![
                OptSpec { name: "steps", help: "training steps", takes_value: true, repeated: false, default: Some("600") },
                OptSpec { name: "variant", help: "regular | xs | both", takes_value: true, repeated: false, default: Some("both") },
                OptSpec { name: "json", help: "write reports as JSON to this file", takes_value: true, repeated: false, default: None },
            ],
            positionals: vec![],
        }],
    };
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "run".into()); // single implicit subcommand
    let parsed = match app.parse(&argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let steps = parsed.get_usize("steps")?.unwrap_or(600);
    let which = parsed.get("variant").unwrap_or("both").to_string();

    let mut reports: Vec<Report> = Vec::new();
    if which == "regular" || which == "both" {
        println!("--- training variant: regular embedding ---");
        reports.push(run_experiment(&cfg_for(EmbeddingKind::Regular, steps))?);
    }
    if which == "xs" || which == "both" {
        println!("--- training variant: word2ketXS 2/10 ---");
        reports.push(run_experiment(&cfg_for(EmbeddingKind::Word2KetXS, steps))?);
    }

    for r in &reports {
        println!("\n{}", r.render());
        // Loss curve, decimated to ≤ 20 points.
        let stride = (r.losses.len() / 20).max(1);
        let pts: Vec<String> = r
            .losses
            .iter()
            .step_by(stride)
            .map(|l| format!("{l:.2}"))
            .collect();
        println!("loss curve: {}", pts.join(" "));
    }

    if reports.len() == 2 {
        let mut t = Table::new(vec!["Variant", "Emb #Params", "Saving", "RG-L", "RG-1"])
            .with_title("regular vs word2ketXS (paper Table 1 shape)");
        for r in &reports {
            let rgl = r.final_metrics.iter().find(|(k, _)| k == "RG-L").map(|x| x.1).unwrap_or(0.0);
            let rg1 = r.final_metrics.iter().find(|(k, _)| k == "RG-1").map(|x| x.1).unwrap_or(0.0);
            t.add_row(vec![
                r.variant.clone(),
                r.emb_params.to_string(),
                format!("{:.0}×", r.space_saving),
                format!("{rgl:.2}"),
                format!("{rg1:.2}"),
            ]);
        }
        println!("\n{}", t.render());
    }

    if let Some(path) = parsed.get("json") {
        let j = Json::arr(reports.iter().map(|r| r.to_json()));
        std::fs::write(path, j.pretty())?;
        println!("reports → {path}");
    }
    Ok(())
}
