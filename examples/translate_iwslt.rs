//! Translation example (paper Table 2 workload): train the seq2seq model on
//! the synthetic DE→EN corpus with a chosen embedding variant and report
//! BLEU, demonstrating the reordering + lexical-mapping task through the
//! full AOT stack.
//!
//! Run: cargo run --release --example translate_iwslt -- [--steps N]
//!      [--order 2 --rank 10] [--regular] [--show-samples]

use word2ket::cli::{App, CommandSpec, OptSpec};
use word2ket::config::{EmbeddingKind, ExperimentConfig, TaskKind};
use word2ket::coordinator::experiment::run_experiment;
use word2ket::corpus::translation;
use word2ket::text::detokenize;

fn main() -> word2ket::Result<()> {
    let app = App {
        name: "translate_iwslt",
        about: "synthetic DE→EN translation through the 3-layer stack",
        commands: vec![CommandSpec {
            name: "run",
            about: "train + evaluate BLEU",
            opts: vec![
                OptSpec { name: "steps", help: "training steps", takes_value: true, repeated: false, default: Some("600") },
                OptSpec { name: "order", help: "word2ketXS tensor order", takes_value: true, repeated: false, default: Some("2") },
                OptSpec { name: "rank", help: "word2ketXS tensor rank", takes_value: true, repeated: false, default: Some("10") },
                OptSpec { name: "regular", help: "use the regular embedding instead", takes_value: false, repeated: false, default: None },
                OptSpec { name: "show-samples", help: "print sample source/target pairs", takes_value: false, repeated: false, default: None },
            ],
            positionals: vec![],
        }],
    };
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "run".into());
    let parsed = match app.parse(&argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };

    let mut cfg = ExperimentConfig::default();
    cfg.name = "e2e-translation".into();
    cfg.task = TaskKind::Translation;
    if parsed.flag("regular") {
        cfg.embedding.kind = EmbeddingKind::Regular;
        cfg.embedding.order = 1;
        cfg.embedding.rank = 1;
    } else {
        cfg.embedding.kind = EmbeddingKind::Word2KetXS;
        cfg.embedding.order = parsed.get_usize("order")?.unwrap_or(2);
        cfg.embedding.rank = parsed.get_usize("rank")?.unwrap_or(10);
    }
    cfg.train.steps = parsed.get_usize("steps")?.unwrap_or(600);
    cfg.train.eval_every = (cfg.train.steps / 4).max(1);
    cfg.train.warmup = 0;
    cfg.train.lr = 5e-3;
    cfg.corpus.train = 2000;
    cfg.corpus.valid = 100;
    cfg.corpus.test = 100;

    if parsed.flag("show-samples") {
        let splits = translation::generate(&cfg.corpus, 1024);
        println!("sample synthetic DE→EN pairs (verb-final source, fused articles):");
        for p in splits.train.iter().take(4) {
            println!("  src: {}", detokenize(&p.src));
            println!("  tgt: {}\n", detokenize(&p.tgt));
        }
    }

    let report = run_experiment(&cfg)?;
    println!("{}", report.render());
    println!(
        "\nBLEU curve over training: {}",
        report
            .curve
            .iter()
            .map(|p| format!("@{}:{:.1}", p.step, p.primary))
            .collect::<Vec<_>>()
            .join("  ")
    );
    Ok(())
}
