//! Serving example: run the embedding server on a compressed word2ketXS
//! table, fire Zipf-distributed concurrent client load at it, and report
//! latency/throughput — the serving-side story of the paper (a 380-parameter
//! table standing in for a 35.6M-parameter one), now through the production
//! path: sharded hot-row cache, worker pool, and binary wire protocol.
//!
//! Run: cargo run --release --example serve_embeddings -- [--requests N]
//!      [--clients C] [--order 4 --rank 1] [--shards 4] [--cache-rows 65536]
//!      [--wire binary|text] [--driver threads|epoll] [--zipf 1.05]
//!      [--knn 0.1 --topk 10] [--index ivf --nlist 64 --nprobe 8]
//!      [--scan-threads 0]
//!      [--save model.snap] [--load model.snap] [--reload model.snap]
//!      [--trace-sample 0.01] [--trace <32-hex id>]
//!
//! `--trace-sample F` head-samples a fraction of requests into the
//! distributed tracer ([`word2ket::obs::Tracer`]); after the run the demo
//! dumps the server's completed-trace ring (`TRACE?slow`). `--trace <id>`
//! fetches one specific trace instead — in cluster mode the router
//! assembles the cross-node span tree from every shard.
//!
//! `--driver epoll` runs every listener on the event-loop reactor instead
//! of the blocking thread-per-connection driver (and, in cluster mode,
//! switches the router's scatter-gather to multiplexed in-flight fan-out);
//! the load generator's numbers are directly comparable across drivers
//! because the wire bytes are identical.
//!
//! `--knn F` makes each client issue a KNN query (Zipf-sampled query word,
//! `--topk` neighbors) instead of a batched lookup with probability F,
//! exercising the similarity-search request path under the same load.
//!
//! Snapshot flags (the zero-downtime model-roll walkthrough in the README):
//! `--save` writes the configured store to a snapshot before serving;
//! `--load` boots the server from a snapshot (memory-mapped) instead of
//! RNG + config; `--reload` issues a binary-protocol `OP_RELOAD` mid-load,
//! hot-swapping the model under the running traffic.
//!
//! Cluster mode: `--cluster topology.toml` self-hosts the whole story —
//! slices the store into per-shard snapshots, spawns one stock shard
//! server per replica listed in the topology (on OS-assigned loopback
//! ports), and drives the same Zipf lookup/KNN mix through a scatter-
//! gather [`word2ket::cluster::Router`] instead of a single server. With
//! `--reload <dir>` the demo performs a mid-load *rolling* reload across
//! every replica. The topology file's ports are treated as a replica
//! *count* here (the demo binds its own); point `w2k cluster route` at
//! real addresses for an actual deployment.

use word2ket::cli::{App, CommandSpec, OptSpec};
use word2ket::cluster::{save_shard_snapshots, Router, RouterConfig, Topology};
use word2ket::config::{EmbeddingKind, ExperimentConfig, IndexKind, TomlDoc};
use word2ket::coordinator::server;
use word2ket::serving::BinaryClient;
use word2ket::util::{Rng, Summary, Timer, ZipfSampler};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn main() -> word2ket::Result<()> {
    let app = App {
        name: "serve_embeddings",
        about: "embedding server + Zipf load generator",
        commands: vec![CommandSpec {
            name: "run",
            about: "serve and measure",
            opts: vec![
                OptSpec { name: "requests", help: "requests per client", takes_value: true, repeated: false, default: Some("500") },
                OptSpec { name: "clients", help: "concurrent clients", takes_value: true, repeated: false, default: Some("4") },
                OptSpec { name: "order", help: "word2ketXS order", takes_value: true, repeated: false, default: Some("4") },
                OptSpec { name: "rank", help: "word2ketXS rank", takes_value: true, repeated: false, default: Some("1") },
                OptSpec { name: "vocab", help: "vocabulary size", takes_value: true, repeated: false, default: Some("118655") },
                OptSpec { name: "dim", help: "embedding dim", takes_value: true, repeated: false, default: Some("300") },
                OptSpec { name: "shards", help: "cache/pool shards", takes_value: true, repeated: false, default: Some("4") },
                OptSpec { name: "cache-rows", help: "hot-row cache size (0 disables)", takes_value: true, repeated: false, default: Some("65536") },
                OptSpec { name: "wire", help: "protocol: binary|text", takes_value: true, repeated: false, default: Some("binary") },
                OptSpec { name: "driver", help: "network driver: threads|epoll", takes_value: true, repeated: false, default: Some("threads") },
                OptSpec { name: "zipf", help: "Zipf exponent of the id stream", takes_value: true, repeated: false, default: Some("1.05") },
                OptSpec { name: "batch", help: "ids per request", takes_value: true, repeated: false, default: Some("8") },
                OptSpec { name: "knn", help: "fraction of requests that are KNN queries", takes_value: true, repeated: false, default: Some("0") },
                OptSpec { name: "topk", help: "neighbors per KNN query", takes_value: true, repeated: false, default: Some("10") },
                OptSpec { name: "index", help: "knn index: brute|ivf", takes_value: true, repeated: false, default: Some("brute") },
                OptSpec { name: "nlist", help: "IVF coarse cells", takes_value: true, repeated: false, default: Some("64") },
                OptSpec { name: "nprobe", help: "IVF cells probed per query", takes_value: true, repeated: false, default: Some("8") },
                OptSpec { name: "scan-threads", help: "KNN scan threads (0 = all cores, 1 = sequential; results are bit-identical at any setting)", takes_value: true, repeated: false, default: Some("0") },
                OptSpec { name: "save", help: "write the configured store to this snapshot file before serving", takes_value: true, repeated: false, default: None },
                OptSpec { name: "load", help: "boot the server from this snapshot (mmap) instead of RNG+config", takes_value: true, repeated: false, default: None },
                OptSpec { name: "reload", help: "hot-swap to this snapshot mid-load via OP_RELOAD (cluster mode: a dir to rolling-reload from)", takes_value: true, repeated: false, default: None },
                OptSpec { name: "cluster", help: "topology TOML ([cluster] section): self-host the shards and route through a scatter-gather router", takes_value: true, repeated: false, default: None },
                OptSpec { name: "trace-sample", help: "fraction of requests head-sampled into the distributed tracer", takes_value: true, repeated: false, default: Some("0") },
                OptSpec { name: "trace", help: "dump this 32-hex trace id after the run instead of the trace ring", takes_value: true, repeated: false, default: None },
            ],
            positionals: vec![],
        }],
    };
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "run".into());
    let parsed = match app.parse(&argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let requests = parsed.get_usize("requests")?.unwrap_or(500);
    let clients = parsed.get_usize("clients")?.unwrap_or(4);
    let batch = parsed.get_usize("batch")?.unwrap_or(8).max(1);
    let wire_mode = parsed.get("wire").unwrap_or("binary").to_string();
    if wire_mode != "binary" && wire_mode != "text" {
        eprintln!("--wire must be 'binary' or 'text', got '{wire_mode}'");
        std::process::exit(2);
    }
    let zipf_s = parsed.get_f64("zipf")?.unwrap_or(1.05);
    let knn_frac = parsed.get_f64("knn")?.unwrap_or(0.0).clamp(0.0, 1.0);
    let topk = parsed.get_usize("topk")?.unwrap_or(10).max(1);
    let trace_sample = parsed.get_f64("trace-sample")?.unwrap_or(0.0).clamp(0.0, 1.0);
    let trace_id = match parsed.get("trace") {
        Some(hex) => match word2ket::obs::TraceContext::parse_hex(hex) {
            Some(id) => Some(id),
            None => {
                eprintln!("--trace must be a 32-hex trace id, got '{hex}'");
                std::process::exit(2);
            }
        },
        None => None,
    };

    let mut cfg = ExperimentConfig::default();
    cfg.embedding.kind = EmbeddingKind::Word2KetXS;
    cfg.embedding.order = parsed.get_usize("order")?.unwrap_or(4);
    cfg.embedding.rank = parsed.get_usize("rank")?.unwrap_or(1);
    cfg.model.vocab = parsed.get_usize("vocab")?.unwrap_or(118_655);
    cfg.model.emb_dim = parsed.get_usize("dim")?.unwrap_or(300);
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.serving.shards = parsed.get_usize("shards")?.unwrap_or(4);
    cfg.serving.cache_rows = parsed.get_usize("cache-rows")?.unwrap_or(65_536);
    cfg.serving.batch_window_us = 150;
    cfg.serving.max_batch = 256;
    cfg.net.driver = word2ket::config::NetDriver::parse(parsed.get("driver").unwrap_or("threads"))
        .map_err(word2ket::Error::Config)?;
    cfg.index.kind = IndexKind::parse(parsed.get("index").unwrap_or("brute"))?;
    cfg.index.nlist = parsed.get_usize("nlist")?.unwrap_or(64);
    cfg.index.nprobe = parsed.get_usize("nprobe")?.unwrap_or(8);
    cfg.index.scan_threads = parsed.get_usize("scan-threads")?.unwrap_or(0);
    cfg.obs.trace_sample = trace_sample;

    if let Some(save) = parsed.get("save") {
        // Build the exact store the server would build (same seed) and
        // persist it, so --save + --load/--reload round-trip one model.
        let mut rng = Rng::new(cfg.train.seed);
        let store = word2ket::embedding::build(
            &cfg.embedding,
            cfg.model.vocab,
            cfg.model.emb_dim,
            &mut rng,
        );
        let info = word2ket::snapshot::save_store(
            store.as_ref(),
            std::path::Path::new(save),
            &word2ket::snapshot::SaveOptions { codec: cfg.snapshot.codec, ..Default::default() },
        )?;
        println!(
            "saved snapshot {} ({} bytes, {} sections, vs {} materialized f32 bytes)",
            save,
            info.bytes,
            info.sections,
            cfg.model.vocab * cfg.model.emb_dim * 4
        );
    }
    if let Some(load) = parsed.get("load") {
        cfg.snapshot.path = load.to_string();
    }
    let reload_path = parsed.get("reload").map(|s| s.to_string());

    if let Some(topo_file) = parsed.get("cluster") {
        let mix = Mix { batch, knn_frac, topk };
        return run_cluster(
            topo_file,
            &cfg,
            requests,
            clients,
            &mix,
            zipf_s,
            reload_path.as_deref(),
            trace_id,
        );
    }

    let (state, listener, addr) = server::spawn(&cfg)?;
    let accept_state = state.clone();
    let accept = std::thread::spawn(move || server::accept_loop(listener, accept_state));

    println!(
        "server on {addr} [{wire_mode} wire, {} driver, {} shards, {} cache rows, {} index, \
         {} kernels, scan-threads {}]; {clients} clients × {requests} reqs (batch {batch}, \
         Zipf s={zipf_s}, knn mix {:.0}% top-{topk})",
        cfg.net.driver,
        cfg.serving.shards,
        cfg.serving.cache_rows,
        cfg.index.kind.name(),
        word2ket::simd::level().name(),
        cfg.index.scan_threads,
        100.0 * knn_frac
    );
    let zipf = Arc::new(ZipfSampler::new(cfg.model.vocab, zipf_s));
    let wall = Timer::start();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            let wire_mode = wire_mode.clone();
            let zipf = zipf.clone();
            std::thread::spawn(move || -> ClientReport {
                let mut rng = Rng::new(100 + c as u64);
                let mix = Mix { batch, knn_frac, topk };
                if wire_mode == "binary" {
                    run_binary_client(&addr, requests, &mix, &zipf, &mut rng)
                } else {
                    run_text_client(&addr, requests, &mix, &zipf, &mut rng)
                }
            })
        })
        .collect();

    // The zero-downtime roll: swap the model while the clients above are
    // mid-flight. In-flight requests drain on the old generation.
    if let Some(rp) = &reload_path {
        std::thread::sleep(std::time::Duration::from_millis(30));
        let mut c = BinaryClient::connect(&addr).expect("reload connect");
        match c.reload(rp) {
            Ok(generation) => println!("hot-swapped to {rp} (model generation {generation})"),
            Err(e) => eprintln!("reload {rp} failed: {e}"),
        }
        c.quit().ok();
    }

    let mut rejected_total = 0u64;
    let mut lookups_total = 0u64;
    let mut knn_total = 0u64;
    for h in handles {
        let r = h.join().expect("client thread");
        rejected_total += r.rejected;
        lookups_total += r.lookups;
        knn_total += r.knn;
        println!(
            "  client done: p50 {:.0}µs p99 {:.0}µs over {} reqs \
             ({} lookups, {} knn, {} rejected)",
            r.lat.p50(),
            r.lat.p99(),
            r.lat.len(),
            r.lookups,
            r.knn,
            r.rejected
        );
    }
    let secs = wall.elapsed().as_secs_f64();
    // Only successfully served rows count toward throughput; rejected
    // batches (backpressure/timeout) and knn queries serve no rows.
    let served_rows = (lookups_total * batch as u64) as f64;
    println!(
        "\nTOTAL: {} rows + {} knn queries in {:.2}s → {:.0} rows/s, {} rejected reqs \
         (served {} from a compressed {}×{} table)",
        served_rows as u64,
        knn_total,
        secs,
        served_rows / secs,
        rejected_total,
        state.served(),
        cfg.model.vocab,
        cfg.model.emb_dim
    );

    // Ask the server for its own view over the binary protocol.
    let mut stats_client = BinaryClient::connect(&addr).expect("stats connect");
    let stats = stats_client.stats().expect("stats");
    println!(
        "server STATS: p50_us={:.0} p99_us={:.0} served={} cache_hits={} cache_misses={} \
         rejected={} knn_queries={} knn_candidates={} knn_mean_probes={:.2} \
         model_generation={} snapshot_bytes={} accept_errors={} simd_level={} \
         (hit rate {:.1}%)",
        stats.p50_us,
        stats.p99_us,
        stats.served,
        stats.cache_hits,
        stats.cache_misses,
        stats.rejected,
        stats.knn_queries,
        stats.knn_candidates,
        stats.knn_mean_probes,
        stats.model_generation,
        stats.snapshot_bytes,
        stats.accept_errors,
        stats.simd_level,
        100.0 * stats.cache_hits as f64 / (stats.cache_hits + stats.cache_misses).max(1) as f64
    );
    // Trace dump: one specific id, or (when sampling was on) the server's
    // completed-trace ring — the single-node span-per-stage story.
    if let Some(id) = trace_id {
        match stats_client.trace(id) {
            Ok(text) => print!("{text}"),
            Err(e) => eprintln!("TRACE fetch failed: {e}"),
        }
    } else if trace_sample > 0.0 {
        match stats_client.trace_slow() {
            Ok(text) => print!("server trace ring:\n{text}"),
            Err(e) => eprintln!("TRACE?slow fetch failed: {e}"),
        }
    }
    stats_client.quit().ok();

    state.shutdown();
    accept.join().ok();
    Ok(())
}

/// Per-request workload shape shared by both protocol drivers.
struct Mix {
    batch: usize,
    knn_frac: f64,
    topk: usize,
}

/// What one client observed.
struct ClientReport {
    lat: Summary,
    lookups: u64,
    knn: u64,
    rejected: u64,
}

/// Drive `requests` Zipf requests over the binary protocol, mixing batched
/// lookups with KNN queries per `mix`. Backpressure rejections
/// (overloaded/timeout) are counted, not fatal — observing them is part of
/// the point of the load generator.
fn run_binary_client(
    addr: &str,
    requests: usize,
    mix: &Mix,
    zipf: &ZipfSampler,
    rng: &mut Rng,
) -> ClientReport {
    let mut report =
        ClientReport { lat: Summary::new(), lookups: 0, knn: 0, rejected: 0 };
    let mut client = BinaryClient::connect(addr).expect("connect");
    let mut ids = vec![0u32; mix.batch];
    for _ in 0..requests {
        if mix.knn_frac > 0.0 && rng.chance(mix.knn_frac) {
            let query = zipf.sample(rng) as u32;
            let t = Timer::start();
            match client.knn(query, mix.topk as u32) {
                Ok(neighbors) => {
                    report.lat.add(t.elapsed_us());
                    report.knn += 1;
                    assert!(neighbors.len() <= mix.topk, "overlong knn response");
                }
                Err(word2ket::serving::WireError::Status(_)) => report.rejected += 1,
                Err(e) => panic!("binary transport error: {e}"),
            }
            continue;
        }
        for id in ids.iter_mut() {
            *id = zipf.sample(rng) as u32;
        }
        let t = Timer::start();
        match client.lookup(&ids) {
            Ok(rows) => {
                report.lat.add(t.elapsed_us());
                report.lookups += 1;
                assert_eq!(rows.len(), mix.batch, "short binary response");
            }
            Err(word2ket::serving::WireError::Status(_)) => report.rejected += 1,
            Err(e) => panic!("binary transport error: {e}"),
        }
    }
    client.quit().ok();
    report
}

/// Self-hosted cluster demo: per-shard snapshots, one stock server per
/// replica, Zipf load through the scatter-gather router, optional mid-load
/// rolling reload. See the module docs.
#[allow(clippy::too_many_arguments)]
fn run_cluster(
    topo_file: &str,
    cfg: &ExperimentConfig,
    requests: usize,
    clients: usize,
    mix: &Mix,
    zipf_s: f64,
    reload_dir: Option<&str>,
    trace_id: Option<u128>,
) -> word2ket::Result<()> {
    let src = std::fs::read_to_string(topo_file).map_err(|e| {
        word2ket::Error::Config(format!("cannot read topology {topo_file}: {e}"))
    })?;
    let doc = TomlDoc::parse(&src)?;
    let shape = Topology::from_doc(&doc)?;
    let mut router_cfg = RouterConfig::from_doc(&doc);
    // The demo's --driver flag overrides the topology file's [net] section
    // so one flag flips the shard servers and the router's fan-out together.
    router_cfg.net = cfg.net;
    // Likewise --trace-sample overrides the topology file's [obs] sampling
    // so one flag arms tracing on the router and (via the shard configs
    // cloned below) every shard server at once.
    router_cfg.obs.trace_sample = cfg.obs.trace_sample;
    let mut cfg = cfg.clone();
    cfg.model.vocab = shape.vocab();
    cfg.validate()?;

    // One global store, sliced into shard snapshot files.
    let mut rng = Rng::new(cfg.train.seed);
    let store = word2ket::embedding::build(
        &cfg.embedding,
        cfg.model.vocab,
        cfg.model.emb_dim,
        &mut rng,
    );
    let dir = std::env::temp_dir().join(format!("w2k_cluster_demo_{}", std::process::id()));
    let opts =
        word2ket::snapshot::SaveOptions { codec: cfg.snapshot.codec, ..Default::default() };
    let saved = save_shard_snapshots(store.as_ref(), &shape, &dir, &opts)?;

    // One stock single-node server per replica, booted from its shard file
    // on an OS-assigned loopback port.
    let mut nodes = Vec::new();
    let mut addrs = Vec::new();
    for (s, (path, info)) in saved.iter().enumerate() {
        let mut group_addrs = Vec::new();
        for _ in 0..shape.replicas(s).len() {
            let mut shard_cfg = cfg.clone();
            shard_cfg.server.addr = "127.0.0.1:0".into();
            shard_cfg.snapshot.path = path.display().to_string();
            let (state, listener, addr) = server::spawn(&shard_cfg)?;
            let accept_state = state.clone();
            let accept = std::thread::spawn(move || server::accept_loop(listener, accept_state));
            group_addrs.push(addr);
            nodes.push((state, accept));
        }
        println!(
            "shard {s}: {} bytes on disk, replicas at {}",
            info.bytes,
            group_addrs.join(", ")
        );
        addrs.push(group_addrs);
    }
    let topo = shape.with_addrs(addrs)?;
    println!(
        "cluster up: {} (router probes every {:?})",
        topo.describe(),
        router_cfg.probe_interval
    );

    let router = Router::new(topo, router_cfg);
    let zipf = Arc::new(ZipfSampler::new(cfg.model.vocab, zipf_s));
    let wall = Timer::start();
    let reload_at = requests / 3;
    let total = std::thread::scope(|scope| -> u64 {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let router = router.clone();
                let zipf = zipf.clone();
                scope.spawn(move || -> (Summary, u64, u64, u64) {
                    let mut rng = Rng::new(500 + c as u64);
                    let mut lat = Summary::new();
                    let (mut lookups, mut knn, mut rejected) = (0u64, 0u64, 0u64);
                    let mut ids = vec![0u32; mix.batch];
                    for _ in 0..requests {
                        if mix.knn_frac > 0.0 && rng.chance(mix.knn_frac) {
                            let q = zipf.sample(&mut rng) as u32;
                            let t = Timer::start();
                            match router.knn(q, mix.topk as u32) {
                                Ok(ns) => {
                                    assert!(ns.len() <= mix.topk);
                                    lat.add(t.elapsed_us());
                                    knn += 1;
                                }
                                // Backpressure is part of the show; a
                                // malformed request is a bug.
                                Err(e) => {
                                    assert!(!matches!(
                                        e,
                                        word2ket::cluster::RouterError::OutOfRange
                                            | word2ket::cluster::RouterError::BadQuery
                                    ));
                                    rejected += 1;
                                }
                            }
                            continue;
                        }
                        for id in ids.iter_mut() {
                            *id = zipf.sample(&mut rng) as u32;
                        }
                        let t = Timer::start();
                        match router.lookup(&ids) {
                            Ok(rows) => {
                                assert_eq!(rows.len(), mix.batch);
                                lat.add(t.elapsed_us());
                                lookups += 1;
                            }
                            Err(e) => {
                                assert!(!matches!(
                                    e,
                                    word2ket::cluster::RouterError::OutOfRange
                                        | word2ket::cluster::RouterError::BadQuery
                                ));
                                rejected += 1;
                            }
                        }
                    }
                    (lat, lookups, knn, rejected)
                })
            })
            .collect();

        // Optional zero-downtime roll while the clients hammer away.
        if let Some(rd) = reload_dir {
            while router.stats().aggregate.served == 0 && wall.elapsed().as_secs() < 10 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            let rd = std::path::Path::new(rd);
            save_shard_snapshots(store.as_ref(), router.topology(), rd, &opts)
                .expect("save generation-2 shard snapshots");
            match router.rolling_reload_dir(rd) {
                Ok(generations) => println!(
                    "rolling reload done after ~{} requests: shard generations {generations:?}",
                    reload_at
                ),
                Err(e) => eprintln!("rolling reload failed: {e}"),
            }
        }

        let mut total = 0u64;
        for h in handles {
            let (lat, lookups, knn, rejected) = h.join().expect("client thread");
            total += lookups + knn;
            println!(
                "  client done: p50 {:.0}µs p99 {:.0}µs over {} reqs \
                 ({lookups} lookups, {knn} knn, {rejected} rejected)",
                lat.p50(),
                lat.p99(),
                lat.len()
            );
        }
        total
    });

    let secs = wall.elapsed().as_secs_f64();
    let cs = router.stats();
    println!(
        "\nCLUSTER TOTAL: {total} reqs in {secs:.2}s → {:.0} reqs/s across {} shards \
         ({}/{} replicas healthy, {} failovers, generations {}..{})",
        total as f64 / secs,
        router.topology().n_shards(),
        cs.healthy_replicas,
        cs.total_replicas,
        cs.failovers,
        cs.min_generation,
        cs.max_generation
    );
    println!(
        "aggregate STATS: served={} cache_hits={} cache_misses={} knn_queries={} p99_us={:.0}",
        cs.aggregate.served,
        cs.aggregate.cache_hits,
        cs.aggregate.cache_misses,
        cs.aggregate.knn_queries,
        cs.aggregate.p99_us
    );
    // Cross-node trace dump: the router assembles its own spans plus every
    // shard's (scraped over OP_TRACE) into one labelled span tree.
    if let Some(id) = trace_id {
        print!("{}", router.trace_text(id));
    } else if cfg.obs.trace_sample > 0.0 {
        print!("router trace ring:\n{}", router.trace_slow_text());
    }

    router.shutdown();
    for (state, accept) in nodes {
        state.shutdown();
        accept.join().ok();
    }
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}

/// Drive `requests` Zipf requests over the text protocol, mixing batched
/// lookups with KNN queries per `mix`. A failed request comes back as a
/// single `ERR ...` line (overloaded/timeout), counted as a rejection rather
/// than a panic.
fn run_text_client(
    addr: &str,
    requests: usize,
    mix: &Mix,
    zipf: &ZipfSampler,
    rng: &mut Rng,
) -> ClientReport {
    let mut report =
        ClientReport { lat: Summary::new(), lookups: 0, knn: 0, rejected: 0 };
    let mut s = TcpStream::connect(addr).expect("connect");
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut line = String::new();
    for _ in 0..requests {
        if mix.knn_frac > 0.0 && rng.chance(mix.knn_frac) {
            let req = format!("KNN {} {}\n", zipf.sample(rng), mix.topk);
            let t = Timer::start();
            s.write_all(req.as_bytes()).unwrap();
            line.clear();
            r.read_line(&mut line).unwrap();
            if line.starts_with("ERR") {
                report.rejected += 1;
            } else {
                assert!(line.starts_with("OK "), "bad response: {line}");
                report.lat.add(t.elapsed_us());
                report.knn += 1;
            }
            continue;
        }
        let mut req = String::from("LOOKUP");
        for _ in 0..mix.batch {
            req.push_str(&format!(" {}", zipf.sample(rng)));
        }
        req.push('\n');
        let t = Timer::start();
        s.write_all(req.as_bytes()).unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        if line.starts_with("ERR") {
            report.rejected += 1;
            continue;
        }
        assert!(line.starts_with("OK "), "bad response: {line}");
        for _ in 1..mix.batch {
            line.clear();
            r.read_line(&mut line).unwrap();
            assert!(line.starts_with("OK "), "bad response: {line}");
        }
        report.lat.add(t.elapsed_us());
        report.lookups += 1;
    }
    s.write_all(b"QUIT\n").ok();
    report
}
