//! Serving example: run the embedding server on a compressed word2ketXS
//! table, fire concurrent client load at it, and report latency/throughput —
//! the serving-side story of the paper (a 380-parameter table standing in
//! for a 35.6M-parameter one).
//!
//! Run: cargo run --release --example serve_embeddings -- [--requests N]
//!      [--clients C] [--order 4 --rank 1]

use word2ket::cli::{App, CommandSpec, OptSpec};
use word2ket::config::{EmbeddingKind, ExperimentConfig};
use word2ket::coordinator::server;
use word2ket::util::{Rng, Summary, Timer};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn main() -> word2ket::Result<()> {
    let app = App {
        name: "serve_embeddings",
        about: "embedding server + load generator",
        commands: vec![CommandSpec {
            name: "run",
            about: "serve and measure",
            opts: vec![
                OptSpec { name: "requests", help: "requests per client", takes_value: true, repeated: false, default: Some("500") },
                OptSpec { name: "clients", help: "concurrent clients", takes_value: true, repeated: false, default: Some("4") },
                OptSpec { name: "order", help: "word2ketXS order", takes_value: true, repeated: false, default: Some("4") },
                OptSpec { name: "rank", help: "word2ketXS rank", takes_value: true, repeated: false, default: Some("1") },
                OptSpec { name: "vocab", help: "vocabulary size", takes_value: true, repeated: false, default: Some("118655") },
                OptSpec { name: "dim", help: "embedding dim", takes_value: true, repeated: false, default: Some("300") },
            ],
            positionals: vec![],
        }],
    };
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "run".into());
    let parsed = match app.parse(&argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let requests = parsed.get_usize("requests")?.unwrap_or(500);
    let clients = parsed.get_usize("clients")?.unwrap_or(4);

    let mut cfg = ExperimentConfig::default();
    cfg.embedding.kind = EmbeddingKind::Word2KetXS;
    cfg.embedding.order = parsed.get_usize("order")?.unwrap_or(4);
    cfg.embedding.rank = parsed.get_usize("rank")?.unwrap_or(1);
    cfg.model.vocab = parsed.get_usize("vocab")?.unwrap_or(118_655);
    cfg.model.emb_dim = parsed.get_usize("dim")?.unwrap_or(300);
    cfg.server.addr = "127.0.0.1:17898".into();
    cfg.server.batch_window_us = 150;
    cfg.server.max_batch = 256;

    let (state, listener, _worker) = server::spawn(&cfg)?;
    let addr = cfg.server.addr.clone();
    let accept_state = state.clone();
    let accept = std::thread::spawn(move || server::accept_loop(listener, accept_state));

    println!("server on {addr}; {clients} clients × {requests} lookups each");
    let wall = Timer::start();
    let vocab = cfg.model.vocab;
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || -> Summary {
                let mut lat = Summary::new();
                let mut rng = Rng::new(100 + c as u64);
                let mut s = TcpStream::connect(&addr).expect("connect");
                let mut r = BufReader::new(s.try_clone().unwrap());
                let mut line = String::new();
                for _ in 0..requests {
                    let id = rng.below(vocab);
                    let t = Timer::start();
                    s.write_all(format!("LOOKUP {id}\n").as_bytes()).unwrap();
                    line.clear();
                    r.read_line(&mut line).unwrap();
                    lat.add(t.elapsed_us());
                    assert!(line.starts_with("OK "), "bad response: {line}");
                }
                s.write_all(b"QUIT\n").ok();
                lat
            })
        })
        .collect();

    for h in handles {
        let lat = h.join().expect("client thread");
        println!(
            "  client done: p50 {:.0}µs p99 {:.0}µs over {} reqs",
            lat.p50(),
            lat.p99(),
            lat.len()
        );
    }
    let secs = wall.elapsed().as_secs_f64();
    let total = (clients * requests) as f64;
    println!(
        "\nTOTAL: {} lookups in {:.2}s → {:.0} lookups/s (served {} rows from a \
         compressed {}×{} table)",
        total as u64,
        secs,
        total / secs,
        state.served(),
        vocab,
        cfg.model.emb_dim
    );
    // Ask the server for its own view.
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"STATS\n").unwrap();
    let mut line = String::new();
    BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
    println!("server STATS: {}", line.trim());
    s.write_all(b"QUIT\n").ok();

    state.shutdown();
    accept.join().ok();
    Ok(())
}
