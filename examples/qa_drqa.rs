//! QA example (paper Table 3 / Figs 2–3 workload): train the DrQA-style
//! reader on the synthetic SQuAD-like corpus with the word2ketXS embedding
//! and report F1/EM. With `--qualitative`, prints Fig.-3-style sample
//! predictions from the trained compressed model.
//!
//! Run: cargo run --release --example qa_drqa -- [--steps N]
//!      [--order 4 --rank 1] [--regular] [--qualitative]

use word2ket::cli::{App, CommandSpec, OptSpec};
use word2ket::config::{EmbeddingKind, ExperimentConfig, TaskKind};
use word2ket::coordinator::experiment::resolve_variant;
use word2ket::coordinator::tasks::prepare_qa;
use word2ket::coordinator::trainer::predict_spans;
use word2ket::coordinator::{experiment, Trainer};
use word2ket::runtime::{Engine, Manifest, ParamStore};
use word2ket::text::detokenize;
use word2ket::util::Rng;
use std::path::Path;

fn main() -> word2ket::Result<()> {
    let app = App {
        name: "qa_drqa",
        about: "extractive QA with compressed embeddings (Table 3 / Fig. 2–3)",
        commands: vec![CommandSpec {
            name: "run",
            about: "train + evaluate F1",
            opts: vec![
                OptSpec { name: "steps", help: "training steps", takes_value: true, repeated: false, default: Some("500") },
                OptSpec { name: "order", help: "word2ketXS order", takes_value: true, repeated: false, default: Some("4") },
                OptSpec { name: "rank", help: "word2ketXS rank", takes_value: true, repeated: false, default: Some("1") },
                OptSpec { name: "regular", help: "use the regular embedding", takes_value: false, repeated: false, default: None },
                OptSpec { name: "qualitative", help: "print Fig. 3-style sample predictions", takes_value: false, repeated: false, default: None },
            ],
            positionals: vec![],
        }],
    };
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "run".into());
    let parsed = match app.parse(&argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };

    let mut cfg = ExperimentConfig::default();
    cfg.name = "e2e-qa".into();
    cfg.task = TaskKind::Qa;
    if parsed.flag("regular") {
        cfg.embedding.kind = EmbeddingKind::Regular;
    } else {
        cfg.embedding.kind = EmbeddingKind::Word2KetXS;
        cfg.embedding.order = parsed.get_usize("order")?.unwrap_or(4);
        cfg.embedding.rank = parsed.get_usize("rank")?.unwrap_or(1);
    }
    cfg.train.steps = parsed.get_usize("steps")?.unwrap_or(500);
    cfg.train.eval_every = (cfg.train.steps / 5).max(1);
    cfg.corpus.train = 2000;
    cfg.corpus.valid = 100;
    cfg.corpus.test = 100;

    let report = experiment::run_experiment(&cfg)?;
    println!("{}", report.render());
    println!(
        "F1 dynamics (Fig. 2 style): {}",
        report
            .curve
            .iter()
            .map(|p| format!("@{}:{:.1}", p.step, p.primary))
            .collect::<Vec<_>>()
            .join("  ")
    );

    if parsed.flag("qualitative") {
        // Fig. 3: sample contexts/questions with model predictions from the
        // trained compressed model (reload checkpoint saved by the run).
        let engine = Engine::cpu(Path::new(&cfg.artifacts_dir))?;
        let manifest = Manifest::load(Path::new(&cfg.artifacts_dir))?;
        let variant = resolve_variant(&cfg, &manifest)?;
        let ckpt = Path::new(&cfg.train.checkpoint_dir).join(format!("{}.ckpt", variant.name));
        let store = ParamStore::load(&variant.params, &ckpt)?;
        let data = prepare_qa(&cfg, variant)?;
        let _ = Trainer::new(&engine, variant, word2ket::coordinator::LrSchedule::new(0.0, 0));
        println!(
            "\n=== Fig. 3 (qualitative): predictions from a {}-parameter embedding ===",
            variant.embedding.num_params
        );
        let mut rng = Rng::new(1);
        let batches = data.test.eval_batches();
        let (batch, real) = &batches[rng.below(batches.len().min(2))];
        let spans = predict_spans(&engine, variant, &store, batch)?;
        for row in 0..(*real).min(5) {
            let ex = &data.test_examples[row];
            let (s, e) = spans[row];
            let e = e.min(ex.context.len().saturating_sub(1));
            let s = s.min(e);
            println!("\nCONTEXT:  {}", detokenize(&ex.context));
            println!("QUESTION: {}", detokenize(&ex.question));
            println!("GOLD:     {}", detokenize(&ex.answers[0]));
            println!("MODEL:    {}", detokenize(&ex.context[s..=e]));
        }
    }
    Ok(())
}
