//! Offline stub of the `xla` PJRT binding.
//!
//! The container this repo builds in has no XLA runtime, so this crate
//! provides the same surface the coordinator uses, split in two tiers:
//!
//! * **Host-side data types** ([`Literal`], [`Shape`], [`ElementType`]) are
//!   fully functional — the engine's Value⇄Literal round-trips and unit tests
//!   run for real against them.
//! * **Runtime ops** (`PjRtClient::compile`, executable execution) return a
//!   clear [`Error`] instead of running: artifacts cannot execute without a
//!   real PJRT plugin. The integration tests skip themselves when
//!   `artifacts/manifest.json` is absent, so `cargo test` stays green.
//!
//! Swapping in a real binding is a one-line Cargo.toml change; the API here
//! mirrors the subset of `xla-rs` the coordinator calls.

use std::cell::RefCell;
use std::fmt;
use std::rc::Rc;

pub type Result<T> = std::result::Result<T, Error>;

/// Error type matching the real binding's surface (stringly, Display-able).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// Element types the coordinator exchanges with artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
    S64,
    U8,
    Pred,
}

/// Array shape: dims + element type.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// Shape of a literal: a dense array or a tuple of shapes.
#[derive(Debug, Clone, PartialEq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

#[derive(Debug, Clone, PartialEq)]
enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side tensor literal (row-major), the PJRT I/O currency.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

/// Rust scalar types that can cross the literal boundary.
pub trait NativeType: Sized + Copy {
    const ELEMENT_TYPE: ElementType;
    fn wrap(data: Vec<Self>) -> LiteralStorage;
    fn unwrap(data: &LiteralStorage) -> Option<&[Self]>;
}

/// Opaque storage handed between [`NativeType`] impls and [`Literal`].
pub struct LiteralStorage(LiteralData);

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;

    fn wrap(data: Vec<f32>) -> LiteralStorage {
        LiteralStorage(LiteralData::F32(data))
    }

    fn unwrap(data: &LiteralStorage) -> Option<&[f32]> {
        match &data.0 {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;

    fn wrap(data: Vec<i32>) -> LiteralStorage {
        LiteralStorage(LiteralData::I32(data))
    }

    fn unwrap(data: &LiteralStorage) -> Option<&[i32]> {
        match &data.0 {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let n = data.len() as i64;
        Literal { data: T::wrap(data.to_vec()).0, dims: vec![n] }
    }

    /// Tuple literal (artifact outputs are tuples).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { data: LiteralData::Tuple(parts), dims: vec![] }
    }

    fn len(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret under new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.data, LiteralData::Tuple(_)) {
            return Err(Error::new("reshape on tuple literal"));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error::new(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn shape(&self) -> Result<Shape> {
        match &self.data {
            LiteralData::Tuple(parts) => Ok(Shape::Tuple(
                parts.iter().map(|p| p.shape()).collect::<Result<Vec<_>>>()?,
            )),
            _ => Ok(Shape::Array(ArrayShape { dims: self.dims.clone(), ty: self.ty() })),
        }
    }

    fn ty(&self) -> ElementType {
        match &self.data {
            LiteralData::F32(_) => ElementType::F32,
            LiteralData::I32(_) => ElementType::S32,
            LiteralData::Tuple(_) => ElementType::Pred, // never queried on tuples
        }
    }

    pub fn element_type(&self) -> Result<ElementType> {
        if matches!(self.data, LiteralData::Tuple(_)) {
            return Err(Error::new("element_type on tuple literal"));
        }
        Ok(self.ty())
    }

    /// Copy elements out as a host vector; errors on dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        let storage = LiteralStorage(self.data.clone());
        T::unwrap(&storage)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error::new(format!("literal is not {:?}", T::ELEMENT_TYPE)))
    }

    /// Unpack a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            LiteralData::Tuple(parts) => Ok(parts.clone()),
            _ => Err(Error::new("literal is not a tuple")),
        }
    }
}

/// Parsed HLO module text (held verbatim; the stub cannot lower it).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("read hlo text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper around a module proto.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }

    pub fn proto(&self) -> &HloModuleProto {
        &self.proto
    }
}

/// PJRT client handle. !Send/!Sync like the real binding (Rc internals).
pub struct PjRtClient {
    _not_send: Rc<RefCell<()>>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _not_send: Rc::new(RefCell::new(())) })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(
            "xla stub: no PJRT runtime in this build — artifacts cannot be compiled \
             (swap vendor/xla for a real binding to execute HLO)",
        ))
    }
}

/// Device buffer holding a result literal.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Compiled executable. Unreachable in the stub (compile always errors), but
/// the type and its `execute` signature must exist for the engine to compile.
pub struct PjRtLoadedExecutable {
    _not_send: Rc<RefCell<()>>,
}

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new("xla stub: execute unavailable without a PJRT runtime"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        match r.shape().unwrap() {
            Shape::Array(a) => {
                assert_eq!(a.dims(), &[2, 2]);
                assert_eq!(a.element_type(), ElementType::F32);
            }
            _ => panic!("expected array shape"),
        }
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_scalar_reshape() {
        let l = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(l.element_type().unwrap(), ElementType::S32);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7]);
        assert!(Literal::vec1(&[1i32, 2]).reshape(&[3]).is_err());
    }

    #[test]
    fn tuple_unpack() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(t.element_type().is_err());
        assert!(Literal::vec1(&[1.0f32]).to_tuple().is_err());
    }

    #[test]
    fn client_exists_but_compile_gated() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.device_count(), 1);
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        assert!(c.compile(&comp).is_err());
    }
}
