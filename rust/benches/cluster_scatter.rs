//! Bench: scatter-gather cluster serving vs a single node — LOOKUP and KNN
//! throughput and tail latency at 1, 2 and 4 shards under the existing
//! Zipf load shape.
//!
//! What this quantifies: the router adds a hop (and, for KNN, a fan-out to
//! every shard plus an exact merge), while sharding divides per-node scan
//! and reconstruction work by N. Lookups are dominated by the extra hop;
//! KNN — whose per-shard brute scan is the real compute — is where the
//! cluster pays for itself. Emits `BENCH_cluster.json` so the scaling
//! trajectory accumulates across PRs.
//!
//! Run: cargo bench --bench cluster_scatter    (W2K_BENCH_FAST=1 to smoke)

use word2ket::bench::header;
use word2ket::cluster::{save_shard_snapshots, Router, RouterConfig, ShardStrategy, Topology};
use word2ket::config::ExperimentConfig;
use word2ket::coordinator::server::{self, ServerState};
use word2ket::embedding::Word2KetXS;
use word2ket::serving::BinaryClient;
use word2ket::snapshot::SaveOptions;
use word2ket::util::{Json, Rng, Summary, Timer, ZipfSampler};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

const DIM: usize = 64;
const BATCH: usize = 8;
const TOPK: u32 = 10;
const ZIPF_S: f64 = 1.05;
const THREADS: usize = 4;

struct Node {
    state: Arc<ServerState>,
    addr: String,
    accept: std::thread::JoinHandle<()>,
}

fn spawn_node(snap: &Path) -> Node {
    let mut cfg = ExperimentConfig::default();
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.serving.batch_window_us = 50;
    cfg.serving.max_batch = 256;
    cfg.snapshot.path = snap.display().to_string();
    let (state, listener, addr) = server::spawn(&cfg).expect("shard server");
    let st = state.clone();
    let accept = std::thread::spawn(move || server::accept_loop(listener, st));
    Node { state, addr, accept }
}

fn kill(node: Node) {
    node.state.shutdown();
    node.accept.join().ok();
}

/// Where a load thread sends its requests.
enum Target {
    /// Straight at one server over its own binary connection per thread.
    Direct(String),
    /// Through the scatter-gather router.
    Routed(Router),
}

/// `threads` workers × `iters` requests of one kind; returns
/// (requests/s, per-request latency summary).
fn run_load(target: &Target, vocab: usize, iters: usize, knn: bool) -> (f64, Summary) {
    let wall = Timer::start();
    let merged = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                scope.spawn(move || {
                    let zipf = ZipfSampler::new(vocab, ZIPF_S);
                    let mut rng = Rng::new(900 + t as u64);
                    let mut lat = Summary::new();
                    let mut direct = match target {
                        Target::Direct(addr) => Some(BinaryClient::connect(addr).unwrap()),
                        Target::Routed(_) => None,
                    };
                    let mut ids = vec![0u32; BATCH];
                    for _ in 0..iters {
                        if knn {
                            let q = zipf.sample(&mut rng) as u32;
                            let timer = Timer::start();
                            let n = match (&mut direct, target) {
                                (Some(c), _) => c.knn(q, TOPK).unwrap().len(),
                                (None, Target::Routed(r)) => r.knn(q, TOPK).unwrap().len(),
                                _ => unreachable!(),
                            };
                            assert!(n > 0);
                            lat.add(timer.elapsed_us());
                        } else {
                            for id in ids.iter_mut() {
                                *id = zipf.sample(&mut rng) as u32;
                            }
                            let timer = Timer::start();
                            let n = match (&mut direct, target) {
                                (Some(c), _) => c.lookup(&ids).unwrap().len(),
                                (None, Target::Routed(r)) => r.lookup(&ids).unwrap().len(),
                                _ => unreachable!(),
                            };
                            assert_eq!(n, BATCH);
                            lat.add(timer.elapsed_us());
                        }
                    }
                    if let Some(c) = direct {
                        c.quit().ok();
                    }
                    lat
                })
            })
            .collect();
        let mut merged = Summary::new();
        for h in handles {
            merged.merge(&h.join().expect("bench thread"));
        }
        merged
    });
    let reqs = (THREADS * iters) as f64;
    (reqs / wall.elapsed().as_secs_f64(), merged)
}

struct RowOut {
    name: String,
    workload: &'static str,
    shards: usize,
    rps: f64,
    p50_us: f64,
    p99_us: f64,
}

fn record(
    out: &mut Vec<RowOut>,
    name: &str,
    workload: &'static str,
    shards: usize,
    r: (f64, Summary),
) {
    let (rps, lat) = r;
    println!(
        "  {name:<24} {workload:<6} {rps:>9.0} req/s  p50 {:>6.0}µs  p99 {:>6.0}µs",
        lat.p50(),
        lat.p99()
    );
    out.push(RowOut {
        name: name.to_string(),
        workload,
        shards,
        rps,
        p50_us: lat.p50(),
        p99_us: lat.p99(),
    });
}

fn main() {
    header(
        "Cluster scatter-gather: 1/2/4 shards vs single node (Zipf load)",
        "compact tables are cheap to partition and replicate; the router \
         fans KNN to every shard and exactly merges the per-shard heaps",
    );
    let fast = std::env::var("W2K_BENCH_FAST").is_ok();
    let vocab = if fast { 4_000 } else { 20_000 };
    let (lookup_iters, knn_iters) = if fast { (100, 20) } else { (1_000, 150) };

    let mut rng = Rng::new(7);
    let store = Word2KetXS::random(vocab, DIM, 2, 2, &mut rng);
    let dir: PathBuf =
        std::env::temp_dir().join(format!("w2k_bench_cluster_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut out: Vec<RowOut> = Vec::new();

    // Baseline: one node over the full snapshot, direct connections.
    let full = dir.join("full.snap");
    word2ket::snapshot::save_store(&store, &full, &SaveOptions::default()).unwrap();
    let single = spawn_node(&full);
    let target = Target::Direct(single.addr.clone());
    println!("single node ({vocab} × {DIM}, xs 2/2):");
    record(&mut out, "single-node", "lookup", 0, run_load(&target, vocab, lookup_iters, false));
    record(&mut out, "single-node", "knn", 0, run_load(&target, vocab, knn_iters, true));
    kill(single);

    // Routed: 1 shard isolates router overhead; 2 and 4 divide the work.
    for shards in [1usize, 2, 4] {
        let placeholder = (0..shards).map(|_| vec!["127.0.0.1:0".to_string()]).collect();
        let shape = Topology::new(vocab, ShardStrategy::Range, placeholder).unwrap();
        let shard_dir = dir.join(format!("{shards}sh"));
        let saved =
            save_shard_snapshots(&store, &shape, &shard_dir, &SaveOptions::default()).unwrap();
        let nodes: Vec<Node> = saved.iter().map(|(p, _)| spawn_node(p)).collect();
        let addrs: Vec<Vec<String>> = nodes.iter().map(|n| vec![n.addr.clone()]).collect();
        let topo = shape.with_addrs(addrs).unwrap();
        let router_cfg = RouterConfig {
            probe_interval: Duration::ZERO,
            ..RouterConfig::default()
        };
        let router = Router::new(topo, router_cfg);
        let target = Target::Routed(router.clone());
        println!("router, {shards} shard(s):");
        let name = format!("router-{shards}shard");
        record(&mut out, &name, "lookup", shards, run_load(&target, vocab, lookup_iters, false));
        record(&mut out, &name, "knn", shards, run_load(&target, vocab, knn_iters, true));
        router.shutdown();
        drop(target);
        for n in nodes {
            kill(n);
        }
    }

    let json = Json::arr(out.iter().map(|r| {
        Json::obj(vec![
            ("name", Json::str(r.name.clone())),
            ("workload", Json::str(r.workload.to_string())),
            ("shards", Json::num(r.shards as f64)),
            ("rps", Json::num(r.rps)),
            ("p50_us", Json::num(r.p50_us)),
            ("p99_us", Json::num(r.p99_us)),
            ("vocab", Json::num(vocab as f64)),
            ("dim", Json::num(DIM as f64)),
            ("threads", Json::num(THREADS as f64)),
        ])
    }));
    let path = "BENCH_cluster.json";
    match std::fs::write(path, json.pretty()) {
        Ok(()) => println!("\nwrote {path} ({} configs)", out.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}
