//! Bench: snapshot persistence — save/load/mmap latency, on-disk size vs
//! the materialized f32 table, and hot-swap pause under live lookups.
//!
//! The paper's space argument becomes operational here: the order-4
//! word2ketXS configuration (118,655 × 300 in 380 parameters) snapshots to
//! a few KB against a ~142 MB materialized table, so model files ship in a
//! packet, load by mmap in microseconds, and hot-swap under traffic with
//! zero failed requests. Emits `BENCH_snapshot.json` so the trajectory
//! accumulates across PRs.
//!
//! Run: cargo bench --bench snapshot_io    (W2K_BENCH_FAST=1 to smoke)

use word2ket::bench::{black_box, header, BenchRunner};
use word2ket::config::{IndexConfig, ServingConfig};
use word2ket::embedding::{EmbeddingStore, Word2Ket, Word2KetXS};
use word2ket::serving::ServingState;
use word2ket::snapshot::{self, Codec, SaveOptions, Snapshot, SnapshotStore};
use word2ket::util::{Json, Rng, Summary, Timer};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("w2k_bench_snap_{}_{}.snap", std::process::id(), name))
}

struct Row {
    name: String,
    codec: &'static str,
    vocab: usize,
    dim: usize,
    disk_bytes: u64,
    materialized_bytes: u64,
    materialized_over_disk: f64,
    save_ms: f64,
    load_heap_ms: f64,
    mmap_open_ms: f64,
    mmap_first_lookup_us: f64,
    mmap_lookups_per_s: f64,
    hot_swap_ms: f64,
    p99_during_swap_us: f64,
}

/// One store config through the full snapshot lifecycle.
fn run_config(
    name: &str,
    store: Box<dyn EmbeddingStore>,
    codec: Codec,
    runner: &BenchRunner,
    results: &mut Vec<Row>,
) {
    let vocab = store.vocab_size();
    let dim = store.dim();
    let materialized_bytes = (vocab * dim * 4) as u64;
    let path = tmp(&name.replace([' ', '/'], "_"));

    // Save.
    let t = Timer::start();
    let opts = SaveOptions { codec, ..Default::default() };
    let info = snapshot::save_store(store.as_ref(), &path, &opts).expect("snapshot save");
    let save_ms = t.elapsed_ms();

    // Heap load (concrete store reconstruction).
    let t = Timer::start();
    let snap = Snapshot::open(&path, false).expect("snapshot open (heap)");
    let heap = snapshot::load_store(&snap).expect("snapshot load (heap)");
    let load_heap_ms = t.elapsed_ms();
    assert_eq!(heap.vocab_size(), vocab);

    // Mmap open + first lookup (cold page-in + reconstruction).
    let t = Timer::start();
    let snap = Arc::new(Snapshot::open(&path, true).expect("snapshot open (mmap)"));
    let mm = SnapshotStore::open(snap).expect("snapshot store");
    let mmap_open_ms = t.elapsed_ms();
    let t = Timer::start();
    black_box(mm.lookup(vocab / 2));
    let mmap_first_lookup_us = t.elapsed_us();

    // Steady-state mapped lookup throughput.
    let next = std::cell::Cell::new(0usize);
    let r = runner.run_throughput("mmap lookup", 1.0, || {
        let id = (next.get() * 2654435761) % vocab;
        next.set(next.get() + 1);
        black_box(mm.lookup(id))
    });
    let mmap_lookups_per_s = r.throughput().unwrap_or(0.0);

    // Hot swap under live lookups: requests hammer a ServingState while
    // the main thread swaps in the snapshot; every request must succeed.
    let scfg = ServingConfig { batch_window_us: 20, ..Default::default() };
    let icfg = IndexConfig::default();
    let st = Arc::new(ServingState::new(store, &scfg, &icfg));
    let stop = Arc::new(AtomicBool::new(false));
    let loaders: Vec<_> = (0..2usize)
        .map(|w| {
            let st = st.clone();
            let stop = stop.clone();
            std::thread::spawn(move || -> (u64, Summary) {
                let mut lat = Summary::new();
                let mut n = 0u64;
                let mut i = w * 17usize;
                while !stop.load(Ordering::SeqCst) {
                    let t = Timer::start();
                    st.lookup_rows(vec![i % vocab, (i * 7 + 1) % vocab])
                        .expect("lookup failed during hot swap");
                    lat.add(t.elapsed_us());
                    n += 1;
                    i += 1;
                }
                (n, lat)
            })
        })
        .collect();
    std::thread::sleep(std::time::Duration::from_millis(30));
    let t = Timer::start();
    let generation = st.reload_snapshot(&path).expect("hot swap");
    let hot_swap_ms = t.elapsed_ms();
    assert_eq!(generation, 2);
    std::thread::sleep(std::time::Duration::from_millis(30));
    stop.store(true, Ordering::SeqCst);
    let mut lat = Summary::new();
    let mut served = 0u64;
    for h in loaders {
        let (n, l) = h.join().expect("loader panicked: request failed during swap");
        served += n;
        lat.merge(&l);
    }
    let p99_during_swap_us = if lat.is_empty() { 0.0 } else { lat.p99() };
    st.shutdown();

    let ratio = materialized_bytes as f64 / info.bytes as f64;
    println!(
        "{name} [{}]: {} bytes on disk vs {} materialized ({ratio:.0}x), save {save_ms:.1}ms, \
         heap load {load_heap_ms:.1}ms, mmap open {mmap_open_ms:.2}ms, first lookup \
         {mmap_first_lookup_us:.0}µs, {mmap_lookups_per_s:.0} lookups/s mapped, hot swap \
         {hot_swap_ms:.1}ms over {served} live reqs (p99 {p99_during_swap_us:.0}µs)",
        codec.name(),
        info.bytes,
        materialized_bytes,
    );
    results.push(Row {
        name: name.to_string(),
        codec: codec.name(),
        vocab,
        dim,
        disk_bytes: info.bytes,
        materialized_bytes,
        materialized_over_disk: ratio,
        save_ms,
        load_heap_ms,
        mmap_open_ms,
        mmap_first_lookup_us,
        mmap_lookups_per_s,
        hot_swap_ms,
        p99_during_swap_us,
    });
    std::fs::remove_file(&path).ok();
}

fn main() {
    header(
        "snapshot: save/load/mmap + hot-swap",
        "a 380-parameter order-4 word2ketXS table stands in for a 142 MB \
         materialized matrix; snapshots make that operational (ship, mmap, \
         hot-swap)",
    );
    let fast = std::env::var("W2K_BENCH_FAST").is_ok();
    let runner = if fast {
        BenchRunner {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 50,
            budget: std::time::Duration::from_millis(300),
        }
    } else {
        BenchRunner::default()
    };
    let (xs_vocab, xs_dim) = if fast { (20_000, 256) } else { (118_655, 300) };
    let (w2k_vocab, w2k_dim) = if fast { (5_000, 256) } else { (30_428, 256) };

    let mut results: Vec<Row> = Vec::new();
    let mut rng = Rng::new(77);

    // The paper's flagship order-4 word2ketXS cell (Fig. 3 / Table 3): the
    // acceptance config for on-disk size ≥ 50× under the materialized table.
    for codec in [Codec::F32, Codec::F16, Codec::Int8] {
        let store = Box::new(Word2KetXS::random(xs_vocab, xs_dim, 4, 1, &mut rng));
        run_config("word2ketxs order-4 rank-1", store, codec, &runner, &mut results);
    }

    // Per-word word2ket order-4 (Table 1 shape): bulkier (d·r·n·q), where
    // the int8 payload pushes past the 50× line on its own.
    for codec in [Codec::F32, Codec::Int8] {
        let store = Box::new(Word2Ket::random(w2k_vocab, w2k_dim, 4, 1, &mut rng));
        run_config("word2ket order-4 rank-1", store, codec, &runner, &mut results);
    }

    let best = results
        .iter()
        .map(|r| r.materialized_over_disk)
        .fold(0.0f64, f64::max);
    println!("\nbest on-disk compression vs materialized f32 table: {best:.0}x");

    let json = Json::arr(results.iter().map(|r| {
        Json::obj(vec![
            ("name", Json::str(r.name.clone())),
            ("codec", Json::str(r.codec)),
            ("vocab", Json::num(r.vocab as f64)),
            ("dim", Json::num(r.dim as f64)),
            ("disk_bytes", Json::num(r.disk_bytes as f64)),
            ("materialized_bytes", Json::num(r.materialized_bytes as f64)),
            ("materialized_over_disk", Json::num(r.materialized_over_disk)),
            ("save_ms", Json::num(r.save_ms)),
            ("load_heap_ms", Json::num(r.load_heap_ms)),
            ("mmap_open_ms", Json::num(r.mmap_open_ms)),
            ("mmap_first_lookup_us", Json::num(r.mmap_first_lookup_us)),
            ("mmap_lookups_per_s", Json::num(r.mmap_lookups_per_s)),
            ("hot_swap_ms", Json::num(r.hot_swap_ms)),
            ("p99_during_swap_us", Json::num(r.p99_during_swap_us)),
        ])
    }));
    let path = "BENCH_snapshot.json";
    match std::fs::write(path, json.pretty()) {
        Ok(()) => println!("wrote {path} ({} configs)", results.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
