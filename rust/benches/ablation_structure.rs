//! Bench: ablations over the design choices DESIGN.md calls out.
//!
//!  A. LayerNorm at tree nodes (paper §2.3's gradient-taming trick):
//!     word2ket QA-scale training with LN on vs off — loss trajectory.
//!  B. Balanced tree vs sequential chain reconstruction: identical math
//!     (associativity), different depth — serving-side latency.
//!  C. Rank/order sweep at a fixed parameter budget: where is capacity best
//!     spent? (paper uses rank for quality, order for compression)
//!
//! Run: cargo bench --bench ablation_structure

mod common;

use word2ket::bench::{black_box, BenchRunner};
use word2ket::kron::{kron_chain, kron_tree, CpTensor};
use word2ket::util::{Rng, Table};

fn main() {
    println!("\n=== Ablations: tree structure, LayerNorm, rank vs order ===\n");

    // ---- B: balanced tree vs chain --------------------------------------
    let mut rng = Rng::new(0);
    let leaves: Vec<Vec<f32>> = (0..8).map(|_| rng.uniform_vec(4, -1.0, 1.0)).collect();
    let refs: Vec<&[f32]> = leaves.iter().map(|v| v.as_slice()).collect();
    let runner = BenchRunner::default();
    let chain = runner.run("chain reconstruct (order 8, q=4 → 65,536 dims)", || {
        black_box(kron_chain(&refs))
    });
    let tree = runner.run("balanced tree reconstruct (same tensor)", || {
        black_box(kron_tree(&refs))
    });
    println!("{}", chain.render());
    println!("{}", tree.render());
    let c = kron_chain(&refs);
    let t = kron_tree(&refs);
    let max_diff = c
        .iter()
        .zip(t.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("identical result (max diff {max_diff:.1e}) — associativity, Fig. 1\n");

    // ---- A: LayerNorm at internal nodes ----------------------------------
    // Proxy for the training-stability claim: gradient magnitude spread of
    // the reconstruction output across random inits with and without LN.
    let mut spread = |ln: bool| -> (f32, f32) {
        let mut norms = Vec::new();
        for seed in 0..200 {
            let mut r = Rng::new(seed);
            let mut t = CpTensor::random(2, 4, 4, &mut r);
            t.layernorm_nodes = ln;
            let v = t.reconstruct();
            norms.push(v.iter().map(|x| x * x).sum::<f32>().sqrt());
        }
        let mean = norms.iter().sum::<f32>() / norms.len() as f32;
        let var = norms.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / norms.len() as f32;
        (mean, var.sqrt() / mean)
    };
    let (m_off, cv_off) = spread(false);
    let (m_on, cv_on) = spread(true);
    let mut tab = Table::new(vec!["LayerNorm", "mean ‖v‖", "coeff. of variation"])
        .with_title("A. output-scale stability across inits (order-4 rank-2 w2k)");
    tab.add_row(vec!["off".to_string(), format!("{m_off:.3}"), format!("{cv_off:.3}")]);
    tab.add_row(vec!["on (paper §2.3)".to_string(), format!("{m_on:.3}"), format!("{cv_on:.3}")]);
    println!("{}", tab.render());
    println!(
        "LN normalizes node scale: CV {} (paper's motivation: bounded gradient Lipschitz)\n",
        if cv_on < cv_off { "reduced ✓" } else { "not reduced (unexpected)" }
    );

    // ---- C: rank vs order at fixed budget --------------------------------
    // p = 256: (order 2, q 16), (order 4, q 4), (order 8, q 2 — paper says
    // q≥4 sensible; include to show why). Budget ≈ 128 f32 per word.
    println!("C. rank/order tradeoff at ~fixed per-word budget (p = 256):");
    let mut tab = Table::new(vec![
        "order n", "q", "rank r", "params r·n·q", "expressible rank bound",
    ]);
    for (n, q, r) in [(2usize, 16usize, 4usize), (4, 4, 8), (8, 2, 8)] {
        tab.add_row(vec![
            n.to_string(),
            q.to_string(),
            r.to_string(),
            (r * n * q).to_string(),
            if q >= 4 { "full (q≥4)".to_string() } else { "degenerate q=2 (§2.3)".to_string() },
        ]);
    }
    println!("{}", tab.render());
    println!(
        "paper §2.3: q≥4 because a q=2 pair consumes the same space as the 4-dim \
         vector it spans without covering it (rank-1 manifold only)."
    );

    // Reconstruction cost scaling with rank (O(r·p·n) claim).
    println!("\nreconstruction cost vs rank (O(r·p·n), p=256, n=4):");
    for r in [1usize, 2, 4, 8] {
        let mut rngr = Rng::new(7);
        let t = CpTensor::random(r, 4, 4, &mut rngr);
        let res = runner.run(&format!("reconstruct rank {r}"), || black_box(t.reconstruct()));
        println!("{}", res.render());
    }
}
