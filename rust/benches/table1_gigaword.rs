//! Bench: paper Table 1 — GIGAWORD summarization across embedding variants.
//!
//! Reproduces the table's *shape* on the synthetic GIGAWORD-like corpus:
//! Regular ≥ word2ketXS 2/10 > word2ketXS 4/1 ≈ word2ket 4/1 on ROUGE, with
//! the published parameter counts reproduced exactly at paper scale by
//! `stats.rs` (see the space_saving bench). Absolute Rouge values differ —
//! our substrate is a synthetic corpus on CPU (DESIGN.md §2).
//!
//! Run: cargo bench --bench table1_gigaword    (W2K_BENCH_FAST=1 to smoke)

mod common;

use word2ket::config::{EmbeddingKind, TaskKind};
use word2ket::util::{fmt_count, Table};

fn main() {
    let steps = common::steps(900);
    println!("\n=== Table 1: GIGAWORD summarization ({} steps/variant) ===", steps);
    println!("paper: RG-1/RG-2/RG-L = 35.80/16.40/32.47 (regular 256) vs 35.19/16.21/31.76 (XS 2/10) vs 34.05/15.39/30.75 (XS 4/1) vs 33.65/14.87/30.47 (w2k 4/1)\n");

    let (engine, manifest) = common::open_runtime();
    let cells = [
        ("Regular", EmbeddingKind::Regular, 1, 1, "35.80/16.40/32.47"),
        ("word2ket", EmbeddingKind::Word2Ket, 4, 1, "33.65/14.87/30.47"),
        ("word2ketXS", EmbeddingKind::Word2KetXS, 2, 10, "35.19/16.21/31.76"),
        ("word2ketXS", EmbeddingKind::Word2KetXS, 4, 1, "34.05/15.39/30.75"),
    ];

    let mut t = Table::new(vec![
        "Embedding", "Order/Rank", "RG-1", "RG-2", "RG-L", "Emb #Params", "Saving",
        "Paper RG-1/2/L",
    ])
    .with_title("Table 1 (measured on synthetic GIGAWORD substrate)");
    let mut results = Vec::new();
    for (label, kind, order, rank, paper) in cells {
        let cfg = common::cell_config(TaskKind::Summarization, kind, order, rank, steps);
        eprintln!("[table1] training {label} {order}/{rank} ...");
        let r = common::run_cell(&engine, &manifest, &cfg);
        t.add_row(vec![
            label.to_string(),
            format!("{order}/{rank}"),
            format!("{:.2}", common::metric(&r, "RG-1")),
            format!("{:.2}", common::metric(&r, "RG-2")),
            format!("{:.2}", common::metric(&r, "RG-L")),
            fmt_count(r.emb_params as u64),
            format!("{:.0}×", r.space_saving),
            paper.to_string(),
        ]);
        results.push((label, order, rank, r));
    }
    println!("{}", t.render());

    // Shape assertions (soft — print verdicts rather than panicking, since
    // short runs are noisy; the full run upholds them).
    let rgl = |i: usize| common::metric(&results[i].3, "RG-L");
    println!("\nshape checks (paper ordering):");
    println!(
        "  regular ({:.1}) >= XS 2/10 ({:.1}) - 5   → {}",
        rgl(0), rgl(2),
        if rgl(0) + 5.0 >= rgl(2) { "OK" } else { "VIOLATED" }
    );
    println!(
        "  XS 2/10 ({:.1}) >= XS 4/1 ({:.1}) - 5    → {}",
        rgl(2), rgl(3),
        if rgl(2) + 5.0 >= rgl(3) { "OK" } else { "VIOLATED" }
    );
    println!(
        "  all compressed variants train (loss falls): {}",
        results
            .iter()
            .all(|(_, _, _, r)| r.losses.last().unwrap_or(&f32::MAX) < r.losses.first().unwrap_or(&0.0))
    );
    println!("\nstep-time overhead vs regular:");
    let base = results[0].3.step_time_mean_ms;
    for (label, order, rank, r) in &results {
        println!(
            "  {label} {order}/{rank}: {:.1}ms = {:.2}× regular",
            r.step_time_mean_ms,
            r.step_time_mean_ms / base
        );
    }
}
