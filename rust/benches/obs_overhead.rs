//! Bench: observability overhead — the same batched LOOKUP load against a
//! server with the metrics plane enabled (the default), one started with
//! `[obs] enable = false`, and one with the metrics plane *plus* the
//! distributed tracer head-sampling 1% of requests, for each net driver.
//!
//! What this quantifies: the per-request cost of the `obs/` plane — one
//! `Instant` read per stage boundary, one relaxed atomic increment per
//! log₂-bucket histogram sample, and the slow-query ring check — and, in
//! the traced column, the sampling branch plus the span allocations for
//! the sampled 1%. The acceptance bar is that every enabled column stays
//! within 5% of the disabled baseline on the batched lookup path; rows
//! land in `BENCH_obs.json` with the measured overhead so regressions are
//! visible in version control, not just in a terminal scrollback.
//!
//! The enabled server is also scraped once over the wire (`OP_METRICS`)
//! after the load run, so the bench doubles as an end-to-end check that the
//! exposition renders under concurrent traffic.
//!
//! Run: cargo bench --bench obs_overhead    (W2K_BENCH_FAST=1 to smoke)

use word2ket::bench::header;
use word2ket::config::{EmbeddingKind, ExperimentConfig, NetDriver};
use word2ket::coordinator::server::{self, ServerState};
use word2ket::serving::BinaryClient;
use word2ket::util::{Json, Rng, Summary, Timer};
use std::sync::Arc;

const DIM: usize = 32;
const BATCH: usize = 16;
const ACTIVE: usize = 4;

struct Server {
    state: Arc<ServerState>,
    addr: String,
    accept: std::thread::JoinHandle<()>,
}

fn spawn_server(driver: NetDriver, obs_enabled: bool, trace_sample: f64, vocab: usize) -> Server {
    let mut cfg = ExperimentConfig::default();
    cfg.embedding.kind = EmbeddingKind::Word2KetXS;
    cfg.embedding.order = 2;
    cfg.embedding.rank = 2;
    cfg.model.vocab = vocab;
    cfg.model.emb_dim = DIM;
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.serving.batch_window_us = 50;
    cfg.net.driver = driver;
    cfg.obs.enable = obs_enabled;
    cfg.obs.trace_sample = trace_sample;
    let (state, listener, addr) = server::spawn(&cfg).expect("bench server");
    let st = state.clone();
    let accept = std::thread::spawn(move || server::accept_loop(listener, st));
    Server { state, addr, accept }
}

/// `ACTIVE` workers × `iters` batched lookups each; returns
/// (requests/s, per-request latency summary).
fn run_load(addr: &str, vocab: usize, iters: usize) -> (f64, Summary) {
    let wall = Timer::start();
    let merged = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..ACTIVE)
            .map(|t| {
                scope.spawn(move || {
                    let mut rng = Rng::new(7200 + t as u64);
                    let mut client = BinaryClient::connect(addr).expect("load conn");
                    let mut lat = Summary::new();
                    let mut ids = vec![0u32; BATCH];
                    for _ in 0..iters {
                        for id in ids.iter_mut() {
                            *id = (rng.next_u64() % vocab as u64) as u32;
                        }
                        let timer = Timer::start();
                        let rows = client.lookup(&ids).expect("lookup");
                        assert_eq!(rows.len(), BATCH);
                        lat.add(timer.elapsed_us());
                    }
                    client.quit().ok();
                    lat
                })
            })
            .collect();
        let mut merged = Summary::new();
        for h in handles {
            merged.merge(&h.join().expect("load worker"));
        }
        merged
    });
    let reqs = (ACTIVE * iters) as f64;
    (reqs / wall.elapsed().as_secs_f64(), merged)
}

/// One bench column: the metrics plane on/off, optionally with the
/// distributed tracer head-sampling a fraction of requests.
struct BenchMode {
    label: &'static str,
    obs_enabled: bool,
    trace_sample: f64,
}

const MODES: [BenchMode; 3] = [
    BenchMode { label: "off", obs_enabled: false, trace_sample: 0.0 },
    BenchMode { label: "on", obs_enabled: true, trace_sample: 0.0 },
    BenchMode { label: "on+trace1%", obs_enabled: true, trace_sample: 0.01 },
];

struct RowOut {
    driver: NetDriver,
    obs: &'static str,
    trace_sample: f64,
    rps: f64,
    p50_us: f64,
    p99_us: f64,
    overhead_pct: f64,
    metrics_lines: usize,
}

fn main() {
    header(
        "Observability overhead: metrics plane on vs off, per net driver",
        "per-stage timing is one Instant read per boundary and one relaxed \
         atomic per histogram sample — cheap enough to leave on in \
         production, and this bench is the receipt",
    );
    let fast = std::env::var("W2K_BENCH_FAST").is_ok();
    let vocab = if fast { 2_000 } else { 10_000 };
    let iters = if fast { 200 } else { 5_000 };

    let mut out: Vec<RowOut> = Vec::new();
    for driver in [NetDriver::Threads, NetDriver::Epoll] {
        println!("driver = {driver}:");
        let mut baseline_rps = 0.0;
        for mode in &MODES {
            let server = spawn_server(driver, mode.obs_enabled, mode.trace_sample, vocab);
            // Warm the cache and the batching path before timing.
            run_load(&server.addr, vocab, iters / 10 + 1);
            let (rps, lat) = run_load(&server.addr, vocab, iters);
            let overhead_pct = if mode.obs_enabled && baseline_rps > 0.0 {
                (baseline_rps - rps) / baseline_rps * 100.0
            } else {
                baseline_rps = rps;
                0.0
            };
            let metrics_lines = if mode.obs_enabled {
                let mut client = BinaryClient::connect(&server.addr).expect("scrape conn");
                let text = client.metrics().expect("METRICS over wire");
                assert!(text.contains("w2k_served_total"), "exposition missing counters");
                assert!(
                    text.contains("w2k_stage_us_count{stage=\"kernel\"}"),
                    "exposition missing stage histograms"
                );
                if mode.trace_sample > 0.0 {
                    // Deterministic counter sampling starts at request 0,
                    // so at least one span tree always lands in the ring.
                    let ring = client.trace_slow().expect("TRACE?slow over wire");
                    assert!(ring.contains("w2k_trace_span"), "tracer sampled nothing");
                    assert!(ring.ends_with("# EOF\n"), "trace ring not EOF-terminated");
                }
                client.quit().ok();
                text.lines().count()
            } else {
                0
            };
            println!(
                "  obs {:<11}  {rps:>9.0} req/s  p50 {:>6.0}µs  p99 {:>6.0}µs{}",
                mode.label,
                lat.p50(),
                lat.p99(),
                if mode.obs_enabled {
                    format!("  overhead {overhead_pct:+.1}%  ({metrics_lines} exposition lines)")
                } else {
                    String::new()
                }
            );
            out.push(RowOut {
                driver,
                obs: mode.label,
                trace_sample: mode.trace_sample,
                rps,
                p50_us: lat.p50(),
                p99_us: lat.p99(),
                overhead_pct,
                metrics_lines,
            });
            server.state.shutdown();
            server.accept.join().ok();
        }
    }

    let worst = out
        .iter()
        .filter(|r| r.obs != "off")
        .map(|r| r.overhead_pct)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nworst-case overhead {worst:+.1}% — {}",
        if worst <= 5.0 {
            "within the 5% budget"
        } else {
            "OVER the 5% budget (loopback noise? rerun without W2K_BENCH_FAST)"
        }
    );

    let doc = Json::arr(out.iter().map(|r| {
        Json::obj(vec![
            ("bench", Json::str("obs_overhead".to_string())),
            ("driver", Json::str(r.driver.as_str().to_string())),
            ("obs", Json::str(r.obs.to_string())),
            ("trace_sample", Json::num(r.trace_sample)),
            ("rps", Json::num(r.rps)),
            ("p50_us", Json::num(r.p50_us)),
            ("p99_us", Json::num(r.p99_us)),
            ("overhead_pct", Json::num(r.overhead_pct)),
            ("metrics_lines", Json::num(r.metrics_lines as f64)),
            ("active", Json::num(ACTIVE as f64)),
            ("batch", Json::num(BATCH as f64)),
            ("vocab", Json::num(vocab as f64)),
            ("dim", Json::num(DIM as f64)),
        ])
    }));
    match std::fs::write("BENCH_obs.json", doc.pretty() + "\n") {
        Ok(()) => println!("wrote BENCH_obs.json ({} rows)", out.len()),
        Err(e) => eprintln!("could not write BENCH_obs.json: {e}"),
    }
}
