//! Bench: allocation-free batched lookups vs the per-row `Vec` path,
//! swept across SIMD dispatch levels.
//!
//! The repr-layer refactor made `EmbeddingStore::lookup_into` /
//! `lookup_batch_into` write caller-provided buffers end to end (per-thread
//! reconstruction scratch, dedup-scatter into a reused arena, cache rows
//! filled in place). This bench quantifies what that buys over the
//! historical per-row path (`lookup` allocating a fresh `Vec<f32>` per id)
//! on the acceptance config — a 10k-vocab order-4 word2ketXS store — plus
//! the order-2 heavy-rank cell and a cache-wrapped variant. Every
//! store-level cell now also runs once per available kernel set
//! (`scalar` → `sse2` → `avx2+fma`), so the scalar-vs-vectorized ratio for
//! the factored reconstruction kernels lands in `BENCH_batch.json` and the
//! perf trajectory accumulates across PRs.
//!
//! Run: cargo bench --bench batch_lookup    (W2K_BENCH_FAST=1 to smoke)

use word2ket::bench::{black_box, header, BenchRunner};
use word2ket::embedding::{EmbeddingStore, Word2Ket, Word2KetXS};
use word2ket::serving::ShardedCache;
use word2ket::simd;
use word2ket::snapshot::{save_store, Codec, SaveOptions, Snapshot, SnapshotStore};
use word2ket::util::{Json, Rng};
use std::sync::Arc;

const VOCAB: usize = 10_000;
const DIM: usize = 256;
const BATCH: usize = 512;

struct Row {
    name: String,
    lookups_per_s: f64,
    p50_us: f64,
    p99_us: f64,
    order: usize,
    rank: usize,
    batched: bool,
    cached: bool,
    simd: &'static str,
}

fn xs_store(order: usize, rank: usize) -> Word2KetXS {
    let mut rng = Rng::new(11);
    Word2KetXS::random(VOCAB, DIM, order, rank, &mut rng)
}

/// Distinct uniform ids per batch (partial Fisher–Yates, no Zipf skew, no
/// repeats): dedup finds zero duplicates, so the batched-vs-per-row
/// comparison isolates allocation + scratch reuse — not dedup or caching.
fn batches(n: usize) -> Vec<Vec<usize>> {
    let mut rng = Rng::new(42);
    let mut ids: Vec<usize> = (0..VOCAB).collect();
    (0..n)
        .map(|_| {
            for i in 0..BATCH {
                let j = rng.range(i, VOCAB - 1);
                ids.swap(i, j);
            }
            ids[..BATCH].to_vec()
        })
        .collect()
}

fn main() {
    header(
        "Batched lookup_into vs per-row Vec reconstruction, per kernel set",
        "the repr layer writes rows into caller buffers (per-thread scratch, \
         reused arenas) through runtime-dispatched kernels; each cell runs \
         under every kernel set the host supports",
    );
    let fast = std::env::var("W2K_BENCH_FAST").is_ok();
    let runner = if fast {
        BenchRunner {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            budget: std::time::Duration::from_millis(300),
        }
    } else {
        BenchRunner::default()
    };
    let workload = batches(if fast { 8 } else { 64 });
    let mut results: Vec<Row> = Vec::new();
    let record = |name: &str,
                      r: &word2ket::bench::BenchResult,
                      order: usize,
                      rank: usize,
                      batched: bool,
                      cached: bool,
                      simd: &'static str,
                      results: &mut Vec<Row>| {
        println!("{}", r.render());
        results.push(Row {
            name: name.to_string(),
            lookups_per_s: r.throughput().unwrap_or(0.0),
            p50_us: r.p50.as_secs_f64() * 1e6,
            p99_us: r.p99.as_secs_f64() * 1e6,
            order,
            rank,
            batched,
            cached,
            simd,
        });
    };

    // The acceptance config (order 4) first, then the rank-heavy order-2
    // cell from the paper's tables — each swept across every kernel set
    // the host supports, scalar first so the vectorized speedup prints
    // against a fresh baseline.
    let levels = simd::available_levels();
    for (order, rank) in [(4usize, 2usize), (2, 10)] {
        let store = xs_store(order, rank);
        let mut scalar_batched_mean = 0.0f64;
        for &lvl in &levels {
            simd::set_level(lvl);
            let simd_name = lvl.name();
            let mut next = 0usize;

            let name = format!("xs {order}/{rank} per-row Vec [{simd_name}] ({BATCH} rows)");
            let per_row = runner.run_throughput(&name, BATCH as f64, || {
                let ids = &workload[next % workload.len()];
                next += 1;
                for &id in ids {
                    black_box(store.lookup(id));
                }
            });
            record(&name, &per_row, order, rank, false, false, simd_name, &mut results);

            let mut arena: Vec<f32> = Vec::new();
            let mut next = 0usize;
            let name = format!("xs {order}/{rank} batched arena [{simd_name}] ({BATCH} rows)");
            let batched = runner.run_throughput(&name, BATCH as f64, || {
                let ids = &workload[next % workload.len()];
                next += 1;
                store.lookup_batch_into(ids, &mut arena);
                black_box(arena.last().copied())
            });
            record(&name, &batched, order, rank, true, false, simd_name, &mut results);

            let speedup = per_row.mean.as_secs_f64() / batched.mean.as_secs_f64();
            println!("  -> batched/per-row speedup {speedup:.2}×");
            let batched_mean = batched.mean.as_secs_f64();
            if lvl == simd::SimdLevel::Scalar {
                scalar_batched_mean = batched_mean;
            } else if scalar_batched_mean > 0.0 {
                let vs_scalar = scalar_batched_mean / batched_mean;
                println!("  -> batched {simd_name}/scalar speedup {vs_scalar:.2}×");
            }
            println!();
        }
    }

    // Cache-wrapped order-4 store at the host's best kernel set: misses
    // reconstruct in place, hits are single memcpys into the arena (the
    // kernel set only matters on the miss path, so one cell suffices).
    let best = simd::set_level(simd::detect());
    let cached = ShardedCache::new(Box::new(xs_store(4, 2)), 4, VOCAB);
    let mut arena: Vec<f32> = Vec::new();
    for ids in &workload {
        cached.lookup_batch_into(ids, &mut arena); // warm
    }
    let mut next = 0usize;
    let name = format!("xs 4/2 cached batched arena [{}] ({BATCH} rows)", best.name());
    let warm = runner.run_throughput(&name, BATCH as f64, || {
        let ids = &workload[next % workload.len()];
        next += 1;
        cached.lookup_batch_into(ids, &mut arena);
        black_box(arena.last().copied())
    });
    record(&name, &warm, 4, 2, true, true, best.name(), &mut results);

    // Snapshot-store lookups per payload codec, at the host's best kernel
    // set: the same word2ket table saved at every codec and served back off
    // its snapshot. Rows are exact for every codec (f16/int8 dequantize at
    // open; the sub-byte codecs serve f16-refined quantized-ket rows — see
    // `word2ket::quant`), so this cell prices what *serving* compressed
    // payloads costs; cold-start load time lands in BENCH_index.json.
    let mut codec_rows: Vec<Json> = Vec::new();
    {
        let mut rng = Rng::new(7);
        let w2k = Word2Ket::random(VOCAB, DIM, 2, 1, &mut rng);
        let dir = std::env::temp_dir().join(format!("w2k_bench_blookup_{}", std::process::id()));
        std::fs::create_dir_all(&dir).ok();
        println!();
        for codec in [Codec::F32, Codec::F16, Codec::Int8, Codec::Int4, Codec::B2, Codec::B1] {
            let path = dir.join(format!("codec_{}.snap", codec.name()));
            save_store(&w2k, &path, &SaveOptions { codec, ..Default::default() })
                .expect("save snapshot");
            let snap = Arc::new(Snapshot::open(&path, true).expect("open snapshot"));
            let store = SnapshotStore::open(snap).expect("load snapshot store");
            let mut arena: Vec<f32> = Vec::new();
            let mut next = 0usize;
            let name = format!("snapshot w2k 2/1 {} batched ({BATCH} rows)", codec.name());
            let r = runner.run_throughput(&name, BATCH as f64, || {
                let ids = &workload[next % workload.len()];
                next += 1;
                store.lookup_batch_into(ids, &mut arena);
                black_box(arena.last().copied())
            });
            println!("{}", r.render());
            codec_rows.push(Json::obj(vec![
                ("name", Json::str(name)),
                ("lookups_per_s", Json::num(r.throughput().unwrap_or(0.0))),
                ("p50_us", Json::num(r.p50.as_secs_f64() * 1e6)),
                ("p99_us", Json::num(r.p99.as_secs_f64() * 1e6)),
                ("codec", Json::str(codec.name())),
                ("payload_bits", Json::num(codec.bits() as f64)),
                ("batched", Json::num(1.0)),
                ("cached", Json::num(0.0)),
                ("simd", Json::str(best.name())),
            ]));
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    let mut items: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::str(r.name.clone())),
                ("lookups_per_s", Json::num(r.lookups_per_s)),
                ("p50_us", Json::num(r.p50_us)),
                ("p99_us", Json::num(r.p99_us)),
                ("order", Json::num(r.order as f64)),
                ("rank", Json::num(r.rank as f64)),
                ("batched", Json::num(if r.batched { 1.0 } else { 0.0 })),
                ("cached", Json::num(if r.cached { 1.0 } else { 0.0 })),
                ("simd", Json::str(r.simd.to_string())),
            ])
        })
        .collect();
    let n_rows = items.len() + codec_rows.len();
    items.extend(codec_rows);
    let json = Json::arr(items);
    let path = "BENCH_batch.json";
    match std::fs::write(path, json.pretty()) {
        Ok(()) => println!("\nwrote {path} ({n_rows} configs)"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
