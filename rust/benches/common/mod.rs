//! Shared helpers for the table/figure benches.
//!
//! `cargo bench` compiles each bench as `harness = false`; they use the
//! crate's own bench substrate (word2ket::bench) and this module for the
//! experiment plumbing shared across tables.

use word2ket::config::{EmbeddingKind, ExperimentConfig, TaskKind};
use word2ket::coordinator::experiment::{resolve_variant, run_with, Report};
use word2ket::runtime::{Engine, Manifest, ParamStore};
use std::path::Path;

/// Steps scale: W2K_BENCH_FAST=1 cuts training to smoke-test length.
pub fn steps(full: usize) -> usize {
    if std::env::var("W2K_BENCH_FAST").is_ok() {
        (full / 20).max(4)
    } else {
        full
    }
}

/// Build a config for a (task, embedding) cell of a paper table.
pub fn cell_config(
    task: TaskKind,
    kind: EmbeddingKind,
    order: usize,
    rank: usize,
    train_steps: usize,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = format!("{}-{}-o{}r{}", task.tag(), kind.name(), order, rank);
    cfg.task = task;
    cfg.embedding.kind = kind;
    cfg.embedding.order = order;
    cfg.embedding.rank = rank;
    cfg.train.steps = train_steps;
    cfg.train.eval_every = 0; // benches only need the final metric
    cfg.train.warmup = 0;
    cfg.train.lr = 5e-3;
    cfg.corpus.train = 2000;
    cfg.corpus.valid = 100;
    cfg.corpus.test = 100;
    cfg
}

/// Run one experiment cell, reusing a shared Engine.
pub fn run_cell(engine: &Engine, manifest: &Manifest, cfg: &ExperimentConfig) -> Report {
    let variant = resolve_variant(cfg, manifest).expect("variant in manifest");
    let mut store = ParamStore::init(&variant.params, cfg.train.seed);
    run_with(cfg, engine, variant, &mut store, false).expect("experiment")
}

/// Open engine + manifest at the default artifacts dir.
pub fn open_runtime() -> (Engine, Manifest) {
    let dir = Path::new("artifacts");
    let engine = Engine::cpu(dir).expect("PJRT engine (run `make artifacts` first)");
    let manifest = Manifest::load(dir).expect("manifest.json (run `make artifacts`)");
    (engine, manifest)
}

/// Pull a named metric out of a report.
pub fn metric(report: &Report, name: &str) -> f64 {
    report
        .final_metrics
        .iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| *v)
        .unwrap_or(0.0)
}
