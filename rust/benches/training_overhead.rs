//! Bench: the paper's in-text §4 training-time claim — DrQA training went
//! from 5.8 h (regular) to 7.4 h (XS order 2, ×1.28) to 9.0 h (XS order 4,
//! ×1.55) on a V100. We measure per-step wall time of the same three QA
//! variants through the full AOT stack and compare the *ratios*.
//!
//! Run: cargo bench --bench training_overhead

mod common;

use word2ket::config::{EmbeddingKind, TaskKind};
use word2ket::util::Table;

fn main() {
    let steps = common::steps(60);
    println!("\n=== Training-time overhead (paper §4 in-text claim) ===");
    println!("paper: 5.8h regular → 7.4h XS order-2 (1.28×) → 9.0h XS order-4 (1.55×)\n");

    let (engine, manifest) = common::open_runtime();
    let variants = [
        ("Regular", EmbeddingKind::Regular, 1, 1, 1.00),
        ("word2ketXS order 2", EmbeddingKind::Word2KetXS, 2, 2, 1.28),
        ("word2ketXS order 4", EmbeddingKind::Word2KetXS, 4, 1, 1.55),
    ];

    let mut rows = Vec::new();
    for (label, kind, order, rank, paper_ratio) in variants {
        let mut cfg = common::cell_config(TaskKind::Qa, kind, order, rank, steps);
        cfg.train.eval_every = 0;
        eprintln!("[overhead] timing {label} ({steps} steps) ...");
        let r = common::run_cell(&engine, &manifest, &cfg);
        rows.push((label, r.step_time_mean_ms, r.step_time_p99_ms, paper_ratio));
    }

    let base = rows[0].1;
    let mut t = Table::new(vec![
        "Variant", "step mean", "step p99", "ratio (ours)", "ratio (paper)",
    ])
    .with_title("per-step wall time, QA train_step through PJRT");
    for (label, mean, p99, paper) in &rows {
        t.add_row(vec![
            label.to_string(),
            format!("{mean:.1}ms"),
            format!("{p99:.1}ms"),
            format!("{:.2}×", mean / base),
            format!("{paper:.2}×"),
        ]);
    }
    println!("{}", t.render());

    println!("\nshape check: overhead grows with order (ours {:.2}× ≤ {:.2}×? {})",
        rows[1].1 / base,
        rows[2].1 / base,
        if rows[1].1 <= rows[2].1 * 1.15 { "OK" } else { "MIXED" });
    println!("note: XLA:CPU fuses the reconstruction almost entirely; on the paper's \
              GPU the gather+product chain dominates, hence larger ratios.");
}
