//! Bench: serving-path hot-row cache — cold reconstruction vs cached vs
//! sharded-concurrent throughput on a Zipf-distributed id stream, the load
//! shape production token traffic actually has.
//!
//! The paper's word2ketXS table is tiny but must be reconstructed per
//! lookup; the serving layer's claim is that a sharded LRU-with-admission
//! cache turns the Zipf head into memcpys. This bench quantifies that and
//! emits `BENCH_serving.json` (throughput + p50/p99 per config) so the perf
//! trajectory accumulates across PRs.
//!
//! Run: cargo bench --bench serving_cache    (W2K_BENCH_FAST=1 to smoke)

use word2ket::bench::{black_box, header, BenchRunner};
use word2ket::embedding::{EmbeddingStore, Word2KetXS};
use word2ket::serving::ShardedCache;
use word2ket::util::{Json, Rng, Summary, Timer, ZipfSampler};
use std::sync::Arc;

const VOCAB: usize = 100_000;
const DIM: usize = 256;
const BATCH: usize = 512;
const ZIPF_S: f64 = 1.05;
const CACHE_ROWS: usize = 65_536;

/// Pregenerated Zipf batches, cycled so successive iterations differ.
struct Workload {
    batches: Vec<Vec<usize>>,
    next: std::cell::Cell<usize>,
}

impl Workload {
    fn new(n_batches: usize) -> Workload {
        let zipf = ZipfSampler::new(VOCAB, ZIPF_S);
        let mut rng = Rng::new(42);
        let batches = (0..n_batches)
            .map(|_| (0..BATCH).map(|_| zipf.sample(&mut rng)).collect())
            .collect();
        Workload { batches, next: std::cell::Cell::new(0) }
    }

    fn next_batch(&self) -> &[usize] {
        let i = self.next.get();
        self.next.set((i + 1) % self.batches.len());
        &self.batches[i]
    }
}

fn xs_store(order: usize, rank: usize) -> Word2KetXS {
    // Same seed everywhere: cached and uncached stores hold identical factors.
    let mut rng = Rng::new(7);
    Word2KetXS::random(VOCAB, DIM, order, rank, &mut rng)
}

struct Row {
    name: String,
    rows_per_s: f64,
    p50_us: f64,
    p99_us: f64,
    config: Vec<(&'static str, f64)>,
}

fn record(results: &mut Vec<Row>, name: &str, r: &word2ket::bench::BenchResult, cfg: Vec<(&'static str, f64)>) {
    results.push(Row {
        name: name.to_string(),
        rows_per_s: r.throughput().unwrap_or(0.0),
        p50_us: r.p50.as_secs_f64() * 1e6,
        p99_us: r.p99.as_secs_f64() * 1e6,
        config: cfg,
    });
}

/// Multi-threaded hammer: `threads` workers each push `iters` batches
/// through the store; returns (rows/s, per-batch latency summary).
fn concurrent_rows_per_s(store: Arc<dyn EmbeddingStore>, threads: usize, iters: usize) -> (f64, Summary) {
    let wall = Timer::start();
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let store = store.clone();
            std::thread::spawn(move || {
                let zipf = ZipfSampler::new(VOCAB, ZIPF_S);
                let mut rng = Rng::new(1000 + t as u64);
                let mut lat = Summary::new();
                let mut ids = vec![0usize; BATCH];
                for _ in 0..iters {
                    for id in ids.iter_mut() {
                        *id = zipf.sample(&mut rng);
                    }
                    let t = Timer::start();
                    black_box(store.lookup_batch(&ids));
                    lat.add(t.elapsed_us());
                }
                lat
            })
        })
        .collect();
    let mut merged = Summary::new();
    for h in handles {
        merged.merge(&h.join().expect("bench thread"));
    }
    let rows = (threads * iters * BATCH) as f64;
    (rows / wall.elapsed().as_secs_f64(), merged)
}

fn main() {
    header(
        "Serving cache: cold vs cached vs sharded (Zipf load)",
        "XS rows are reconstructed per lookup (§3.2); a sharded hot-row cache \
         with frequency admission turns the Zipf head into memcpys",
    );
    let fast = std::env::var("W2K_BENCH_FAST").is_ok();
    let runner = if fast {
        BenchRunner { warmup_iters: 1, min_iters: 3, max_iters: 20, budget: std::time::Duration::from_millis(300) }
    } else {
        BenchRunner::default()
    };
    let workload = Workload::new(if fast { 16 } else { 256 });
    let mut results: Vec<Row> = Vec::new();

    // The heavy paper cell (XS 2/10: rank-10 fused reconstruction) is the
    // headline comparison; XS 4/1 shows the cheap-reconstruction end.
    for (order, rank) in [(2usize, 10usize), (4, 1)] {
        let tag = format!("xs {order}/{rank}");
        let uncached = xs_store(order, rank);
        let bare = runner.run_throughput(
            &format!("{tag} uncached reconstruct ({BATCH} Zipf rows)"),
            BATCH as f64,
            || black_box(uncached.lookup_batch(workload.next_batch())),
        );
        println!("{}", bare.render());
        record(&mut results, &format!("{tag} uncached"), &bare, vec![
            ("order", order as f64),
            ("rank", rank as f64),
            ("shards", 0.0),
            ("cache_rows", 0.0),
        ]);

        for shards in [1usize, 8] {
            let cached = ShardedCache::new(Box::new(xs_store(order, rank)), shards, CACHE_ROWS);
            // Warm the cache with one pass over the workload's head.
            for _ in 0..workload.batches.len().min(64) {
                black_box(cached.lookup_batch(workload.next_batch()));
            }
            let warm = runner.run_throughput(
                &format!("{tag} cached {shards}-shard ({BATCH} Zipf rows)"),
                BATCH as f64,
                || black_box(cached.lookup_batch(workload.next_batch())),
            );
            println!("{}", warm.render());
            let stats = cached.stats();
            record(&mut results, &format!("{tag} cached {shards}sh"), &warm, vec![
                ("order", order as f64),
                ("rank", rank as f64),
                ("shards", shards as f64),
                ("cache_rows", CACHE_ROWS as f64),
                ("hit_rate", stats.hit_rate()),
            ]);
            if shards == 1 {
                let speedup = bare.mean.as_secs_f64() / warm.mean.as_secs_f64();
                println!(
                    "  -> cached/uncached speedup {speedup:.1}× (hit rate {:.1}%)",
                    100.0 * stats.hit_rate()
                );
            }
        }
        println!();
    }

    // Sharding under concurrency: 8 threads hammering one cache; 1 shard
    // serializes on a single mutex, 8 shards mostly don't collide.
    println!("concurrent load (8 threads × {BATCH}-row Zipf batches):");
    let iters = if fast { 8 } else { 64 };
    for shards in [1usize, 8] {
        let cached: Arc<dyn EmbeddingStore> =
            Arc::new(ShardedCache::new(Box::new(xs_store(2, 10)), shards, CACHE_ROWS));
        // Warm.
        for _ in 0..8 {
            black_box(cached.lookup_batch(workload.next_batch()));
        }
        let (rows_per_s, lat) = concurrent_rows_per_s(cached, 8, iters);
        println!(
            "  {shards}-shard: {rows_per_s:>12.0} rows/s  p50 {:.0}µs p99 {:.0}µs",
            lat.p50(),
            lat.p99()
        );
        results.push(Row {
            name: format!("xs 2/10 concurrent {shards}sh"),
            rows_per_s,
            p50_us: lat.p50(),
            p99_us: lat.p99(),
            config: vec![
                ("order", 2.0),
                ("rank", 10.0),
                ("shards", shards as f64),
                ("cache_rows", CACHE_ROWS as f64),
                ("threads", 8.0),
            ],
        });
    }

    // Persist the trajectory point.
    let json = Json::arr(results.iter().map(|r| {
        let mut pairs = vec![
            ("name", Json::str(r.name.clone())),
            ("rows_per_s", Json::num(r.rows_per_s)),
            ("p50_us", Json::num(r.p50_us)),
            ("p99_us", Json::num(r.p99_us)),
        ];
        for &(k, v) in &r.config {
            pairs.push((k, Json::num(v)));
        }
        Json::obj(pairs)
    }));
    let path = "BENCH_serving.json";
    match std::fs::write(path, json.pretty()) {
        Ok(()) => println!("\nwrote {path} ({} configs)", results.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
