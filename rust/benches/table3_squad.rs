//! Bench: paper Table 3 — SQuAD/DrQA F1 with word2ketXS embeddings.
//! Paper: regular F1 ≈ XS 2/2 (1,433× saving), XS 4/1 = 70.65 (93,675×
//! saving, < 3% relative drop).
//!
//! Run: cargo bench --bench table3_squad    (W2K_BENCH_FAST=1 to smoke)

mod common;

use word2ket::config::{EmbeddingKind, TaskKind};
use word2ket::util::{fmt_count, Table};

fn main() {
    let steps = common::steps(700);
    println!("\n=== Table 3: SQuAD / DrQA-style QA ({} steps/variant) ===", steps);
    println!("paper: F1 ~72 (regular) ≈ XS 2/2 @1,433× saving; 70.65 XS 4/1 @93,675×\n");

    let (engine, manifest) = common::open_runtime();
    let cells = [
        ("Regular", EmbeddingKind::Regular, 1, 1, "~72"),
        ("word2ketXS", EmbeddingKind::Word2KetXS, 2, 2, "~71.5"),
        ("word2ketXS", EmbeddingKind::Word2KetXS, 4, 1, "70.65"),
    ];

    let mut t = Table::new(vec![
        "Embedding", "Order/Rank", "F1", "EM", "Emb #Params", "Saving", "Paper F1",
    ])
    .with_title("Table 3 (measured on synthetic SQuAD substrate)");
    let mut results = Vec::new();
    for (label, kind, order, rank, paper) in cells {
        let cfg = common::cell_config(TaskKind::Qa, kind, order, rank, steps);
        eprintln!("[table3] training {label} {order}/{rank} ...");
        let r = common::run_cell(&engine, &manifest, &cfg);
        t.add_row(vec![
            label.to_string(),
            format!("{order}/{rank}"),
            format!("{:.2}", common::metric(&r, "F1")),
            format!("{:.2}", common::metric(&r, "EM")),
            fmt_count(r.emb_params as u64),
            format!("{:.0}×", r.space_saving),
            paper.to_string(),
        ]);
        results.push(r);
    }
    println!("{}", t.render());

    let f1: Vec<f64> = results.iter().map(|r| common::metric(r, "F1")).collect();
    println!("\nshape checks:");
    println!(
        "  XS 2/2 within 10 F1 of regular ({:.1} vs {:.1})  → {}",
        f1[1], f1[0],
        if f1[1] + 10.0 >= f1[0] { "OK" } else { "VIOLATED" }
    );
    println!(
        "  XS 4/1 (72-param embedding!) learns (F1 {:.1} > 20) → {}",
        f1[2],
        if f1[2] > 20.0 { "OK" } else { "VIOLATED" }
    );
    println!("\nrelative drop XS 4/1 vs regular: {:.1}% (paper: <3% at full scale/epochs)",
        if f1[0] > 0.0 { 100.0 * (f1[0] - f1[2]) / f1[0] } else { 0.0 });
}
