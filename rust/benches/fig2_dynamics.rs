//! Bench: paper Fig. 2 — test-set F1 dynamics during training on SQuAD for
//! regular vs word2ketXS 2/2 vs word2ketXS 4/1. Paper shape: all three
//! converge along similar trajectories, XS 4/1 slightly below.
//!
//! Emits the three curves as aligned series (step → F1), ASCII-plotted.
//!
//! Run: cargo bench --bench fig2_dynamics    (W2K_BENCH_FAST=1 to smoke)

mod common;

use word2ket::config::{EmbeddingKind, TaskKind};

fn ascii_plot(curves: &[(&str, Vec<(usize, f64)>)]) -> String {
    // 60×16 character plot, F1 range [0, 100].
    const W: usize = 64;
    const H: usize = 16;
    let max_step = curves
        .iter()
        .flat_map(|(_, c)| c.iter().map(|&(s, _)| s))
        .max()
        .unwrap_or(1)
        .max(1);
    let mut grid = vec![vec![' '; W]; H];
    let marks = ['R', 'x', '4'];
    for (ci, (_, curve)) in curves.iter().enumerate() {
        for &(step, f1) in curve {
            let x = (step * (W - 1)) / max_step;
            let y = ((f1.clamp(0.0, 100.0) / 100.0) * (H - 1) as f64).round() as usize;
            grid[H - 1 - y][x] = marks[ci % marks.len()];
        }
    }
    let mut out = String::new();
    out.push_str("F1\n");
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            "100".to_string()
        } else if i == H - 1 {
            "  0".to_string()
        } else {
            "   ".to_string()
        };
        out.push_str(&format!("{label}|{}\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!("   +{}\n    0 {:>56}\n", "-".repeat(W), format!("steps → {max_step}")));
    out
}

fn main() {
    let steps = common::steps(600);
    let eval_every = (steps / 8).max(1);
    println!("\n=== Fig. 2: F1 training dynamics (eval every {eval_every} steps) ===");
    println!("paper: regular ≈ XS 2/2, XS 4/1 slightly below; all converge\n");

    let (engine, manifest) = common::open_runtime();
    let variants = [
        ("Regular    (R)", EmbeddingKind::Regular, 1, 1),
        ("XS 2/2     (x)", EmbeddingKind::Word2KetXS, 2, 2),
        ("XS 4/1     (4)", EmbeddingKind::Word2KetXS, 4, 1),
    ];

    let mut curves = Vec::new();
    for (label, kind, order, rank) in variants {
        let mut cfg = common::cell_config(TaskKind::Qa, kind, order, rank, steps);
        cfg.train.eval_every = eval_every;
        eprintln!("[fig2] training {label} ...");
        let r = common::run_cell(&engine, &manifest, &cfg);
        let curve: Vec<(usize, f64)> = r.curve.iter().map(|p| (p.step, p.primary)).collect();
        curves.push((label, curve));
    }

    for (label, curve) in &curves {
        println!(
            "{label}: {}",
            curve
                .iter()
                .map(|(s, f)| format!("@{s}:{f:.1}"))
                .collect::<Vec<_>>()
                .join("  ")
        );
    }
    println!();
    let named: Vec<(&str, Vec<(usize, f64)>)> =
        curves.iter().map(|(l, c)| (*l, c.clone())).collect();
    println!("{}", ascii_plot(&named));

    // Shape: final F1 of XS 2/2 within 15 of regular; all curves monotone-ish
    // (final >= first).
    let finals: Vec<f64> = curves.iter().map(|(_, c)| c.last().map(|x| x.1).unwrap_or(0.0)).collect();
    let firsts: Vec<f64> = curves.iter().map(|(_, c)| c.first().map(|x| x.1).unwrap_or(0.0)).collect();
    println!("shape checks:");
    println!(
        "  curves improve over training: {}",
        if finals.iter().zip(&firsts).all(|(f, s)| f + 1e-9 >= *s) { "OK" } else { "MIXED (short run)" }
    );
    println!(
        "  XS 2/2 final ({:.1}) within 15 F1 of regular ({:.1}): {}",
        finals[1], finals[0],
        if finals[1] + 15.0 >= finals[0] { "OK" } else { "VIOLATED" }
    );
}
