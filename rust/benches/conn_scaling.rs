//! Bench: connection scaling — thousands of mostly-idle connections plus a
//! small active set, thread-per-connection driver vs the epoll reactor.
//!
//! What this quantifies: the cost of *holding* connections. The blocking
//! driver pays one OS thread (stack, scheduler state) per open socket, so a
//! mostly-idle fleet of clients degrades it long before CPU does; the
//! reactor pays ~one slab entry. Each sweep level tops the idle pool up to
//! the target, confirms every connection completed the binary hello (it is
//! actually served, not parked in a SYN backlog), then measures LOOKUP
//! latency/throughput on a small active set threaded through the same
//! listener. Rows land in `BENCH_cluster.json` next to the scatter-gather
//! results, replacing prior conn_scaling rows and preserving everything
//! else.
//!
//! Honest-degradation notes: the sweep records `conns_open` next to
//! `conns_target` — a driver (or the loopback ephemeral-port range, around
//! 28k 4-tuples to one destination) refusing further connections shows up
//! as `conns_open < conns_target` rather than a crash. `RLIMIT_NOFILE` is
//! raised first via [`word2ket::net::sys::raise_nofile_limit`].
//!
//! Run: cargo bench --bench conn_scaling    (W2K_BENCH_FAST=1 to smoke)

use word2ket::bench::header;
use word2ket::config::{EmbeddingKind, ExperimentConfig, NetDriver};
use word2ket::coordinator::server::{self, ServerState};
use word2ket::net::sys;
use word2ket::serving::BinaryClient;
use word2ket::util::{Json, Rng, Summary, Timer};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

const DIM: usize = 32;
const BATCH: usize = 8;
const ACTIVE: usize = 8;

struct Server {
    state: Arc<ServerState>,
    addr: String,
    accept: std::thread::JoinHandle<()>,
}

fn spawn_server(driver: NetDriver, vocab: usize) -> Server {
    let mut cfg = ExperimentConfig::default();
    cfg.embedding.kind = EmbeddingKind::Word2KetXS;
    cfg.embedding.order = 2;
    cfg.embedding.rank = 2;
    cfg.model.vocab = vocab;
    cfg.model.emb_dim = DIM;
    cfg.server.addr = "127.0.0.1:0".into();
    cfg.serving.batch_window_us = 50;
    cfg.net.driver = driver;
    // Idle connections must outlive the whole sweep.
    cfg.net.idle_timeout_ms = 600_000;
    let (state, listener, addr) = server::spawn(&cfg).expect("bench server");
    let st = state.clone();
    let accept = std::thread::spawn(move || server::accept_loop(listener, st));
    Server { state, addr, accept }
}

/// Top `pool` up to `target` fully-established idle binary connections
/// (hello completed). Stops early after a run of consecutive failures —
/// port exhaustion or a driver refusing more connections — and reports how
/// far it got.
fn top_up_idle(pool: &mut Vec<TcpStream>, addr: &SocketAddr, target: usize) {
    let mut consecutive_failures = 0usize;
    while pool.len() < target {
        if consecutive_failures >= 200 {
            eprintln!(
                "  stopping at {} conns: {consecutive_failures} consecutive connect failures",
                pool.len()
            );
            break;
        }
        let ok = (|| -> std::io::Result<TcpStream> {
            let mut s = TcpStream::connect_timeout(addr, Duration::from_secs(5))?;
            s.set_read_timeout(Some(Duration::from_secs(5)))?;
            s.write_all(&word2ket::serving::wire::MAGIC)?;
            let mut hello = [0u8; 8];
            s.read_exact(&mut hello)?;
            Ok(s)
        })();
        match ok {
            Ok(s) => {
                consecutive_failures = 0;
                pool.push(s);
                if pool.len() % 5_000 == 0 {
                    println!("  {} idle conns open", pool.len());
                }
            }
            Err(_) => consecutive_failures += 1,
        }
    }
}

/// `ACTIVE` workers × `iters` batched lookups each through fresh
/// connections on the same listener; returns (requests/s, latency summary).
fn run_active(addr: &str, vocab: usize, iters: usize) -> (f64, Summary) {
    let wall = Timer::start();
    let merged = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..ACTIVE)
            .map(|t| {
                scope.spawn(move || {
                    let mut rng = Rng::new(4100 + t as u64);
                    let mut client = BinaryClient::connect(addr).expect("active conn");
                    let mut lat = Summary::new();
                    let mut ids = vec![0u32; BATCH];
                    for _ in 0..iters {
                        for id in ids.iter_mut() {
                            *id = (rng.next_u64() % vocab as u64) as u32;
                        }
                        let timer = Timer::start();
                        let rows = client.lookup(&ids).expect("lookup under idle load");
                        assert_eq!(rows.len(), BATCH);
                        lat.add(timer.elapsed_us());
                    }
                    client.quit().ok();
                    lat
                })
            })
            .collect();
        let mut merged = Summary::new();
        for h in handles {
            merged.merge(&h.join().expect("active worker"));
        }
        merged
    });
    let reqs = (ACTIVE * iters) as f64;
    (reqs / wall.elapsed().as_secs_f64(), merged)
}

struct RowOut {
    driver: NetDriver,
    conns_target: usize,
    conns_open: usize,
    open_ms: f64,
    rps: f64,
    p50_us: f64,
    p99_us: f64,
}

/// Split a JSON document into its top-level `{...}` object substrings
/// (string-literal aware), so rows written by other benches survive a
/// rewrite verbatim.
fn top_level_objects(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let (mut depth, mut start, mut in_str, mut esc) = (0i32, None::<usize>, false, false);
    for (i, c) in s.char_indices() {
        if in_str {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' => {
                if depth == 0 {
                    start = Some(i);
                }
                depth += 1;
            }
            '}' => {
                depth -= 1;
                if depth == 0 {
                    if let Some(st) = start.take() {
                        out.push(s[st..=i].to_string());
                    }
                }
            }
            _ => {}
        }
    }
    out
}

/// Merge this bench's rows into `BENCH_cluster.json`: keep every existing
/// row except prior conn_scaling rows (marked by their `"bench"` field),
/// append ours.
fn splice_results(path: &str, rows: &[RowOut], vocab: usize) {
    let mine = Json::arr(rows.iter().map(|r| {
        Json::obj(vec![
            ("bench", Json::str("conn_scaling".to_string())),
            ("driver", Json::str(r.driver.as_str().to_string())),
            ("conns_target", Json::num(r.conns_target as f64)),
            ("conns_open", Json::num(r.conns_open as f64)),
            ("open_ms", Json::num(r.open_ms)),
            ("rps", Json::num(r.rps)),
            ("p50_us", Json::num(r.p50_us)),
            ("p99_us", Json::num(r.p99_us)),
            ("active", Json::num(ACTIVE as f64)),
            ("vocab", Json::num(vocab as f64)),
            ("dim", Json::num(DIM as f64)),
        ])
    }));
    let mut chunks: Vec<String> = match std::fs::read_to_string(path) {
        Ok(prev) => top_level_objects(&prev)
            .into_iter()
            .filter(|c| !c.contains("\"conn_scaling\""))
            .collect(),
        Err(_) => Vec::new(),
    };
    let kept = chunks.len();
    chunks.extend(top_level_objects(&mine.pretty()));
    let body = chunks.join(",\n");
    match std::fs::write(path, format!("[\n{body}\n]\n")) {
        Ok(()) => println!(
            "\nwrote {path} ({} conn_scaling rows, {kept} rows from other benches kept)",
            rows.len()
        ),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn main() {
    header(
        "Connection scaling: N mostly-idle conns + small active set, threads vs epoll",
        "a factored embedding table leaves memory for connections, not the \
         other way around — the reactor holds an idle socket for a slab \
         entry where the blocking driver parks a whole thread",
    );
    let fast = std::env::var("W2K_BENCH_FAST").is_ok();
    let vocab = if fast { 2_000 } else { 10_000 };
    let levels: &[usize] = if fast { &[100, 500] } else { &[1_000, 10_000, 50_000] };
    let iters = if fast { 100 } else { 1_000 };

    match sys::raise_nofile_limit(150_000) {
        Ok((before, after)) => println!("RLIMIT_NOFILE: {before} -> {after}"),
        Err(e) => eprintln!("could not raise RLIMIT_NOFILE ({e}); expect early saturation"),
    }

    let mut out: Vec<RowOut> = Vec::new();
    for driver in [NetDriver::Threads, NetDriver::Epoll] {
        println!("driver = {driver}:");
        let server = spawn_server(driver, vocab);
        let sock_addr: SocketAddr = server.addr.parse().expect("bound addr");
        let mut pool: Vec<TcpStream> = Vec::new();
        for &target in levels {
            let open_timer = Timer::start();
            top_up_idle(&mut pool, &sock_addr, target);
            let open_ms = open_timer.elapsed().as_secs_f64() * 1e3;
            let (rps, lat) = run_active(&server.addr, vocab, iters);
            println!(
                "  {target:>6} idle target ({:>6} open, {open_ms:>8.0}ms to open)  \
                 {rps:>9.0} req/s  p50 {:>6.0}µs  p99 {:>6.0}µs",
                pool.len(),
                lat.p50(),
                lat.p99()
            );
            out.push(RowOut {
                driver,
                conns_target: target,
                conns_open: pool.len(),
                open_ms,
                rps,
                p50_us: lat.p50(),
                p99_us: lat.p99(),
            });
        }
        drop(pool);
        server.state.shutdown();
        server.accept.join().ok();
    }

    splice_results("BENCH_cluster.json", &out, vocab);
}
