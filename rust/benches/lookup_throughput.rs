//! Bench: §2.3 complexity claims — lookup/reconstruction throughput and the
//! factored inner product, across representations, pure-Rust serving path.
//!
//!  * regular lookup: memcpy of a row (baseline)
//!  * word2ket reconstruct: O(r·p·n) per row, balanced tree
//!  * word2ketXS lazy row: gather n columns + tree product (§3.2)
//!  * factored inner product: O(r²·n·q) — no reconstruction (§2.3)
//!
//! Also measures the Pallas kernel artifacts through PJRT for the same ops.
//!
//! Run: cargo bench --bench lookup_throughput

mod common;

use word2ket::bench::{black_box, header, BenchRunner};
use word2ket::embedding::{EmbeddingStore, RegularEmbedding, Word2Ket, Word2KetXS};
use word2ket::runtime::Value;
use word2ket::util::Rng;

fn main() {
    header(
        "Lookup / reconstruction throughput (serving path)",
        "word2ket costs O(r·p·n) per row; XS row touches one column per factor; \
         factored dot is O(r²·n·q) with O(1) extra space (§2.3, §3.2)",
    );
    let mut rng = Rng::new(0);
    let vocab = 100_000;
    let dim = 256;
    let batch: Vec<usize> = (0..512).map(|_| rng.below(vocab)).collect();

    let regular = RegularEmbedding::random(vocab, dim, &mut rng);
    let w2k = Word2Ket::random(vocab, dim, 4, 2, &mut rng);
    let xs2 = Word2KetXS::random(vocab, dim, 2, 10, &mut rng);
    let xs4 = Word2KetXS::random(vocab, dim, 4, 1, &mut rng);

    let runner = BenchRunner::default();
    let mut results = Vec::new();
    results.push(runner.run_throughput("regular lookup_batch (512 rows)", 512.0, || {
        black_box(regular.lookup_batch(&batch))
    }));
    results.push(runner.run_throughput("word2ket 4/2 reconstruct (512 rows)", 512.0, || {
        black_box(w2k.lookup_batch(&batch))
    }));
    results.push(runner.run_throughput("word2ketXS 2/10 lazy rows (512)", 512.0, || {
        black_box(xs2.lookup_batch(&batch))
    }));
    results.push(runner.run_throughput("word2ketXS 4/1 lazy rows (512)", 512.0, || {
        black_box(xs4.lookup_batch(&batch))
    }));
    for r in &results {
        println!("{}", r.render());
    }

    // Factored inner product vs dense dot.
    println!();
    let dense_dot = runner.run_throughput("dense dot after reconstruct (w2k)", 1.0, || {
        let a = w2k.lookup(17);
        let b = w2k.lookup(9_999);
        black_box(word2ket::tensor::dot(&a, &b))
    });
    let factored = runner.run_throughput("factored inner product (§2.3)", 1.0, || {
        black_box(w2k.inner(17, 9_999))
    });
    println!("{}", dense_dot.render());
    println!("{}", factored.render());
    println!(
        "factored/dense speedup: {:.1}×",
        dense_dot.mean.as_secs_f64() / factored.mean.as_secs_f64()
    );

    // Memory story.
    println!("\nresident embedding bytes:");
    for (name, params) in [
        ("regular", regular.num_params()),
        ("word2ket 4/2", w2k.num_params()),
        ("XS 2/10", xs2.num_params()),
        ("XS 4/1", xs4.num_params()),
    ] {
        println!("  {name:<14} {:>12} f32 = {:>10.1} KiB", params, params as f64 * 4.0 / 1024.0);
    }

    // Pallas kernel path through PJRT (same ops, compiled artifacts).
    println!("\nPJRT kernel artifacts (interpret-mode Pallas lowered to HLO):");
    let (engine, manifest) = common::open_runtime();
    if let Some(k) = manifest.kernels.get("kernel_xs_rows") {
        let ins: Vec<Value> = k
            .inputs
            .iter()
            .map(|spec| {
                Value::F32(
                    Rng::new(1).uniform_vec(spec.num_elements(), -1.0, 1.0),
                    spec.shape.clone(),
                )
            })
            .collect();
        engine.run(&k.file, &ins).expect("warmup");
        let r = runner.run_throughput("kernel_xs_rows via PJRT (16 rows)", 16.0, || {
            black_box(engine.run(&k.file, &ins).unwrap())
        });
        println!("{}", r.render());
    }
    if let Some(k) = manifest.kernels.get("kernel_kron_pair") {
        let ins: Vec<Value> = k
            .inputs
            .iter()
            .map(|spec| {
                Value::F32(
                    Rng::new(2).uniform_vec(spec.num_elements(), -1.0, 1.0),
                    spec.shape.clone(),
                )
            })
            .collect();
        engine.run(&k.file, &ins).expect("warmup");
        let r = runner.run_throughput("kernel_kron_pair via PJRT (16 rows)", 16.0, || {
            black_box(engine.run(&k.file, &ins).unwrap())
        });
        println!("{}", r.render());
    }
}
