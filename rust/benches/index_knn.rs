//! Bench: top-k similarity search over a compressed store — brute force
//! over materialized rows vs brute force in *factored space* vs IVF.
//!
//! The paper's CP representation makes each pair score `O(r² n q)` instead
//! of `O(q^n)` (§2.3), so exact search over the compressed table beats the
//! dense scan without any approximation; IVF stacks a sub-linear candidate
//! scan on top (probe `nprobe` of `nlist` k-means cells, exact factored
//! re-rank). This bench quantifies both speedups plus IVF recall@k, sweeps
//! the factored scans across `scan_threads` 1/2/4 (the blocked parallel
//! scan — bit-identical results, so only throughput moves), sweeps the
//! snapshot payload codecs (f32/f16/int8/int4/b2/b1) recording recall@k,
//! bytes/query and cold-start load time per codec, and emits
//! `BENCH_index.json` so the perf trajectory accumulates across PRs.
//!
//! Run: cargo bench --bench index_knn    (W2K_BENCH_FAST=1 to smoke)

use word2ket::bench::{black_box, header, BenchRunner};
use word2ket::embedding::{EmbeddingStore, Word2Ket};
use word2ket::index::{BruteForce, IvfIndex, KnnIndex, Neighbor, Query, Scorer};
use word2ket::snapshot::{save_store, Codec, SaveOptions, Snapshot, SnapshotStore};
use word2ket::tensor::dot;
use word2ket::util::{Json, Rng, Timer};
use std::cell::Cell;
use std::collections::HashSet;
use std::sync::Arc;

const DIM: usize = 256; // q = 16, 16² = 256: exact reconstruction
const ORDER: usize = 2;
const RANK: usize = 1; // paper Table 1 word2ket 2/1-style cell
const K: usize = 10;

/// Dense scan over a pre-materialized matrix: the baseline every index is
/// judged against. Insertion top-k, query row excluded.
fn dense_top_k(matrix: &[f32], vocab: usize, query: usize, k: usize) -> Vec<(usize, f32)> {
    let q = &matrix[query * DIM..(query + 1) * DIM];
    let mut best: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
    for b in 0..vocab {
        if b == query {
            continue;
        }
        let s = dot(q, &matrix[b * DIM..(b + 1) * DIM]);
        if best.len() < k || s > best.last().unwrap().1 {
            let pos = best.partition_point(|&(_, bs)| bs > s);
            best.insert(pos, (b, s));
            if best.len() > k {
                best.pop();
            }
        }
    }
    best
}

struct Row {
    name: String,
    queries_per_s: f64,
    p50_us: f64,
    p99_us: f64,
    mean_candidates: f64,
    recall_at_k: f64,
    scan_threads: usize,
}

fn main() {
    header(
        "k-NN: materialized brute vs factored brute vs IVF",
        "factored inner products score pairs in O(r²nq) instead of O(q^n) \
         (§2.3); IVF probes nprobe/nlist of the vocabulary on top",
    );
    let fast = std::env::var("W2K_BENCH_FAST").is_ok();
    let vocab = if fast { 5_000 } else { 30_000 };
    let n_queries = if fast { 16 } else { 64 };
    let (nlist, nprobe) = if fast { (32usize, 4usize) } else { (128usize, 8usize) };
    let runner = if fast {
        BenchRunner {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            budget: std::time::Duration::from_millis(500),
        }
    } else {
        BenchRunner::default()
    };

    let mut rng = Rng::new(7);
    let store = Arc::new(Word2Ket::random(vocab, DIM, ORDER, RANK, &mut rng));
    println!("store: {}\n", store.describe());
    let queries: Vec<usize> = (0..n_queries).map(|_| rng.below(vocab)).collect();
    let mut results: Vec<Row> = Vec::new();

    // --- materialized brute force -----------------------------------------
    let t = Timer::start();
    let matrix = {
        let mut m = Vec::with_capacity(vocab * DIM);
        for id in 0..vocab {
            m.extend_from_slice(&store.lookup(id));
        }
        m
    };
    println!(
        "materialized {}×{} matrix in {:.0}ms ({} MB vs {} KB of factors)",
        vocab,
        DIM,
        t.elapsed_ms(),
        vocab * DIM * 4 / (1 << 20),
        store.num_params() * 4 / (1 << 10)
    );
    let next = Cell::new(0usize);
    let mat = runner.run_throughput(&format!("materialized brute top-{K}"), 1.0, || {
        let q = queries[next.get() % queries.len()];
        next.set(next.get() + 1);
        black_box(dense_top_k(&matrix, vocab, q, K))
    });
    println!("{}", mat.render());
    results.push(Row {
        name: "materialized brute".into(),
        queries_per_s: mat.throughput().unwrap_or(0.0),
        p50_us: mat.p50.as_secs_f64() * 1e6,
        p99_us: mat.p99.as_secs_f64() * 1e6,
        mean_candidates: (vocab - 1) as f64,
        recall_at_k: 1.0,
        scan_threads: 1,
    });

    // --- factored brute force, swept across the scan-thread knob ----------
    // The 1-thread row is the historical cell; the 2- and 4-thread rows are
    // the blocked parallel scan (results bit-identical by construction, so
    // the only thing that moves is throughput — the scaling column).
    let mut fac_base_mean = 0.0f64;
    for threads in [1usize, 2, 4] {
        let brute = BruteForce::new(Scorer::new(store.clone() as Arc<dyn EmbeddingStore>, false))
            .with_scan_threads(threads);
        if threads == 1 {
            assert!(brute.scorer().is_factored(), "bench premise: factored scoring path");
        }
        let next = Cell::new(0usize);
        let fac = runner.run_throughput(&format!("factored brute top-{K} [{threads}t]"), 1.0, || {
            let q = queries[next.get() % queries.len()];
            next.set(next.get() + 1);
            black_box(brute.top_k(&Query::Id(q), K))
        });
        println!("{}", fac.render());
        let fac_mean = fac.mean.as_secs_f64();
        if threads == 1 {
            fac_base_mean = fac_mean;
            let fac_speedup = mat.mean.as_secs_f64() / fac_mean;
            println!("  -> factored/materialized speedup {fac_speedup:.1}×");
        } else if fac_base_mean > 0.0 {
            let scaling = fac_base_mean / fac_mean;
            println!("  -> {threads}-thread scan scaling {scaling:.2}× over 1 thread");
        }
        results.push(Row {
            name: format!("factored brute {threads}t"),
            queries_per_s: fac.throughput().unwrap_or(0.0),
            p50_us: fac.p50.as_secs_f64() * 1e6,
            p99_us: fac.p99.as_secs_f64() * 1e6,
            mean_candidates: (vocab - 1) as f64,
            recall_at_k: 1.0,
            scan_threads: threads,
        });
    }

    // --- IVF ----------------------------------------------------------------
    let t = Timer::start();
    let ivf = IvfIndex::build(
        Scorer::new(store.clone() as Arc<dyn EmbeddingStore>, false),
        nlist,
        nprobe,
        42,
    );
    println!("\nbuilt {} in {:.0}ms", ivf.describe(), t.elapsed_ms());

    // Recall + candidate accounting against the materialized ground truth.
    let mut hits = 0usize;
    let mut candidates = 0usize;
    for &q in &queries {
        let exact: HashSet<usize> =
            dense_top_k(&matrix, vocab, q, K).into_iter().map(|(id, _)| id).collect();
        let (approx, stats) = ivf.top_k(&Query::Id(q), K);
        candidates += stats.candidates;
        hits += approx.iter().filter(|n: &&Neighbor| exact.contains(&n.id)).count();
    }
    let recall = hits as f64 / (queries.len() * K) as f64;
    let mean_candidates = candidates as f64 / queries.len() as f64;

    let next = Cell::new(0usize);
    let ivf_r = runner.run_throughput(
        &format!("ivf[{nlist}/{nprobe}] top-{K}"),
        1.0,
        || {
            let q = queries[next.get() % queries.len()];
            next.set(next.get() + 1);
            black_box(ivf.top_k(&Query::Id(q), K))
        },
    );
    println!("{}", ivf_r.render());
    let ivf_speedup = mat.mean.as_secs_f64() / ivf_r.mean.as_secs_f64();
    println!(
        "  -> ivf/materialized speedup {ivf_speedup:.1}× at recall@{K} {recall:.2} \
         ({mean_candidates:.0} of {} candidates scanned)",
        vocab - 1
    );
    results.push(Row {
        name: format!("ivf nlist={nlist} nprobe={nprobe}"),
        queries_per_s: ivf_r.throughput().unwrap_or(0.0),
        p50_us: ivf_r.p50.as_secs_f64() * 1e6,
        p99_us: ivf_r.p99.as_secs_f64() * 1e6,
        mean_candidates,
        recall_at_k: recall,
        scan_threads: 1,
    });

    // --- IVF with a parallel re-rank ----------------------------------------
    // Same probed cells, same bit-identical results; the candidate scan is
    // chunked across the scan team (the knob clamps itself when the probed
    // lists are too small to split, so small configs just run sequentially).
    let ivf = ivf.with_scan_threads(4);
    let next = Cell::new(0usize);
    let ivf_p = runner.run_throughput(
        &format!("ivf[{nlist}/{nprobe}] top-{K} [4t]"),
        1.0,
        || {
            let q = queries[next.get() % queries.len()];
            next.set(next.get() + 1);
            black_box(ivf.top_k(&Query::Id(q), K))
        },
    );
    println!("{}", ivf_p.render());
    let rerank_scaling = ivf_r.mean.as_secs_f64() / ivf_p.mean.as_secs_f64();
    println!("  -> 4-thread re-rank scaling {rerank_scaling:.2}× over 1 thread");
    results.push(Row {
        name: format!("ivf nlist={nlist} nprobe={nprobe} 4t"),
        queries_per_s: ivf_p.throughput().unwrap_or(0.0),
        p50_us: ivf_p.p50.as_secs_f64() * 1e6,
        p99_us: ivf_p.p99.as_secs_f64() * 1e6,
        mean_candidates,
        recall_at_k: recall,
        scan_threads: 4,
    });

    // --- payload-codec sweep -----------------------------------------------
    // The same word2ket table saved at every snapshot codec, cold-booted the
    // way a server would boot it, and searched with the same top-k workload.
    // Probing every cell (nprobe = nlist) removes the cell-miss term, so
    // recall@K isolates what the *codec* costs: f16/int8 dequantize at open
    // and scan factored f32 rows; the sub-byte codecs scan packed codes
    // coarsely and re-rank the survivors against exact f16-refined rows
    // (see `word2ket::quant`). bytes_per_query counts the payload bytes one
    // query touches — coarse codes + scales per candidate plus the re-ranked
    // rows — against the dim·4 per candidate a dense scan reads.
    let vocab_q = if fast { 2_000 } else { 10_000 };
    let nlist_q = if fast { 16usize } else { 64usize };
    let mut rng_q = Rng::new(19);
    let store_q = Word2Ket::random(vocab_q, DIM, ORDER, RANK, &mut rng_q);
    let leaf = store_q.leaf_dim();
    let leaves = ORDER * RANK;
    let matrix_q = {
        let mut m = Vec::with_capacity(vocab_q * DIM);
        for id in 0..vocab_q {
            m.extend_from_slice(&store_q.lookup(id));
        }
        m
    };
    let queries_q: Vec<usize> = (0..n_queries).map(|_| rng_q.below(vocab_q)).collect();
    let dir = std::env::temp_dir().join(format!("w2k_bench_codecs_{}", std::process::id()));
    std::fs::create_dir_all(&dir).ok();
    println!("\ncodec sweep: vocab {vocab_q}, full probe ({nlist_q}/{nlist_q}), top-{K}");
    let mut codec_rows: Vec<Json> = Vec::new();
    for codec in [Codec::F32, Codec::F16, Codec::Int8, Codec::Int4, Codec::B2, Codec::B1] {
        let path = dir.join(format!("codec_{}.snap", codec.name()));
        let opts = SaveOptions { codec, ..Default::default() };
        let info = save_store(&store_q, &path, &opts).expect("save snapshot");
        let t = Timer::start();
        let snap = Arc::new(Snapshot::open(&path, true).expect("open snapshot"));
        let loaded = SnapshotStore::open(snap).expect("load snapshot store");
        let cold_load_ms = t.elapsed_ms();
        let ivf = IvfIndex::build(
            Scorer::new(Arc::new(loaded) as Arc<dyn EmbeddingStore>, false),
            nlist_q,
            nlist_q,
            42,
        );
        let mut hits = 0usize;
        let mut candidates = 0usize;
        for &q in &queries_q {
            let exact: HashSet<usize> =
                dense_top_k(&matrix_q, vocab_q, q, K).into_iter().map(|(id, _)| id).collect();
            let (approx, stats) = ivf.top_k(&Query::Id(q), K);
            candidates += stats.candidates;
            hits += approx.iter().filter(|n: &&Neighbor| exact.contains(&n.id)).count();
        }
        let recall = hits as f64 / (queries_q.len() * K) as f64;
        let mean_candidates = candidates as f64 / queries_q.len() as f64;
        // Coarse bytes per candidate: sub-byte scans packed codes + one
        // scale per leaf; every other codec scans f32 factors in memory
        // (f16/int8 payloads dequantize at open). Sub-byte then re-reads
        // `(K·8).max(64)` refined rows — the IVF re-rank depth.
        let coarse_bytes = if codec.is_sub_byte() {
            let wpl = (leaf * codec.bits()).div_ceil(32);
            (leaves * (wpl * 4 + 4)) as f64
        } else {
            (leaves * leaf * 4) as f64
        };
        let rerank_rows = if codec.is_sub_byte() { (K * 8).max(64) } else { 0 };
        let bytes_per_query =
            mean_candidates * coarse_bytes + (rerank_rows * leaves * leaf * 4) as f64;
        let reduction = mean_candidates * (DIM * 4) as f64 / bytes_per_query;
        let next = Cell::new(0usize);
        let r = runner.run_throughput(&format!("codec {} top-{K}", codec.name()), 1.0, || {
            let q = queries_q[next.get() % queries_q.len()];
            next.set(next.get() + 1);
            black_box(ivf.top_k(&Query::Id(q), K))
        });
        println!("{}", r.render());
        println!(
            "  -> recall@{K} {recall:.3}, {:.1} KB/query ({reduction:.1}× less than a dense \
             scan), snapshot {} KB, cold load {cold_load_ms:.0}ms",
            bytes_per_query / 1024.0,
            info.bytes / 1024,
        );
        codec_rows.push(Json::obj(vec![
            ("name", Json::str(format!("codec {}", codec.name()))),
            ("codec", Json::str(codec.name())),
            ("payload_bits", Json::num(codec.bits() as f64)),
            ("queries_per_s", Json::num(r.throughput().unwrap_or(0.0))),
            ("p50_us", Json::num(r.p50.as_secs_f64() * 1e6)),
            ("p99_us", Json::num(r.p99.as_secs_f64() * 1e6)),
            ("mean_candidates", Json::num(mean_candidates)),
            ("recall_at_k", Json::num(recall)),
            ("bytes_per_query", Json::num(bytes_per_query)),
            ("reduction_x_vs_dense", Json::num(reduction)),
            ("file_bytes", Json::num(info.bytes as f64)),
            ("cold_load_ms", Json::num(cold_load_ms)),
            ("scan_threads", Json::num(1.0)),
            ("vocab", Json::num(vocab_q as f64)),
            ("dim", Json::num(DIM as f64)),
            ("k", Json::num(K as f64)),
        ]));
    }
    std::fs::remove_dir_all(&dir).ok();

    // Persist the trajectory point (scan rows first, then the codec sweep).
    let mut items: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::str(r.name.clone())),
                ("queries_per_s", Json::num(r.queries_per_s)),
                ("p50_us", Json::num(r.p50_us)),
                ("p99_us", Json::num(r.p99_us)),
                ("mean_candidates", Json::num(r.mean_candidates)),
                ("recall_at_k", Json::num(r.recall_at_k)),
                ("scan_threads", Json::num(r.scan_threads as f64)),
                ("vocab", Json::num(vocab as f64)),
                ("dim", Json::num(DIM as f64)),
                ("k", Json::num(K as f64)),
            ])
        })
        .collect();
    let n_rows = items.len() + codec_rows.len();
    items.extend(codec_rows);
    let json = Json::arr(items);
    let path = "BENCH_index.json";
    match std::fs::write(path, json.pretty()) {
        Ok(()) => println!("\nwrote {path} ({n_rows} configs)"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
