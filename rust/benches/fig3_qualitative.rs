//! Bench: paper Fig. 3 — qualitative QA predictions from the order-4 rank-1
//! word2ketXS model whose *entire embedding table is 72 parameters* at our
//! scale (380 at paper scale — four 19×5 matrices; reproduced exactly in
//! the space_saving bench).
//!
//! Trains briefly, then prints context / question / gold / prediction
//! samples in the figure's format.
//!
//! Run: cargo bench --bench fig3_qualitative    (W2K_BENCH_FAST=1 to smoke)

mod common;

use word2ket::config::{EmbeddingKind, TaskKind};
use word2ket::coordinator::experiment::resolve_variant;
use word2ket::coordinator::tasks::prepare_qa;
use word2ket::coordinator::trainer::predict_spans;
use word2ket::metrics::{qa_f1, exact_match};
use word2ket::runtime::ParamStore;
use word2ket::text::detokenize;

fn main() {
    let steps = common::steps(700);
    println!("\n=== Fig. 3: qualitative predictions from a 72-parameter embedding ===\n");

    let (engine, manifest) = common::open_runtime();
    let cfg = common::cell_config(TaskKind::Qa, EmbeddingKind::Word2KetXS, 4, 1, steps);
    let variant = resolve_variant(&cfg, &manifest).expect("variant");
    println!(
        "embedding: {} order-4 rank-1, {} trainable parameters for a {}×{} table\n",
        variant.embedding.kind,
        variant.embedding.num_params,
        variant.dims["vocab"],
        variant.dims["emb_dim"],
    );

    eprintln!("[fig3] training XS 4/1 for {steps} steps ...");
    let mut store = ParamStore::init(&variant.params, cfg.train.seed);
    let report =
        word2ket::coordinator::experiment::run_with(&cfg, &engine, variant, &mut store, false)
            .expect("train");
    println!("trained to test F1 {:.1} / EM {:.1}\n", report.primary(),
        common::metric(&report, "EM"));

    let data = prepare_qa(&cfg, variant).expect("data");
    let batches = data.test.eval_batches();
    let mut shown = 0;
    let mut offset = 0;
    for (batch, real) in &batches {
        let spans = predict_spans(&engine, variant, &store, batch).expect("predict");
        for row in 0..*real {
            if shown >= 6 {
                break;
            }
            let ex = &data.test_examples[offset + row];
            let (s, e) = spans[row];
            let e = e.min(ex.context.len().saturating_sub(1));
            let s = s.min(e);
            let pred: Vec<String> = ex.context[s..=e].to_vec();
            let f1 = qa_f1(&pred, &ex.answers[0]);
            let em = exact_match(&pred, &ex.answers[0]);
            println!("CONTEXT:   {}", detokenize(&ex.context));
            println!("QUESTION:  {}", detokenize(&ex.question));
            println!("TRUE:      {}", detokenize(&ex.answers[0]));
            println!("PREDICTED: {}   [F1 {f1:.2}{}]", detokenize(&pred),
                if em > 0.0 { ", exact" } else { "" });
            println!();
            shown += 1;
        }
        offset += real;
        if shown >= 6 {
            break;
        }
    }
    println!("(paper Fig. 3 shows the same format from a 380-parameter, 118,655-word model)");
}
