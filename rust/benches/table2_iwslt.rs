//! Bench: paper Table 2 — IWSLT2014 DE-EN translation with word2ketXS at
//! decreasing parameter budgets. Paper shape: BLEU degrades gently
//! (26.44 → 25.97 → 25.33 → 25.02) as savings grow (1× → 38× → 114× → 853×).
//!
//! Run: cargo bench --bench table2_iwslt    (W2K_BENCH_FAST=1 to smoke)

mod common;

use word2ket::config::{EmbeddingKind, TaskKind};
use word2ket::util::{fmt_count, Table};

fn main() {
    let steps = common::steps(900);
    println!("\n=== Table 2: IWSLT2014 DE-EN translation ({} steps/variant) ===", steps);
    println!("paper: BLEU 26.44 (regular) / 25.97 (XS 2/30) / 25.33 (XS 2/10) / 25.02 (XS 3/10)\n");

    let (engine, manifest) = common::open_runtime();
    let cells = [
        ("Regular", EmbeddingKind::Regular, 1, 1, 26.44),
        ("word2ketXS", EmbeddingKind::Word2KetXS, 2, 30, 25.97),
        ("word2ketXS", EmbeddingKind::Word2KetXS, 2, 10, 25.33),
        ("word2ketXS", EmbeddingKind::Word2KetXS, 3, 10, 25.02),
    ];

    let mut t = Table::new(vec![
        "Embedding", "Order/Rank", "BLEU", "BP", "Emb #Params", "Saving", "Paper BLEU",
    ])
    .with_title("Table 2 (measured on synthetic DE→EN substrate)");
    let mut results = Vec::new();
    for (label, kind, order, rank, paper) in cells {
        let cfg = common::cell_config(TaskKind::Translation, kind, order, rank, steps);
        eprintln!("[table2] training {label} {order}/{rank} ...");
        let r = common::run_cell(&engine, &manifest, &cfg);
        t.add_row(vec![
            label.to_string(),
            format!("{order}/{rank}"),
            format!("{:.2}", common::metric(&r, "BLEU")),
            format!("{:.2}", common::metric(&r, "BP")),
            fmt_count(r.emb_params as u64),
            format!("{:.0}×", r.space_saving),
            format!("{paper:.2}"),
        ]);
        results.push(r);
    }
    println!("{}", t.render());

    println!("\nshape checks:");
    let bleu: Vec<f64> = results.iter().map(|r| common::metric(r, "BLEU")).collect();
    println!(
        "  regular ({:.1}) is best or near-best            → {}",
        bleu[0],
        if bleu.iter().all(|&b| bleu[0] + 5.0 >= b) { "OK" } else { "VIOLATED" }
    );
    println!(
        "  higher-rank XS (2/30 = {:.1}) >= lower (2/10 = {:.1}) - 5 → {}",
        bleu[1], bleu[2],
        if bleu[1] + 5.0 >= bleu[2] { "OK" } else { "VIOLATED" }
    );
    println!(
        "  all variants reach BLEU > 0:                     → {}",
        if bleu.iter().all(|&b| b > 0.0) { "OK" } else { "VIOLATED" }
    );
}
