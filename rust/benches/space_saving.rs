//! Bench: exact reproduction of every #Params / space-saving cell of the
//! paper's Tables 1–3 (closed-form, no training), plus the related-work
//! bounds the paper argues against (§4.1): 32/b for b-bit quantization and
//! d·p/(d+p) for PCA/low-rank.
//!
//! Run: cargo bench --bench space_saving

use word2ket::embedding::stats;
use word2ket::embedding::{
    EmbeddingStore, LowRankEmbedding, QuantizedEmbedding, Word2Ket, Word2KetXS,
};
use word2ket::util::{fmt_count, Rng, Table};

fn main() {
    println!("\n=== Space-saving accounting: paper Tables 1–3, digit-for-digit ===\n");
    print!("{}", stats::render_paper_tables());

    // Cross-check the closed forms against live stores at paper scale.
    let mut rng = Rng::new(0);
    let xs41 = Word2KetXS::random(stats::SQUAD_VOCAB, stats::SQUAD_DIM, 4, 1, &mut rng);
    assert_eq!(xs41.num_params(), 380);
    let xs22 = Word2KetXS::random(stats::SQUAD_VOCAB, stats::SQUAD_DIM, 2, 2, &mut rng);
    assert_eq!(xs22.num_params(), 24_840);
    let w2k = Word2Ket::random(stats::GIGAWORD_VOCAB, 256, 4, 1, &mut rng);
    assert_eq!(w2k.num_params(), 486_848);
    println!("\nlive-store cross-check: word2ketXS 4/1 = {} params ✓, 2/2 = {} ✓, w2k 4/1 = {} ✓",
        xs41.num_params(), xs22.num_params(), w2k.num_params());

    // Related-work structural bounds (paper §4.1).
    let mut t = Table::new(vec!["Method", "Bound", "At SQuAD scale", "word2ketXS 4/1"])
        .with_title("\nwhy bit-encoding and PCA cannot match (paper §4.1)");
    let d = stats::SQUAD_VOCAB as f64;
    let p = stats::SQUAD_DIM as f64;
    let pca_bound = d * p / (d + p);
    t.add_row(vec![
        "b-bit quantization".to_string(),
        "≤ 32/b ×".to_string(),
        "≤ 32× (b=1)".to_string(),
        "93,675×".to_string(),
    ]);
    t.add_row(vec![
        "PCA / low-rank".to_string(),
        "≤ d·p/(d+p) ×".to_string(),
        format!("≤ {:.0}×", pca_bound),
        "93,675×".to_string(),
    ]);
    println!("{}", t.render());

    // Live confirmation of the bounds.
    let mut rng = Rng::new(1);
    let q8 = QuantizedEmbedding::random(2000, 512, 8, &mut rng);
    assert!(q8.space_saving_rate() <= 4.0 + 1e-9);
    let lr1 = LowRankEmbedding::random(stats::SQUAD_VOCAB, stats::SQUAD_DIM, 1, &mut rng);
    assert!(lr1.space_saving_rate() <= pca_bound + 1e-6);
    println!(
        "live: quantized-8bit = {:.2}× (≤4), lowrank k=1 = {:.0}× (≤{:.0})",
        q8.space_saving_rate(),
        lr1.space_saving_rate(),
        pca_bound
    );
    println!("\ntotal verified cells: 13 exact + 1 documented paper inconsistency (see DESIGN.md §5)");
    println!("\nbench space_saving: {} / {} / {}",
        fmt_count(7_789_568), fmt_count(8_194_816), fmt_count(35_596_500));
}
