//! # word2ket — space-efficient word embeddings inspired by quantum entanglement
//!
//! Full-system reproduction of *Panahi, Saeedi & Arodz, "word2ket:
//! Space-efficient Word Embeddings inspired by Quantum Entanglement"*
//! (ICLR 2020) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — coordinator: configs, CLI, synthetic corpora,
//!   tokenizer, batching, training/eval loops, metrics (ROUGE/BLEU/F1),
//!   checkpointing, an embedding server, and a pure-Rust mirror of the
//!   paper's tensor-product embedding algebra used on the serving path.
//! * **L2 (python/compile)** — JAX model graphs (GRU seq2seq with attention,
//!   QA reader) with embeddings represented per the paper; AOT-lowered once
//!   to HLO text.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the reconstruction
//!   hot path, validated against pure-jnp oracles.
//!
//! The runtime executes the AOT artifacts through the PJRT C API (`xla`
//! crate); Python never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use word2ket::embedding::{EmbeddingStore, Word2KetXS};
//! use word2ket::util::Rng;
//!
//! // The paper's Fig. 3 setting: 118,655-word, 300-dim embedding in 380 params.
//! let mut rng = Rng::new(0);
//! let emb = Word2KetXS::random(118_655, 300, /*order=*/4, /*rank=*/1, &mut rng);
//! assert_eq!(emb.num_params(), 380);
//! let v = emb.lookup(42); // lazily reconstructs one row
//! assert_eq!(v.len(), 300);
//! # let _ = v;
//! ```

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod data;
pub mod embedding;
pub mod error;
pub mod index;
pub mod kron;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod quant;
pub mod repr;
pub mod runtime;
pub mod serving;
pub mod simd;
pub mod snapshot;
pub mod tensor;
pub mod testing;
pub mod text;
pub mod util;

pub use error::{Error, Result};
