//! Worker pool for the serving request path.
//!
//! Replaces the single funnel worker + one global unbounded queue of the
//! original server with `workers` independent workers, one bounded queue
//! each. Requests are distributed round-robin with full-queue spill-over;
//! when every queue is at `queue_depth`, submission fails fast
//! (backpressure) instead of growing memory and latency without limit.
//!
//! Two job kinds flow through the same queues: batched row lookups and k-NN
//! similarity queries. Each worker micro-batches: once a job arrives it
//! waits `batch_window` for more to land, then drains up to `max_batch`
//! jobs. Lookup jobs across the drain are flattened into one `lookup_batch`
//! call (which dedups repeated ids) and rows are scattered back per job;
//! k-NN jobs run against the shared [`KnnIndex`] on the worker thread, so
//! index scans never block the listener.
//!
//! Latency accounting lives in the pool's [`Obs`] registry: end-to-end
//! request latencies and per-stage spans (`batch_wait`, `serialize`, the
//! cache/kernel split recorded by [`super::ShardedCache`]) land in
//! constant-memory log₂-bucket histograms — lock-free relaxed atomics, no
//! per-worker sample vectors, no growth with server age — and the queue
//! depth high-water mark is tracked at submit time.

use crate::embedding::EmbeddingStore;
use crate::index::{KnnIndex, KnnResult, Query};
use crate::obs::{Obs, Span, Stage};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued request.
pub enum Job {
    /// Reconstruct rows for `ids`; rows come back in request order.
    Lookup {
        ids: Vec<usize>,
        enqueued: Instant,
        /// Live trace span riding the job (sampled requests only); the
        /// worker fills its queue/compute stages and finishes it just
        /// before the reply is sent.
        span: Option<Span>,
        reply: mpsc::Sender<Vec<Vec<f32>>>,
    },
    /// Top-`k` similarity search against the pool's index.
    Knn {
        query: Query,
        k: usize,
        enqueued: Instant,
        /// Live trace span riding the job (see [`Job::Lookup`]).
        span: Option<Span>,
        reply: mpsc::Sender<KnnResult>,
    },
}

/// Submission failed because every queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Overloaded;

struct ShardQueue {
    jobs: Mutex<VecDeque<Job>>,
    ready: Condvar,
}

struct PoolShared {
    queues: Vec<ShardQueue>,
    store: Arc<dyn EmbeddingStore>,
    /// Index serving `Job::Knn`; a pool built without one drops knn reply
    /// channels, which surfaces to the caller as an immediate disconnect on
    /// its receiver (not a hang). Servers always attach an index.
    index: Option<Arc<dyn KnnIndex>>,
    stop: AtomicBool,
    served: AtomicU64,
    rejected: AtomicU64,
    /// k-NN accounting, incremented by workers as queries complete (like
    /// `served`, and unlike caller-side counting it still counts queries
    /// whose caller gave up waiting).
    knn_queries: AtomicU64,
    knn_candidates: AtomicU64,
    knn_probes: AtomicU64,
    /// Metrics plane: e2e/stage/batch histograms + queue high-water mark.
    /// Shared with the serving state (and across model generations), so
    /// its series never reset while the process lives.
    obs: Arc<Obs>,
    depth: usize,
    window: Duration,
    max_batch: usize,
}

/// The pool handle: submit jobs, read stats, shut down.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next: AtomicUsize,
}

impl WorkerPool {
    pub fn new(
        store: Arc<dyn EmbeddingStore>,
        workers: usize,
        queue_depth: usize,
        batch_window: Duration,
        max_batch: usize,
        index: Option<Arc<dyn KnnIndex>>,
        obs: Arc<Obs>,
    ) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            queues: (0..workers)
                .map(|_| ShardQueue { jobs: Mutex::new(VecDeque::new()), ready: Condvar::new() })
                .collect(),
            store,
            index,
            stop: AtomicBool::new(false),
            served: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            knn_queries: AtomicU64::new(0),
            knn_candidates: AtomicU64::new(0),
            knn_probes: AtomicU64::new(0),
            obs,
            depth: queue_depth.max(1),
            window: batch_window,
            max_batch: max_batch.max(1),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool { shared, workers: Mutex::new(handles), next: AtomicUsize::new(0) }
    }

    /// Enqueue a job. Round-robin across queues, spilling to the next queue
    /// when the preferred one is full; errors only when all are full.
    pub fn submit(&self, job: Job) -> Result<(), Overloaded> {
        let n = self.shared.queues.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed) % n;
        for off in 0..n {
            let q = &self.shared.queues[(start + off) % n];
            let mut jobs = q.jobs.lock().unwrap();
            // The stop check must happen under the queue lock: workers take
            // the same lock before deciding to exit, so a job enqueued here
            // with stop still false is guaranteed a drain pass. Checked
            // before the flag means a job could land just after the last
            // worker exited and strand until the caller's timeout.
            if self.shared.stop.load(Ordering::SeqCst) {
                break;
            }
            if jobs.len() < self.shared.depth {
                jobs.push_back(job);
                let depth = jobs.len();
                drop(jobs);
                self.shared.obs.note_queue_depth(depth);
                q.ready.notify_one();
                return Ok(());
            }
        }
        self.shared.rejected.fetch_add(1, Ordering::Relaxed);
        Err(Overloaded)
    }

    /// Total rows served across all workers (lookup jobs only; knn queries
    /// are tracked separately in [`Self::knn_counters`]).
    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::Relaxed)
    }

    /// Jobs rejected for backpressure.
    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// k-NN accounting: (queries answered, candidates exactly scored,
    /// coarse cells probed), counted worker-side as scans complete.
    pub fn knn_counters(&self) -> (u64, u64, u64) {
        (
            self.shared.knn_queries.load(Ordering::Relaxed),
            self.shared.knn_candidates.load(Ordering::Relaxed),
            self.shared.knn_probes.load(Ordering::Relaxed),
        )
    }

    /// The metrics registry this pool records into — the end-to-end
    /// latency histogram here is the `STATS` p50/p99 source.
    pub fn obs(&self) -> &Arc<Obs> {
        &self.shared.obs
    }

    /// Stop workers after they drain their queues; idempotent.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        for q in &self.shared.queues {
            q.ready.notify_all();
        }
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            h.join().ok();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Block until this worker's queue has a job (or the pool stops and the
/// queue is drained), then micro-batch: wait `window` for stragglers and
/// drain up to `max_batch`.
fn take_batch(shared: &PoolShared, w: usize) -> Option<Vec<Job>> {
    let q = &shared.queues[w];
    let mut jobs = q.jobs.lock().unwrap();
    loop {
        if !jobs.is_empty() {
            break;
        }
        if shared.stop.load(Ordering::SeqCst) {
            return None;
        }
        let (guard, _) = q.ready.wait_timeout(jobs, Duration::from_millis(20)).unwrap();
        jobs = guard;
    }
    if !shared.window.is_zero() && jobs.len() < shared.max_batch {
        drop(jobs);
        std::thread::sleep(shared.window);
        jobs = q.jobs.lock().unwrap();
    }
    let take = jobs.len().min(shared.max_batch);
    Some(jobs.drain(..take).collect())
}

fn worker_loop(shared: &PoolShared, w: usize) {
    // Per-worker buffers, reused across micro-batches: the flattened id
    // list, the reconstruction arena `lookup_batch_into` fills, and the job
    // split lists. In steady state a drain allocates only the reply rows it
    // actually sends.
    let mut all_ids: Vec<usize> = Vec::new();
    let mut flat: Vec<f32> = Vec::new();
    let mut lookups = Vec::new();
    let mut knns = Vec::new();
    let timing = shared.obs.enabled();
    while let Some(batch) = take_batch(shared, w) {
        // The drain boundary: everything before it is `batch_wait`, the
        // span from here to the last reply is the batch's service time.
        let drained = Instant::now();
        // Split the drain: lookups are scattered and answered first — their
        // rows come from one flat store call and must not wait behind index
        // scans that happen to share the micro-batch.
        all_ids.clear();
        for job in batch {
            match job {
                Job::Lookup { ids, enqueued, span, reply } => {
                    all_ids.extend_from_slice(&ids);
                    lookups.push((ids, enqueued, span, reply));
                }
                Job::Knn { query, k, enqueued, span, reply } => {
                    knns.push((query, k, enqueued, span, reply))
                }
            }
        }

        // One flat store call covering every lookup job in the drain: dedup
        // inside lookup_batch_into collapses the Zipf head across all of
        // them, and the arena write skips the per-drain tensor allocation.
        // The cache/kernel stage split for this span is recorded per-row by
        // the [`super::ShardedCache`] underneath.
        if !lookups.is_empty() {
            shared.store.lookup_batch_into(&all_ids, &mut flat);
            let dim = shared.store.dim();
            let fetched = Instant::now();
            let mut row = 0usize;
            let mut slowest_wait = Duration::ZERO;
            for (ids, enqueued, span, reply) in lookups.drain(..) {
                let mut rows = Vec::with_capacity(ids.len());
                for _ in 0..ids.len() {
                    rows.push(flat[row * dim..(row + 1) * dim].to_vec());
                    row += 1;
                }
                // Each job's latency is recorded *before* its reply is
                // sent, so a caller that has received its reply is
                // guaranteed to see the request in STATS.
                if timing {
                    let wait = drained.duration_since(enqueued);
                    slowest_wait = slowest_wait.max(wait);
                    shared.obs.record_stage(Stage::BatchWait, wait);
                    shared.obs.record_e2e(Instant::now().duration_since(enqueued));
                }
                // The span is finished (ring-visible) before the reply is
                // sent, so a caller that has its rows can fetch the trace.
                // The `cache` stage carries the whole batch fetch span —
                // cache + kernel combined, same granularity as the slow
                // ring below.
                if let Some(mut s) = span {
                    s.stage(Stage::BatchWait, drained.duration_since(enqueued).as_micros() as u64);
                    s.stage(Stage::Cache, fetched.duration_since(drained).as_micros() as u64);
                    s.stage(
                        Stage::Serialize,
                        Instant::now().duration_since(fetched).as_micros() as u64,
                    );
                    shared.obs.tracer().finish(s);
                }
                shared.served.fetch_add(ids.len() as u64, Ordering::Relaxed);
                let _ = reply.send(rows);
            }
            if timing {
                let done = Instant::now();
                shared.obs.record_stage(Stage::Serialize, done.duration_since(fetched));
                shared.obs.record_batch(done.duration_since(drained));
                // Slow-ring entry for the batch's longest-waiting request.
                // The `cache` slot here carries the whole fetch span
                // (cache + kernel combined — the split is batch-granular).
                shared.obs.note_slow(
                    "lookup",
                    slowest_wait + done.duration_since(drained),
                    vec![
                        (Stage::BatchWait, slowest_wait.as_micros() as u64),
                        (Stage::Cache, fetched.duration_since(drained).as_micros() as u64),
                        (Stage::Serialize, done.duration_since(fetched).as_micros() as u64),
                    ],
                );
            }
        }

        // Index scans run after lookup replies are out (a brute scan is
        // milliseconds; row replies must not block on it).
        for (query, k, enqueued, span, reply) in knns.drain(..) {
            match shared.index.as_deref() {
                Some(index) => {
                    let scan_start = Instant::now();
                    let result = index.top_k(&query, k);
                    let stats = result.1;
                    shared.knn_queries.fetch_add(1, Ordering::Relaxed);
                    shared.knn_candidates.fetch_add(stats.candidates as u64, Ordering::Relaxed);
                    shared.knn_probes.fetch_add(stats.probes as u64, Ordering::Relaxed);
                    if timing {
                        let done = Instant::now();
                        let wait = scan_start.duration_since(enqueued);
                        let scan = done.duration_since(scan_start);
                        let total = done.duration_since(enqueued);
                        shared.obs.record_stage(Stage::BatchWait, wait);
                        shared.obs.record_stage(Stage::Kernel, scan);
                        shared.obs.record_e2e(total);
                        shared.obs.note_slow(
                            "knn",
                            total,
                            vec![
                                (Stage::BatchWait, wait.as_micros() as u64),
                                (Stage::Kernel, scan.as_micros() as u64),
                            ],
                        );
                    }
                    // Finished (ring-visible) before the reply, like the
                    // lookup path above.
                    if let Some(mut s) = span {
                        let done = Instant::now();
                        s.stage(
                            Stage::BatchWait,
                            scan_start.duration_since(enqueued).as_micros() as u64,
                        );
                        s.stage(Stage::Kernel, done.duration_since(scan_start).as_micros() as u64);
                        shared.obs.tracer().finish(s);
                    }
                    let _ = reply.send(result);
                }
                // A pool without an index drops the reply channel; the
                // caller's recv fails immediately with a disconnect
                // (servers always attach one).
                None => drop(reply),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{EmbeddingStore, RegularEmbedding};
    use crate::index::{BruteForce, Scorer};
    use crate::util::Rng;

    fn pool_with(
        workers: usize,
        depth: usize,
        window_us: u64,
        with_index: bool,
    ) -> (WorkerPool, Arc<dyn EmbeddingStore>) {
        let mut rng = Rng::new(0);
        let store: Arc<dyn EmbeddingStore> = Arc::new(RegularEmbedding::random(64, 8, &mut rng));
        let index: Option<Arc<dyn KnnIndex>> = if with_index {
            Some(Arc::new(BruteForce::new(Scorer::new(store.clone(), false))))
        } else {
            None
        };
        (
            WorkerPool::new(
                store.clone(),
                workers,
                depth,
                Duration::from_micros(window_us),
                16,
                index,
                Arc::new(Obs::default()),
            ),
            store,
        )
    }

    fn pool(workers: usize, depth: usize, window_us: u64) -> (WorkerPool, Arc<dyn EmbeddingStore>) {
        pool_with(workers, depth, window_us, false)
    }

    fn submit_ids(pool: &WorkerPool, ids: Vec<usize>) -> mpsc::Receiver<Vec<Vec<f32>>> {
        let (tx, rx) = mpsc::channel();
        pool.submit(Job::Lookup { ids, enqueued: Instant::now(), span: None, reply: tx })
            .unwrap();
        rx
    }

    #[test]
    fn rows_match_store_across_workers() {
        let (pool, store) = pool(4, 32, 50);
        let rxs: Vec<_> = (0..20)
            .map(|i| {
                let ids = vec![i % 64, (i * 7) % 64, 5];
                (ids.clone(), submit_ids(&pool, ids))
            })
            .collect();
        for (ids, rx) in rxs {
            let rows = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(rows.len(), ids.len());
            for (row, &id) in rows.iter().zip(&ids) {
                assert_eq!(row.as_slice(), store.lookup(id).as_slice());
            }
        }
        assert_eq!(pool.served(), 60);
        assert_eq!(pool.obs().e2e().count(), 20);
        pool.shutdown();
    }

    #[test]
    fn knn_jobs_flow_through_the_pool() {
        let (pool, store) = pool_with(2, 32, 50, true);
        let (tx, rx) = mpsc::channel();
        pool.submit(Job::Knn {
            query: Query::Id(5),
            k: 4,
            enqueued: Instant::now(),
            span: None,
            reply: tx,
        })
        .unwrap();
        let (neighbors, stats) = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(neighbors.len(), 4);
        assert_eq!(stats.candidates, store.vocab_size() - 1);
        assert!(neighbors.iter().all(|n| n.id != 5));
        // Knn latency lands in the same e2e histogram; rows served stays 0;
        // worker-side knn counters reflect the scan.
        assert_eq!(pool.obs().e2e().count(), 1);
        assert_eq!(pool.served(), 0);
        assert_eq!(pool.knn_counters(), (1, 63, 0));
        pool.shutdown();
    }

    #[test]
    fn mixed_batches_serve_both_kinds() {
        let (pool, store) = pool_with(1, 64, 2_000, true);
        let look = submit_ids(&pool, vec![1, 2, 3]);
        let (tx, knn_rx) = mpsc::channel();
        pool.submit(Job::Knn {
            query: Query::Id(1),
            k: 2,
            enqueued: Instant::now(),
            span: None,
            reply: tx,
        })
        .unwrap();
        let rows = look.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(rows[2], store.lookup(3));
        let (neighbors, _) = knn_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(neighbors.len(), 2);
        pool.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // One worker, depth 1, long window: the worker sleeps inside the
        // window while more submits pile in; beyond (in-flight + depth) they
        // must be rejected, not buffered without bound.
        let (pool, _) = pool(1, 1, 50_000);
        let mut receivers = Vec::new();
        let mut rejected = 0usize;
        for _ in 0..16 {
            let (tx, rx) = mpsc::channel();
            let job = Job::Lookup { ids: vec![1], enqueued: Instant::now(), span: None, reply: tx };
            match pool.submit(job) {
                Ok(()) => receivers.push(rx),
                Err(Overloaded) => rejected += 1,
            }
        }
        assert!(rejected > 0, "no submission was rejected");
        assert!(pool.rejected() as usize == rejected);
        // Accepted jobs still complete.
        for rx in receivers {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        pool.shutdown();
    }

    #[test]
    fn queue_depth_high_water_is_tracked() {
        // One worker with a long batch window: jobs submitted while it
        // sleeps inside the window pile up in the queue, so the high-water
        // mark must reflect the pile, not just 1.
        let (pool, _) = pool(1, 8, 50_000);
        let rxs: Vec<_> = (0..5).map(|i| submit_ids(&pool, vec![i])).collect();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        assert!(
            pool.obs().queue_depth_hwm() >= 3,
            "queue high-water {} never saw the pile-up",
            pool.obs().queue_depth_hwm()
        );
        pool.shutdown();
    }

    #[test]
    fn stage_histograms_partition_end_to_end_latency() {
        // Acceptance: with every stage instrumented (batch_wait + the
        // cache/kernel split from ShardedCache + serialize), the per-stage
        // sums must account for the e2e sum to within one log₂ bucket width
        // plus the per-sample microsecond truncation.
        let obs = Arc::new(Obs::default());
        let mut rng = Rng::new(0);
        let mut cache = crate::serving::ShardedCache::new(
            Box::new(RegularEmbedding::random(64, 8, &mut rng)),
            2,
            64,
        );
        cache.set_obs(obs.clone());
        let store: Arc<dyn EmbeddingStore> = Arc::new(cache);
        let pool =
            WorkerPool::new(store, 1, 32, Duration::from_micros(0), 16, None, obs.clone());
        let n = 50u64;
        // Sequential awaited submits with a zero window: every job is its
        // own single-id batch, so per-job and per-batch stages line up.
        for i in 0..n as usize {
            let rx = submit_ids(&pool, vec![i % 64]);
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        assert_eq!(obs.e2e().count(), n);
        assert_eq!(obs.stage(Stage::BatchWait).count(), n);
        assert_eq!(obs.stage(Stage::Serialize).count(), n);
        // Unique ids 0..50: all misses, so cache and kernel both record.
        assert_eq!(obs.stage(Stage::Cache).count(), n);
        assert_eq!(obs.stage(Stage::Kernel).count(), n);
        let stage_total: u64 = [Stage::BatchWait, Stage::Cache, Stage::Kernel, Stage::Serialize]
            .iter()
            .map(|&s| obs.stage(s).sum())
            .sum();
        let e2e_total = obs.e2e().sum();
        let tol = crate::obs::bucket_width(stage_total.max(e2e_total)).max(4 * n);
        let gap = stage_total.abs_diff(e2e_total);
        assert!(
            gap <= tol,
            "stage sum {stage_total}us vs e2e sum {e2e_total}us: gap {gap} > tol {tol}"
        );
        pool.shutdown();
    }

    #[test]
    fn shutdown_drains_pending_jobs() {
        let (pool, _) = pool(2, 64, 20_000);
        let rxs: Vec<_> = (0..8).map(|i| submit_ids(&pool, vec![i])).collect();
        pool.shutdown();
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(1)).is_ok(), "job dropped on shutdown");
        }
    }
}
