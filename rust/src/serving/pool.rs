//! Worker pool for the serving request path.
//!
//! Replaces the single funnel worker + one global unbounded queue of the
//! original server with `workers` independent workers, one bounded queue
//! each. Requests are distributed round-robin with full-queue spill-over;
//! when every queue is at `queue_depth`, submission fails fast
//! (backpressure) instead of growing memory and latency without limit.
//!
//! Each worker micro-batches: once a job arrives it waits `batch_window` for
//! more to land, then drains up to `max_batch` jobs, flattens their ids into
//! one `lookup_batch` call (which dedups repeated ids), and scatters rows
//! back to each job's reply channel. Per-worker latency summaries avoid a
//! shared stats lock on the hot path and are merged on demand for `STATS`.

use crate::embedding::EmbeddingStore;
use crate::util::Summary;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued lookup request: ids in, rows out through `reply`.
pub struct Job {
    pub ids: Vec<usize>,
    pub enqueued: Instant,
    pub reply: mpsc::Sender<Vec<Vec<f32>>>,
}

/// Submission failed because every queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Overloaded;

struct ShardQueue {
    jobs: Mutex<VecDeque<Job>>,
    ready: Condvar,
}

struct PoolShared {
    queues: Vec<ShardQueue>,
    store: Arc<dyn EmbeddingStore>,
    stop: AtomicBool,
    served: AtomicU64,
    rejected: AtomicU64,
    latencies_us: Vec<Mutex<Summary>>,
    depth: usize,
    window: Duration,
    max_batch: usize,
}

/// The pool handle: submit jobs, read stats, shut down.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    next: AtomicUsize,
}

impl WorkerPool {
    pub fn new(
        store: Arc<dyn EmbeddingStore>,
        workers: usize,
        queue_depth: usize,
        batch_window: Duration,
        max_batch: usize,
    ) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(PoolShared {
            queues: (0..workers)
                .map(|_| ShardQueue { jobs: Mutex::new(VecDeque::new()), ready: Condvar::new() })
                .collect(),
            store,
            stop: AtomicBool::new(false),
            served: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            latencies_us: (0..workers).map(|_| Mutex::new(Summary::new())).collect(),
            depth: queue_depth.max(1),
            window: batch_window,
            max_batch: max_batch.max(1),
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("serve-worker-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("spawn worker")
            })
            .collect();
        WorkerPool { shared, workers: Mutex::new(handles), next: AtomicUsize::new(0) }
    }

    /// Enqueue a job. Round-robin across queues, spilling to the next queue
    /// when the preferred one is full; errors only when all are full.
    pub fn submit(&self, job: Job) -> Result<(), Overloaded> {
        let n = self.shared.queues.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed) % n;
        for off in 0..n {
            let q = &self.shared.queues[(start + off) % n];
            let mut jobs = q.jobs.lock().unwrap();
            // The stop check must happen under the queue lock: workers take
            // the same lock before deciding to exit, so a job enqueued here
            // with stop still false is guaranteed a drain pass. Checked
            // before the flag means a job could land just after the last
            // worker exited and strand until the caller's timeout.
            if self.shared.stop.load(Ordering::SeqCst) {
                break;
            }
            if jobs.len() < self.shared.depth {
                jobs.push_back(job);
                drop(jobs);
                q.ready.notify_one();
                return Ok(());
            }
        }
        self.shared.rejected.fetch_add(1, Ordering::Relaxed);
        Err(Overloaded)
    }

    /// Total rows served across all workers.
    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::Relaxed)
    }

    /// Jobs rejected for backpressure.
    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Merge the per-worker latency summaries into one view.
    pub fn latency_summary(&self) -> Summary {
        let mut merged = Summary::new();
        for lat in &self.shared.latencies_us {
            merged.merge(&lat.lock().unwrap());
        }
        merged
    }

    /// Stop workers after they drain their queues; idempotent.
    pub fn shutdown(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        for q in &self.shared.queues {
            q.ready.notify_all();
        }
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            h.join().ok();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Block until this worker's queue has a job (or the pool stops and the
/// queue is drained), then micro-batch: wait `window` for stragglers and
/// drain up to `max_batch`.
fn take_batch(shared: &PoolShared, w: usize) -> Option<Vec<Job>> {
    let q = &shared.queues[w];
    let mut jobs = q.jobs.lock().unwrap();
    loop {
        if !jobs.is_empty() {
            break;
        }
        if shared.stop.load(Ordering::SeqCst) {
            return None;
        }
        let (guard, _) = q.ready.wait_timeout(jobs, Duration::from_millis(20)).unwrap();
        jobs = guard;
    }
    if !shared.window.is_zero() && jobs.len() < shared.max_batch {
        drop(jobs);
        std::thread::sleep(shared.window);
        jobs = q.jobs.lock().unwrap();
    }
    let take = jobs.len().min(shared.max_batch);
    Some(jobs.drain(..take).collect())
}

/// Per-worker latency samples kept for percentile queries. The summary is a
/// *tumbling* window: once it fills it is reset and starts collecting fresh,
/// so STATS reflects roughly the most recent window rather than all of
/// uptime. Unbounded accumulation would leak memory and make every STATS
/// percentile sort grow with server age.
const LATENCY_WINDOW: usize = 1 << 16;

fn worker_loop(shared: &PoolShared, w: usize) {
    while let Some(batch) = take_batch(shared, w) {
        // One flat store call per drained batch: dedup inside lookup_batch
        // collapses the Zipf head across all jobs in the batch.
        let mut all_ids = Vec::new();
        for job in &batch {
            all_ids.extend_from_slice(&job.ids);
        }
        let tensor = shared.store.lookup_batch(&all_ids);
        let dim = shared.store.dim();
        let now = Instant::now();
        let mut row = 0usize;
        let mut lat = shared.latencies_us[w].lock().unwrap();
        if lat.len() >= LATENCY_WINDOW {
            *lat = Summary::new();
        }
        for job in batch {
            let mut rows = Vec::with_capacity(job.ids.len());
            for _ in 0..job.ids.len() {
                rows.push(tensor.data()[row * dim..(row + 1) * dim].to_vec());
                row += 1;
            }
            lat.add(now.duration_since(job.enqueued).as_secs_f64() * 1e6);
            shared.served.fetch_add(job.ids.len() as u64, Ordering::Relaxed);
            let _ = job.reply.send(rows);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{EmbeddingStore, RegularEmbedding};
    use crate::util::Rng;

    fn pool(workers: usize, depth: usize, window_us: u64) -> (WorkerPool, Arc<dyn EmbeddingStore>) {
        let mut rng = Rng::new(0);
        let store: Arc<dyn EmbeddingStore> =
            Arc::new(RegularEmbedding::random(64, 8, &mut rng));
        (
            WorkerPool::new(
                store.clone(),
                workers,
                depth,
                Duration::from_micros(window_us),
                16,
            ),
            store,
        )
    }

    fn submit_ids(pool: &WorkerPool, ids: Vec<usize>) -> mpsc::Receiver<Vec<Vec<f32>>> {
        let (tx, rx) = mpsc::channel();
        pool.submit(Job { ids, enqueued: Instant::now(), reply: tx }).unwrap();
        rx
    }

    #[test]
    fn rows_match_store_across_workers() {
        let (pool, store) = pool(4, 32, 50);
        let rxs: Vec<_> = (0..20)
            .map(|i| {
                let ids = vec![i % 64, (i * 7) % 64, 5];
                (ids.clone(), submit_ids(&pool, ids))
            })
            .collect();
        for (ids, rx) in rxs {
            let rows = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert_eq!(rows.len(), ids.len());
            for (row, &id) in rows.iter().zip(&ids) {
                assert_eq!(row.as_slice(), store.lookup(id).as_slice());
            }
        }
        assert_eq!(pool.served(), 60);
        assert_eq!(pool.latency_summary().len(), 20);
        pool.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // One worker, depth 1, long window: the worker sleeps inside the
        // window while more submits pile in; beyond (in-flight + depth) they
        // must be rejected, not buffered without bound.
        let (pool, _) = pool(1, 1, 50_000);
        let mut receivers = Vec::new();
        let mut rejected = 0usize;
        for _ in 0..16 {
            let (tx, rx) = mpsc::channel();
            match pool.submit(Job { ids: vec![1], enqueued: Instant::now(), reply: tx }) {
                Ok(()) => receivers.push(rx),
                Err(Overloaded) => rejected += 1,
            }
        }
        assert!(rejected > 0, "no submission was rejected");
        assert!(pool.rejected() as usize == rejected);
        // Accepted jobs still complete.
        for rx in receivers {
            rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        pool.shutdown();
    }

    #[test]
    fn shutdown_drains_pending_jobs() {
        let (pool, _) = pool(2, 64, 20_000);
        let rxs: Vec<_> = (0..8).map(|i| submit_ids(&pool, vec![i])).collect();
        pool.shutdown();
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(1)).is_ok(), "job dropped on shutdown");
        }
    }
}
