//! Production serving layer: sharded hot-row cache, worker pool, binary wire
//! protocol, the k-NN request path, and live model hot-swap.
//!
//! This is the request path behind `w2k serve` and the `serve_embeddings`
//! example. The paper's word2ketXS table is small enough to live in cache
//! but must be *reconstructed* per lookup, so at production traffic the hot
//! path is reconstruction compute — this layer attacks exactly that:
//!
//! * [`cache::ShardedCache`] — N-way sharded LRU with frequency-based
//!   admission wrapping any [`EmbeddingStore`]; Zipf-head tokens are
//!   reconstructed once and then served as memcpys.
//! * [`pool::WorkerPool`] — per-shard bounded queues drained in micro-batches
//!   by independent workers, with fail-fast backpressure. Latency lands in
//!   the shared [`crate::obs::Obs`] registry's log₂-bucket histograms
//!   (`STATS` percentiles and the `METRICS` exposition read the same
//!   series). Lookup *and* k-NN jobs flow through the same queues.
//! * [`wire`] — a length-prefixed binary protocol negotiated on the same
//!   TCP listener as the text protocol (see `coordinator::server`).
//! * similarity search — a [`crate::index::KnnIndex`] (brute force or IVF,
//!   `[index]` config) built over the cached store at startup serves
//!   `KNN`/`OP_KNN` queries, scoring in factored space when the store is
//!   tensorized.
//!
//! ## Model generations and hot swap
//!
//! Cache + index + pool together form one immutable **model generation**
//! (`Arc<Model>`). Every request clones the current generation's `Arc` once
//! and runs entirely against it. `RELOAD <path>` / `OP_RELOAD` builds a new
//! generation from a snapshot file on the *calling connection's* thread
//! (listener and workers keep serving), validates it, then atomically swaps
//! the shared pointer: new requests land on the new model while in-flight
//! requests drain on the old one, whose workers shut down only after the
//! last holder drops it — zero failed requests across a swap. The retired
//! generation's counters fold into a carry so `STATS` stays cumulative;
//! `model_generation` and `snapshot_bytes` expose the swap state.
//!
//! Configuration arrives via `[serving]` in the experiment TOML
//! ([`crate::config::ServingConfig`]): `shards`, `cache_rows`,
//! `batch_window_us`, `queue_depth`, `max_batch`; the index via `[index]`
//! ([`crate::config::IndexConfig`]): `kind`, `nlist`, `nprobe`, `cosine`;
//! snapshot startup/reload behavior via `[snapshot]`
//! ([`crate::config::SnapshotConfig`]): `path`, `mmap`, `codec`.

pub mod cache;
pub mod pool;
pub mod wire;

pub use cache::{CacheStats, ShardedCache};
pub use pool::{Job, Overloaded, WorkerPool};
pub use wire::{BinaryClient, WireError, WireStats};

use crate::config::{IndexConfig, IndexKind, ServingConfig};
use crate::embedding::EmbeddingStore;
use crate::error::Error;
use crate::index::{build_index, IvfIndex, KnnIndex, Neighbor, Query, Scorer};
use crate::obs::{Obs, ObsConfig, Span, Stage, TraceContext};
use crate::snapshot::{self, IndexPayload, Snapshot, SnapshotStore};
use std::fmt::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Why a request could not be served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LookupError {
    /// Request contained no ids.
    Empty,
    /// Some id is >= vocab_size.
    OutOfRange,
    /// Malformed knn query (k == 0, or query vector of the wrong dimension).
    BadQuery,
    /// Every pool queue is full (backpressure).
    Overloaded,
    /// The pool did not reply within the request deadline.
    Timeout,
}

impl LookupError {
    /// Short status tag stamped on trace spans for failed requests.
    fn trace_tag(self) -> &'static str {
        match self {
            LookupError::Empty => "empty",
            LookupError::OutOfRange => "range",
            LookupError::BadQuery => "bad_query",
            LookupError::Overloaded => "overloaded",
            LookupError::Timeout => "timeout",
        }
    }
}

impl std::fmt::Display for LookupError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            LookupError::Empty => "empty request",
            LookupError::OutOfRange => "id out of range",
            LookupError::BadQuery => "bad query",
            LookupError::Overloaded => "overloaded",
            LookupError::Timeout => "timeout",
        };
        write!(f, "{s}")
    }
}

/// Aggregate serving statistics (pool + cache + knn + swap state), zeros
/// (and generation 1) before any traffic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingStats {
    pub p50_us: f64,
    pub p99_us: f64,
    pub served: u64,
    pub rejected: u64,
    pub cache: CacheStats,
    /// k-NN queries answered.
    pub knn_queries: u64,
    /// Candidates exactly scored across all knn queries.
    pub knn_candidates: u64,
    /// Mean IVF cells probed per knn query (0 for brute force / no traffic).
    pub knn_mean_probes: f64,
    /// Current model generation (1 at boot, +1 per successful reload).
    pub model_generation: u64,
    /// On-disk bytes of the snapshot backing the current generation (0 when
    /// the model was built in memory).
    pub snapshot_bytes: u64,
    /// Transient accept(2) failures the listener survived (EMFILE /
    /// ECONNABORTED backoff-and-retry events).
    pub accept_errors: u64,
    /// SIMD dispatch level of the serving kernels
    /// ([`crate::simd::SimdLevel::code`]: 0 = scalar, 1 = sse2,
    /// 2 = avx2+fma). Constant per process; on the wire so operators can
    /// see which kernel set a replica runs without shell access.
    pub simd_level: u64,
    /// Stored precision of the served factor payload in bits per value
    /// ([`crate::repr::Repr::payload_bits`]): 32 for float stores, the
    /// packed code width (16/8/4/2/1) for quantized payloads. Changes on
    /// hot swap; the cluster roll-up reports the maximum across replicas.
    pub payload_bits: u64,
}

impl ServingStats {
    /// The STATS payload in [`wire::STATS_FIELD_NAMES`] order — the single
    /// source both protocols serialize from (binary writes these f64s
    /// verbatim; the text line formats them name=value), so the two cannot
    /// drift when a field is added.
    pub fn fields(&self) -> [f64; wire::STATS_FIELDS] {
        [
            self.p50_us,
            self.p99_us,
            self.served as f64,
            self.cache.hits as f64,
            self.cache.misses as f64,
            self.rejected as f64,
            self.knn_queries as f64,
            self.knn_candidates as f64,
            self.knn_mean_probes,
            self.model_generation as f64,
            self.snapshot_bytes as f64,
            self.accept_errors as f64,
            self.simd_level as f64,
            self.payload_bits as f64,
        ]
    }
}

/// One immutable model generation: cache + index + worker pool.
struct Model {
    store: Arc<ShardedCache>,
    index: Arc<dyn KnnIndex>,
    pool: WorkerPool,
    snapshot_bytes: u64,
}

/// Counters carried across generations so `STATS` stays cumulative after a
/// hot swap (a retired pool's totals fold in here once it drains).
#[derive(Default)]
struct Carry {
    served: AtomicU64,
    rejected: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    knn_queries: AtomicU64,
    knn_candidates: AtomicU64,
    knn_probes: AtomicU64,
}

/// Shared per-server serving state: the current model generation plus the
/// configuration needed to build replacement generations on reload.
///
/// Protocol handlers (text in `coordinator::server`, binary in [`wire`])
/// validate and format; everything between socket and store lives here.
pub struct ServingState {
    model: Mutex<Arc<Model>>,
    serving_cfg: ServingConfig,
    index_cfg: IndexConfig,
    /// Whether reloads map the snapshot (zero-copy) or heap-buffer it;
    /// follows `[snapshot] mmap` so boot and reload behave identically.
    reload_mmap: bool,
    generation: AtomicU64,
    carry: Arc<Carry>,
    timeout: Duration,
    /// Transient accept(2) failures survived by this state's listener;
    /// lives here (not in the pool) so it persists across hot swaps.
    accept_errors: AtomicU64,
    /// The metrics plane: e2e/stage/batch histograms, reload durations,
    /// queue high-water, the slow-request ring. One registry for the whole
    /// process lifetime — each new model generation's cache and pool record
    /// into the *same* histograms, so every series is monotonic across hot
    /// swaps by construction.
    obs: Arc<Obs>,
}

impl ServingState {
    pub fn new(
        inner: Box<dyn EmbeddingStore>,
        cfg: &ServingConfig,
        index_cfg: &IndexConfig,
    ) -> ServingState {
        Self::new_with_obs(inner, cfg, index_cfg, &ObsConfig::default())
    }

    /// [`Self::new`] with an explicit `[obs]` config (the server's entry
    /// point; the plain constructor defaults to metrics enabled).
    pub fn new_with_obs(
        inner: Box<dyn EmbeddingStore>,
        cfg: &ServingConfig,
        index_cfg: &IndexConfig,
        obs_cfg: &ObsConfig,
    ) -> ServingState {
        let obs = Arc::new(Obs::new(obs_cfg));
        let model = Self::assemble(inner, cfg, index_cfg, None, 0, &obs);
        Self::with_model(model, cfg, index_cfg, obs)
    }

    /// Boot directly from a snapshot file (`[snapshot] path`): the store
    /// serves off the (optionally memory-mapped) file and, when the
    /// snapshot embeds IVF centroids, the index loads instead of re-running
    /// k-means.
    pub fn from_snapshot(
        path: &Path,
        cfg: &ServingConfig,
        index_cfg: &IndexConfig,
        mmap: bool,
    ) -> crate::Result<ServingState> {
        Self::from_snapshot_with_obs(path, cfg, index_cfg, mmap, &ObsConfig::default())
    }

    /// [`Self::from_snapshot`] with an explicit `[obs]` config.
    pub fn from_snapshot_with_obs(
        path: &Path,
        cfg: &ServingConfig,
        index_cfg: &IndexConfig,
        mmap: bool,
        obs_cfg: &ObsConfig,
    ) -> crate::Result<ServingState> {
        let obs = Arc::new(Obs::new(obs_cfg));
        let model = Self::model_from_snapshot(path, cfg, index_cfg, mmap, &obs)?;
        let mut state = Self::with_model(model, cfg, index_cfg, obs);
        state.reload_mmap = mmap;
        Ok(state)
    }

    /// Set how future `RELOAD`s open snapshots (`[snapshot] mmap`); defaults
    /// to memory-mapped.
    pub fn set_reload_mmap(&mut self, mmap: bool) {
        self.reload_mmap = mmap;
    }

    fn with_model(
        model: Model,
        cfg: &ServingConfig,
        index_cfg: &IndexConfig,
        obs: Arc<Obs>,
    ) -> ServingState {
        ServingState {
            model: Mutex::new(Arc::new(model)),
            serving_cfg: cfg.clone(),
            index_cfg: index_cfg.clone(),
            reload_mmap: true,
            generation: AtomicU64::new(1),
            carry: Arc::new(Carry::default()),
            timeout: Duration::from_secs(5),
            accept_errors: AtomicU64::new(0),
            obs,
        }
    }

    /// Count one transient accept(2) failure the listener survived.
    pub fn note_accept_error(&self) {
        self.accept_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Build one model generation over `inner`. `index_payload` (from a
    /// snapshot) skips IVF training when compatible with the `[index]`
    /// config; incompatible or invalid payloads fall back to a fresh build
    /// rather than failing the whole generation.
    fn assemble(
        inner: Box<dyn EmbeddingStore>,
        cfg: &ServingConfig,
        index_cfg: &IndexConfig,
        index_payload: Option<IndexPayload>,
        snapshot_bytes: u64,
        obs: &Arc<Obs>,
    ) -> Model {
        let mut cache = ShardedCache::new(inner, cfg.shards, cfg.cache_rows);
        cache.set_obs(obs.clone());
        let store = Arc::new(cache);
        let index_store: Arc<dyn EmbeddingStore> = store.clone();
        let mut index: Option<Arc<dyn KnnIndex>> = None;
        if index_cfg.kind == IndexKind::Ivf {
            if let Some(p) = index_payload {
                if p.cosine == index_cfg.cosine {
                    let scorer = Scorer::new(index_store.clone(), index_cfg.cosine);
                    match IvfIndex::from_parts(scorer, index_cfg.nprobe, p.centroids, p.lists) {
                        Ok(ivf) => {
                            index = Some(Arc::new(ivf.with_scan_threads(index_cfg.scan_threads)))
                        }
                        Err(e) => crate::warn!("snapshot index rejected ({e}); retraining"),
                    }
                } else {
                    crate::warn!("snapshot index metric differs from [index] config; retraining");
                }
            }
        }
        let index: Arc<dyn KnnIndex> = match index {
            Some(i) => i,
            // Fixed seed: index structure (IVF centroids) is deterministic
            // for a given store, so restarts serve identical results.
            None => Arc::from(build_index(index_cfg, index_store, 0x6b6e6e)),
        };
        // Index construction (IVF k-means, cosine norm pass) reads rows
        // through the cache — useful warming, but it must not count as
        // traffic: STATS stays all-zero until the first real request.
        store.reset_stats();
        let pool_store: Arc<dyn EmbeddingStore> = store.clone();
        let pool = WorkerPool::new(
            pool_store,
            cfg.shards,
            cfg.queue_depth,
            Duration::from_micros(cfg.batch_window_us),
            cfg.max_batch,
            Some(index.clone()),
            obs.clone(),
        );
        Model { store, index, pool, snapshot_bytes }
    }

    fn model_from_snapshot(
        path: &Path,
        cfg: &ServingConfig,
        index_cfg: &IndexConfig,
        mmap: bool,
        obs: &Arc<Obs>,
    ) -> crate::Result<Model> {
        let snap = Arc::new(Snapshot::open(path, mmap)?);
        let payload = snapshot::load_index_payload(&snap)?;
        let bytes = snap.file_len();
        let store = SnapshotStore::open(snap)?;
        Ok(Self::assemble(Box::new(store), cfg, index_cfg, payload, bytes, obs))
    }

    /// Swap in a new model generation loaded from `path` (memory-mapped
    /// unless `[snapshot] mmap = false`).
    ///
    /// Runs on the caller's thread: the new snapshot is opened and fully
    /// CRC-validated, its cache/index/pool built and warmed, all while the
    /// current generation keeps serving. Only then is the shared pointer
    /// replaced — an atomic swap under a lock held for a pointer move.
    /// In-flight requests drain on the old generation; its workers stop
    /// once the last holder lets go, and its counters fold into the carry.
    /// Returns the new generation number.
    pub fn reload_snapshot(&self, path: &Path) -> crate::Result<u64> {
        let t0 = Instant::now();
        let model = Self::model_from_snapshot(
            path,
            &self.serving_cfg,
            &self.index_cfg,
            self.reload_mmap,
            &self.obs,
        )?;
        if model.store.dim() != self.dim() {
            return Err(Error::Snapshot(format!(
                "snapshot dim {} does not match serving dim {} (connected clients negotiated \
                 the old dimension)",
                model.store.dim(),
                self.dim()
            )));
        }
        let old = {
            let mut cur = self.model.lock().unwrap();
            std::mem::replace(&mut *cur, Arc::new(model))
        };
        let generation = self.generation.fetch_add(1, Ordering::SeqCst) + 1;
        // Fold the retired generation's counters into the carry *now*, so
        // STATS stays monotonic through the swap (a deferred fold would make
        // `served` dip to ~0 until the old pool drains — a negative-rate
        // spike on any monitoring). Requests still draining on the old
        // generation after this point are bounded by its queue depth and are
        // not re-counted (the fold happens exactly once, here).
        self.carry.served.fetch_add(old.pool.served(), Ordering::Relaxed);
        self.carry.rejected.fetch_add(old.pool.rejected(), Ordering::Relaxed);
        let (q, c, p) = old.pool.knn_counters();
        self.carry.knn_queries.fetch_add(q, Ordering::Relaxed);
        self.carry.knn_candidates.fetch_add(c, Ordering::Relaxed);
        self.carry.knn_probes.fetch_add(p, Ordering::Relaxed);
        let cs = old.store.stats();
        self.carry.hits.fetch_add(cs.hits, Ordering::Relaxed);
        self.carry.misses.fetch_add(cs.misses, Ordering::Relaxed);
        self.carry.evictions.fetch_add(old.store.evictions(), Ordering::Relaxed);
        // Build + validate + swap wall time, one histogram sample per
        // successful reload (failures never reach this point).
        self.obs.record_reload(t0.elapsed());
        // Retire off-thread: in-flight requests still hold the old Arc and
        // must be able to submit + drain against its live pool before its
        // workers stop.
        std::thread::Builder::new()
            .name("model-retire".into())
            .spawn(move || retire(old))
            .ok();
        Ok(generation)
    }

    fn current(&self) -> Arc<Model> {
        self.model.lock().unwrap().clone()
    }

    /// The current generation's cached store.
    pub fn store(&self) -> Arc<ShardedCache> {
        self.current().store.clone()
    }

    /// The current generation's similarity index.
    pub fn index(&self) -> Arc<dyn KnnIndex> {
        self.current().index.clone()
    }

    /// Current model generation (1 at boot, +1 per successful reload).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    pub fn dim(&self) -> usize {
        self.current().store.dim()
    }

    pub fn vocab_size(&self) -> usize {
        self.current().store.vocab_size()
    }

    pub fn served(&self) -> u64 {
        self.carry.served.load(Ordering::Relaxed) + self.current().pool.served()
    }

    /// Validate and enqueue a lookup, blocking until rows arrive or the
    /// deadline passes. Rows come back in request order. The whole request
    /// runs against one model generation (captured here), so a concurrent
    /// hot swap can never mix rows from two models.
    pub fn lookup_rows(&self, ids: Vec<usize>) -> Result<Vec<Vec<f32>>, LookupError> {
        self.lookup_rows_traced(ids, None)
    }

    /// [`Self::lookup_rows`] carrying an optional propagated trace context
    /// plus the microseconds the driver spent parsing the frame. This is
    /// the tracing edge for both protocols and both drivers: a span is
    /// adopted from the wire context (the sampling decision was made
    /// upstream) or head-sampled fresh, rides the pool job, and is
    /// finished worker-side before the reply is sent. Unsampled requests
    /// that end slow or in error still reach the trace ring via
    /// tail-capture.
    pub fn lookup_rows_traced(
        &self,
        ids: Vec<usize>,
        trace: Option<(TraceContext, u64)>,
    ) -> Result<Vec<Vec<f32>>, LookupError> {
        let t0 = Instant::now();
        let mut span = self.edge_span("lookup", trace);
        let sampled = span.is_some();
        let result = (|| {
            if ids.is_empty() {
                return Err(LookupError::Empty);
            }
            let m = self.current();
            let vocab = m.store.vocab_size();
            if ids.iter().any(|&id| id >= vocab) {
                return Err(LookupError::OutOfRange);
            }
            let (tx, rx) = mpsc::channel();
            let te = self.obs.enabled().then(Instant::now);
            if let Some(s) = span.as_mut() {
                s.stage(Stage::Enqueue, t0.elapsed().as_micros() as u64);
            }
            m.pool
                .submit(Job::Lookup { ids, enqueued: Instant::now(), span: span.take(), reply: tx })
                .map_err(|_| LookupError::Overloaded)?;
            if let Some(te) = te {
                self.obs.record_stage(Stage::Enqueue, te.elapsed());
            }
            rx.recv_timeout(self.timeout).map_err(|_| LookupError::Timeout)
        })();
        self.close_edge_span("lookup", span.take(), sampled, result.as_ref().err().copied(), t0);
        result
    }

    /// Mint the edge span for one request: a child when the peer
    /// propagated a context, otherwise a head-sampling roll for a fresh
    /// root. `parse_us` (driver frame-parse time) lands as the `parse`
    /// stage and extends the span's total.
    fn edge_span(&self, op: &'static str, trace: Option<(TraceContext, u64)>) -> Option<Span> {
        let tracer = self.obs.tracer();
        let mut span = match trace {
            Some((ctx, parse_us)) => tracer.start_child(ctx, op, parse_us),
            None => tracer.maybe_start_root(op),
        };
        if let (Some(s), Some((_, parse_us))) = (span.as_mut(), trace) {
            if parse_us > 0 {
                s.stage(Stage::Parse, parse_us);
            }
        }
        span
    }

    /// Close out the edge span after the reply (or failure). A span still
    /// held here never reached a worker (validation or submit failure) and
    /// is finished with the error tag; requests whose span rode the job —
    /// or that were never sampled — fall through to tail-capture, which
    /// keeps slow and errored requests regardless of the sampling rate.
    fn close_edge_span(
        &self,
        op: &'static str,
        span: Option<Span>,
        sampled: bool,
        err: Option<LookupError>,
        t0: Instant,
    ) {
        let tracer = self.obs.tracer();
        if let Some(mut s) = span {
            if let Some(e) = err {
                s.set_status(e.trace_tag());
            }
            tracer.finish(s);
        } else if err.is_some() || !sampled {
            tracer.tail_capture(op, t0.elapsed().as_micros() as u64, err.is_some());
        }
    }

    /// Inner product of two rows. Served synchronously through the cache
    /// (two row fetches), bypassing the batching queue.
    pub fn dot(&self, a: usize, b: usize) -> Result<f32, LookupError> {
        let m = self.current();
        let vocab = m.store.vocab_size();
        if a >= vocab || b >= vocab {
            return Err(LookupError::OutOfRange);
        }
        let va = m.store.lookup(a);
        let vb = m.store.lookup(b);
        Ok(crate::tensor::dot(&va, &vb))
    }

    /// Validate and enqueue a top-k similarity query through the worker
    /// pool; neighbors come back best-first. For [`Query::Id`] the query
    /// word itself is excluded from the results. `k` is clamped to the
    /// vocabulary size (the answer can never be larger, and an unclamped
    /// client-supplied k would size the selection heap — a u32::MAX k from
    /// the binary wire must not turn into a giant eager allocation).
    pub fn knn(&self, query: Query, k: usize) -> Result<Vec<Neighbor>, LookupError> {
        self.knn_traced(query, k, None)
    }

    /// [`Self::knn`] with an optional propagated trace context; see
    /// [`Self::lookup_rows_traced`] for the span lifecycle.
    pub fn knn_traced(
        &self,
        query: Query,
        k: usize,
        trace: Option<(TraceContext, u64)>,
    ) -> Result<Vec<Neighbor>, LookupError> {
        let t0 = Instant::now();
        let mut span = self.edge_span("knn", trace);
        let sampled = span.is_some();
        let result = (|| {
            if k == 0 {
                return Err(LookupError::BadQuery);
            }
            let m = self.current();
            let k = k.min(m.store.vocab_size());
            match &query {
                Query::Id(id) => {
                    if *id >= m.store.vocab_size() {
                        return Err(LookupError::OutOfRange);
                    }
                }
                Query::Vector(v) => {
                    if v.len() != m.store.dim() {
                        return Err(LookupError::BadQuery);
                    }
                }
            }
            let (tx, rx) = mpsc::channel();
            let te = self.obs.enabled().then(Instant::now);
            if let Some(s) = span.as_mut() {
                s.stage(Stage::Enqueue, t0.elapsed().as_micros() as u64);
            }
            m.pool
                .submit(Job::Knn { query, k, enqueued: Instant::now(), span: span.take(), reply: tx })
                .map_err(|_| LookupError::Overloaded)?;
            if let Some(te) = te {
                self.obs.record_stage(Stage::Enqueue, te.elapsed());
            }
            // knn accounting happens worker-side (like `served`), so queries
            // the caller gives up on are still counted when the scan
            // finishes.
            let (neighbors, _stats) =
                rx.recv_timeout(self.timeout).map_err(|_| LookupError::Timeout)?;
            Ok(neighbors)
        })();
        self.close_edge_span("knn", span.take(), sampled, result.as_ref().err().copied(), t0);
        result
    }

    /// Pool + cache + knn statistics, cumulative across hot swaps; all-zero
    /// counters (never NaN) before any traffic.
    pub fn stats(&self) -> ServingStats {
        let m = self.current();
        // Percentiles come from the process-lifetime e2e histogram (exact
        // to within one log₂ bucket width); an empty histogram reads 0.
        let e2e = self.obs.e2e();
        let (p50, p99) = (e2e.p50(), e2e.p99());
        let (knn_q, knn_c, knn_p) = m.pool.knn_counters();
        let knn_queries = self.carry.knn_queries.load(Ordering::Relaxed) + knn_q;
        let knn_candidates = self.carry.knn_candidates.load(Ordering::Relaxed) + knn_c;
        let knn_probes = self.carry.knn_probes.load(Ordering::Relaxed) + knn_p;
        let knn_mean_probes =
            if knn_queries == 0 { 0.0 } else { knn_probes as f64 / knn_queries as f64 };
        let cs = m.store.stats();
        ServingStats {
            p50_us: p50,
            p99_us: p99,
            served: self.carry.served.load(Ordering::Relaxed) + m.pool.served(),
            rejected: self.carry.rejected.load(Ordering::Relaxed) + m.pool.rejected(),
            cache: CacheStats {
                hits: self.carry.hits.load(Ordering::Relaxed) + cs.hits,
                misses: self.carry.misses.load(Ordering::Relaxed) + cs.misses,
                entries: cs.entries,
            },
            knn_queries,
            knn_candidates,
            knn_mean_probes,
            model_generation: self.generation(),
            snapshot_bytes: m.snapshot_bytes,
            accept_errors: self.accept_errors.load(Ordering::Relaxed),
            simd_level: crate::simd::level().code() as u64,
            payload_bits: crate::repr::Repr::resolve(m.store.as_ref()).payload_bits() as u64,
        }
    }

    /// The metrics registry shared by this state's cache, pool, and (via
    /// [`crate::net::Service::obs`]) its network driver.
    pub fn obs(&self) -> Arc<Obs> {
        self.obs.clone()
    }

    /// Full Prometheus-style metrics exposition: counters first (fixed
    /// order), then every histogram family from the [`Obs`] registry, then
    /// `# EOF`. Both the text `METRICS` verb and binary `OP_METRICS` return
    /// exactly this string, and the render order is deterministic, so a
    /// quiescent server exposes byte-identical metrics regardless of
    /// protocol or network driver.
    pub fn metrics_text(&self) -> String {
        let m = self.current();
        let s = self.stats();
        let knn_probes = self.carry.knn_probes.load(Ordering::Relaxed) + m.pool.knn_counters().2;
        let evictions = self.carry.evictions.load(Ordering::Relaxed) + m.store.evictions();
        let mut out = String::new();
        let _ = writeln!(out, "w2k_served_total {}", s.served);
        let _ = writeln!(out, "w2k_rejected_total {}", s.rejected);
        let _ = writeln!(out, "w2k_cache_hits_total {}", s.cache.hits);
        let _ = writeln!(out, "w2k_cache_misses_total {}", s.cache.misses);
        let _ = writeln!(out, "w2k_cache_evictions_total {evictions}");
        for (i, n) in m.store.shard_entries().iter().enumerate() {
            let _ = writeln!(out, "w2k_cache_entries{{shard=\"{i}\"}} {n}");
        }
        let _ = writeln!(out, "w2k_knn_queries_total {}", s.knn_queries);
        let _ = writeln!(out, "w2k_knn_candidates_total {}", s.knn_candidates);
        let _ = writeln!(out, "w2k_knn_probes_total {knn_probes}");
        let _ = writeln!(out, "w2k_model_generation {}", s.model_generation);
        let _ = writeln!(out, "w2k_snapshot_bytes {}", s.snapshot_bytes);
        let _ = writeln!(out, "w2k_accept_errors_total {}", s.accept_errors);
        // Info-style gauge: the label names the kernel set, the value is
        // its numeric code (0 = scalar, 1 = sse2, 2 = avx2+fma).
        let simd = crate::simd::level();
        let _ = writeln!(out, "w2k_simd_level{{level=\"{}\"}} {}", simd.name(), simd.code());
        // Serving-payload precision gauge: 32 = float rows, below that the
        // factor payload is quantized to that many bits per value.
        let _ = writeln!(out, "w2k_payload_bits {}", s.payload_bits);
        self.obs.render_into(&mut out);
        out.push_str("# EOF\n");
        out
    }

    /// The slow-request ring (`METRICS?slow`): worst observed requests with
    /// their per-stage breakdowns, rank order.
    pub fn metrics_slow_text(&self) -> String {
        self.obs.render_slow()
    }

    /// One trace's stored spans (`TRACE <id>` / `OP_TRACE`), exposition
    /// formatted and `# EOF`-terminated; an unknown id yields just the
    /// terminator.
    pub fn trace_text(&self, trace_id: u128) -> String {
        let mut out = String::new();
        self.obs.tracer().render_trace(trace_id, &mut out);
        out.push_str("# EOF\n");
        out
    }

    /// The completed-trace ring (`TRACE?slow`): one summary line per
    /// stored span, oldest first, `# EOF`-terminated. Clients pick trace
    /// ids for `TRACE <id>` from here.
    pub fn trace_slow_text(&self) -> String {
        let mut out = String::new();
        self.obs.tracer().render_ring(&mut out);
        out.push_str("# EOF\n");
        out
    }

    /// Stop the current generation's pool workers after their queues drain;
    /// idempotent.
    pub fn shutdown(&self) {
        self.current().pool.shutdown();
    }
}

/// Wait for every in-flight holder of a retired generation to finish, then
/// drain + stop its workers (counters were already folded at swap time).
fn retire(old: Arc<Model>) {
    while Arc::strong_count(&old) > 1 {
        std::thread::sleep(Duration::from_millis(2));
    }
    old.pool.shutdown();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{IndexConfig, IndexKind, ServingConfig};
    use crate::embedding::{EmbeddingStore, Word2KetXS};
    use crate::snapshot::SaveOptions;
    use crate::util::Rng;

    fn state() -> ServingState {
        state_with_index(IndexConfig::default())
    }

    fn state_with_index(index_cfg: IndexConfig) -> ServingState {
        let mut rng = Rng::new(0);
        let inner = Box::new(Word2KetXS::random(200, 16, 2, 2, &mut rng));
        ServingState::new(
            inner,
            &ServingConfig { batch_window_us: 50, ..Default::default() },
            &index_cfg,
        )
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("w2k_serving_{}_{}.snap", std::process::id(), name))
    }

    #[test]
    fn lookup_validates_then_serves() {
        let st = state();
        assert_eq!(st.lookup_rows(vec![]), Err(LookupError::Empty));
        assert_eq!(st.lookup_rows(vec![3, 200]), Err(LookupError::OutOfRange));
        let rows = st.lookup_rows(vec![3, 7, 3]).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], st.store().lookup(3));
        assert_eq!(rows[0], rows[2]);
        st.shutdown();
    }

    #[test]
    fn dot_matches_reconstruction() {
        let st = state();
        let d = st.dot(1, 2).unwrap();
        let want = crate::tensor::dot(&st.store().lookup(1), &st.store().lookup(2));
        assert_eq!(d, want);
        assert_eq!(st.dot(0, 999), Err(LookupError::OutOfRange));
        st.shutdown();
    }

    #[test]
    fn knn_validates_then_serves() {
        let st = state();
        assert_eq!(st.knn(Query::Id(999), 5).unwrap_err(), LookupError::OutOfRange);
        assert_eq!(st.knn(Query::Id(3), 0).unwrap_err(), LookupError::BadQuery);
        assert_eq!(st.knn(Query::Vector(vec![0.0; 3]), 5).unwrap_err(), LookupError::BadQuery);

        let ns = st.knn(Query::Id(3), 5).unwrap();
        assert_eq!(ns.len(), 5);
        assert!(ns.iter().all(|n| n.id != 3));
        for w in ns.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        // Best neighbor agrees with an exhaustive dot scan through the cache
        // (tie-robust: the returned winner's dense score must match the true
        // maximum within float noise).
        let q = st.store().lookup(3);
        let mut best_s = f32::NEG_INFINITY;
        for b in 0..200 {
            if b != 3 {
                best_s = best_s.max(crate::tensor::dot(&q, &st.store().lookup(b)));
            }
        }
        let winner_dense = crate::tensor::dot(&q, &st.store().lookup(ns[0].id));
        assert!(
            (winner_dense - best_s).abs() < 1e-4,
            "knn winner {winner_dense} vs exhaustive max {best_s}"
        );
        st.shutdown();
    }

    #[test]
    fn knn_counters_track_traffic() {
        let st = state_with_index(IndexConfig {
            kind: IndexKind::Ivf,
            nlist: 8,
            nprobe: 3,
            cosine: false,
            scan_threads: 1,
        });
        let before = st.stats();
        assert_eq!(before.knn_queries, 0);
        assert_eq!(before.knn_candidates, 0);
        assert_eq!(before.knn_mean_probes, 0.0);
        // IVF construction reconstructs rows through the cache; that must
        // not surface as pre-traffic cache activity.
        assert_eq!(before.cache.hits, 0, "index build leaked into cache stats");
        assert_eq!(before.cache.misses, 0, "index build leaked into cache stats");

        for id in [1usize, 2, 3, 4] {
            st.knn(Query::Id(id), 4).unwrap();
        }
        let after = st.stats();
        assert_eq!(after.knn_queries, 4);
        assert!(after.knn_candidates > 0);
        assert!((after.knn_mean_probes - 3.0).abs() < 1e-9, "{}", after.knn_mean_probes);
        st.shutdown();
    }

    #[test]
    fn stats_zero_before_traffic() {
        let st = state();
        let s = st.stats();
        assert_eq!(s.served, 0);
        assert_eq!(s.p50_us, 0.0);
        assert_eq!(s.p99_us, 0.0);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.cache.hits, 0);
        assert_eq!(s.knn_queries, 0);
        assert_eq!(s.knn_candidates, 0);
        assert_eq!(s.knn_mean_probes, 0.0);
        assert_eq!(s.model_generation, 1);
        assert_eq!(s.snapshot_bytes, 0);
        assert_eq!(s.accept_errors, 0);
        // Not a traffic counter: reports the process's kernel set.
        assert_eq!(s.simd_level, crate::simd::level().code() as u64);
        // Float store: the served payload is full-precision.
        assert_eq!(s.payload_bits, 32);
        st.shutdown();
    }

    /// A server over a sub-byte store reports the packed code width in
    /// STATS and as the `w2k_payload_bits` gauge, and still serves exact
    /// rows / sane KNN through the coarse-scan + re-rank path.
    #[test]
    fn quantized_store_reports_payload_bits() {
        let mut rng = Rng::new(6);
        let w2k = crate::embedding::Word2Ket::random(200, 16, 2, 2, &mut rng);
        let qk = crate::quant::QuantizedKet::from_word2ket(&w2k, 4).unwrap();
        let rows: Vec<Vec<f32>> = (0..200).map(|id| qk.lookup(id)).collect();
        let st = ServingState::new(
            Box::new(qk),
            &ServingConfig { batch_window_us: 50, ..Default::default() },
            &IndexConfig {
                kind: IndexKind::Ivf,
                nlist: 4,
                nprobe: 4,
                cosine: false,
                scan_threads: 1,
            },
        );
        let s = st.stats();
        assert_eq!(s.payload_bits, 4);
        assert!(
            st.metrics_text().contains("w2k_payload_bits 4\n"),
            "gauge missing: {}",
            st.metrics_text()
        );
        // Served rows are the exact refined rows; KNN scores are exact
        // dense scores (re-ranked), not coarse quantized ones.
        let got = st.lookup_rows(vec![3]).unwrap();
        assert_eq!(got[0], rows[3]);
        let ns = st.knn(Query::Id(3), 5).unwrap();
        for n in &ns {
            let exact = crate::tensor::dot(&rows[3], &rows[n.id]);
            assert_eq!(n.score.to_bits(), exact.to_bits(), "id {}", n.id);
        }
        st.shutdown();
    }

    /// Acceptance: what goes on the wire — reconstructed rows and KNN
    /// results — is byte-identical across SIMD dispatch levels and across
    /// `scan_threads` settings. Each run builds its own server (separate
    /// caches), so every value is recomputed under the forced kernel set.
    #[test]
    fn wire_responses_identical_across_simd_levels_and_scan_threads() {
        use crate::simd::{self, SimdLevel};

        type Harvest = (Vec<Vec<u32>>, Vec<Vec<(usize, u32)>>);
        fn harvest(scan_threads: usize) -> Harvest {
            let mut rng = Rng::new(4242);
            let store = Word2KetXS::random(2560, 16, 2, 2, &mut rng);
            let icfg = IndexConfig {
                kind: IndexKind::Brute,
                nlist: 64,
                nprobe: 8,
                cosine: false,
                scan_threads,
            };
            let st = ServingState::new(
                Box::new(store),
                &ServingConfig { batch_window_us: 50, ..Default::default() },
                &icfg,
            );
            let rows: Vec<Vec<u32>> = st
                .lookup_rows(vec![0, 1, 7, 1000, 2559])
                .unwrap()
                .into_iter()
                .map(|r| r.into_iter().map(f32::to_bits).collect())
                .collect();
            let knn: Vec<Vec<(usize, u32)>> = [0usize, 1234, 2555]
                .iter()
                .map(|&q| {
                    st.knn(Query::Id(q), 7)
                        .unwrap()
                        .into_iter()
                        .map(|n| (n.id, n.score.to_bits()))
                        .collect()
                })
                .collect();
            st.shutdown();
            (rows, knn)
        }

        let scalar = simd::with_level(SimdLevel::Scalar, || harvest(1));
        let auto = simd::with_level(simd::detect(), || harvest(1));
        assert_eq!(scalar, auto, "scalar vs detected kernel set must match bitwise");
        let threaded = simd::with_level(simd::detect(), || harvest(4));
        assert_eq!(auto, threaded, "scan_threads 1 vs 4 must match bitwise");
    }

    #[test]
    fn reload_swaps_generation_and_serves_new_rows() {
        // Save a *different* store (same dim, different seed + vocab) and
        // hot-swap to it: generation bumps, vocab/rows/snapshot_bytes all
        // follow the new model, and old counters stay cumulative.
        let st = state();
        let before_rows = st.lookup_rows(vec![0, 1]).unwrap();
        let served_before = st.served();
        assert_eq!(served_before, 2);

        let mut rng = Rng::new(99);
        let other = Word2KetXS::random(120, 16, 2, 3, &mut rng);
        let path = tmp("reload_basic");
        snapshot::save_store(&other, &path, &SaveOptions::default()).unwrap();

        let generation = st.reload_snapshot(&path).unwrap();
        assert_eq!(generation, 2);
        assert_eq!(st.generation(), 2);
        assert_eq!(st.vocab_size(), 120, "vocab must follow the new model");
        let after = st.lookup_rows(vec![0]).unwrap();
        assert_eq!(after[0], other.lookup(0), "rows must come from the new model");
        assert_ne!(before_rows[0], after[0], "different seed ⇒ different rows");
        let s = st.stats();
        assert_eq!(s.model_generation, 2);
        assert!(s.snapshot_bytes > 0);

        // The retired generation's served count folds into the carry.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while st.served() < 3 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(st.served(), 3, "cumulative served across the swap");

        st.shutdown();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reload_rejects_dim_mismatch_and_garbage() {
        let st = state();
        // Wrong dimension: connected binary clients negotiated dim once.
        let mut rng = Rng::new(5);
        let wrong = Word2KetXS::random(50, 64, 2, 2, &mut rng);
        let path = tmp("wrong_dim");
        snapshot::save_store(&wrong, &path, &SaveOptions::default()).unwrap();
        assert!(matches!(st.reload_snapshot(&path), Err(Error::Snapshot(_))));
        std::fs::write(&path, b"garbage").unwrap();
        assert!(matches!(st.reload_snapshot(&path), Err(Error::Snapshot(_))));
        assert!(st.reload_snapshot(Path::new("/nonexistent/no.snap")).is_err());
        // Still generation 1 and still serving.
        assert_eq!(st.generation(), 1);
        assert_eq!(st.lookup_rows(vec![7]).unwrap().len(), 1);
        st.shutdown();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reload_with_embedded_ivf_skips_training_and_matches() {
        // Snapshot carries the IVF payload; the reloaded server must answer
        // identically to the original index (same centroids, same lists).
        let mut rng = Rng::new(7);
        let store = Word2KetXS::random(300, 16, 2, 2, &mut rng);
        let icfg = IndexConfig {
            kind: IndexKind::Ivf,
            nlist: 8,
            nprobe: 3,
            cosine: false,
            scan_threads: 1,
        };
        let st = ServingState::new(
            Box::new(store.clone()),
            &ServingConfig { batch_window_us: 50, ..Default::default() },
            &icfg,
        );
        let before: Vec<Vec<usize>> = (0..5)
            .map(|q| st.knn(Query::Id(q), 6).unwrap().iter().map(|n| n.id).collect())
            .collect();

        // Build the same index standalone and embed it in the snapshot.
        let arc: Arc<dyn EmbeddingStore> = Arc::new(store.clone());
        let ivf = IvfIndex::build(Scorer::new(arc, false), 8, 3, 0x6b6e6e);
        let path = tmp("embedded_ivf");
        snapshot::save_store_with_index(&store, Some(&ivf), &path, &SaveOptions::default())
            .unwrap();

        let generation = st.reload_snapshot(&path).unwrap();
        assert_eq!(generation, 2);
        assert!(st.index().describe().contains("ivf"), "{}", st.index().describe());
        for (q, want) in before.iter().enumerate() {
            let got: Vec<usize> =
                st.knn(Query::Id(q), 6).unwrap().iter().map(|n| n.id).collect();
            assert_eq!(&got, want, "query {q} differs after ivf-carrying reload");
        }
        st.shutdown();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn metrics_exposition_is_deterministic_and_eof_terminated() {
        let st = state();
        st.lookup_rows(vec![1, 2, 3]).unwrap();
        let text = st.metrics_text();
        assert!(text.contains("w2k_served_total 3"), "{text}");
        assert!(text.contains("w2k_model_generation 1"), "{text}");
        assert!(text.contains("w2k_cache_entries{shard=\"0\"}"), "{text}");
        assert!(text.contains("w2k_request_us_count 3"), "{text}");
        assert!(text.contains("w2k_stage_us_count{stage=\"batch_wait\"}"), "{text}");
        assert!(text.ends_with("# EOF\n"), "{text}");
        // Quiescent server: two scrapes are byte-identical (the scrape
        // itself must not perturb any series).
        assert_eq!(st.metrics_text(), st.metrics_text());
        // The slow ring saw the traffic too.
        assert!(st.metrics_slow_text().contains("w2k_slow_total_us"), "no slow entries");
        st.shutdown();
    }

    #[test]
    fn metrics_and_stats_are_monotonic_across_reload() {
        // The obs registry is shared across generations, and counters fold
        // into the carry at swap time — nothing may dip through a RELOAD.
        let st = state();
        st.lookup_rows(vec![1, 2, 3]).unwrap();
        let before = st.stats();
        let e2e_before = st.obs().e2e().count();
        assert!(before.p50_us >= 0.0);

        let mut rng = Rng::new(99);
        let other = Word2KetXS::random(120, 16, 2, 3, &mut rng);
        let path = tmp("metrics_reload");
        snapshot::save_store(&other, &path, &SaveOptions::default()).unwrap();
        st.reload_snapshot(&path).unwrap();

        st.lookup_rows(vec![0]).unwrap();
        let after = st.stats();
        assert!(after.served >= before.served + 1, "served dipped across reload");
        assert!(after.cache.misses >= before.cache.misses, "misses dipped across reload");
        assert!(st.obs().e2e().count() >= e2e_before + 1, "e2e histogram reset across reload");
        let text = st.metrics_text();
        assert!(text.contains("w2k_model_generation 2"), "{text}");
        assert!(text.contains("w2k_reload_us_count 1"), "{text}");
        st.shutdown();
        std::fs::remove_file(&path).ok();
    }
}
