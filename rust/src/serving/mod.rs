//! Production serving layer: sharded hot-row cache, worker pool, binary wire
//! protocol, and the k-NN request path.
//!
//! This is the request path behind `w2k serve` and the `serve_embeddings`
//! example. The paper's word2ketXS table is small enough to live in cache
//! but must be *reconstructed* per lookup, so at production traffic the hot
//! path is reconstruction compute — this layer attacks exactly that:
//!
//! * [`cache::ShardedCache`] — N-way sharded LRU with frequency-based
//!   admission wrapping any [`EmbeddingStore`]; Zipf-head tokens are
//!   reconstructed once and then served as memcpys.
//! * [`pool::WorkerPool`] — per-shard bounded queues drained in micro-batches
//!   by independent workers, with fail-fast backpressure and per-worker
//!   latency summaries merged on `STATS`. Lookup *and* k-NN jobs flow
//!   through the same queues.
//! * [`wire`] — a length-prefixed binary protocol negotiated on the same
//!   TCP listener as the text protocol (see `coordinator::server`).
//! * similarity search — a [`crate::index::KnnIndex`] (brute force or IVF,
//!   `[index]` config) built over the cached store at startup serves
//!   `KNN`/`OP_KNN` queries, scoring in factored space when the store is
//!   tensorized.
//!
//! Configuration arrives via `[serving]` in the experiment TOML
//! ([`crate::config::ServingConfig`]): `shards`, `cache_rows`,
//! `batch_window_us`, `queue_depth`, `max_batch`; the index via `[index]`
//! ([`crate::config::IndexConfig`]): `kind`, `nlist`, `nprobe`, `cosine`.

pub mod cache;
pub mod pool;
pub mod wire;

pub use cache::{CacheStats, ShardedCache};
pub use pool::{Job, Overloaded, WorkerPool};
pub use wire::{BinaryClient, WireError, WireStats};

use crate::config::{IndexConfig, ServingConfig};
use crate::embedding::EmbeddingStore;
use crate::index::{build_index, KnnIndex, Neighbor, Query};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Why a request could not be served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LookupError {
    /// Request contained no ids.
    Empty,
    /// Some id is >= vocab_size.
    OutOfRange,
    /// Malformed knn query (k == 0, or query vector of the wrong dimension).
    BadQuery,
    /// Every pool queue is full (backpressure).
    Overloaded,
    /// The pool did not reply within the request deadline.
    Timeout,
}

impl std::fmt::Display for LookupError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            LookupError::Empty => "empty request",
            LookupError::OutOfRange => "id out of range",
            LookupError::BadQuery => "bad query",
            LookupError::Overloaded => "overloaded",
            LookupError::Timeout => "timeout",
        };
        write!(f, "{s}")
    }
}

/// Aggregate serving statistics (pool + cache + knn), zeros before any
/// traffic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingStats {
    pub p50_us: f64,
    pub p99_us: f64,
    pub served: u64,
    pub rejected: u64,
    pub cache: CacheStats,
    /// k-NN queries answered.
    pub knn_queries: u64,
    /// Candidates exactly scored across all knn queries.
    pub knn_candidates: u64,
    /// Mean IVF cells probed per knn query (0 for brute force / no traffic).
    pub knn_mean_probes: f64,
}

/// Shared per-server serving state: cached store + worker pool + knn index.
///
/// Protocol handlers (text in `coordinator::server`, binary in [`wire`])
/// validate and format; everything between socket and store lives here.
pub struct ServingState {
    store: Arc<ShardedCache>,
    index: Arc<dyn KnnIndex>,
    pool: WorkerPool,
    timeout: Duration,
}

impl ServingState {
    pub fn new(
        inner: Box<dyn EmbeddingStore>,
        cfg: &ServingConfig,
        index_cfg: &IndexConfig,
    ) -> ServingState {
        let store = Arc::new(ShardedCache::new(inner, cfg.shards, cfg.cache_rows));
        let index_store: Arc<dyn EmbeddingStore> = store.clone();
        // Fixed seed: index structure (IVF centroids) is deterministic for a
        // given store, so restarts serve identical results.
        let index: Arc<dyn KnnIndex> = Arc::from(build_index(index_cfg, index_store, 0x6b6e6e));
        // Index construction (IVF k-means, cosine norm pass) reads rows
        // through the cache — useful warming, but it must not count as
        // traffic: STATS stays all-zero until the first real request.
        store.reset_stats();
        let pool_store: Arc<dyn EmbeddingStore> = store.clone();
        let pool = WorkerPool::new(
            pool_store,
            cfg.shards,
            cfg.queue_depth,
            Duration::from_micros(cfg.batch_window_us),
            cfg.max_batch,
            Some(index.clone()),
        );
        ServingState { store, index, pool, timeout: Duration::from_secs(5) }
    }

    pub fn store(&self) -> &ShardedCache {
        &self.store
    }

    /// The similarity index answering `KNN` queries.
    pub fn index(&self) -> &dyn KnnIndex {
        self.index.as_ref()
    }

    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    pub fn vocab_size(&self) -> usize {
        self.store.vocab_size()
    }

    pub fn served(&self) -> u64 {
        self.pool.served()
    }

    /// Validate and enqueue a lookup, blocking until rows arrive or the
    /// deadline passes. Rows come back in request order.
    pub fn lookup_rows(&self, ids: Vec<usize>) -> Result<Vec<Vec<f32>>, LookupError> {
        if ids.is_empty() {
            return Err(LookupError::Empty);
        }
        let vocab = self.store.vocab_size();
        if ids.iter().any(|&id| id >= vocab) {
            return Err(LookupError::OutOfRange);
        }
        let (tx, rx) = mpsc::channel();
        self.pool
            .submit(Job::Lookup { ids, enqueued: Instant::now(), reply: tx })
            .map_err(|_| LookupError::Overloaded)?;
        rx.recv_timeout(self.timeout).map_err(|_| LookupError::Timeout)
    }

    /// Inner product of two rows. Served synchronously through the cache
    /// (two row fetches), bypassing the batching queue.
    pub fn dot(&self, a: usize, b: usize) -> Result<f32, LookupError> {
        let vocab = self.store.vocab_size();
        if a >= vocab || b >= vocab {
            return Err(LookupError::OutOfRange);
        }
        let va = self.store.lookup(a);
        let vb = self.store.lookup(b);
        Ok(crate::tensor::dot(&va, &vb))
    }

    /// Validate and enqueue a top-k similarity query through the worker
    /// pool; neighbors come back best-first. For [`Query::Id`] the query
    /// word itself is excluded from the results. `k` is clamped to the
    /// vocabulary size (the answer can never be larger, and an unclamped
    /// client-supplied k would size the selection heap — a u32::MAX k from
    /// the binary wire must not turn into a giant eager allocation).
    pub fn knn(&self, query: Query, k: usize) -> Result<Vec<Neighbor>, LookupError> {
        if k == 0 {
            return Err(LookupError::BadQuery);
        }
        let k = k.min(self.store.vocab_size());
        match &query {
            Query::Id(id) => {
                if *id >= self.store.vocab_size() {
                    return Err(LookupError::OutOfRange);
                }
            }
            Query::Vector(v) => {
                if v.len() != self.dim() {
                    return Err(LookupError::BadQuery);
                }
            }
        }
        let (tx, rx) = mpsc::channel();
        self.pool
            .submit(Job::Knn { query, k, enqueued: Instant::now(), reply: tx })
            .map_err(|_| LookupError::Overloaded)?;
        // knn accounting happens worker-side (like `served`), so queries
        // the caller gives up on are still counted when the scan finishes.
        let (neighbors, _stats) = rx.recv_timeout(self.timeout).map_err(|_| LookupError::Timeout)?;
        Ok(neighbors)
    }

    /// Pool + cache + knn statistics; all-zero (never NaN) before any
    /// traffic.
    pub fn stats(&self) -> ServingStats {
        let lat = self.pool.latency_summary();
        let (p50, p99) = if lat.is_empty() { (0.0, 0.0) } else { (lat.p50(), lat.p99()) };
        let (knn_queries, knn_candidates, knn_probes) = self.pool.knn_counters();
        let knn_mean_probes =
            if knn_queries == 0 { 0.0 } else { knn_probes as f64 / knn_queries as f64 };
        ServingStats {
            p50_us: p50,
            p99_us: p99,
            served: self.pool.served(),
            rejected: self.pool.rejected(),
            cache: self.store.stats(),
            knn_queries,
            knn_candidates,
            knn_mean_probes,
        }
    }

    /// Stop pool workers after their queues drain; idempotent.
    pub fn shutdown(&self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{IndexConfig, IndexKind, ServingConfig};
    use crate::embedding::{EmbeddingStore, Word2KetXS};
    use crate::util::Rng;

    fn state() -> ServingState {
        state_with_index(IndexConfig::default())
    }

    fn state_with_index(index_cfg: IndexConfig) -> ServingState {
        let mut rng = Rng::new(0);
        let inner = Box::new(Word2KetXS::random(200, 16, 2, 2, &mut rng));
        ServingState::new(
            inner,
            &ServingConfig { batch_window_us: 50, ..Default::default() },
            &index_cfg,
        )
    }

    #[test]
    fn lookup_validates_then_serves() {
        let st = state();
        assert_eq!(st.lookup_rows(vec![]), Err(LookupError::Empty));
        assert_eq!(st.lookup_rows(vec![3, 200]), Err(LookupError::OutOfRange));
        let rows = st.lookup_rows(vec![3, 7, 3]).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], st.store().lookup(3));
        assert_eq!(rows[0], rows[2]);
        st.shutdown();
    }

    #[test]
    fn dot_matches_reconstruction() {
        let st = state();
        let d = st.dot(1, 2).unwrap();
        let want = crate::tensor::dot(&st.store().lookup(1), &st.store().lookup(2));
        assert_eq!(d, want);
        assert_eq!(st.dot(0, 999), Err(LookupError::OutOfRange));
        st.shutdown();
    }

    #[test]
    fn knn_validates_then_serves() {
        let st = state();
        assert_eq!(st.knn(Query::Id(999), 5).unwrap_err(), LookupError::OutOfRange);
        assert_eq!(st.knn(Query::Id(3), 0).unwrap_err(), LookupError::BadQuery);
        assert_eq!(st.knn(Query::Vector(vec![0.0; 3]), 5).unwrap_err(), LookupError::BadQuery);

        let ns = st.knn(Query::Id(3), 5).unwrap();
        assert_eq!(ns.len(), 5);
        assert!(ns.iter().all(|n| n.id != 3));
        for w in ns.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        // Best neighbor agrees with an exhaustive dot scan through the cache
        // (tie-robust: the returned winner's dense score must match the true
        // maximum within float noise).
        let q = st.store().lookup(3);
        let mut best_s = f32::NEG_INFINITY;
        for b in 0..200 {
            if b != 3 {
                best_s = best_s.max(crate::tensor::dot(&q, &st.store().lookup(b)));
            }
        }
        let winner_dense = crate::tensor::dot(&q, &st.store().lookup(ns[0].id));
        assert!(
            (winner_dense - best_s).abs() < 1e-4,
            "knn winner {winner_dense} vs exhaustive max {best_s}"
        );
        st.shutdown();
    }

    #[test]
    fn knn_counters_track_traffic() {
        let st = state_with_index(IndexConfig {
            kind: IndexKind::Ivf,
            nlist: 8,
            nprobe: 3,
            cosine: false,
        });
        let before = st.stats();
        assert_eq!(before.knn_queries, 0);
        assert_eq!(before.knn_candidates, 0);
        assert_eq!(before.knn_mean_probes, 0.0);
        // IVF construction reconstructs rows through the cache; that must
        // not surface as pre-traffic cache activity.
        assert_eq!(before.cache.hits, 0, "index build leaked into cache stats");
        assert_eq!(before.cache.misses, 0, "index build leaked into cache stats");

        for id in [1usize, 2, 3, 4] {
            st.knn(Query::Id(id), 4).unwrap();
        }
        let after = st.stats();
        assert_eq!(after.knn_queries, 4);
        assert!(after.knn_candidates > 0);
        assert!((after.knn_mean_probes - 3.0).abs() < 1e-9, "{}", after.knn_mean_probes);
        st.shutdown();
    }

    #[test]
    fn stats_zero_before_traffic() {
        let st = state();
        let s = st.stats();
        assert_eq!(s.served, 0);
        assert_eq!(s.p50_us, 0.0);
        assert_eq!(s.p99_us, 0.0);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.cache.hits, 0);
        assert_eq!(s.knn_queries, 0);
        assert_eq!(s.knn_candidates, 0);
        assert_eq!(s.knn_mean_probes, 0.0);
        st.shutdown();
    }
}
