//! Production serving layer: sharded hot-row cache, worker pool, binary wire
//! protocol.
//!
//! This is the request path behind `w2k serve` and the `serve_embeddings`
//! example. The paper's word2ketXS table is small enough to live in cache
//! but must be *reconstructed* per lookup, so at production traffic the hot
//! path is reconstruction compute — this layer attacks exactly that:
//!
//! * [`cache::ShardedCache`] — N-way sharded LRU with frequency-based
//!   admission wrapping any [`EmbeddingStore`]; Zipf-head tokens are
//!   reconstructed once and then served as memcpys.
//! * [`pool::WorkerPool`] — per-shard bounded queues drained in micro-batches
//!   by independent workers, with fail-fast backpressure and per-worker
//!   latency summaries merged on `STATS`.
//! * [`wire`] — a length-prefixed binary protocol negotiated on the same
//!   TCP listener as the text protocol (see `coordinator::server`).
//!
//! Configuration arrives via `[serving]` in the experiment TOML
//! ([`crate::config::ServingConfig`]): `shards`, `cache_rows`,
//! `batch_window_us`, `queue_depth`, `max_batch`.

pub mod cache;
pub mod pool;
pub mod wire;

pub use cache::{CacheStats, ShardedCache};
pub use pool::{Job, Overloaded, WorkerPool};
pub use wire::{BinaryClient, WireError, WireStats};

use crate::config::ServingConfig;
use crate::embedding::EmbeddingStore;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Why a lookup could not be served.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LookupError {
    /// Request contained no ids.
    Empty,
    /// Some id is >= vocab_size.
    OutOfRange,
    /// Every pool queue is full (backpressure).
    Overloaded,
    /// The pool did not reply within the request deadline.
    Timeout,
}

impl std::fmt::Display for LookupError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            LookupError::Empty => "empty request",
            LookupError::OutOfRange => "id out of range",
            LookupError::Overloaded => "overloaded",
            LookupError::Timeout => "timeout",
        };
        write!(f, "{s}")
    }
}

/// Aggregate serving statistics (pool + cache), zeros before any traffic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingStats {
    pub p50_us: f64,
    pub p99_us: f64,
    pub served: u64,
    pub rejected: u64,
    pub cache: CacheStats,
}

/// Shared per-server serving state: cached store + worker pool.
///
/// Protocol handlers (text in `coordinator::server`, binary in [`wire`])
/// validate and format; everything between socket and store lives here.
pub struct ServingState {
    store: Arc<ShardedCache>,
    pool: WorkerPool,
    timeout: Duration,
}

impl ServingState {
    pub fn new(inner: Box<dyn EmbeddingStore>, cfg: &ServingConfig) -> ServingState {
        let store = Arc::new(ShardedCache::new(inner, cfg.shards, cfg.cache_rows));
        let pool_store: Arc<dyn EmbeddingStore> = store.clone();
        let pool = WorkerPool::new(
            pool_store,
            cfg.shards,
            cfg.queue_depth,
            Duration::from_micros(cfg.batch_window_us),
            cfg.max_batch,
        );
        ServingState { store, pool, timeout: Duration::from_secs(5) }
    }

    pub fn store(&self) -> &ShardedCache {
        &self.store
    }

    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    pub fn vocab_size(&self) -> usize {
        self.store.vocab_size()
    }

    pub fn served(&self) -> u64 {
        self.pool.served()
    }

    /// Validate and enqueue a lookup, blocking until rows arrive or the
    /// deadline passes. Rows come back in request order.
    pub fn lookup_rows(&self, ids: Vec<usize>) -> Result<Vec<Vec<f32>>, LookupError> {
        if ids.is_empty() {
            return Err(LookupError::Empty);
        }
        let vocab = self.store.vocab_size();
        if ids.iter().any(|&id| id >= vocab) {
            return Err(LookupError::OutOfRange);
        }
        let (tx, rx) = mpsc::channel();
        self.pool
            .submit(Job { ids, enqueued: Instant::now(), reply: tx })
            .map_err(|_| LookupError::Overloaded)?;
        rx.recv_timeout(self.timeout).map_err(|_| LookupError::Timeout)
    }

    /// Inner product of two rows. Served synchronously through the cache
    /// (two row fetches), bypassing the batching queue.
    pub fn dot(&self, a: usize, b: usize) -> Result<f32, LookupError> {
        let vocab = self.store.vocab_size();
        if a >= vocab || b >= vocab {
            return Err(LookupError::OutOfRange);
        }
        let va = self.store.lookup(a);
        let vb = self.store.lookup(b);
        Ok(crate::tensor::dot(&va, &vb))
    }

    /// Pool + cache statistics; all-zero (never NaN) before any traffic.
    pub fn stats(&self) -> ServingStats {
        let lat = self.pool.latency_summary();
        let (p50, p99) = if lat.is_empty() { (0.0, 0.0) } else { (lat.p50(), lat.p99()) };
        ServingStats {
            p50_us: p50,
            p99_us: p99,
            served: self.pool.served(),
            rejected: self.pool.rejected(),
            cache: self.store.stats(),
        }
    }

    /// Stop pool workers after their queues drain; idempotent.
    pub fn shutdown(&self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::embedding::{EmbeddingStore, Word2KetXS};
    use crate::util::Rng;

    fn state() -> ServingState {
        let mut rng = Rng::new(0);
        let inner = Box::new(Word2KetXS::random(200, 16, 2, 2, &mut rng));
        ServingState::new(inner, &ServingConfig { batch_window_us: 50, ..Default::default() })
    }

    #[test]
    fn lookup_validates_then_serves() {
        let st = state();
        assert_eq!(st.lookup_rows(vec![]), Err(LookupError::Empty));
        assert_eq!(st.lookup_rows(vec![3, 200]), Err(LookupError::OutOfRange));
        let rows = st.lookup_rows(vec![3, 7, 3]).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], st.store().lookup(3));
        assert_eq!(rows[0], rows[2]);
        st.shutdown();
    }

    #[test]
    fn dot_matches_reconstruction() {
        let st = state();
        let d = st.dot(1, 2).unwrap();
        let want = crate::tensor::dot(&st.store().lookup(1), &st.store().lookup(2));
        assert_eq!(d, want);
        assert_eq!(st.dot(0, 999), Err(LookupError::OutOfRange));
        st.shutdown();
    }

    #[test]
    fn stats_zero_before_traffic() {
        let st = state();
        let s = st.stats();
        assert_eq!(s.served, 0);
        assert_eq!(s.p50_us, 0.0);
        assert_eq!(s.p99_us, 0.0);
        assert_eq!(s.rejected, 0);
        assert_eq!(s.cache.hits, 0);
        st.shutdown();
    }
}
