//! Length-prefixed binary wire protocol for the embedding server.
//!
//! The text protocol formats every f32 as decimal text and re-parses ids per
//! request — measurable overhead at production rates. This module defines a
//! compact binary framing negotiated *on the same listener*: a connection
//! whose first byte is `MAGIC[0]` (0xB2, never a valid text-command byte)
//! speaks binary; anything else falls through to the line-oriented text
//! handler.
//!
//! ## Framing (all integers/floats little-endian)
//!
//! ```text
//! client hello:  MAGIC (4 bytes: B2 4B 45 54, i.e. 0xB2 "KET")
//! server hello:  MAGIC, u32 dim
//! request:       u32 op, u32 count, count × u32 id
//!   op 1 LOOKUP  count >= 1 ids
//!   op 2 DOT     count == 2 ids
//!   op 3 STATS   count == 0
//!   op 4 QUIT    count == 0 (server closes the connection)
//!   op 5 KNN     count == 2: [query id, k]; k == 0 is a bad frame
//!   op 6 RELOAD  count = path byte length, payload = count raw UTF-8 path
//!                bytes (not ids); hot-swaps the model to that snapshot
//! response:      u32 status, u32 count, payload
//!   LOOKUP ok    count = #ids,  payload = count × dim × f32 rows
//!   DOT ok       count = 1,     payload = 1 × f32
//!   STATS ok     count = 11,    payload = 11 × f64:
//!                p50_us, p99_us, served, cache_hits, cache_misses, rejected,
//!                knn_queries, knn_candidates, knn_mean_probes,
//!                model_generation, snapshot_bytes
//!   KNN ok       count = #neighbors (≤ k), payload = count × (u32 id,
//!                f32 score), best first
//!   RELOAD ok    count = 1,     payload = 1 × u32 new model generation
//!   error        status != 0,   count = 0, no payload
//! status codes:  0 ok, 1 id out of range, 2 bad frame, 3 overloaded
//!                (backpressure), 4 timeout, 5 reload failed
//! ```
//!
//! Hostile-frame hardening: `count` is validated against [`MAX_IDS`]
//! (or [`MAX_PATH_BYTES`] for RELOAD) *before* any buffer is allocated, so
//! a 4 GiB count header costs the attacker a `STATUS_BAD_FRAME` and a
//! closed connection, not a server allocation.

use super::{LookupError, ServingState};
use crate::index::Query;
use std::io::{self, BufReader, Read, Write};
use std::net::TcpStream;

/// Connection preamble; first byte 0xB2 is outside printable ASCII so the
/// listener can sniff binary vs text from one byte.
pub const MAGIC: [u8; 4] = [0xB2, b'K', b'E', b'T'];

pub const OP_LOOKUP: u32 = 1;
pub const OP_DOT: u32 = 2;
pub const OP_STATS: u32 = 3;
pub const OP_QUIT: u32 = 4;
pub const OP_KNN: u32 = 5;
pub const OP_RELOAD: u32 = 6;

pub const STATUS_OK: u32 = 0;
pub const STATUS_RANGE: u32 = 1;
pub const STATUS_BAD_FRAME: u32 = 2;
pub const STATUS_OVERLOADED: u32 = 3;
pub const STATUS_TIMEOUT: u32 = 4;
pub const STATUS_RELOAD_FAILED: u32 = 5;

/// Per-request id-count cap: bounds allocation from a hostile frame header.
pub const MAX_IDS: u32 = 1 << 16;

/// RELOAD path byte cap (PATH_MAX-ish): same allocation-bounding role as
/// [`MAX_IDS`] for the one op whose payload is bytes, not ids.
pub const MAX_PATH_BYTES: u32 = 4096;

/// Number of f64 values in a STATS response payload.
pub const STATS_FIELDS: usize = 11;

pub fn status_name(status: u32) -> &'static str {
    match status {
        STATUS_OK => "ok",
        STATUS_RANGE => "id out of range",
        STATUS_BAD_FRAME => "bad frame",
        STATUS_OVERLOADED => "overloaded",
        STATUS_TIMEOUT => "timeout",
        STATUS_RELOAD_FAILED => "reload failed",
        _ => "unknown status",
    }
}

// ---- primitive framing ----------------------------------------------------

fn read_u32(r: &mut impl Read) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_f64s(buf: &mut Vec<u8>, xs: &[f64]) {
    buf.reserve(xs.len() * 8);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn read_f32s(r: &mut impl Read, n: usize) -> io::Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_f64s(r: &mut impl Read, n: usize) -> io::Result<Vec<f64>> {
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

fn write_error(w: &mut impl Write, status: u32) -> io::Result<()> {
    let mut buf = Vec::with_capacity(8);
    put_u32(&mut buf, status);
    put_u32(&mut buf, 0);
    w.write_all(&buf)
}

fn status_of(e: LookupError) -> u32 {
    match e {
        LookupError::Empty => STATUS_BAD_FRAME,
        LookupError::BadQuery => STATUS_BAD_FRAME,
        LookupError::OutOfRange => STATUS_RANGE,
        LookupError::Overloaded => STATUS_OVERLOADED,
        LookupError::Timeout => STATUS_TIMEOUT,
    }
}

// ---- server side ----------------------------------------------------------

/// Serve binary frames on an accepted connection. Called by the listener
/// after it consumed and verified [`MAGIC`]; sends the server hello and
/// loops until QUIT, EOF, or an unrecoverable framing error.
pub fn handle_binary(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    state: &ServingState,
) -> io::Result<()> {
    let mut hello = Vec::with_capacity(8);
    hello.extend_from_slice(&MAGIC);
    put_u32(&mut hello, state.dim() as u32);
    writer.write_all(&hello)?;
    loop {
        let op = match read_u32(reader) {
            Ok(op) => op,
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(()), // clean close
            Err(e) => return Err(e),
        };
        let count = read_u32(reader)?;
        if op == OP_RELOAD {
            // RELOAD's payload is path bytes, not ids; cap checked before
            // any allocation, like MAX_IDS below.
            if count == 0 || count > MAX_PATH_BYTES {
                // The remaining stream length is untrustworthy: error, close.
                return write_error(writer, STATUS_BAD_FRAME);
            }
            let mut raw = vec![0u8; count as usize];
            reader.read_exact(&mut raw)?;
            let Ok(path) = String::from_utf8(raw) else {
                write_error(writer, STATUS_BAD_FRAME)?;
                continue;
            };
            match state.reload_snapshot(std::path::Path::new(&path)) {
                Ok(generation) => {
                    let mut buf = Vec::with_capacity(12);
                    put_u32(&mut buf, STATUS_OK);
                    put_u32(&mut buf, 1);
                    put_u32(&mut buf, generation as u32);
                    writer.write_all(&buf)?;
                }
                Err(e) => {
                    crate::warn!("binary RELOAD {path:?} failed: {e}");
                    write_error(writer, STATUS_RELOAD_FAILED)?;
                }
            }
            continue;
        }
        // Hostile-header guard: the cap check precedes the id-buffer
        // allocation, so a 4 GiB count never reserves memory.
        if count > MAX_IDS {
            // The remaining stream length is untrustworthy: error and close.
            return write_error(writer, STATUS_BAD_FRAME);
        }
        let mut ids = Vec::with_capacity(count as usize);
        for _ in 0..count {
            ids.push(read_u32(reader)? as usize);
        }
        match op {
            OP_QUIT => return Ok(()),
            OP_LOOKUP if !ids.is_empty() => match state.lookup_rows(ids) {
                Ok(rows) => {
                    let mut buf = Vec::with_capacity(8 + rows.len() * state.dim() * 4);
                    put_u32(&mut buf, STATUS_OK);
                    put_u32(&mut buf, rows.len() as u32);
                    for row in &rows {
                        put_f32s(&mut buf, row);
                    }
                    writer.write_all(&buf)?;
                }
                Err(e) => write_error(writer, status_of(e))?,
            },
            OP_DOT if ids.len() == 2 => match state.dot(ids[0], ids[1]) {
                Ok(d) => {
                    let mut buf = Vec::with_capacity(12);
                    put_u32(&mut buf, STATUS_OK);
                    put_u32(&mut buf, 1);
                    put_f32s(&mut buf, &[d]);
                    writer.write_all(&buf)?;
                }
                Err(e) => write_error(writer, status_of(e))?,
            },
            // Zero-length k is rejected here, before the job could be built
            // or enqueued (state.knn would also catch it; failing at the
            // frame layer keeps the invalid request off the pool entirely).
            OP_KNN if ids.len() == 2 && ids[1] == 0 => {
                write_error(writer, STATUS_BAD_FRAME)?
            }
            OP_KNN if ids.len() == 2 => {
                let (query, k) = (ids[0], ids[1]);
                match state.knn(Query::Id(query), k) {
                    Ok(neighbors) => {
                        let mut buf = Vec::with_capacity(8 + neighbors.len() * 8);
                        put_u32(&mut buf, STATUS_OK);
                        put_u32(&mut buf, neighbors.len() as u32);
                        for n in &neighbors {
                            put_u32(&mut buf, n.id as u32);
                            put_f32s(&mut buf, &[n.score]);
                        }
                        writer.write_all(&buf)?;
                    }
                    Err(e) => write_error(writer, status_of(e))?,
                }
            }
            OP_STATS => {
                let s = state.stats();
                let mut buf = Vec::with_capacity(8 + STATS_FIELDS * 8);
                put_u32(&mut buf, STATUS_OK);
                put_u32(&mut buf, STATS_FIELDS as u32);
                put_f64s(
                    &mut buf,
                    &[
                        s.p50_us,
                        s.p99_us,
                        s.served as f64,
                        s.cache.hits as f64,
                        s.cache.misses as f64,
                        s.rejected as f64,
                        s.knn_queries as f64,
                        s.knn_candidates as f64,
                        s.knn_mean_probes,
                        s.model_generation as f64,
                        s.snapshot_bytes as f64,
                    ],
                );
                writer.write_all(&buf)?;
            }
            // Known op with a bad id count, or an unknown op: the frame was
            // still consumed in full, so report and keep the connection.
            _ => write_error(writer, STATUS_BAD_FRAME)?,
        }
    }
}

// ---- client side ----------------------------------------------------------

/// Client-side failure: transport error or a non-zero server status.
#[derive(Debug)]
pub enum WireError {
    Io(io::Error),
    Status(u32),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire io: {e}"),
            WireError::Status(s) => write!(f, "server status {s}: {}", status_name(*s)),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Aggregate server statistics decoded from a STATS response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireStats {
    pub p50_us: f64,
    pub p99_us: f64,
    pub served: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub rejected: u64,
    pub knn_queries: u64,
    pub knn_candidates: u64,
    pub knn_mean_probes: f64,
    pub model_generation: u64,
    pub snapshot_bytes: u64,
}

/// Minimal binary-protocol client (load generator, tests, examples).
pub struct BinaryClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    pub dim: usize,
}

impl BinaryClient {
    /// Connect and perform the magic handshake.
    pub fn connect(addr: &str) -> Result<BinaryClient, WireError> {
        let stream = TcpStream::connect(addr)?;
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);
        writer.write_all(&MAGIC)?;
        let mut ack = [0u8; 4];
        reader.read_exact(&mut ack)?;
        if ack != MAGIC {
            return Err(WireError::Io(io::Error::new(
                io::ErrorKind::InvalidData,
                "server did not ack binary magic",
            )));
        }
        let dim = read_u32(&mut reader)? as usize;
        Ok(BinaryClient { reader, writer, dim })
    }

    fn request(&mut self, op: u32, ids: &[u32]) -> Result<u32, WireError> {
        let mut buf = Vec::with_capacity(8 + ids.len() * 4);
        put_u32(&mut buf, op);
        put_u32(&mut buf, ids.len() as u32);
        for &id in ids {
            put_u32(&mut buf, id);
        }
        self.writer.write_all(&buf)?;
        let status = read_u32(&mut self.reader)?;
        Ok(status)
    }

    /// Fetch rows for `ids`; one `dim`-length vector per id, request order.
    pub fn lookup(&mut self, ids: &[u32]) -> Result<Vec<Vec<f32>>, WireError> {
        let status = self.request(OP_LOOKUP, ids)?;
        let count = read_u32(&mut self.reader)? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let mut rows = Vec::with_capacity(count);
        for _ in 0..count {
            rows.push(read_f32s(&mut self.reader, self.dim)?);
        }
        Ok(rows)
    }

    /// Inner product of two rows, computed server-side.
    pub fn dot(&mut self, a: u32, b: u32) -> Result<f32, WireError> {
        let status = self.request(OP_DOT, &[a, b])?;
        let count = read_u32(&mut self.reader)? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let xs = read_f32s(&mut self.reader, count)?;
        Ok(xs[0])
    }

    /// Top-`k` neighbors of word `id`, computed server-side (best first).
    pub fn knn(&mut self, id: u32, k: u32) -> Result<Vec<(u32, f32)>, WireError> {
        let status = self.request(OP_KNN, &[id, k])?;
        let count = read_u32(&mut self.reader)? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let nid = read_u32(&mut self.reader)?;
            let score = read_f32s(&mut self.reader, 1)?[0];
            out.push((nid, score));
        }
        Ok(out)
    }

    pub fn stats(&mut self) -> Result<WireStats, WireError> {
        let status = self.request(OP_STATS, &[])?;
        let count = read_u32(&mut self.reader)? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let xs = read_f64s(&mut self.reader, count)?;
        if xs.len() < STATS_FIELDS {
            return Err(WireError::Io(io::Error::new(
                io::ErrorKind::InvalidData,
                "short STATS payload",
            )));
        }
        Ok(WireStats {
            p50_us: xs[0],
            p99_us: xs[1],
            served: xs[2] as u64,
            cache_hits: xs[3] as u64,
            cache_misses: xs[4] as u64,
            rejected: xs[5] as u64,
            knn_queries: xs[6] as u64,
            knn_candidates: xs[7] as u64,
            knn_mean_probes: xs[8],
            model_generation: xs[9] as u64,
            snapshot_bytes: xs[10] as u64,
        })
    }

    /// Ask the server to hot-swap its model to the snapshot at `path`
    /// (server-side path). Returns the new model generation.
    pub fn reload(&mut self, path: &str) -> Result<u32, WireError> {
        let bytes = path.as_bytes();
        let mut buf = Vec::with_capacity(8 + bytes.len());
        put_u32(&mut buf, OP_RELOAD);
        put_u32(&mut buf, bytes.len() as u32);
        buf.extend_from_slice(bytes);
        self.writer.write_all(&buf)?;
        let status = read_u32(&mut self.reader)?;
        let count = read_u32(&mut self.reader)? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let mut generation = 0u32;
        for _ in 0..count {
            generation = read_u32(&mut self.reader)?;
        }
        Ok(generation)
    }

    /// Send QUIT; the server closes the connection without replying, so
    /// this writes the frame and returns (no status read).
    pub fn quit(mut self) -> Result<(), WireError> {
        let mut buf = Vec::with_capacity(8);
        put_u32(&mut buf, OP_QUIT);
        put_u32(&mut buf, 0);
        self.writer.write_all(&buf)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn primitive_roundtrips() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_f32s(&mut buf, &[1.5, -2.25]);
        put_f64s(&mut buf, &[3.5e12]);
        let mut c = Cursor::new(buf);
        assert_eq!(read_u32(&mut c).unwrap(), 0xDEAD_BEEF);
        assert_eq!(read_f32s(&mut c, 2).unwrap(), vec![1.5, -2.25]);
        assert_eq!(read_f64s(&mut c, 1).unwrap(), vec![3.5e12]);
    }

    #[test]
    fn magic_first_byte_is_not_ascii_text() {
        // The dispatcher relies on this: every text command starts with an
        // uppercase ASCII letter, so 0xB2 can never be confused for text.
        assert!(!MAGIC[0].is_ascii());
    }

    #[test]
    fn status_names_cover_codes() {
        for s in [
            STATUS_OK,
            STATUS_RANGE,
            STATUS_BAD_FRAME,
            STATUS_OVERLOADED,
            STATUS_TIMEOUT,
            STATUS_RELOAD_FAILED,
        ] {
            assert_ne!(status_name(s), "unknown status");
        }
        assert_eq!(status_name(99), "unknown status");
    }
}
