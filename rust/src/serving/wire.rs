//! Length-prefixed binary wire protocol for the embedding server.
//!
//! The text protocol formats every f32 as decimal text and re-parses ids per
//! request — measurable overhead at production rates. This module defines a
//! compact binary framing negotiated *on the same listener*: a connection
//! whose first byte is `MAGIC[0]` (0xB2, never a valid text-command byte)
//! speaks binary; anything else falls through to the line-oriented text
//! handler.
//!
//! ## Framing (all integers/floats little-endian)
//!
//! ```text
//! client hello:  MAGIC (4 bytes: B2 4B 45 54, i.e. 0xB2 "KET")
//! server hello:  MAGIC, u32 dim
//! request:       u32 op, u32 count, count × u32 id
//!   op 1 LOOKUP  count >= 1 ids
//!   op 2 DOT     count == 2 ids
//!   op 3 STATS   count == 0
//!   op 4 QUIT    count == 0 (server closes the connection)
//!   op 5 KNN     count == 2: [query id, k]; k == 0 is a bad frame
//!   op 6 RELOAD  count = path byte length, payload = count raw UTF-8 path
//!                bytes (not ids); hot-swaps the model to that snapshot
//!   op 7 PING    count == 0; liveness probe (the cluster health prober's
//!                op). A PING carrying ids is a bad request.
//!   op 8 KNN_VEC count = query dimensionality, payload = u32 k then
//!                count × f32 query vector (not ids); the scatter half of
//!                cluster KNN — shards that do not own the query word score
//!                the caller-supplied vector
//!   op 9 METRICS count == 0; full metrics exposition (the binary twin of
//!                the text `METRICS` verb — same bytes)
//!   op 10 TRACE  count == 4: the 16-byte trace id as 4 little-endian u32
//!                words (low word first), or count == 0 for the stored-
//!                trace ring summary; payload = UTF-8 trace dump (the
//!                binary twin of `TRACE <id>` / `TRACE?slow`)
//!
//! trace-context extension: a request whose op word has the high bit
//! ([`OP_TRACE_CTX`]) set carries 24 extension bytes between the 8-byte
//! header and the payload — u128 trace id + u64 parent span id, both
//! little-endian. The flag changes nothing else: caps are enforced on the
//! masked op *before* the extension is read, and responses never carry the
//! extension. With tracing off (or a request unsampled) the flag is never
//! set, so the wire is byte-identical to the untraced protocol.
//!
//! response:      u32 status, u32 count, payload
//!   LOOKUP ok    count = #ids,  payload = count × dim × f32 rows
//!   DOT ok       count = 1,     payload = 1 × f32
//!   STATS ok     count = 13,    payload = 13 × f64 in
//!                [`STATS_FIELD_NAMES`] order
//!   METRICS ok   count = payload byte length, payload = UTF-8 exposition
//!                text (Prometheus-style lines, `# EOF` terminated)
//!   KNN ok       count = #neighbors (≤ k), payload = count × (u32 id,
//!                f32 score), best first (KNN_VEC identical, query word
//!                not excluded)
//!   RELOAD ok    count = 1,     payload = 1 × u32 new model generation
//!   PING ok      count = 0,     no payload (status-only)
//!   error        status != 0,   count = 0, no payload
//! status codes:  0 ok, 1 id out of range, 2 bad frame/request, 3
//!                overloaded (backpressure), 4 timeout, 5 reload failed
//! ```
//!
//! Hostile-frame hardening: `count` is validated against [`MAX_IDS`]
//! (or [`MAX_PATH_BYTES`] for RELOAD) *before* any buffer is allocated, so
//! a 4 GiB count header costs the attacker a `STATUS_BAD_FRAME` and a
//! closed connection, not a server allocation.

use super::{LookupError, ServingState};
use crate::index::Query;
use crate::obs::TraceContext;
use std::io::{self, BufReader, Read, Write};
use std::net::TcpStream;

/// Connection preamble; first byte 0xB2 is outside printable ASCII so the
/// listener can sniff binary vs text from one byte.
pub const MAGIC: [u8; 4] = [0xB2, b'K', b'E', b'T'];

pub const OP_LOOKUP: u32 = 1;
pub const OP_DOT: u32 = 2;
pub const OP_STATS: u32 = 3;
pub const OP_QUIT: u32 = 4;
pub const OP_KNN: u32 = 5;
pub const OP_RELOAD: u32 = 6;
pub const OP_PING: u32 = 7;
pub const OP_KNN_VEC: u32 = 8;
pub const OP_METRICS: u32 = 9;
pub const OP_TRACE: u32 = 10;

/// High bit of the request op word: the frame carries a 24-byte
/// trace-context extension (u128 trace id + u64 parent span id, both
/// little-endian) between the header and the payload. Never set on
/// responses; never set when tracing is off or the request is unsampled —
/// which keeps the untraced wire byte-identical.
pub const OP_TRACE_CTX: u32 = 0x8000_0000;

pub const STATUS_OK: u32 = 0;
pub const STATUS_RANGE: u32 = 1;
pub const STATUS_BAD_FRAME: u32 = 2;
pub const STATUS_OVERLOADED: u32 = 3;
pub const STATUS_TIMEOUT: u32 = 4;
pub const STATUS_RELOAD_FAILED: u32 = 5;

/// A syntactically valid frame carrying a semantically invalid request
/// (e.g. `PING` with ids). Same wire code as [`STATUS_BAD_FRAME`] — the
/// distinction is documentation-level, the connection stays usable either
/// way because the frame was consumed in full.
pub const STATUS_BAD_REQUEST: u32 = STATUS_BAD_FRAME;

/// Per-request id-count cap: bounds allocation from a hostile frame header.
pub const MAX_IDS: u32 = 1 << 16;

/// RELOAD path byte cap (PATH_MAX-ish): same allocation-bounding role as
/// [`MAX_IDS`] for the one op whose payload is bytes, not ids.
pub const MAX_PATH_BYTES: u32 = 4096;

/// Number of f64 values in a STATS response payload.
pub const STATS_FIELDS: usize = 14;

/// The one canonical STATS field list. The binary payload is these values
/// in this order; the text `STATS` line is `name=value` pairs in this order
/// (formatted by [`format_stats_field`]); [`WireStats`] decodes positionally
/// from it. Adding a field means touching exactly this table,
/// [`crate::serving::ServingStats::fields`], and the [`WireStats`] struct —
/// the compiler and the shared drift test
/// ([`crate::testing::assert_stats_consistent`]) catch anything missed, so
/// the two protocols cannot desync again.
pub const STATS_FIELD_NAMES: [&str; STATS_FIELDS] = [
    "p50_us",
    "p99_us",
    "served",
    "cache_hits",
    "cache_misses",
    "rejected",
    "knn_queries",
    "knn_candidates",
    "knn_mean_probes",
    "model_generation",
    "snapshot_bytes",
    // Appended last so binary decoders built against the 11-field layout
    // still parse newer servers (trailing fields are ignored).
    "accept_errors",
    // SIMD dispatch level of the serving kernels (0 = scalar, 1 = sse2,
    // 2 = avx2+fma); the cluster roll-up reports the minimum across
    // replicas. Appended after accept_errors for the same trailing-field
    // back-compat reason.
    "simd_level",
    // Stored precision of the served factor payload in bits per value
    // (32 = float, 16/8/4/2/1 = quantized — see `crate::quant`); the
    // cluster roll-up reports the maximum across replicas. Trailing for
    // the same back-compat reason.
    "payload_bits",
];

/// Text-protocol rendering of one STATS field: microsecond percentiles as
/// whole numbers, `knn_mean_probes` with two decimals, everything else as
/// an integer counter. Shared by the server's text `STATS` line and the
/// drift test so a formatting change cannot split them.
pub fn format_stats_field(name: &str, value: f64) -> String {
    match name {
        "p50_us" | "p99_us" => format!("{value:.0}"),
        "knn_mean_probes" => format!("{value:.2}"),
        _ => format!("{}", value as u64),
    }
}

/// Render the canonical text-protocol `STATS` line (no trailing newline):
/// `OK name=value ...` over [`STATS_FIELD_NAMES`]. Both the single-node
/// server and the cluster router's listener emit exactly this (the router
/// appends its rollup extras after), so the text rendering exists once.
pub fn format_stats_line(fields: &[f64; STATS_FIELDS]) -> String {
    let mut line = String::from("OK");
    for (name, value) in STATS_FIELD_NAMES.iter().zip(fields) {
        line.push(' ');
        line.push_str(name);
        line.push('=');
        line.push_str(&format_stats_field(name, *value));
    }
    line
}

/// Write a binary STATS response frame — the one encoding of the shared
/// field table, used by the single-node handler and the cluster listener.
pub(crate) fn write_stats_frame(
    w: &mut impl Write,
    fields: &[f64; STATS_FIELDS],
) -> io::Result<()> {
    let mut buf = Vec::with_capacity(8 + STATS_FIELDS * 8);
    put_u32(&mut buf, STATUS_OK);
    put_u32(&mut buf, STATS_FIELDS as u32);
    put_f64s(&mut buf, fields);
    w.write_all(&buf)
}

/// Write a KNN/KNN_VEC response frame: `count × (u32 id, f32 score)`,
/// best first. One encoding for OP_KNN, OP_KNN_VEC, and the cluster
/// listener's merged results.
pub(crate) fn write_neighbors_frame<I>(w: &mut impl Write, neighbors: I) -> io::Result<()>
where
    I: ExactSizeIterator<Item = (u32, f32)>,
{
    let mut buf = Vec::with_capacity(8 + neighbors.len() * 8);
    put_u32(&mut buf, STATUS_OK);
    put_u32(&mut buf, neighbors.len() as u32);
    for (id, score) in neighbors {
        put_u32(&mut buf, id);
        put_f32s(&mut buf, &[score]);
    }
    w.write_all(&buf)
}

pub fn status_name(status: u32) -> &'static str {
    match status {
        STATUS_OK => "ok",
        STATUS_RANGE => "id out of range",
        STATUS_BAD_FRAME => "bad frame",
        STATUS_OVERLOADED => "overloaded",
        STATUS_TIMEOUT => "timeout",
        STATUS_RELOAD_FAILED => "reload failed",
        _ => "unknown status",
    }
}

// ---- primitive framing ----------------------------------------------------
// pub(crate): the cluster router's listener (`cluster::server`) speaks the
// identical frame grammar upstream and reuses these instead of re-deriving
// the byte layout.

pub(crate) fn read_u32(r: &mut impl Read) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub(crate) fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

pub(crate) fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn put_f64s(buf: &mut Vec<u8>, xs: &[f64]) {
    buf.reserve(xs.len() * 8);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

pub(crate) fn read_f32s(r: &mut impl Read, n: usize) -> io::Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_f64s(r: &mut impl Read, n: usize) -> io::Result<Vec<f64>> {
    let mut bytes = vec![0u8; n * 8];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

pub(crate) fn write_error(w: &mut impl Write, status: u32) -> io::Result<()> {
    let mut buf = Vec::with_capacity(8);
    put_u32(&mut buf, status);
    put_u32(&mut buf, 0);
    w.write_all(&buf)
}

fn status_of(e: LookupError) -> u32 {
    match e {
        LookupError::Empty => STATUS_BAD_FRAME,
        LookupError::BadQuery => STATUS_BAD_FRAME,
        LookupError::OutOfRange => STATUS_RANGE,
        LookupError::Overloaded => STATUS_OVERLOADED,
        LookupError::Timeout => STATUS_TIMEOUT,
    }
}

// ---- server side ----------------------------------------------------------

/// One decoded binary request frame, shared by both network drivers: the
/// blocking driver decodes it with [`read_frame`], the reactor with
/// `crate::net::parser::next_frame`, and both dispatch through
/// [`respond_binary`] — so the two drivers answer byte-identically by
/// construction.
#[derive(Debug, Clone, PartialEq)]
pub enum BinRequest {
    /// LOOKUP / DOT / STATS / QUIT / KNN / PING — and any unknown op — with
    /// `count` ids as payload.
    Ids { op: u32, ids: Vec<u32> },
    /// RELOAD; `path` is `None` when the payload bytes are not UTF-8 (a
    /// consumed-in-full frame: BAD_FRAME reply, connection survives).
    Reload { path: Option<String> },
    /// KNN_VEC: external query vector plus k.
    KnnVec { k: u32, query: Vec<f32> },
    /// A request whose op word carried the [`OP_TRACE_CTX`] extension:
    /// the propagated upstream context wraps the decoded inner request.
    /// `parse_us` is filled by the driver after decode (both drivers
    /// already time the parse stage) so the span can bill it.
    Traced { ctx: TraceContext, parse_us: u64, inner: Box<BinRequest> },
    /// Hostile count header (cap exceeded before any allocation): error
    /// frame, then close — the remaining stream length is untrustworthy.
    Fatal,
}

impl BinRequest {
    /// Does this request end the connection? (QUIT closes silently, a
    /// hostile header closes after the error frame.) The reactor uses this
    /// to stop parsing pipelined bytes past a terminal frame, which the
    /// blocking driver never sees either.
    pub fn is_terminal(&self) -> bool {
        match self {
            BinRequest::Fatal | BinRequest::Ids { op: OP_QUIT, .. } => true,
            BinRequest::Traced { inner, .. } => inner.is_terminal(),
            _ => false,
        }
    }
}

/// The shared hostile-count screen, applied to the *masked* op before any
/// allocation or further read — including the trace-context extension —
/// so both drivers reject a hostile header after exactly 8 bytes.
pub(crate) fn count_is_hostile(op: u32, count: u32) -> bool {
    match op {
        OP_RELOAD => count == 0 || count > MAX_PATH_BYTES,
        OP_KNN_VEC => count == 0 || count > MAX_IDS,
        _ => count > MAX_IDS,
    }
}

fn read_trace_ctx(r: &mut impl Read) -> io::Result<TraceContext> {
    let mut b = [0u8; 24];
    r.read_exact(&mut b)?;
    Ok(TraceContext {
        trace_id: u128::from_le_bytes(b[..16].try_into().expect("16-byte slice")),
        span_id: u64::from_le_bytes(b[16..].try_into().expect("8-byte slice")),
    })
}

/// Blocking-read one request frame (`Ok(None)` = clean EOF between frames).
/// The grammar — caps, payload shapes, hostile-header short-circuits — is
/// mirrored incrementally by `crate::net::parser::next_frame`.
pub(crate) fn read_frame(r: &mut impl Read) -> io::Result<Option<BinRequest>> {
    let word = match read_u32(r) {
        Ok(word) => word,
        Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => return Ok(None), // clean close
        Err(e) => return Err(e),
    };
    let count = read_u32(r)?;
    let op = word & !OP_TRACE_CTX;
    // Hostile-header guard: the cap check precedes every allocation and
    // every further read (including the trace-context extension), so a
    // 4 GiB count never reserves memory and fails after 8 header bytes
    // whether or not the frame claimed an extension.
    if count_is_hostile(op, count) {
        return Ok(Some(BinRequest::Fatal));
    }
    let ctx = if word & OP_TRACE_CTX != 0 { Some(read_trace_ctx(r)?) } else { None };
    let inner = if op == OP_RELOAD {
        // RELOAD's payload is path bytes, not ids.
        let mut raw = vec![0u8; count as usize];
        r.read_exact(&mut raw)?;
        BinRequest::Reload { path: String::from_utf8(raw).ok() }
    } else if op == OP_KNN_VEC {
        // KNN_VEC's payload is `u32 k` + `count` f32s, not ids. The whole
        // frame is consumed before validation so the connection stays
        // usable after a semantic error.
        let k = read_u32(r)?;
        let query = read_f32s(r, count as usize)?;
        BinRequest::KnnVec { k, query }
    } else {
        let mut ids = Vec::with_capacity(count as usize);
        for _ in 0..count {
            ids.push(read_u32(r)?);
        }
        BinRequest::Ids { op, ids }
    };
    Ok(Some(match ctx {
        Some(ctx) => BinRequest::Traced { ctx, parse_us: 0, inner: Box::new(inner) },
        None => inner,
    }))
}

/// Append the response frame for `req` to `out`; returns true when the
/// connection must close after `out` is flushed. This is the single binary
/// dispatcher behind both network drivers.
pub(crate) fn respond_binary(state: &ServingState, req: BinRequest, out: &mut Vec<u8>) -> bool {
    match req {
        // Unwrap a propagated trace context and dispatch the inner request
        // through the traced serving paths. The response bytes are
        // identical to the untraced dispatch by construction — the context
        // only decides whether a span is recorded server-side.
        BinRequest::Traced { ctx, parse_us, inner } => {
            dispatch_binary(state, *inner, out, Some((ctx, parse_us)))
        }
        other => dispatch_binary(state, other, out, None),
    }
}

fn dispatch_binary(
    state: &ServingState,
    req: BinRequest,
    out: &mut Vec<u8>,
    trace: Option<(TraceContext, u64)>,
) -> bool {
    match req {
        // Decoders never nest contexts; a hand-built nested frame is a
        // semantic error (the frame was consumed, connection survives).
        BinRequest::Traced { .. } => {
            put_u32(out, STATUS_BAD_REQUEST);
            put_u32(out, 0);
            false
        }
        BinRequest::Fatal => {
            put_u32(out, STATUS_BAD_FRAME);
            put_u32(out, 0);
            true
        }
        BinRequest::Reload { path: None } => {
            put_u32(out, STATUS_BAD_FRAME);
            put_u32(out, 0);
            false
        }
        BinRequest::Reload { path: Some(path) } => {
            match state.reload_snapshot(std::path::Path::new(&path)) {
                Ok(generation) => {
                    put_u32(out, STATUS_OK);
                    put_u32(out, 1);
                    put_u32(out, generation as u32);
                }
                Err(e) => {
                    crate::warn!("binary RELOAD {path:?} failed: {e}");
                    put_u32(out, STATUS_RELOAD_FAILED);
                    put_u32(out, 0);
                }
            }
            false
        }
        BinRequest::KnnVec { k: 0, .. } => {
            put_u32(out, STATUS_BAD_REQUEST);
            put_u32(out, 0);
            false
        }
        BinRequest::KnnVec { k, query } => {
            match state.knn_traced(Query::Vector(query), k as usize, trace) {
                Ok(neighbors) => {
                    let pairs = neighbors.iter().map(|n| (n.id as u32, n.score));
                    let _ = write_neighbors_frame(out, pairs);
                }
                Err(e) => {
                    put_u32(out, status_of(e));
                    put_u32(out, 0);
                }
            }
            false
        }
        BinRequest::Ids { op: OP_QUIT, .. } => true, // closes without a reply
        BinRequest::Ids { op, ids } => {
            match op {
                // Status-only liveness probe (the cluster health prober's
                // op): no state is touched, so a wedged model cannot fake
                // liveness — only the listener/framing path is exercised.
                OP_PING if ids.is_empty() => {
                    put_u32(out, STATUS_OK);
                    put_u32(out, 0);
                }
                // A PING carrying ids is a bad request (the frame was
                // consumed, so the connection survives).
                OP_PING => {
                    put_u32(out, STATUS_BAD_REQUEST);
                    put_u32(out, 0);
                }
                OP_LOOKUP if !ids.is_empty() => {
                    let ids: Vec<usize> = ids.iter().map(|&id| id as usize).collect();
                    match state.lookup_rows_traced(ids, trace) {
                        Ok(rows) => {
                            out.reserve(8 + rows.len() * state.dim() * 4);
                            put_u32(out, STATUS_OK);
                            put_u32(out, rows.len() as u32);
                            for row in &rows {
                                put_f32s(out, row);
                            }
                        }
                        Err(e) => {
                            put_u32(out, status_of(e));
                            put_u32(out, 0);
                        }
                    }
                }
                OP_DOT if ids.len() == 2 => {
                    match state.dot(ids[0] as usize, ids[1] as usize) {
                        Ok(d) => {
                            put_u32(out, STATUS_OK);
                            put_u32(out, 1);
                            put_f32s(out, &[d]);
                        }
                        Err(e) => {
                            put_u32(out, status_of(e));
                            put_u32(out, 0);
                        }
                    }
                }
                // Zero-length k is rejected here, before the job could be
                // built or enqueued (state.knn would also catch it; failing
                // at the frame layer keeps it off the pool entirely).
                OP_KNN if ids.len() == 2 && ids[1] == 0 => {
                    put_u32(out, STATUS_BAD_FRAME);
                    put_u32(out, 0);
                }
                OP_KNN if ids.len() == 2 => {
                    match state.knn_traced(Query::Id(ids[0] as usize), ids[1] as usize, trace) {
                        Ok(neighbors) => {
                            let pairs = neighbors.iter().map(|n| (n.id as u32, n.score));
                            let _ = write_neighbors_frame(out, pairs);
                        }
                        Err(e) => {
                            put_u32(out, status_of(e));
                            put_u32(out, 0);
                        }
                    }
                }
                OP_STATS => {
                    // The payload is the shared field table in canonical
                    // order (the text protocol renders the same array).
                    let _ = write_stats_frame(out, &state.stats().fields());
                }
                // Full metrics exposition: the payload is the exact UTF-8
                // text the text-protocol `METRICS` verb returns, so the two
                // protocols (and both network drivers) expose identical
                // bytes by construction.
                OP_METRICS if ids.is_empty() => {
                    let text = state.metrics_text();
                    put_u32(out, STATUS_OK);
                    put_u32(out, text.len() as u32);
                    out.extend_from_slice(text.as_bytes());
                }
                // METRICS carrying ids is a bad request (frame consumed,
                // connection survives) — mirrors PING.
                OP_METRICS => {
                    put_u32(out, STATUS_BAD_REQUEST);
                    put_u32(out, 0);
                }
                // One stored trace by id (four little-endian u32 words) —
                // the binary twin of the text `TRACE <hex id>` verb.
                OP_TRACE if ids.len() == 4 => {
                    let text = state.trace_text(trace_id_from_words(&ids));
                    put_u32(out, STATUS_OK);
                    put_u32(out, text.len() as u32);
                    out.extend_from_slice(text.as_bytes());
                }
                // No id: the stored-trace ring summary (`TRACE?slow`).
                OP_TRACE if ids.is_empty() => {
                    let text = state.trace_slow_text();
                    put_u32(out, STATUS_OK);
                    put_u32(out, text.len() as u32);
                    out.extend_from_slice(text.as_bytes());
                }
                // Any other TRACE id count is a bad request — mirrors PING.
                OP_TRACE => {
                    put_u32(out, STATUS_BAD_REQUEST);
                    put_u32(out, 0);
                }
                // Known op with a bad id count, or an unknown op: the frame
                // was consumed in full, so report and keep the connection.
                _ => {
                    put_u32(out, STATUS_BAD_FRAME);
                    put_u32(out, 0);
                }
            }
            false
        }
    }
}

/// Serve binary frames on an accepted connection (blocking driver). Called
/// by the listener after it consumed and verified [`MAGIC`]; sends the
/// server hello and loops until QUIT, EOF, or an unrecoverable framing
/// error.
pub fn handle_binary(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    state: &ServingState,
) -> io::Result<()> {
    let mut hello = Vec::with_capacity(8);
    hello.extend_from_slice(&MAGIC);
    put_u32(&mut hello, state.dim() as u32);
    writer.write_all(&hello)?;
    let mut out = Vec::new();
    loop {
        let Some(req) = read_frame(reader)? else {
            return Ok(());
        };
        out.clear();
        let close = respond_binary(state, req, &mut out);
        if !out.is_empty() {
            writer.write_all(&out)?;
        }
        if close {
            return Ok(());
        }
    }
}

// ---- client side ----------------------------------------------------------

/// Client-side failure, typed so callers (the cluster router above all) can
/// tell *what kind* of transport problem occurred instead of pattern-
/// matching on a raw `io::Error`:
///
/// * [`Status`](WireError::Status) — the server answered with a non-zero
///   status; the connection is fine.
/// * [`Connect`](WireError::Connect) — establishing the connection (resolve
///   / connect / handshake) failed; nothing was sent.
/// * [`TimedOut`](WireError::TimedOut) — a configured read/write deadline
///   expired; the connection state is unknown and the client will reconnect
///   on the next request.
/// * [`Io`](WireError::Io) — any other transport error.
#[derive(Debug)]
pub enum WireError {
    Io(io::Error),
    Status(u32),
    Connect { addr: String, message: String },
    TimedOut,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire io: {e}"),
            WireError::Status(s) => write!(f, "server status {s}: {}", status_name(*s)),
            WireError::Connect { addr, message } => write!(f, "connect {addr}: {message}"),
            WireError::TimedOut => write!(f, "wire deadline expired"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> Self {
        classify(e)
    }
}

/// Typed mapping of raw transport errors: deadline expiries (both the unix
/// `WouldBlock` and the windows `TimedOut` spellings of a socket timeout)
/// become [`WireError::TimedOut`]; everything else stays [`WireError::Io`].
pub(crate) fn classify(e: io::Error) -> WireError {
    match e.kind() {
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => WireError::TimedOut,
        _ => WireError::Io(e),
    }
}

/// Did the peer drop the connection (as opposed to answering or timing
/// out)? These are the errors worth one transparent reconnect: a server
/// restart or an idle-connection reap, not a protocol problem.
fn connection_dropped(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::BrokenPipe
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::UnexpectedEof
            | io::ErrorKind::NotConnected
    )
}

/// Aggregate server statistics decoded from a STATS response.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct WireStats {
    pub p50_us: f64,
    pub p99_us: f64,
    pub served: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub rejected: u64,
    pub knn_queries: u64,
    pub knn_candidates: u64,
    pub knn_mean_probes: f64,
    pub model_generation: u64,
    pub snapshot_bytes: u64,
    /// Transient accept(2) failures survived by the listener (EMFILE /
    /// ECONNABORTED backoff-and-retry events).
    pub accept_errors: u64,
    /// SIMD dispatch level of the serving kernels
    /// ([`crate::simd::SimdLevel::code`]: 0 = scalar, 1 = sse2,
    /// 2 = avx2+fma). The cluster roll-up reports the minimum across
    /// replicas.
    pub simd_level: u64,
    /// Stored precision of the served factor payload in bits per value
    /// ([`crate::repr::Repr::payload_bits`]): 32 for float stores, the
    /// packed code width for quantized payloads. The cluster roll-up
    /// reports the *maximum* across replicas (the least-compressed
    /// serving payload in the fleet).
    pub payload_bits: u64,
}

impl WireStats {
    /// Decode from a STATS payload ([`STATS_FIELD_NAMES`] order). Extra
    /// trailing fields from a newer server are ignored.
    pub fn from_fields(xs: &[f64]) -> WireStats {
        WireStats {
            p50_us: xs[0],
            p99_us: xs[1],
            served: xs[2] as u64,
            cache_hits: xs[3] as u64,
            cache_misses: xs[4] as u64,
            rejected: xs[5] as u64,
            knn_queries: xs[6] as u64,
            knn_candidates: xs[7] as u64,
            knn_mean_probes: xs[8],
            model_generation: xs[9] as u64,
            snapshot_bytes: xs[10] as u64,
            accept_errors: xs[11] as u64,
            simd_level: xs[12] as u64,
            payload_bits: xs[13] as u64,
        }
    }

    /// Re-encode in [`STATS_FIELD_NAMES`] order (drift tests, the cluster
    /// router's rolled-up STATS responses).
    pub fn fields(&self) -> [f64; STATS_FIELDS] {
        [
            self.p50_us,
            self.p99_us,
            self.served as f64,
            self.cache_hits as f64,
            self.cache_misses as f64,
            self.rejected as f64,
            self.knn_queries as f64,
            self.knn_candidates as f64,
            self.knn_mean_probes,
            self.model_generation as f64,
            self.snapshot_bytes as f64,
            self.accept_errors as f64,
            self.simd_level as f64,
            self.payload_bits as f64,
        ]
    }
}

/// Encode one id-payload request frame (LOOKUP/DOT/KNN/STATS/PING/QUIT).
/// Shared by [`BinaryClient`] and the router's multiplexed fan-out so both
/// paths put identical bytes on the wire.
pub(crate) fn encode_ids_frame(op: u32, ids: &[u32]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(8 + ids.len() * 4);
    put_u32(&mut buf, op);
    put_u32(&mut buf, ids.len() as u32);
    for &id in ids {
        put_u32(&mut buf, id);
    }
    buf
}

/// Encode one KNN_VEC request frame (count = query dimension).
pub(crate) fn encode_knn_vec_frame(query: &[f32], k: u32) -> Vec<u8> {
    encode_knn_vec_frame_traced(query, k, None)
}

fn put_trace_ctx(buf: &mut Vec<u8>, ctx: TraceContext) {
    buf.extend_from_slice(&ctx.trace_id.to_le_bytes());
    buf.extend_from_slice(&ctx.span_id.to_le_bytes());
}

/// [`encode_ids_frame`] with an optional trace-context extension; `None`
/// produces the exact untraced bytes. The router's fan-out uses this to
/// propagate the root span's context to every shard.
pub(crate) fn encode_ids_frame_traced(op: u32, ids: &[u32], ctx: Option<TraceContext>) -> Vec<u8> {
    let Some(ctx) = ctx else {
        return encode_ids_frame(op, ids);
    };
    let mut buf = Vec::with_capacity(32 + ids.len() * 4);
    put_u32(&mut buf, op | OP_TRACE_CTX);
    put_u32(&mut buf, ids.len() as u32);
    put_trace_ctx(&mut buf, ctx);
    for &id in ids {
        put_u32(&mut buf, id);
    }
    buf
}

/// [`encode_knn_vec_frame`] with an optional trace-context extension.
pub(crate) fn encode_knn_vec_frame_traced(
    query: &[f32],
    k: u32,
    ctx: Option<TraceContext>,
) -> Vec<u8> {
    let mut buf = Vec::with_capacity(36 + query.len() * 4);
    put_u32(&mut buf, if ctx.is_some() { OP_KNN_VEC | OP_TRACE_CTX } else { OP_KNN_VEC });
    put_u32(&mut buf, query.len() as u32);
    if let Some(ctx) = ctx {
        put_trace_ctx(&mut buf, ctx);
    }
    put_u32(&mut buf, k);
    put_f32s(&mut buf, query);
    buf
}

/// Pack a 16-byte trace id into the four little-endian u32 id words an
/// `OP_TRACE` request carries (low word first).
pub fn trace_id_words(trace_id: u128) -> [u32; 4] {
    std::array::from_fn(|i| (trace_id >> (32 * i)) as u32)
}

/// Unpack an `OP_TRACE` id payload (inverse of [`trace_id_words`]; short
/// or long payloads fold the words that are present).
pub fn trace_id_from_words(words: &[u32]) -> u128 {
    words
        .iter()
        .take(4)
        .enumerate()
        .fold(0u128, |acc, (i, &w)| acc | ((w as u128) << (32 * i)))
}

/// Binary-protocol client (load generator, tests, examples, and the unit of
/// connection pooling inside the cluster router).
///
/// Hardened for use from a router: optional connect/read/write timeouts
/// (deadline expiry surfaces as [`WireError::TimedOut`]), and a single
/// transparent reconnect when the server dropped the connection between
/// requests (idle reap, server restart). The retry resends only when it is
/// safe: a failed *write* always retries (nothing reached the server), a
/// failed first *read* retries only for idempotent ops — `RELOAD` is never
/// replayed, because a reload that was applied but whose reply was lost
/// would double-bump the generation.
pub struct BinaryClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    pub dim: usize,
    addr: String,
    timeouts: Option<Timeouts>,
    /// The stream may hold a half-read or late response (a deadline expired
    /// mid-exchange): the next request must reconnect first, or it would
    /// consume the previous request's bytes as its own reply.
    broken: bool,
}

#[derive(Debug, Clone, Copy)]
struct Timeouts {
    connect: std::time::Duration,
    io: std::time::Duration,
}

impl BinaryClient {
    /// Connect and perform the magic handshake (no deadlines: a request
    /// blocks until the server answers or drops the connection).
    pub fn connect(addr: &str) -> Result<BinaryClient, WireError> {
        Self::connect_opts(addr, None)
    }

    /// Connect with a connection deadline plus per-operation read/write
    /// deadlines. Expired deadlines surface as [`WireError::TimedOut`]; the
    /// next request reconnects.
    pub fn connect_with_timeouts(
        addr: &str,
        connect: std::time::Duration,
        io: std::time::Duration,
    ) -> Result<BinaryClient, WireError> {
        Self::connect_opts(addr, Some(Timeouts { connect, io }))
    }

    fn connect_opts(addr: &str, timeouts: Option<Timeouts>) -> Result<BinaryClient, WireError> {
        let fail = |message: String| WireError::Connect { addr: addr.to_string(), message };
        let stream = match timeouts {
            None => TcpStream::connect(addr).map_err(|e| fail(e.to_string()))?,
            Some(t) => {
                use std::net::ToSocketAddrs;
                let sock = addr
                    .to_socket_addrs()
                    .map_err(|e| fail(format!("resolve: {e}")))?
                    .next()
                    .ok_or_else(|| fail("resolved to no addresses".into()))?;
                let stream = TcpStream::connect_timeout(&sock, t.connect)
                    .map_err(|e| fail(e.to_string()))?;
                stream.set_read_timeout(Some(t.io)).map_err(|e| fail(e.to_string()))?;
                stream.set_write_timeout(Some(t.io)).map_err(|e| fail(e.to_string()))?;
                stream
            }
        };
        let mut writer = stream.try_clone().map_err(|e| fail(e.to_string()))?;
        let mut reader = BufReader::new(stream);
        writer.write_all(&MAGIC).map_err(|e| fail(e.to_string()))?;
        let mut ack = [0u8; 4];
        reader.read_exact(&mut ack).map_err(|e| fail(e.to_string()))?;
        if ack != MAGIC {
            return Err(fail("server did not ack binary magic".into()));
        }
        let dim = read_u32(&mut reader).map_err(|e| fail(e.to_string()))? as usize;
        Ok(BinaryClient {
            reader,
            writer,
            dim,
            addr: addr.to_string(),
            timeouts,
            broken: false,
        })
    }

    /// The address this client connects (and reconnects) to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Replace this client's transport with a fresh connection to the same
    /// address (re-handshakes, so `dim` tracks a restarted server). On
    /// failure the client stays marked broken, so the next request retries
    /// the reconnect instead of touching the stale stream.
    fn reconnect(&mut self) -> Result<(), WireError> {
        self.broken = true;
        let fresh = Self::connect_opts(&self.addr, self.timeouts)?;
        *self = fresh;
        Ok(())
    }

    /// Mark the transport unusable (a deadline expired or the stream died
    /// mid-exchange — response framing can no longer be trusted) and
    /// convert the error.
    fn fail(&mut self, e: io::Error) -> WireError {
        self.broken = true;
        classify(e)
    }

    /// Payload reads: any failure poisons the connection (a partial read
    /// leaves the stream mid-frame).
    fn recv_u32(&mut self) -> Result<u32, WireError> {
        match read_u32(&mut self.reader) {
            Ok(x) => Ok(x),
            Err(e) => Err(self.fail(e)),
        }
    }

    fn recv_f32s(&mut self, n: usize) -> Result<Vec<f32>, WireError> {
        match read_f32s(&mut self.reader, n) {
            Ok(xs) => Ok(xs),
            Err(e) => Err(self.fail(e)),
        }
    }

    fn recv_f64s(&mut self, n: usize) -> Result<Vec<f64>, WireError> {
        match read_f64s(&mut self.reader, n) {
            Ok(xs) => Ok(xs),
            Err(e) => Err(self.fail(e)),
        }
    }

    fn recv_bytes(&mut self, n: usize) -> Result<Vec<u8>, WireError> {
        let mut bytes = vec![0u8; n];
        match self.reader.read_exact(&mut bytes) {
            Ok(()) => Ok(bytes),
            Err(e) => Err(self.fail(e)),
        }
    }

    /// Send `frame` and read the response status word, reconnecting and
    /// resending once if the server dropped the connection. See the type
    /// docs for when the retry is safe (`idempotent`). A connection
    /// poisoned by an earlier timeout/partial read reconnects *before*
    /// sending — its stream may hold a late reply that would otherwise be
    /// consumed as this request's response.
    fn roundtrip(&mut self, frame: &[u8], idempotent: bool) -> Result<u32, WireError> {
        if self.broken {
            self.reconnect()?;
        }
        if let Err(e) = self.writer.write_all(frame) {
            if !connection_dropped(&e) {
                return Err(self.fail(e));
            }
            // Nothing reached the server: always safe to resend.
            self.reconnect()?;
            if let Err(e) = self.writer.write_all(frame) {
                return Err(self.fail(e));
            }
            return self.recv_u32();
        }
        match read_u32(&mut self.reader) {
            Ok(status) => Ok(status),
            Err(e) if idempotent && connection_dropped(&e) => {
                // The write landed in a dead socket's buffer; the server
                // never processed it (or its answer is lost either way).
                // Safe to replay idempotent ops exactly once.
                self.reconnect()?;
                if let Err(e) = self.writer.write_all(frame) {
                    return Err(self.fail(e));
                }
                self.recv_u32()
            }
            Err(e) => Err(self.fail(e)),
        }
    }

    fn request(&mut self, op: u32, ids: &[u32]) -> Result<u32, WireError> {
        let buf = encode_ids_frame(op, ids);
        self.roundtrip(&buf, true)
    }

    // ---- multiplexed fan-out hooks (`crate::net::fanout`) ----------------
    //
    // The router's epoll fan-out writes request frames on many pooled
    // clients, then multiplexes the responses on one poller instead of one
    // scoped thread per shard. That path bypasses `roundtrip`, so it needs
    // raw access to the transport plus a way to honor / set the `broken`
    // poison flag.

    /// Safe to use for a raw multiplexed exchange: not poisoned, and no
    /// stale buffered response bytes from an earlier exchange.
    pub(crate) fn fanout_ready(&self) -> bool {
        !self.broken && self.reader.buffer().is_empty()
    }

    /// The underlying stream, for readiness registration and direct reads.
    pub(crate) fn stream(&self) -> &TcpStream {
        &self.writer
    }

    /// Poison the transport after a failed raw exchange; the next pooled
    /// request reconnects instead of trusting the stream's framing.
    pub(crate) fn mark_broken(&mut self) {
        self.broken = true;
    }

    /// Fetch rows for `ids`; one `dim`-length vector per id, request order.
    pub fn lookup(&mut self, ids: &[u32]) -> Result<Vec<Vec<f32>>, WireError> {
        self.lookup_traced(ids, None)
    }

    /// [`lookup`](Self::lookup) with an optional propagated trace context
    /// (the router's fan-out path); `None` sends the exact untraced frame.
    pub fn lookup_traced(
        &mut self,
        ids: &[u32],
        ctx: Option<TraceContext>,
    ) -> Result<Vec<Vec<f32>>, WireError> {
        let buf = encode_ids_frame_traced(OP_LOOKUP, ids, ctx);
        let status = self.roundtrip(&buf, true)?;
        let count = self.recv_u32()? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let mut rows = Vec::with_capacity(count);
        for _ in 0..count {
            let dim = self.dim;
            rows.push(self.recv_f32s(dim)?);
        }
        Ok(rows)
    }

    /// Inner product of two rows, computed server-side.
    pub fn dot(&mut self, a: u32, b: u32) -> Result<f32, WireError> {
        let status = self.request(OP_DOT, &[a, b])?;
        let count = self.recv_u32()? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let xs = self.recv_f32s(count)?;
        Ok(xs[0])
    }

    /// Top-`k` neighbors of word `id`, computed server-side (best first).
    pub fn knn(&mut self, id: u32, k: u32) -> Result<Vec<(u32, f32)>, WireError> {
        let status = self.request(OP_KNN, &[id, k])?;
        let count = self.recv_u32()? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let nid = self.recv_u32()?;
            let score = self.recv_f32s(1)?[0];
            out.push((nid, score));
        }
        Ok(out)
    }

    /// Top-`k` neighbors of an external query vector, computed server-side
    /// (best first). Unlike [`knn`](Self::knn) no word is excluded — the
    /// server cannot know which id (if any) the vector came from. This is
    /// the scatter half of cluster KNN: the router sends the query row to
    /// every shard and merges the per-shard heaps.
    pub fn knn_vec(&mut self, query: &[f32], k: u32) -> Result<Vec<(u32, f32)>, WireError> {
        self.knn_vec_traced(query, k, None)
    }

    /// [`knn_vec`](Self::knn_vec) with an optional propagated trace
    /// context; `None` sends the exact untraced frame.
    pub fn knn_vec_traced(
        &mut self,
        query: &[f32],
        k: u32,
        ctx: Option<TraceContext>,
    ) -> Result<Vec<(u32, f32)>, WireError> {
        let buf = encode_knn_vec_frame_traced(query, k, ctx);
        let status = self.roundtrip(&buf, true)?;
        let count = self.recv_u32()? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let nid = self.recv_u32()?;
            let score = self.recv_f32s(1)?[0];
            out.push((nid, score));
        }
        Ok(out)
    }

    /// Status-only liveness probe (the health prober's request).
    pub fn ping(&mut self) -> Result<(), WireError> {
        let status = self.request(OP_PING, &[])?;
        let _count = self.recv_u32()?;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        Ok(())
    }

    pub fn stats(&mut self) -> Result<WireStats, WireError> {
        let status = self.request(OP_STATS, &[])?;
        let count = self.recv_u32()? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let xs = self.recv_f64s(count)?;
        if xs.len() < STATS_FIELDS {
            return Err(WireError::Io(io::Error::new(
                io::ErrorKind::InvalidData,
                "short STATS payload",
            )));
        }
        Ok(WireStats::from_fields(&xs))
    }

    /// Fetch the server's full metrics exposition (the binary twin of the
    /// text `METRICS` verb; the cluster router scrapes replicas with this).
    pub fn metrics(&mut self) -> Result<String, WireError> {
        let status = self.request(OP_METRICS, &[])?;
        let count = self.recv_u32()? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let bytes = self.recv_bytes(count)?;
        String::from_utf8(bytes).map_err(|_| {
            WireError::Io(io::Error::new(io::ErrorKind::InvalidData, "non-UTF-8 METRICS payload"))
        })
    }

    /// Fetch one stored trace (span + stage exposition lines, `# EOF`
    /// terminated) from the server by trace id — the binary twin of the
    /// text `TRACE <hex id>` verb. The cluster router assembles
    /// cross-node traces by calling this on every replica.
    pub fn trace(&mut self, trace_id: u128) -> Result<String, WireError> {
        let status = self.request(OP_TRACE, &trace_id_words(trace_id))?;
        let count = self.recv_u32()? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let bytes = self.recv_bytes(count)?;
        String::from_utf8(bytes).map_err(|_| {
            WireError::Io(io::Error::new(io::ErrorKind::InvalidData, "non-UTF-8 TRACE payload"))
        })
    }

    /// Fetch the server's stored-trace ring summary (the binary twin of
    /// the text `TRACE?slow` verb) — how a client finds trace ids worth
    /// fetching with [`trace`](Self::trace).
    pub fn trace_slow(&mut self) -> Result<String, WireError> {
        let status = self.request(OP_TRACE, &[])?;
        let count = self.recv_u32()? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let bytes = self.recv_bytes(count)?;
        String::from_utf8(bytes).map_err(|_| {
            WireError::Io(io::Error::new(io::ErrorKind::InvalidData, "non-UTF-8 TRACE payload"))
        })
    }

    /// Ask the server to hot-swap its model to the snapshot at `path`
    /// (server-side path). Returns the new model generation. Never replayed
    /// after a lost reply (see the type docs): a duplicate reload would
    /// bump the generation twice.
    pub fn reload(&mut self, path: &str) -> Result<u32, WireError> {
        let bytes = path.as_bytes();
        let mut buf = Vec::with_capacity(8 + bytes.len());
        put_u32(&mut buf, OP_RELOAD);
        put_u32(&mut buf, bytes.len() as u32);
        buf.extend_from_slice(bytes);
        let status = self.roundtrip(&buf, false)?;
        let count = self.recv_u32()? as usize;
        if status != STATUS_OK {
            return Err(WireError::Status(status));
        }
        let mut generation = 0u32;
        for _ in 0..count {
            generation = self.recv_u32()?;
        }
        Ok(generation)
    }

    /// Send QUIT; the server closes the connection without replying, so
    /// this writes the frame and returns (no status read).
    pub fn quit(mut self) -> Result<(), WireError> {
        let mut buf = Vec::with_capacity(8);
        put_u32(&mut buf, OP_QUIT);
        put_u32(&mut buf, 0);
        self.writer.write_all(&buf)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn primitive_roundtrips() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_f32s(&mut buf, &[1.5, -2.25]);
        put_f64s(&mut buf, &[3.5e12]);
        let mut c = Cursor::new(buf);
        assert_eq!(read_u32(&mut c).unwrap(), 0xDEAD_BEEF);
        assert_eq!(read_f32s(&mut c, 2).unwrap(), vec![1.5, -2.25]);
        assert_eq!(read_f64s(&mut c, 1).unwrap(), vec![3.5e12]);
    }

    #[test]
    fn magic_first_byte_is_not_ascii_text() {
        // The dispatcher relies on this: every text command starts with an
        // uppercase ASCII letter, so 0xB2 can never be confused for text.
        assert!(!MAGIC[0].is_ascii());
    }

    #[test]
    fn wire_stats_fields_roundtrip() {
        // from_fields ∘ fields must be the identity, and the table length
        // must match the struct — the compile-time half of the drift guard.
        let s = WireStats {
            p50_us: 12.0,
            p99_us: 99.5,
            served: 7,
            cache_hits: 3,
            cache_misses: 4,
            rejected: 1,
            knn_queries: 2,
            knn_candidates: 150,
            knn_mean_probes: 2.5,
            model_generation: 3,
            snapshot_bytes: 4096,
            accept_errors: 5,
            simd_level: 2,
            payload_bits: 4,
        };
        assert_eq!(WireStats::from_fields(&s.fields()), s);
        assert_eq!(STATS_FIELD_NAMES.len(), s.fields().len());
    }

    #[test]
    fn stats_field_formatting() {
        assert_eq!(format_stats_field("p50_us", 12.6), "13");
        assert_eq!(format_stats_field("knn_mean_probes", 2.0), "2.00");
        assert_eq!(format_stats_field("served", 42.0), "42");
        assert_eq!(format_stats_field("model_generation", 1.0), "1");
    }

    #[test]
    fn trace_id_words_roundtrip() {
        let id = 0x0011_2233_4455_6677_8899_aabb_ccdd_eeffu128;
        let words = trace_id_words(id);
        assert_eq!(words[0], 0xccdd_eeff, "low word first");
        assert_eq!(trace_id_from_words(&words), id);
        assert_eq!(trace_id_from_words(&[]), 0);
    }

    #[test]
    fn traced_frames_extend_untraced_frames_byte_exactly() {
        // A traced frame is the untraced frame with the flag bit set and
        // 24 context bytes spliced after the 8-byte header — nothing else
        // moves, so the payload grammar is unchanged.
        let ctx = TraceContext { trace_id: 0xAB, span_id: 0xCD };
        let plain = encode_ids_frame(OP_LOOKUP, &[5, 9]);
        let traced = encode_ids_frame_traced(OP_LOOKUP, &[5, 9], Some(ctx));
        assert_eq!(encode_ids_frame_traced(OP_LOOKUP, &[5, 9], None), plain);
        assert_eq!(traced.len(), plain.len() + 24);
        assert_eq!(traced[0..4], (OP_LOOKUP | OP_TRACE_CTX).to_le_bytes());
        assert_eq!(traced[4..8], plain[4..8], "count unchanged");
        assert_eq!(traced[8..24], 0xABu128.to_le_bytes());
        assert_eq!(traced[24..32], 0xCDu64.to_le_bytes());
        assert_eq!(traced[32..], plain[8..], "payload unchanged");

        let plain_kv = encode_knn_vec_frame(&[0.5, 1.5], 3);
        let traced_kv = encode_knn_vec_frame_traced(&[0.5, 1.5], 3, Some(ctx));
        assert_eq!(encode_knn_vec_frame_traced(&[0.5, 1.5], 3, None), plain_kv);
        assert_eq!(traced_kv.len(), plain_kv.len() + 24);
        assert_eq!(traced_kv[32..], plain_kv[8..], "k + query unchanged");

        // Both decode paths agree with the blocking reader.
        let got = read_frame(&mut Cursor::new(traced)).unwrap().unwrap();
        match got {
            BinRequest::Traced { ctx: c, parse_us, inner } => {
                assert_eq!(c, ctx);
                assert_eq!(parse_us, 0);
                assert_eq!(*inner, BinRequest::Ids { op: OP_LOOKUP, ids: vec![5, 9] });
                assert!(!BinRequest::Traced { ctx: c, parse_us, inner }.is_terminal());
            }
            other => panic!("expected Traced, got {other:?}"),
        }
        // A traced QUIT is still terminal through the wrapper.
        let q = encode_ids_frame_traced(OP_QUIT, &[], Some(ctx));
        assert!(read_frame(&mut Cursor::new(q)).unwrap().unwrap().is_terminal());
        // A hostile count fails before the extension is read: 8 bytes of
        // header with the flag set and an absurd count is Fatal even
        // though no 24 context bytes follow.
        let mut hostile = Vec::new();
        put_u32(&mut hostile, OP_LOOKUP | OP_TRACE_CTX);
        put_u32(&mut hostile, u32::MAX & !OP_TRACE_CTX);
        assert_eq!(read_frame(&mut Cursor::new(hostile)).unwrap().unwrap(), BinRequest::Fatal);
    }

    #[test]
    fn status_names_cover_codes() {
        for s in [
            STATUS_OK,
            STATUS_RANGE,
            STATUS_BAD_FRAME,
            STATUS_OVERLOADED,
            STATUS_TIMEOUT,
            STATUS_RELOAD_FAILED,
        ] {
            assert_ne!(status_name(s), "unknown status");
        }
        assert_eq!(status_name(99), "unknown status");
    }
}
