//! Sharded hot-row cache over any [`EmbeddingStore`].
//!
//! The paper's serving argument (word2ketXS fits in cache, rows are
//! *reconstructed* on demand) makes reconstruction compute the hot path at
//! production traffic. Token-id request streams are Zipf-skewed, so a small
//! cache of reconstructed rows absorbs most of that compute. Design:
//!
//! * **Sharding**: `shards` independent locks keyed by `id % shards`, so
//!   concurrent workers don't serialize on one mutex. Reconstruction on miss
//!   happens *outside* the shard lock; the lock only covers map/list updates.
//! * **LRU + frequency-based admission** (TinyLFU-style): eviction order is
//!   LRU, but a candidate row only displaces the LRU victim when its
//!   estimated access frequency (4-bit count-min sketch, periodically halved)
//!   is at least the victim's. One-hit-wonder tail ids therefore cannot flush
//!   the Zipf head out of the cache.
//! * **Transparency**: `ShardedCache` itself implements [`EmbeddingStore`]
//!   and returns bit-identical rows (cached rows are byte copies of what the
//!   wrapped store reconstructed), so the server, benches and tests compose
//!   it like any other store.

use crate::embedding::EmbeddingStore;
use crate::obs::{Obs, Stage};
use crate::repr::Repr;
use crate::util::ceil_div;
use crate::util::rng::splitmix64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

const NIL: usize = usize::MAX;

/// 4-bit count-min sketch with periodic halving ("aging"), sized to the
/// shard capacity. Estimates access frequency without storing per-id state.
#[derive(Debug)]
struct FreqSketch {
    counters: Vec<u8>,
    mask: usize,
    ops: u32,
    halve_at: u32,
}

impl FreqSketch {
    fn new(cap: usize) -> FreqSketch {
        let size = (cap.max(8) * 8).next_power_of_two();
        FreqSketch {
            counters: vec![0; size],
            mask: size - 1,
            ops: 0,
            halve_at: (cap.max(8) * 8) as u32,
        }
    }

    #[inline]
    fn slots(&self, id: usize) -> (usize, usize) {
        let mut s = id as u64;
        let h1 = splitmix64(&mut s);
        let h2 = splitmix64(&mut s);
        (h1 as usize & self.mask, h2 as usize & self.mask)
    }

    fn touch(&mut self, id: usize) {
        let (a, b) = self.slots(id);
        if self.counters[a] < 15 {
            self.counters[a] += 1;
        }
        if self.counters[b] < 15 {
            self.counters[b] += 1;
        }
        self.ops += 1;
        if self.ops >= self.halve_at {
            self.ops = 0;
            for c in self.counters.iter_mut() {
                *c >>= 1;
            }
        }
    }

    fn estimate(&self, id: usize) -> u8 {
        let (a, b) = self.slots(id);
        self.counters[a].min(self.counters[b])
    }
}

/// One cached row in the intrusive LRU list.
#[derive(Debug)]
struct Slot {
    id: usize,
    row: Vec<f32>,
    prev: usize,
    next: usize,
}

/// One shard: bounded LRU map with admission control.
#[derive(Debug)]
struct Shard {
    cap: usize,
    map: HashMap<usize, usize>,
    slots: Vec<Slot>,
    head: usize,
    tail: usize,
    sketch: FreqSketch,
}

impl Shard {
    fn new(cap: usize) -> Shard {
        Shard {
            cap,
            map: HashMap::with_capacity(cap),
            slots: Vec::with_capacity(cap),
            head: NIL,
            tail: NIL,
            sketch: FreqSketch::new(cap),
        }
    }

    fn detach(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev != NIL {
            self.slots[prev].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }

    /// Hit path: copy the row straight into `out` (no allocation) and
    /// refresh recency. Records the access in the frequency sketch either
    /// way, so admission sees the full stream.
    fn get_into(&mut self, id: usize, out: &mut [f32]) -> bool {
        self.sketch.touch(id);
        let Some(&i) = self.map.get(&id) else { return false };
        self.detach(i);
        self.push_front(i);
        out.copy_from_slice(&self.slots[i].row);
        true
    }

    /// Miss path: admit `row` if there is room, or if `id` is at least as
    /// frequent as the LRU victim (frequency-based admission). The row is
    /// copied *into* the victim's existing buffer when one is evicted —
    /// after the shard fills, admission never allocates. Returns `true`
    /// when a resident row was displaced (an eviction, counted cache-wide).
    fn insert_if_absent(&mut self, id: usize, row: &[f32]) -> bool {
        if self.cap == 0 || self.map.contains_key(&id) {
            return false;
        }
        if self.slots.len() < self.cap {
            let i = self.slots.len();
            self.slots.push(Slot { id, row: row.to_vec(), prev: NIL, next: NIL });
            self.push_front(i);
            self.map.insert(id, i);
            return false;
        }
        let victim = self.tail;
        let victim_id = self.slots[victim].id;
        if self.sketch.estimate(id) < self.sketch.estimate(victim_id) {
            return false; // victim is hotter: reject the candidate
        }
        self.map.remove(&victim_id);
        self.detach(victim);
        self.slots[victim].id = id;
        self.slots[victim].row.copy_from_slice(row);
        self.push_front(victim);
        self.map.insert(id, victim);
        true
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// Cache-wide counters, readable without locking the shards.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Sharded hot-row cache wrapping any [`EmbeddingStore`]; itself a store.
pub struct ShardedCache {
    inner: Box<dyn EmbeddingStore>,
    shards: Vec<Mutex<Shard>>,
    /// false when `cache_rows == 0`: lookups bypass the shards entirely so
    /// the "uncached" baseline pays no lock or sketch cost.
    enabled: bool,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    /// Metrics plane this cache reports cache/kernel stage timings into;
    /// defaults to a disabled registry (one branch per lookup).
    obs: Arc<Obs>,
}

impl ShardedCache {
    /// `cache_rows` is the *total* row budget, split evenly across `shards`.
    /// `cache_rows == 0` disables caching (every lookup hits the inner store).
    pub fn new(inner: Box<dyn EmbeddingStore>, shards: usize, cache_rows: usize) -> ShardedCache {
        let shards = shards.max(1);
        let per_shard = if cache_rows == 0 { 0 } else { ceil_div(cache_rows, shards) };
        ShardedCache {
            inner,
            shards: (0..shards).map(|_| Mutex::new(Shard::new(per_shard))).collect(),
            enabled: cache_rows > 0,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            obs: Arc::new(Obs::disabled()),
        }
    }

    /// Attach the server's metrics plane: cache-stage and kernel-stage
    /// durations record into `obs`'s per-stage histograms.
    pub fn set_obs(&mut self, obs: Arc<Obs>) {
        self.obs = obs;
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Rows displaced by admission since construction (never reset).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Resident row count per shard, in shard order (locks each shard
    /// briefly; exposition-path only).
    pub fn shard_entries(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.lock().unwrap().len()).collect()
    }

    /// The wrapped store.
    pub fn inner(&self) -> &dyn EmbeddingStore {
        self.inner.as_ref()
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.shards.iter().map(|s| s.lock().unwrap().len()).sum(),
        }
    }

    /// Zero the hit/miss counters; cached rows stay resident. The serving
    /// layer calls this after k-NN index construction, which intentionally
    /// reads rows through the cache (warming it) but must not show up as
    /// request traffic in `STATS`.
    pub fn reset_stats(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
    }

    /// Fill `out` with row `id` through the cache: one copy on a hit, one
    /// in-place reconstruction on a miss (the row is rebuilt directly into
    /// `out` via `lookup_into`, then copied into a cache slot only if
    /// admission accepts it — evictions reuse the victim's buffer, so the
    /// steady-state miss path allocates nothing). Reconstruction happens
    /// *outside* the shard lock — concurrent misses on the same id may
    /// duplicate work but never block each other, and the result is
    /// identical either way.
    fn fetch_into(&self, id: usize, out: &mut [f32]) {
        // Stage attribution: hits bill their whole duration to `cache`;
        // misses bill the inner reconstruction to `kernel` and the
        // remaining lock/sketch/admission time to `cache`. With obs
        // disabled the only cost is this one branch. Trace spans attribute
        // at *batch* granularity instead (the pool worker bills one `cache`
        // stage for the whole drained batch, hits and kernels combined) —
        // per-row stage splits here would mean per-row span bookkeeping on
        // the hot path, which the histograms above already cover.
        let t0 = if self.obs.enabled() { Some(Instant::now()) } else { None };
        if !self.enabled {
            // cache_rows == 0: a true pass-through baseline — no shard
            // locks, no sketch updates, just the inner reconstruction.
            self.inner.lookup_into(id, out);
            self.misses.fetch_add(1, Ordering::Relaxed);
            if let Some(t0) = t0 {
                self.obs.record_stage(Stage::Kernel, t0.elapsed());
            }
            return;
        }
        let s = id % self.shards.len();
        if self.shards[s].lock().unwrap().get_into(id, out) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            if let Some(t0) = t0 {
                self.obs.record_stage(Stage::Cache, t0.elapsed());
            }
            return;
        }
        let t1 = t0.map(|_| Instant::now());
        self.inner.lookup_into(id, out);
        let kernel = t1.map(|t| t.elapsed());
        self.misses.fetch_add(1, Ordering::Relaxed);
        if self.shards[s].lock().unwrap().insert_if_absent(id, out) {
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        if let (Some(t0), Some(k)) = (t0, kernel) {
            self.obs.record_stage(Stage::Kernel, k);
            self.obs.record_stage(Stage::Cache, t0.elapsed().saturating_sub(k));
        }
    }
}

impl EmbeddingStore for ShardedCache {
    fn vocab_size(&self) -> usize {
        self.inner.vocab_size()
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn num_params(&self) -> usize {
        // Cached rows are derived data, not trainable parameters.
        self.inner.num_params()
    }

    fn lookup(&self, id: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.inner.dim()];
        self.fetch_into(id, &mut out);
        out
    }

    fn lookup_into(&self, id: usize, out: &mut [f32]) {
        self.fetch_into(id, out);
    }

    fn lookup_batch_into(&self, ids: &[usize], out: &mut Vec<f32>) {
        // Dedup-and-scatter like the trait default, but each distinct id is
        // copied exactly once into the flat arena (no per-row Vec on hits).
        crate::embedding::dedup_scatter_into(ids, self.inner.dim(), out, |id, row| {
            self.fetch_into(id, row)
        });
    }

    fn repr(&self) -> Repr<'_> {
        // Lets [`Repr::resolve`] peel the cache and reach the factored
        // store underneath (cached rows are dense; factored scoring wants
        // the factors).
        Repr::Cached(self)
    }

    fn describe(&self) -> String {
        format!(
            "sharded-cache[{} shards, {} rows] over {}",
            self.shards.len(),
            self.shards.iter().map(|s| s.lock().unwrap().cap).sum::<usize>(),
            self.inner.describe()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{materialize, Word2KetXS};
    use crate::util::{Rng, ZipfSampler};

    fn xs_store(seed: u64) -> Box<dyn EmbeddingStore> {
        let mut rng = Rng::new(seed);
        Box::new(Word2KetXS::random(500, 16, 2, 2, &mut rng))
    }

    #[test]
    fn cached_rows_bit_identical_to_uncached() {
        // Same seed ⇒ identical factor tensors ⇒ the uncached twin is an
        // oracle for the cached store. Cache sized to hold the whole vocab so
        // the warm pass is all hits.
        let uncached = xs_store(7);
        let cached = ShardedCache::new(xs_store(7), 4, 512);
        let want = materialize(uncached.as_ref());
        // Two passes: first fills the cache (all misses), second must serve
        // hits that are byte-for-byte what the store reconstructed.
        let got_cold = materialize(&cached);
        let got_warm = materialize(&cached);
        assert_eq!(want.data(), got_cold.data());
        assert_eq!(want.data(), got_warm.data());
        let stats = cached.stats();
        assert_eq!(stats.misses, 500, "cold pass should reconstruct every row once");
        assert_eq!(stats.hits, 500, "warm pass should be all cache hits");
    }

    #[test]
    fn shard_routing_and_capacity_bound() {
        let cached = ShardedCache::new(xs_store(1), 4, 16);
        for id in 0..500 {
            cached.lookup(id);
        }
        let stats = cached.stats();
        assert!(stats.entries <= 16, "entries {} exceed budget", stats.entries);
        assert_eq!(stats.misses, 500);
    }

    #[test]
    fn zipf_head_sticks_under_churn() {
        // A head-heavy stream through a small cache must end with a high hit
        // rate: admission keeps hot ids resident despite tail churn.
        let cached = ShardedCache::new(xs_store(2), 2, 32);
        let zipf = ZipfSampler::new(500, 1.1);
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            cached.lookup(zipf.sample(&mut rng));
        }
        let warmup = cached.stats();
        for _ in 0..2000 {
            cached.lookup(zipf.sample(&mut rng));
        }
        let after = cached.stats();
        let late_hits = after.hits - warmup.hits;
        let late_total = (after.hits + after.misses) - (warmup.hits + warmup.misses);
        let rate = late_hits as f64 / late_total as f64;
        assert!(rate > 0.5, "steady-state hit rate {rate:.2} too low");
    }

    #[test]
    fn admission_rejects_one_hit_wonders() {
        let cached = ShardedCache::new(xs_store(4), 1, 4);
        // Make ids 0..4 hot.
        for _ in 0..10 {
            for id in 0..4 {
                cached.lookup(id);
            }
        }
        // A long scan of one-hit-wonder tail ids, interleaved with ongoing
        // hot traffic (the realistic Zipf shape): admission must keep the hot
        // ids resident, so almost every hot lookup during the churn hits.
        let mut hot_hits = 0u64;
        let mut hot_lookups = 0u64;
        for cold in 100..300usize {
            cached.lookup(cold);
            let before = cached.stats().hits;
            cached.lookup(cold % 4);
            hot_hits += cached.stats().hits - before;
            hot_lookups += 1;
        }
        let rate = hot_hits as f64 / hot_lookups as f64;
        assert!(rate > 0.9, "hot hit rate {rate:.2} during cold churn");
        // And all four survive the scan outright.
        let before = cached.stats().hits;
        for id in 0..4 {
            cached.lookup(id);
        }
        assert_eq!(cached.stats().hits - before, 4, "hot ids were evicted by cold scan");
    }

    #[test]
    fn evictions_and_stage_timings_are_recorded() {
        let mut cached = ShardedCache::new(xs_store(8), 1, 2);
        let obs = Arc::new(Obs::default());
        cached.set_obs(obs.clone());
        // Fill both slots (no evictions yet — growth, not displacement).
        for _ in 0..4 {
            cached.lookup(0);
            cached.lookup(1);
        }
        assert_eq!(cached.evictions(), 0);
        assert_eq!(cached.shard_entries(), vec![2]);
        // Hammer a third id until its sketch estimate displaces a victim.
        for _ in 0..20 {
            cached.lookup(2);
        }
        assert!(cached.evictions() >= 1, "hot candidate never displaced a victim");
        assert_eq!(cached.shard_entries(), vec![2], "capacity bound broken by eviction");
        // Hits billed to the cache stage, misses split cache/kernel — with
        // traffic on both paths, both histograms must have samples.
        assert!(obs.stage(Stage::Cache).count() > 0);
        assert!(obs.stage(Stage::Kernel).count() > 0);
        // Disabled registry records nothing (the default wiring).
        let quiet = ShardedCache::new(xs_store(8), 1, 2);
        quiet.lookup(0);
        assert_eq!(quiet.obs.stage(Stage::Kernel).count(), 0);
    }

    #[test]
    fn zero_rows_disables_cache() {
        let cached = ShardedCache::new(xs_store(5), 4, 0);
        for _ in 0..3 {
            cached.lookup(42);
        }
        let stats = cached.stats();
        assert_eq!(stats.hits, 0);
        assert_eq!(stats.misses, 3);
        assert_eq!(stats.entries, 0);
    }

    #[test]
    fn store_metadata_delegates() {
        let inner = xs_store(6);
        let params = inner.num_params();
        let cached = ShardedCache::new(inner, 3, 8);
        assert_eq!(cached.vocab_size(), 500);
        assert_eq!(cached.dim(), 16);
        assert_eq!(cached.num_params(), params);
        assert!(cached.describe().contains("sharded-cache"));
    }
}
