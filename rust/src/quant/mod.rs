//! Sub-byte factored payloads scored directly in the quantized domain.
//!
//! word2ket's space story (§2.3) compounds with Word2Bits-style sub-byte
//! quantization (Lam, 2018): each CP leaf `v_jk ∈ R^q` is stored as
//! bit-packed codes plus one per-leaf scale, and the factored inner product
//! is computed *without dequantizing* — `⟨v_jk, w_jk'⟩ ≈ s_v s_w Σ c_v c_w`
//! where the code sum runs through the integer SIMD kernels in
//! [`crate::simd`] ([`crate::simd::idot_b1`] and friends). Because those
//! sums are exact `i32` arithmetic, quantized-domain scores are
//! bit-identical across scalar/SSE2/AVX2 by construction.
//!
//! # Payload layout
//!
//! Leaf `l = (word·rank + k)·order + j` owns
//!
//! * `codes[l·W .. (l+1)·W]` — `q` codes packed LSB-first into `W =
//!   ⌈q·bits/32⌉` u32 words (bits are powers of two, so codes never
//!   straddle a word; padding bits are zero), and
//! * `scales[l]` — one non-negative finite f32.
//!
//! Code semantics per width (encode: deterministic round-half-away-from-
//! zero; decode: `value = scale · c`):
//!
//! | bits | scale            | code `u`                      | centered `c` |
//! |------|------------------|-------------------------------|--------------|
//! | 1    | `Σ\|x\|/q`       | `x ≥ 0`                       | `2u-1` ∈ {±1} |
//! | 2    | `max\|x\|/3`     | `clamp(round((x/s+3)/2),0,3)` | `2u-3` ∈ {±1,±3} |
//! | 4    | `max\|x\|/7`     | `clamp(round(x/s),-7,7)+7`    | `u-7` ∈ -7..=7 |
//! | 8    | `max\|x\|/127`   | `clamp(round(x/s),-127,127)+127` | `u-127` ∈ -127..=127 |
//!
//! # The refinement payload and the coarse contract
//!
//! Quantized-domain dots are *coarse*: int4 alone ranks top-10 neighbours
//! at ~0.85 recall on the standard config, below the ≥ 0.95 bar. So a
//! [`QuantizedKet`] additionally carries its leaves rounded through f16
//! (half the f32 factor bytes), and serving uses the two payloads for what
//! each is good at: candidate scans run in the quantized domain (the
//! bandwidth win), rows and the IVF re-rank come from the f16-refined
//! leaves (the accuracy win — recall@10 returns to 1.0 for int8/int4).
//!
//! This makes `QuantizedKet` the one *documented deviation* from the
//! [`FactoredRepr`] invariant that `inner` reproduces the dense dot of
//! `write_row` outputs: here `inner`/`block_inner` are quantized-domain
//! approximations of it, while `factors`/`write_row` expose the exact
//! refined leaves. Consumers that need exact scores re-rank through rows;
//! the IVF index does so automatically (see `index/ivf.rs`).

use crate::embedding::{EmbeddingStore, Word2Ket};
use crate::error::{Error, Result};
use crate::kron::tree_term;
use crate::repr::{kernels, FactorGeometry, FactoredRepr, Repr, MAX_ORDER};
use crate::simd;
use crate::snapshot::format::{f16_bits_to_f32, f32_to_f16_bits};

/// Packed code widths the quantized-domain kernels support.
pub const SUPPORTED_BITS: [usize; 4] = [1, 2, 4, 8];

/// Upper bound on the leaf dimension: keeps the worst-case int8 code sum
/// (`127² · q`) inside the kernels' exact `i32` accumulators.
pub const MAX_LEAF_DIM: usize = 65536;

/// Packed u32 words per `q`-long leaf at the given code width.
pub fn words_per_leaf(q: usize, bits: usize) -> usize {
    (q * bits).div_ceil(32)
}

/// Centered code value for width `bits` (the `c` column of the module-doc
/// table).
#[inline]
fn code_val(u: u32, bits: usize) -> i32 {
    match bits {
        1 => 2 * u as i32 - 1,
        2 => 2 * u as i32 - 3,
        4 => u as i32 - 7,
        _ => u as i32 - 127,
    }
}

#[inline]
fn encode_value(x: f32, scale: f32, bits: usize) -> u32 {
    if bits == 1 {
        // Sign bit; an all-zero leaf still gets well-defined codes (its
        // scale is 0, so decode is 0 regardless).
        return (x >= 0.0) as u32;
    }
    if scale <= 0.0 {
        return 0;
    }
    match bits {
        2 => ((x / scale + 3.0) * 0.5).round().clamp(0.0, 3.0) as u32,
        4 => ((x / scale).round().clamp(-7.0, 7.0) + 7.0) as u32,
        _ => ((x / scale).round().clamp(-127.0, 127.0) + 127.0) as u32,
    }
}

/// Quantize one leaf into `codes` (length [`words_per_leaf`], fully
/// overwritten including zero padding bits) and return its scale.
/// Deterministic: `f32::round` half-away-from-zero, no data-dependent
/// branching.
pub fn encode_leaf(x: &[f32], bits: usize, codes: &mut [u32]) -> f32 {
    debug_assert_eq!(codes.len(), words_per_leaf(x.len(), bits));
    codes.fill(0);
    let scale = match bits {
        1 => x.iter().map(|v| v.abs()).sum::<f32>() / (x.len().max(1)) as f32,
        2 => x.iter().fold(0.0f32, |m, v| m.max(v.abs())) / 3.0,
        4 => x.iter().fold(0.0f32, |m, v| m.max(v.abs())) / 7.0,
        _ => x.iter().fold(0.0f32, |m, v| m.max(v.abs())) / 127.0,
    };
    let per = 32 / bits;
    for (i, &v) in x.iter().enumerate() {
        codes[i / per] |= encode_value(v, scale, bits) << ((i % per) * bits);
    }
    scale
}

/// Dequantize one packed leaf: `out[i] = scale · c_i`.
pub fn decode_leaf(codes: &[u32], bits: usize, scale: f32, out: &mut [f32]) {
    let per = 32 / bits;
    let mask = (1u32 << bits) - 1;
    for (i, o) in out.iter_mut().enumerate() {
        let u = (codes[i / per] >> ((i % per) * bits)) & mask;
        *o = scale * code_val(u, bits) as f32;
    }
}

/// In-domain dot of two packed leaves: `(sa·sb) · Σ c_a·c_b`, the code sum
/// running through the exact-integer SIMD kernels.
#[inline]
pub fn leaf_dot(a: &[u32], sa: f32, b: &[u32], sb: f32, q: usize, bits: usize) -> f32 {
    let idot = match bits {
        1 => simd::idot_b1(a, b, q),
        2 => simd::idot_b2(a, b, q),
        4 => simd::idot_i4(a, b, q),
        _ => simd::idot_i8(a, b, q),
    };
    (sa * sb) * idot as f32
}

/// Borrowed view over a quantized-ket payload triplet. [`QuantizedKet`]
/// and the snapshot-mapped store both score and reconstruct through this
/// one struct, so in-memory and mapped serving are bit-identical by
/// construction (the same guarantee the float stores get from sharing
/// `repr::kernels`).
#[derive(Clone, Copy)]
pub struct QketView<'a> {
    /// Tensor order `n`.
    pub order: usize,
    /// CP rank `r`.
    pub rank: usize,
    /// Per-leaf length `q`.
    pub leaf_dim: usize,
    /// Packed code width (1, 2, 4 or 8).
    pub bits: usize,
    /// Packed codes, `words_per_leaf(q, bits)` u32 words per leaf.
    pub codes: &'a [u32],
    /// One scale per leaf.
    pub scales: &'a [f32],
    /// f16-refined leaves (decoded to f32), `q` values per leaf, same leaf
    /// order as `codes`/`scales`.
    pub leaves: &'a [f32],
}

impl<'a> QketView<'a> {
    #[inline]
    fn wpl(&self) -> usize {
        words_per_leaf(self.leaf_dim, self.bits)
    }

    #[inline]
    fn leaf_index(&self, w: usize, k: usize, j: usize) -> usize {
        (w * self.rank + k) * self.order + j
    }

    /// Packed codes of word `w`'s `(k, j)` leaf.
    #[inline]
    pub fn leaf_codes(&self, w: usize, k: usize, j: usize) -> &'a [u32] {
        let (l, wpl) = (self.leaf_index(w, k, j), self.wpl());
        &self.codes[l * wpl..(l + 1) * wpl]
    }

    /// Scale of word `w`'s `(k, j)` leaf.
    #[inline]
    pub fn leaf_scale(&self, w: usize, k: usize, j: usize) -> f32 {
        self.scales[self.leaf_index(w, k, j)]
    }

    /// f16-refined `(k, j)` leaf of word `w`.
    #[inline]
    pub fn refined_leaf(&self, w: usize, k: usize, j: usize) -> &'a [f32] {
        let (l, q) = (self.leaf_index(w, k, j), self.leaf_dim);
        &self.leaves[l * q..(l + 1) * q]
    }

    /// Coarse quantized-domain inner product `⟨row a, row b⟩`: the §2.3
    /// rank-pair sum with every leaf dot taken in the quantized domain.
    /// Deterministic and SIMD-level-independent (exact integer code sums;
    /// same early-out-on-zero and summation order as
    /// `kernels::product_of_dots`/`rank_pair_sum`).
    pub fn inner(&self, a: usize, b: usize) -> f32 {
        kernels::rank_pair_sum(self.rank, self.rank, |k, k2| {
            let mut prod = 1.0f32;
            for j in 0..self.order {
                prod *= leaf_dot(
                    self.leaf_codes(a, k, j),
                    self.leaf_scale(a, k, j),
                    self.leaf_codes(b, k2, j),
                    self.leaf_scale(b, k2, j),
                    self.leaf_dim,
                    self.bits,
                );
                if prod == 0.0 {
                    break;
                }
            }
            prod
        })
    }

    /// Coarse block scoring: `out[i] = inner(a, bs[i])`, bitwise equal to
    /// the per-pair form.
    pub fn block_inner(&self, a: usize, bs: &[usize], out: &mut [f32]) {
        debug_assert_eq!(bs.len(), out.len());
        for (o, &b) in out.iter_mut().zip(bs) {
            *o = self.inner(a, b);
        }
    }

    /// Materialize row `id` from the *refined* leaves (truncating to
    /// `out.len()` when `q^order > dim`) — the exact payload, mirroring
    /// `Word2Ket::lookup_into`.
    pub fn write_row(&self, id: usize, out: &mut [f32]) {
        out.fill(0.0);
        let mut refs: [&[f32]; MAX_ORDER] = [&[]; MAX_ORDER];
        for k in 0..self.rank {
            for j in 0..self.order {
                refs[j] = self.refined_leaf(id, k, j);
            }
            let term = tree_term(&refs[..self.order], false);
            kernels::add_assign(out, &term);
        }
    }

    /// Bytes a coarse scan touches per candidate word: packed codes plus
    /// scales for all `r·n` leaves (the bandwidth denominator the benches
    /// report).
    pub fn coarse_bytes_per_word(&self) -> usize {
        self.rank * self.order * (self.wpl() * 4 + 4)
    }
}

/// A word2ket store with sub-byte quantized leaf payloads plus f16-refined
/// leaves (see the module docs for the split contract). Built from a
/// trained [`Word2Ket`] via [`QuantizedKet::from_word2ket`] or loaded from
/// a snapshot.
pub struct QuantizedKet {
    vocab: usize,
    dim: usize,
    order: usize,
    rank: usize,
    leaf_dim: usize,
    bits: usize,
    codes: Vec<u32>,
    scales: Vec<f32>,
    leaves: Vec<f32>,
}

impl QuantizedKet {
    /// Quantize a raw-CP word2ket store: every leaf is packed at `bits`
    /// (∈ {1, 2, 4, 8}) with one scale, and the refinement copy of the
    /// leaf is rounded through f16 *at construction* — so in-memory
    /// serving is bit-identical to serving the store back off a snapshot
    /// (whose leaf section is stored as f16).
    ///
    /// LayerNorm-ed stores are rejected: the quantized-domain identity
    /// needs raw CP leaves. Truncated dims (`q^order > dim`) are accepted
    /// for row serving but excluded from factored scoring by the
    /// [`Repr::factored`] gate, same as [`Word2Ket`].
    pub fn from_word2ket(w: &Word2Ket, bits: usize) -> Result<QuantizedKet> {
        if w.layernorm() {
            return Err(Error::Shape(
                "quantized-ket requires raw CP leaves (disable LayerNorm before quantizing)"
                    .into(),
            ));
        }
        let (vocab, dim) = (w.vocab_size(), w.dim());
        let (order, rank, q) = (w.order(), w.rank(), w.leaf_dim());
        if !SUPPORTED_BITS.contains(&bits) {
            return Err(Error::Shape(format!(
                "quantized-ket bits must be one of {SUPPORTED_BITS:?}, got {bits}"
            )));
        }
        let wpl = words_per_leaf(q, bits);
        let n_leaves = vocab * rank * order;
        let mut codes = vec![0u32; n_leaves * wpl];
        let mut scales = vec![0.0f32; n_leaves];
        let mut leaves = vec![0.0f32; n_leaves * q];
        for id in 0..vocab {
            for k in 0..rank {
                for j in 0..order {
                    let leaf = w.word(id).leaf(k, j);
                    let l = (id * rank + k) * order + j;
                    scales[l] = encode_leaf(leaf, bits, &mut codes[l * wpl..(l + 1) * wpl]);
                    for (dst, &v) in leaves[l * q..(l + 1) * q].iter_mut().zip(leaf) {
                        *dst = f16_bits_to_f32(f32_to_f16_bits(v));
                    }
                }
            }
        }
        Self::from_parts(vocab, dim, order, rank, q, bits, codes, scales, leaves)
    }

    /// Assemble a store from raw payloads (the snapshot loader's entry
    /// point), validating geometry and values as if the inputs were
    /// hostile: unsupported widths, order/leaf-dim bounds, truncation
    /// beyond the w2k envelope, length mismatches, non-finite or negative
    /// scales, and nonzero padding bits are all typed errors.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        vocab: usize,
        dim: usize,
        order: usize,
        rank: usize,
        leaf_dim: usize,
        bits: usize,
        codes: Vec<u32>,
        scales: Vec<f32>,
        leaves: Vec<f32>,
    ) -> Result<QuantizedKet> {
        if !SUPPORTED_BITS.contains(&bits) {
            return Err(Error::Shape(format!(
                "quantized-ket bits must be one of {SUPPORTED_BITS:?}, got {bits}"
            )));
        }
        if !(2..=MAX_ORDER).contains(&order) {
            return Err(Error::Shape(format!(
                "quantized-ket order must be in 2..={MAX_ORDER}, got {order}"
            )));
        }
        if rank == 0 || dim == 0 {
            return Err(Error::Shape("quantized-ket rank and dim must be >= 1".into()));
        }
        if leaf_dim == 0 || leaf_dim > MAX_LEAF_DIM {
            return Err(Error::Shape(format!(
                "quantized-ket leaf_dim must be in 1..={MAX_LEAF_DIM}, got {leaf_dim}"
            )));
        }
        // Same envelope the snapshot store enforces for w2k leaves: the
        // full tensor covers the row, and truncation stays below 2^order
        // (each leaf at most doubling past the covered prefix).
        let full = leaf_dim.checked_pow(order as u32);
        let envelope = dim.saturating_mul(1usize << order);
        if !matches!(full, Some(f) if f >= dim && f <= envelope) {
            return Err(Error::Shape(format!(
                "quantized-ket geometry q={leaf_dim} order={order} incompatible with dim={dim}"
            )));
        }
        let wpl = words_per_leaf(leaf_dim, bits);
        let n_leaves = vocab
            .checked_mul(rank)
            .and_then(|v| v.checked_mul(order))
            .ok_or_else(|| Error::Shape("quantized-ket leaf count overflows".into()))?;
        if codes.len() != n_leaves * wpl {
            return Err(Error::Shape(format!(
                "quantized-ket codes length {} != {} leaves × {wpl} words",
                codes.len(),
                n_leaves
            )));
        }
        if scales.len() != n_leaves {
            return Err(Error::Shape(format!(
                "quantized-ket scales length {} != {} leaves",
                scales.len(),
                n_leaves
            )));
        }
        if leaves.len() != n_leaves * leaf_dim {
            return Err(Error::Shape(format!(
                "quantized-ket refined-leaves length {} != {} leaves × q={leaf_dim}",
                leaves.len(),
                n_leaves
            )));
        }
        if let Some(bad) = scales.iter().find(|s| !s.is_finite() || **s < 0.0) {
            return Err(Error::Shape(format!(
                "quantized-ket scales must be finite and non-negative, found {bad}"
            )));
        }
        // Nonzero padding bits would corrupt the whole-word b1 popcount
        // (and claim codes past q) — reject them outright.
        let used = leaf_dim * bits - (wpl - 1) * 32;
        if used < 32 {
            let pad_mask = !0u32 << used;
            for l in 0..n_leaves {
                if codes[l * wpl + wpl - 1] & pad_mask != 0 {
                    return Err(Error::Shape(format!(
                        "quantized-ket leaf {l} has nonzero padding bits"
                    )));
                }
            }
        }
        Ok(QuantizedKet { vocab, dim, order, rank, leaf_dim, bits, codes, scales, leaves })
    }

    /// Tensor order `n`.
    pub fn order(&self) -> usize {
        self.order
    }

    /// CP rank `r`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Per-leaf length `q`.
    pub fn leaf_dim(&self) -> usize {
        self.leaf_dim
    }

    /// Packed code width (1, 2, 4 or 8).
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Whether `q^order == dim` exactly (factored scoring requires it).
    pub fn exact_dim(&self) -> bool {
        self.leaf_dim.checked_pow(self.order as u32) == Some(self.dim)
    }

    /// Packed code words, all leaves concatenated.
    pub fn codes(&self) -> &[u32] {
        &self.codes
    }

    /// Per-leaf scales.
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// f16-refined leaves (decoded to f32), all leaves concatenated.
    pub fn leaves(&self) -> &[f32] {
        &self.leaves
    }

    /// The borrowed payload view (shared with the snapshot store).
    pub fn view(&self) -> QketView<'_> {
        QketView {
            order: self.order,
            rank: self.rank,
            leaf_dim: self.leaf_dim,
            bits: self.bits,
            codes: &self.codes,
            scales: &self.scales,
            leaves: &self.leaves,
        }
    }
}

impl EmbeddingStore for QuantizedKet {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn num_params(&self) -> usize {
        // 4-byte units actually stored: one per u32 code word, one per f32
        // scale, and half per refined leaf value (persisted as f16).
        self.codes.len() + self.scales.len() + self.leaves.len().div_ceil(2)
    }

    fn lookup(&self, id: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.lookup_into(id, &mut out);
        out
    }

    fn lookup_into(&self, id: usize, out: &mut [f32]) {
        self.view().write_row(id, out);
    }

    fn describe(&self) -> String {
        format!(
            "quantized-ket(d={}, p={}, n={}, r={}, q={}, {}-bit codes + f16 leaves): {} params",
            self.vocab,
            self.dim,
            self.order,
            self.rank,
            self.leaf_dim,
            self.bits,
            self.num_params()
        )
    }

    fn repr(&self) -> Repr<'_> {
        Repr::QuantizedKet(self)
    }
}

impl FactoredRepr for QuantizedKet {
    fn geometry(&self) -> FactorGeometry {
        FactorGeometry { order: self.order, rank: self.rank, leaf_dim: self.leaf_dim }
    }

    fn factors<'s>(&'s self, id: usize, k: usize, out: &mut [&'s [f32]]) {
        let v = self.view();
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = v.refined_leaf(id, k, j);
        }
    }

    fn kind_name(&self) -> &'static str {
        "quantized_ket"
    }

    // Coarse contract (module docs): quantized-domain approximations of
    // the row dot, not the trait's default exact identity.
    fn inner(&self, a: usize, b: usize) -> f32 {
        self.view().inner(a, b)
    }

    fn block_inner(&self, a: usize, bs: &[usize], out: &mut [f32]) {
        self.view().block_inner(a, bs, out)
    }

    fn write_row(&self, id: usize, out: &mut [f32]) {
        self.view().write_row(id, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::{available_levels, with_level, SimdLevel};
    use crate::tensor::dot;
    use crate::util::Rng;

    #[test]
    fn encode_decode_error_bounds_per_width() {
        let mut rng = Rng::new(11);
        let q = 16;
        let x: Vec<f32> = (0..q).map(|_| rng.normal(0.0, 1.0)).collect();
        let max_abs = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for &(bits, steps) in &[(2usize, 3.0f32), (4, 7.0), (8, 127.0)] {
            let mut codes = vec![0u32; words_per_leaf(q, bits)];
            let scale = encode_leaf(&x, bits, &mut codes);
            let mut back = vec![0.0f32; q];
            decode_leaf(&codes, bits, scale, &mut back);
            // Grid step is max_abs/steps; round-to-nearest halves it.
            let bound = max_abs / steps * 0.5 + 1e-6;
            for (i, (&orig, &dec)) in x.iter().zip(&back).enumerate() {
                assert!(
                    (orig - dec).abs() <= bound,
                    "bits={bits} i={i}: |{orig} - {dec}| > {bound}"
                );
            }
        }
        // b1 preserves signs exactly.
        let mut codes = vec![0u32; words_per_leaf(q, 1)];
        let scale = encode_leaf(&x, 1, &mut codes);
        let mut back = vec![0.0f32; q];
        decode_leaf(&codes, 1, scale, &mut back);
        for (&orig, &dec) in x.iter().zip(&back) {
            assert_eq!(orig >= 0.0, dec >= 0.0);
            assert!((dec.abs() - scale).abs() < 1e-6);
        }
    }

    #[test]
    fn encode_golden_pins_code_semantics() {
        // int4: scale = 0.7/7 ≈ 0.1; codes round to the nearest grid step
        // (inputs sit safely off the rounding ties).
        let x = [0.7f32, -0.7, 0.06, -0.06, 0.24, 0.0];
        let mut codes = vec![0u32; words_per_leaf(6, 4)];
        let scale = encode_leaf(&x, 4, &mut codes);
        assert!((scale - 0.1).abs() < 1e-7);
        let per_code: Vec<u32> = (0..6).map(|i| (codes[i / 8] >> ((i % 8) * 4)) & 0xf).collect();
        // c = round(x/0.1): 7, -7, 1, -1, 2, 0.
        assert_eq!(per_code, vec![14, 0, 8, 6, 9, 7]);
        // b2: scale = 0.9/3 = 0.3; u = clamp(round((x/0.3 + 3)/2), 0, 3).
        let x = [0.9f32, -0.9, 0.1, -0.4];
        let mut codes = vec![0u32; words_per_leaf(4, 2)];
        let scale = encode_leaf(&x, 2, &mut codes);
        assert!((scale - 0.3).abs() < 1e-7);
        let per_code: Vec<u32> = (0..4).map(|i| (codes[0] >> (i * 2)) & 0x3).collect();
        assert_eq!(per_code, vec![3, 0, 2, 1]);
        // Zero-scale leaves decode to exactly zero.
        let zeros = [0.0f32; 8];
        for &bits in &SUPPORTED_BITS {
            let mut codes = vec![0u32; words_per_leaf(8, bits)];
            let scale = encode_leaf(&zeros, bits, &mut codes);
            assert_eq!(scale, 0.0, "bits={bits}");
            let mut back = [f32::NAN; 8];
            decode_leaf(&codes, bits, scale, &mut back);
            assert_eq!(back, [0.0f32; 8], "bits={bits}");
        }
    }

    #[test]
    fn leaf_dot_matches_decoded_dot() {
        let mut rng = Rng::new(23);
        for &bits in &SUPPORTED_BITS {
            for q in [1usize, 4, 16, 33, 100] {
                let xa: Vec<f32> = (0..q).map(|_| rng.normal(0.0, 1.0)).collect();
                let xb: Vec<f32> = (0..q).map(|_| rng.normal(0.0, 1.0)).collect();
                let wpl = words_per_leaf(q, bits);
                let (mut ca, mut cb) = (vec![0u32; wpl], vec![0u32; wpl]);
                let sa = encode_leaf(&xa, bits, &mut ca);
                let sb = encode_leaf(&xb, bits, &mut cb);
                let got = leaf_dot(&ca, sa, &cb, sb, q, bits);
                let (mut da, mut db) = (vec![0.0f32; q], vec![0.0f32; q]);
                decode_leaf(&ca, bits, sa, &mut da);
                decode_leaf(&cb, bits, sb, &mut db);
                let want = dot(&da, &db);
                // Same value up to f32 rounding of the two summation
                // orders (the in-domain sum is exact in integers).
                let tol = 1e-4 * (1.0 + want.abs());
                assert!(
                    (got - want).abs() <= tol,
                    "bits={bits} q={q}: {got} vs {want}"
                );
            }
        }
    }

    fn sample(vocab: usize, dim: usize, order: usize, rank: usize, seed: u64) -> Word2Ket {
        let mut rng = Rng::new(seed);
        Word2Ket::random(vocab, dim, order, rank, &mut rng)
    }

    #[test]
    fn rows_match_f16_rounded_word2ket() {
        let w = sample(20, 16, 2, 2, 5);
        let qk = QuantizedKet::from_word2ket(&w, 4).unwrap();
        assert!(qk.exact_dim());
        // Row = CP tree over f16-rounded leaves; independently reconstruct.
        for id in [0usize, 7, 19] {
            let mut refs: [&[f32]; MAX_ORDER] = [&[]; MAX_ORDER];
            let mut want = vec![0.0f32; 16];
            let rounded: Vec<Vec<f32>> = (0..2)
                .flat_map(|k| {
                    (0..2).map(move |j| (k, j)).collect::<Vec<_>>()
                })
                .map(|(k, j)| {
                    w.word(id)
                        .leaf(k, j)
                        .iter()
                        .map(|&v| f16_bits_to_f32(f32_to_f16_bits(v)))
                        .collect()
                })
                .collect();
            for k in 0..2 {
                for j in 0..2 {
                    refs[j] = &rounded[k * 2 + j];
                }
                let term = tree_term(&refs[..2], false);
                kernels::add_assign(&mut want, &term);
            }
            assert_eq!(qk.lookup(id), want, "id={id}");
            // And the refinement is close to the original row.
            let orig = w.lookup(id);
            for (a, b) in orig.iter().zip(&want) {
                assert!((a - b).abs() <= 2e-3 * (1.0 + a.abs()), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn coarse_inner_approximates_row_dot() {
        let w = sample(40, 64, 2, 2, 9);
        for &bits in &[4usize, 8] {
            let qk = QuantizedKet::from_word2ket(&w, bits).unwrap();
            for (a, b) in [(0usize, 1usize), (3, 30), (12, 12)] {
                let coarse = FactoredRepr::inner(&qk, a, b);
                let exact = dot(&qk.lookup(a), &qk.lookup(b));
                let tol = 0.5 * (1.0 + exact.abs());
                assert!(
                    (coarse - exact).abs() <= tol,
                    "bits={bits} ({a},{b}): coarse {coarse} vs exact {exact}"
                );
            }
        }
    }

    #[test]
    fn coarse_inner_is_simd_level_invariant() {
        let w = sample(12, 256, 2, 2, 13);
        for &bits in &SUPPORTED_BITS {
            let qk = QuantizedKet::from_word2ket(&w, bits).unwrap();
            let want: Vec<f32> = with_level(SimdLevel::Scalar, || {
                (0..12).map(|b| qk.view().inner(3, b)).collect()
            });
            for l in available_levels() {
                let got: Vec<f32> =
                    with_level(l, || (0..12).map(|b| qk.view().inner(3, b)).collect());
                for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.to_bits(), w_.to_bits(), "bits={bits} level={l:?} b={i}");
                }
            }
        }
    }

    #[test]
    fn block_inner_matches_per_pair() {
        let w = sample(30, 81, 4, 2, 17);
        let qk = QuantizedKet::from_word2ket(&w, 2).unwrap();
        let bs: Vec<usize> = (0..30).collect();
        let mut block = vec![0.0f32; 30];
        qk.view().block_inner(5, &bs, &mut block);
        for (i, &b) in bs.iter().enumerate() {
            assert_eq!(block[i].to_bits(), qk.view().inner(5, b).to_bits());
        }
    }

    #[test]
    fn factors_expose_refined_leaves() {
        let w = sample(10, 16, 2, 3, 21);
        let qk = QuantizedKet::from_word2ket(&w, 8).unwrap();
        let mut fs: [&[f32]; MAX_ORDER] = [&[]; MAX_ORDER];
        qk.factors(4, 1, &mut fs[..2]);
        assert_eq!(fs[0], qk.view().refined_leaf(4, 1, 0));
        assert_eq!(fs[1], qk.view().refined_leaf(4, 1, 1));
        // Refined leaves are exactly f16-representable.
        for &v in fs[0] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v);
        }
    }

    #[test]
    fn truncated_dim_serves_rows_but_is_not_exact() {
        // dim 20, order 2 -> q = 5, 25 > 20: rows truncate like Word2Ket.
        let w = sample(15, 20, 2, 1, 25);
        let qk = QuantizedKet::from_word2ket(&w, 4).unwrap();
        assert!(!qk.exact_dim());
        assert_eq!(qk.lookup(3).len(), 20);
    }

    #[test]
    fn from_parts_rejects_hostile_payloads() {
        let w = sample(4, 16, 2, 1, 33);
        let qk = QuantizedKet::from_word2ket(&w, 4).unwrap();
        let (codes, scales, leaves) =
            (qk.codes().to_vec(), qk.scales().to_vec(), qk.leaves().to_vec());
        let ok = |c: Vec<u32>, s: Vec<f32>, l: Vec<f32>, bits: usize| {
            QuantizedKet::from_parts(4, 16, 2, 1, 4, bits, c, s, l)
        };
        assert!(ok(codes.clone(), scales.clone(), leaves.clone(), 4).is_ok());
        // Unsupported width.
        assert!(ok(codes.clone(), scales.clone(), leaves.clone(), 3).is_err());
        // NaN / negative / infinite scales.
        for bad in [f32::NAN, f32::INFINITY, -1.0] {
            let mut s = scales.clone();
            s[1] = bad;
            assert!(ok(codes.clone(), s, leaves.clone(), 4).is_err(), "scale {bad}");
        }
        // Geometry mismatches.
        assert!(ok(codes[..codes.len() - 1].to_vec(), scales.clone(), leaves.clone(), 4).is_err());
        assert!(ok(codes.clone(), scales[1..].to_vec(), leaves.clone(), 4).is_err());
        assert!(ok(codes.clone(), scales.clone(), leaves[1..].to_vec(), 4).is_err());
        // Nonzero padding bits (q=4 at 4 bits uses 16 of 32 word bits).
        let mut c = codes.clone();
        c[0] |= 1 << 20;
        assert!(ok(c, scales.clone(), leaves.clone(), 4).is_err());
        // Degenerate geometry.
        assert!(QuantizedKet::from_parts(4, 16, 1, 1, 16, 4, vec![], vec![], vec![]).is_err());
        assert!(QuantizedKet::from_parts(4, 16, 2, 0, 4, 4, vec![], vec![], vec![]).is_err());
    }

    #[test]
    fn space_story_beats_float_factors() {
        // q = 16: int8 leaves pack 4× (4 words/leaf), int4 8×, b2/b1 both
        // hit the one-word-per-leaf floor, so the total is dominated by the
        // shared f16 refinement payload (half the float bytes).
        let w = sample(100, 256, 2, 2, 41);
        let float_params = w.num_params();
        for (bits, min_gain) in [(8usize, 1.2f64), (4, 1.4), (2, 1.55), (1, 1.55)] {
            let qk = QuantizedKet::from_word2ket(&w, bits).unwrap();
            let gain = float_params as f64 / qk.num_params() as f64;
            assert!(gain >= min_gain, "bits={bits}: gain {gain:.2} < {min_gain}");
        }
    }
}
