//! Text processing substrate: tokenization, vocabulary, byte-pair encoding.

mod bpe;
mod tokenizer;
mod vocab;

pub use bpe::Bpe;
pub use tokenizer::{detokenize, tokenize, Token};
pub use vocab::{Vocab, BOS, EOS, PAD, UNK};
