//! Whitespace + punctuation tokenizer with lowercasing — the preprocessing
//! regime of the paper's seq2seq baselines (Texar GIGAWORD / IWSLT pipelines
//! lowercase and split punctuation).

/// A token is just an owned lowercase string here; ids come from [`super::Vocab`].
pub type Token = String;

/// Tokenize: lowercase, split on whitespace, split leading/trailing
/// punctuation into separate tokens, keep digits grouped.
pub fn tokenize(text: &str) -> Vec<Token> {
    let mut out = Vec::new();
    for raw in text.split_whitespace() {
        let lower = raw.to_lowercase();
        push_word(&lower, &mut out);
    }
    out
}

fn is_punct(c: char) -> bool {
    c.is_ascii_punctuation()
}

fn push_word(w: &str, out: &mut Vec<Token>) {
    if w.is_empty() {
        return;
    }
    // Strip leading punctuation.
    let mut chars: Vec<char> = w.chars().collect();
    let mut start = 0;
    while start < chars.len() && is_punct(chars[start]) {
        out.push(chars[start].to_string());
        start += 1;
    }
    // Collect trailing punctuation (emitted after the core).
    let mut end = chars.len();
    let mut trail = Vec::new();
    while end > start && is_punct(chars[end - 1]) {
        trail.push(chars[end - 1].to_string());
        end -= 1;
    }
    if start < end {
        // Split internal hyphenation: "low-memory" → low - memory
        let core: String = chars[start..end].iter().collect();
        let mut piece = String::new();
        for c in core.chars() {
            if c == '-' || c == '/' {
                if !piece.is_empty() {
                    out.push(std::mem::take(&mut piece));
                }
                out.push(c.to_string());
            } else {
                piece.push(c);
            }
        }
        if !piece.is_empty() {
            out.push(piece);
        }
    }
    out.extend(trail.into_iter().rev());
    let _ = chars.drain(..); // keep clippy quiet about unused tail
}

/// Detokenize for display: join with spaces, attach simple punctuation.
pub fn detokenize(tokens: &[Token]) -> String {
    let mut s = String::new();
    for (i, t) in tokens.iter().enumerate() {
        let attach = t.len() == 1 && matches!(t.as_str(), "." | "," | "!" | "?" | ";" | ":");
        if i > 0 && !attach {
            s.push(' ');
        }
        s.push_str(t);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lowercases_and_splits() {
        assert_eq!(tokenize("The Cat sat"), vec!["the", "cat", "sat"]);
    }

    #[test]
    fn punctuation_separated() {
        assert_eq!(tokenize("Hello, world!"), vec!["hello", ",", "world", "!"]);
        assert_eq!(tokenize("(nested)"), vec!["(", "nested", ")"]);
    }

    #[test]
    fn hyphens_split() {
        assert_eq!(tokenize("low-memory"), vec!["low", "-", "memory"]);
    }

    #[test]
    fn digits_kept_together() {
        assert_eq!(tokenize("in 1999 it"), vec!["in", "1999", "it"]);
    }

    #[test]
    fn pure_punct_token() {
        assert_eq!(tokenize("..."), vec![".", ".", "."]);
        assert_eq!(tokenize(""), Vec::<Token>::new());
    }

    #[test]
    fn detokenize_attaches_punct() {
        let toks: Vec<Token> = vec!["hello".into(), ",".into(), "world".into(), "!".into()];
        assert_eq!(detokenize(&toks), "hello, world!");
    }
}
