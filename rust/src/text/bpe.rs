//! Byte-pair encoding: learned subword merges over a word-frequency table.
//!
//! Used to keep synthetic-task vocabularies closed (no UNK explosion) when a
//! corpus generator emits inflected forms; also exercises the `t^n ≥ d`
//! vocabulary-padding path of word2ketXS with realistic subword vocabularies.

use std::collections::HashMap;

/// A trained BPE model: an ordered list of merges.
#[derive(Debug, Clone)]
pub struct Bpe {
    /// Merge rules in priority order: (left, right) → merged.
    merges: Vec<(String, String)>,
    rank: HashMap<(String, String), usize>,
    /// End-of-word marker appended to the final symbol of each word.
    eow: &'static str,
}

impl Bpe {
    pub const EOW: &'static str = "</w>";

    /// Learn `num_merges` merges from (word, frequency) pairs.
    pub fn train(word_freq: &HashMap<String, usize>, num_merges: usize) -> Bpe {
        // Represent each word as a symbol sequence.
        let mut words: Vec<(Vec<String>, usize)> = word_freq
            .iter()
            .map(|(w, &f)| {
                let mut syms: Vec<String> = w.chars().map(|c| c.to_string()).collect();
                if let Some(last) = syms.last_mut() {
                    last.push_str(Self::EOW);
                }
                (syms, f)
            })
            .collect();
        // Deterministic processing order.
        words.sort_by(|a, b| a.0.cmp(&b.0));

        let mut merges = Vec::with_capacity(num_merges);
        for _ in 0..num_merges {
            // Count adjacent pairs.
            let mut pair_count: HashMap<(String, String), usize> = HashMap::new();
            for (syms, f) in &words {
                for w in syms.windows(2) {
                    *pair_count
                        .entry((w[0].clone(), w[1].clone()))
                        .or_insert(0) += *f;
                }
            }
            // Best pair (ties alphabetical for determinism).
            let best = pair_count
                .into_iter()
                .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)));
            let Some(((l, r), count)) = best else { break };
            if count < 2 {
                break; // nothing worth merging
            }
            // Apply merge.
            let merged = format!("{l}{r}");
            for (syms, _) in &mut words {
                let mut i = 0;
                while i + 1 < syms.len() {
                    if syms[i] == l && syms[i + 1] == r {
                        syms[i] = merged.clone();
                        syms.remove(i + 1);
                    } else {
                        i += 1;
                    }
                }
            }
            merges.push((l, r));
        }
        let rank = merges
            .iter()
            .enumerate()
            .map(|(i, m)| (m.clone(), i))
            .collect();
        Bpe { merges, rank, eow: Self::EOW }
    }

    pub fn num_merges(&self) -> usize {
        self.merges.len()
    }

    /// Segment one word into subword symbols by greedily applying the
    /// lowest-rank applicable merge (standard BPE inference).
    pub fn segment(&self, word: &str) -> Vec<String> {
        let mut syms: Vec<String> = word.chars().map(|c| c.to_string()).collect();
        if syms.is_empty() {
            return syms;
        }
        if let Some(last) = syms.last_mut() {
            last.push_str(self.eow);
        }
        loop {
            // Find the best-ranked adjacent pair.
            let mut best: Option<(usize, usize)> = None; // (rank, position)
            for i in 0..syms.len().saturating_sub(1) {
                if let Some(&rk) = self.rank.get(&(syms[i].clone(), syms[i + 1].clone())) {
                    if best.map_or(true, |(brk, _)| rk < brk) {
                        best = Some((rk, i));
                    }
                }
            }
            match best {
                Some((_, i)) => {
                    let merged = format!("{}{}", syms[i], syms[i + 1]);
                    syms[i] = merged;
                    syms.remove(i + 1);
                }
                None => break,
            }
        }
        syms
    }

    /// Segment a token stream, flattening subwords.
    pub fn segment_all(&self, tokens: &[String]) -> Vec<String> {
        tokens.iter().flat_map(|t| self.segment(t)).collect()
    }

    /// Undo segmentation: join symbols, splitting words at EOW markers.
    pub fn join(&self, symbols: &[String]) -> Vec<String> {
        let mut words = Vec::new();
        let mut cur = String::new();
        for s in symbols {
            if let Some(stripped) = s.strip_suffix(self.eow) {
                cur.push_str(stripped);
                words.push(std::mem::take(&mut cur));
            } else {
                cur.push_str(s);
            }
        }
        if !cur.is_empty() {
            words.push(cur);
        }
        words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn freq(pairs: &[(&str, usize)]) -> HashMap<String, usize> {
        pairs.iter().map(|(w, f)| (w.to_string(), *f)).collect()
    }

    #[test]
    fn learns_frequent_pairs() {
        let wf = freq(&[("lower", 10), ("low", 10), ("lowest", 5), ("newer", 8)]);
        let bpe = Bpe::train(&wf, 10);
        assert!(bpe.num_merges() > 0);
        // "low" should segment into few symbols after training.
        let segs = bpe.segment("low");
        assert!(segs.len() <= 2, "{segs:?}");
    }

    #[test]
    fn roundtrip_join() {
        let wf = freq(&[("abab", 5), ("ab", 9)]);
        let bpe = Bpe::train(&wf, 5);
        for w in ["abab", "ab", "ba", "xyz"] {
            let segs = bpe.segment(w);
            let joined = bpe.join(&segs);
            assert_eq!(joined, vec![w.to_string()], "word {w}: {segs:?}");
        }
    }

    #[test]
    fn segment_all_flattens() {
        let wf = freq(&[("aa", 5)]);
        let bpe = Bpe::train(&wf, 2);
        let toks: Vec<String> = vec!["aa".into(), "b".into()];
        let segs = bpe.segment_all(&toks);
        let joined = bpe.join(&segs);
        assert_eq!(joined, toks);
    }

    #[test]
    fn deterministic_training() {
        let wf = freq(&[("hello", 3), ("help", 3), ("held", 2)]);
        let a = Bpe::train(&wf, 8);
        let b = Bpe::train(&wf, 8);
        assert_eq!(a.merges, b.merges);
    }

    #[test]
    fn empty_and_single_char() {
        let wf = freq(&[("ab", 2)]);
        let bpe = Bpe::train(&wf, 2);
        assert!(bpe.segment("").is_empty());
        let one = bpe.segment("x");
        assert_eq!(bpe.join(&one), vec!["x".to_string()]);
    }
}
