//! Frequency-cut vocabulary with the four standard special tokens.

use std::collections::HashMap;

/// Special token ids (fixed positions at the front of every vocabulary).
pub const PAD: usize = 0;
pub const UNK: usize = 1;
pub const BOS: usize = 2;
pub const EOS: usize = 3;

/// Token ↔ id bijection, built from corpus frequencies.
#[derive(Debug, Clone)]
pub struct Vocab {
    token_to_id: HashMap<String, usize>,
    id_to_token: Vec<String>,
}

impl Vocab {
    /// Build from token sequences: keep the `max_size - 4` most frequent
    /// tokens appearing at least `min_freq` times. Ties break alphabetically
    /// so vocabularies are deterministic.
    pub fn build<'a, I>(sequences: I, max_size: usize, min_freq: usize) -> Vocab
    where
        I: IntoIterator<Item = &'a [String]>,
    {
        let mut freq: HashMap<&str, usize> = HashMap::new();
        for seq in sequences {
            for t in seq {
                *freq.entry(t.as_str()).or_insert(0) += 1;
            }
        }
        let mut items: Vec<(&str, usize)> =
            freq.into_iter().filter(|&(_, c)| c >= min_freq).collect();
        items.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
        let keep = max_size.saturating_sub(4);
        items.truncate(keep);

        let mut id_to_token: Vec<String> =
            vec!["<pad>".into(), "<unk>".into(), "<bos>".into(), "<eos>".into()];
        id_to_token.extend(items.into_iter().map(|(t, _)| t.to_string()));
        let token_to_id = id_to_token
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i))
            .collect();
        Vocab { token_to_id, id_to_token }
    }

    /// Vocabulary size including specials.
    pub fn len(&self) -> usize {
        self.id_to_token.len()
    }

    pub fn is_empty(&self) -> bool {
        self.id_to_token.is_empty()
    }

    pub fn id(&self, token: &str) -> usize {
        self.token_to_id.get(token).copied().unwrap_or(UNK)
    }

    pub fn token(&self, id: usize) -> &str {
        self.id_to_token.get(id).map(|s| s.as_str()).unwrap_or("<unk>")
    }

    pub fn contains(&self, token: &str) -> bool {
        self.token_to_id.contains_key(token)
    }

    /// Encode a token sequence (no BOS/EOS added).
    pub fn encode(&self, tokens: &[String]) -> Vec<usize> {
        tokens.iter().map(|t| self.id(t)).collect()
    }

    /// Encode with BOS/EOS wrapping.
    pub fn encode_wrapped(&self, tokens: &[String]) -> Vec<usize> {
        let mut ids = Vec::with_capacity(tokens.len() + 2);
        ids.push(BOS);
        ids.extend(tokens.iter().map(|t| self.id(t)));
        ids.push(EOS);
        ids
    }

    /// Decode ids to tokens, dropping specials.
    pub fn decode(&self, ids: &[usize]) -> Vec<String> {
        ids.iter()
            .filter(|&&i| i >= 4)
            .map(|&i| self.token(i).to_string())
            .collect()
    }

    /// Out-of-vocabulary rate over a token stream.
    pub fn oov_rate(&self, tokens: &[String]) -> f64 {
        if tokens.is_empty() {
            return 0.0;
        }
        let oov = tokens.iter().filter(|t| !self.contains(t)).count();
        oov as f64 / tokens.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seqs(data: &[&[&str]]) -> Vec<Vec<String>> {
        data.iter()
            .map(|s| s.iter().map(|t| t.to_string()).collect())
            .collect()
    }

    #[test]
    fn specials_at_front() {
        let data = seqs(&[&["a", "b", "a"]]);
        let refs: Vec<&[String]> = data.iter().map(|v| v.as_slice()).collect();
        let v = Vocab::build(refs.iter().copied(), 100, 1);
        assert_eq!(v.token(PAD), "<pad>");
        assert_eq!(v.token(UNK), "<unk>");
        assert_eq!(v.token(BOS), "<bos>");
        assert_eq!(v.token(EOS), "<eos>");
        assert_eq!(v.id("a"), 4); // most frequent real token gets first slot
        assert_eq!(v.len(), 6);
    }

    #[test]
    fn frequency_order_and_cutoff() {
        let data = seqs(&[&["x", "y", "y", "z", "z", "z", "zebra"]]);
        let refs: Vec<&[String]> = data.iter().map(|v| v.as_slice()).collect();
        let v = Vocab::build(refs.iter().copied(), 7, 1); // room for 3 real tokens
        assert_eq!(v.id("z"), 4);
        assert_eq!(v.id("y"), 5);
        assert_eq!(v.id("x"), 6); // alphabetical tie-break beats "zebra"
        assert_eq!(v.id("zebra"), UNK); // truncated by max_size
    }

    #[test]
    fn min_freq_filters() {
        let data = seqs(&[&["a", "a", "b"]]);
        let refs: Vec<&[String]> = data.iter().map(|v| v.as_slice()).collect();
        let v = Vocab::build(refs.iter().copied(), 100, 2);
        assert!(v.contains("a"));
        assert!(!v.contains("b"));
    }

    #[test]
    fn encode_decode_roundtrip() {
        let data = seqs(&[&["the", "cat", "sat"]]);
        let refs: Vec<&[String]> = data.iter().map(|v| v.as_slice()).collect();
        let v = Vocab::build(refs.iter().copied(), 100, 1);
        let toks: Vec<String> = ["the", "cat", "sat"].iter().map(|s| s.to_string()).collect();
        let ids = v.encode_wrapped(&toks);
        assert_eq!(ids[0], BOS);
        assert_eq!(*ids.last().unwrap(), EOS);
        assert_eq!(v.decode(&ids), toks);
    }

    #[test]
    fn unk_for_unknown() {
        let data = seqs(&[&["known"]]);
        let refs: Vec<&[String]> = data.iter().map(|v| v.as_slice()).collect();
        let v = Vocab::build(refs.iter().copied(), 100, 1);
        assert_eq!(v.id("unknown-token"), UNK);
        let toks: Vec<String> = vec!["unknown-token".into(), "known".into()];
        assert_eq!(v.oov_rate(&toks), 0.5);
    }

    #[test]
    fn deterministic_tie_break() {
        let data = seqs(&[&["b", "a"]]);
        let refs: Vec<&[String]> = data.iter().map(|v| v.as_slice()).collect();
        let v = Vocab::build(refs.iter().copied(), 100, 1);
        assert_eq!(v.id("a"), 4); // alphabetical among equal-frequency
        assert_eq!(v.id("b"), 5);
    }
}
