//! TOML-subset parser: `[section]`, `key = value`, `#` comments.
//! Values: strings ("..." or '...'), booleans, integers, floats, flat arrays.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Parse a bare scalar token (used both by the file parser and --set).
    pub fn parse_scalar(tok: &str) -> TomlValue {
        let t = tok.trim();
        if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
            || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
        {
            return TomlValue::Str(t[1..t.len() - 1].to_string());
        }
        match t {
            "true" => return TomlValue::Bool(true),
            "false" => return TomlValue::Bool(false),
            _ => {}
        }
        if let Ok(i) = t.replace('_', "").parse::<i64>() {
            return TomlValue::Int(i);
        }
        if let Ok(f) = t.parse::<f64>() {
            return TomlValue::Float(f);
        }
        TomlValue::Str(t.to_string())
    }
}

/// A parsed document: `section.key → value`. Keys without a section live
/// under the empty section "".
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(Error::Config(format!(
                        "line {}: malformed section header '{raw}'",
                        lineno + 1
                    )));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value, got '{raw}'", lineno + 1))
            })?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            doc.entries.insert(key, parse_value(v.trim(), lineno + 1)?);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn set(&mut self, key: &str, val: TomlValue) {
        self.entries.insert(key.to_string(), val);
    }

    /// Set from a raw string (CLI override path).
    pub fn set_str(&mut self, key: &str, raw: &str) -> Result<()> {
        if key.is_empty() {
            return Err(Error::Config("empty override key".into()));
        }
        self.entries.insert(key.to_string(), parse_value(raw, 0)?);
        Ok(())
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }

    // typed getters with defaults --------------------------------------------

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str: Option<char> = None;
    for (i, c) in line.char_indices() {
        match (in_str, c) {
            (None, '#') => return &line[..i],
            (None, '"') => in_str = Some('"'),
            (None, '\'') => in_str = Some('\''),
            (Some(q), c) if c == q => in_str = None,
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str, lineno: usize) -> Result<TomlValue> {
    let t = raw.trim();
    if t.is_empty() {
        return Err(Error::Config(format!("line {lineno}: empty value")));
    }
    if t.starts_with('[') {
        if !t.ends_with(']') {
            return Err(Error::Config(format!("line {lineno}: unterminated array")));
        }
        let inner = &t[1..t.len() - 1];
        let items: Vec<TomlValue> = split_top_level(inner)
            .into_iter()
            .filter(|s| !s.trim().is_empty())
            .map(|s| TomlValue::parse_scalar(&s))
            .collect();
        return Ok(TomlValue::Arr(items));
    }
    Ok(TomlValue::parse_scalar(t))
}

/// Split an array body on commas, respecting quotes.
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str: Option<char> = None;
    for c in s.chars() {
        match (in_str, c) {
            (None, ',') => {
                out.push(std::mem::take(&mut cur));
            }
            (None, '"') | (None, '\'') => {
                in_str = Some(c);
                cur.push(c);
            }
            (Some(q), c) if c == q => {
                in_str = None;
                cur.push(c);
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let src = r#"
# experiment config
name = "table1"          # inline comment
[embedding]
kind = "word2ketxs"
order = 2
rank = 10
layernorm = true
scale = 0.5
dims = [20, 175]
"#;
        let doc = TomlDoc::parse(src).unwrap();
        assert_eq!(doc.get("name").unwrap().as_str(), Some("table1"));
        assert_eq!(doc.get("embedding.kind").unwrap().as_str(), Some("word2ketxs"));
        assert_eq!(doc.get("embedding.order").unwrap().as_usize(), Some(2));
        assert_eq!(doc.get("embedding.layernorm").unwrap().as_bool(), Some(true));
        assert_eq!(doc.get("embedding.scale").unwrap().as_f64(), Some(0.5));
        match doc.get("embedding.dims").unwrap() {
            TomlValue::Arr(v) => {
                assert_eq!(v.len(), 2);
                assert_eq!(v[1].as_usize(), Some(175));
            }
            _ => panic!("expected array"),
        }
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn underscored_ints() {
        let doc = TomlDoc::parse("n = 7_789_568").unwrap();
        assert_eq!(doc.get("n").unwrap().as_i64(), Some(7_789_568));
    }

    #[test]
    fn errors() {
        assert!(TomlDoc::parse("[bad").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k =").is_err());
    }

    #[test]
    fn set_str_overrides() {
        let mut doc = TomlDoc::parse("[a]\nb = 1").unwrap();
        doc.set_str("a.b", "2").unwrap();
        assert_eq!(doc.get("a.b").unwrap().as_i64(), Some(2));
        doc.set_str("a.c", "\"hi\"").unwrap();
        assert_eq!(doc.get("a.c").unwrap().as_str(), Some("hi"));
    }

    #[test]
    fn typed_defaults() {
        let doc = TomlDoc::parse("[t]\nsteps = 5").unwrap();
        assert_eq!(doc.usize_or("t.steps", 99), 5);
        assert_eq!(doc.usize_or("t.missing", 99), 99);
        assert_eq!(doc.str_or("t.name", "dflt"), "dflt");
    }
}
