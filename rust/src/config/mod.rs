//! Layered configuration system.
//!
//! Experiments are described by TOML-subset files (see `configs/`) with CLI
//! `--set section.key=value` overrides layered on top. No `serde`/`toml`
//! crates exist in this environment, so `toml.rs` is a from-scratch parser of
//! the subset we use: `[section]` headers, `key = value` with string, bool,
//! integer, float and flat-array values, `#` comments.

mod schema;
mod toml;

pub use schema::{
    CorpusConfig, EmbeddingConfig, EmbeddingKind, ExperimentConfig, IndexConfig, IndexKind,
    ModelConfig, ServerConfig, ServingConfig, SnapshotConfig, TaskKind, TrainConfig,
};
pub use toml::{TomlDoc, TomlValue};

// The `[net]` section's types live with the drivers in `crate::net`, and
// the `[obs]` section's with the metrics plane in `crate::obs`;
// re-exported here so config consumers see one namespace.
pub use crate::net::{NetConfig, NetDriver};
pub use crate::obs::ObsConfig;

use crate::error::{Error, Result};
use std::path::Path;

/// Load a config file and apply `--set a.b=c` overrides in order.
pub fn load_with_overrides(path: Option<&Path>, overrides: &[String]) -> Result<ExperimentConfig> {
    let mut doc = match path {
        Some(p) => {
            let src = std::fs::read_to_string(p)
                .map_err(|e| Error::Config(format!("cannot read {}: {e}", p.display())))?;
            TomlDoc::parse(&src)?
        }
        None => TomlDoc::default(),
    };
    for ov in overrides {
        let (key, val) = ov
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("override '{ov}' is not key=value")))?;
        doc.set_str(key.trim(), val.trim())?;
    }
    ExperimentConfig::from_doc(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overrides_without_file() {
        let cfg = load_with_overrides(
            None,
            &[
                "task.kind=translation".to_string(),
                "embedding.kind=word2ketxs".to_string(),
                "embedding.order=4".to_string(),
                "train.steps=17".to_string(),
            ],
        )
        .unwrap();
        assert_eq!(cfg.task, TaskKind::Translation);
        assert_eq!(cfg.embedding.kind, EmbeddingKind::Word2KetXS);
        assert_eq!(cfg.embedding.order, 4);
        assert_eq!(cfg.train.steps, 17);
    }

    #[test]
    fn bad_override_rejected() {
        assert!(load_with_overrides(None, &["nonsense".to_string()]).is_err());
    }
}
