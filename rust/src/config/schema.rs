//! Typed experiment configuration assembled from a [`TomlDoc`].

use super::toml::TomlDoc;
use crate::error::{Error, Result};
use crate::net::NetConfig;
use crate::obs::ObsConfig;
use crate::snapshot::Codec;

/// Which downstream NLP task (paper §4 evaluates three).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// GIGAWORD-style headline generation (Table 1).
    Summarization,
    /// IWSLT-style machine translation (Table 2).
    Translation,
    /// SQuAD-style extractive question answering (Table 3, Figs 2–3).
    Qa,
}

impl TaskKind {
    pub fn parse(s: &str) -> Result<TaskKind> {
        match s.to_ascii_lowercase().as_str() {
            "summarization" | "gigaword" | "sum" => Ok(TaskKind::Summarization),
            "translation" | "iwslt" | "mt" => Ok(TaskKind::Translation),
            "qa" | "squad" => Ok(TaskKind::Qa),
            other => Err(Error::Config(format!("unknown task '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Summarization => "summarization",
            TaskKind::Translation => "translation",
            TaskKind::Qa => "qa",
        }
    }

    /// Short tag used in artifact names (matches python/compile/aot.py).
    pub fn tag(&self) -> &'static str {
        match self {
            TaskKind::Summarization => "sum",
            TaskKind::Translation => "mt",
            TaskKind::Qa => "qa",
        }
    }
}

/// Embedding representation families. The first three are the paper's;
/// the rest are related-work baselines (§4.1) used for comparison benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EmbeddingKind {
    Regular,
    Word2Ket,
    Word2KetXS,
    /// Uniform b-bit quantization of a regular embedding (May et al., 2019).
    Quantized,
    /// Low-rank factorization M = U·V (PCA-style; storage ≥ d + p per rank).
    LowRank,
    /// Parameter-sharing via hashing (Suzuki & Nagata, 2016).
    Hashed,
    /// word2ket with sub-byte quantized leaf payloads scored in the
    /// quantized domain plus an f16 refinement (see `quant/`). Uses
    /// `order`/`rank` like word2ket and `bits` ∈ {1, 2, 4, 8}.
    QuantizedKet,
}

impl EmbeddingKind {
    pub fn parse(s: &str) -> Result<EmbeddingKind> {
        match s.to_ascii_lowercase().replace('-', "").as_str() {
            "regular" => Ok(EmbeddingKind::Regular),
            "word2ket" | "w2k" => Ok(EmbeddingKind::Word2Ket),
            "word2ketxs" | "xs" | "w2kxs" => Ok(EmbeddingKind::Word2KetXS),
            "quantized" => Ok(EmbeddingKind::Quantized),
            "lowrank" => Ok(EmbeddingKind::LowRank),
            "hashed" => Ok(EmbeddingKind::Hashed),
            "quantizedket" | "qket" => Ok(EmbeddingKind::QuantizedKet),
            other => Err(Error::Config(format!("unknown embedding kind '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EmbeddingKind::Regular => "regular",
            EmbeddingKind::Word2Ket => "word2ket",
            EmbeddingKind::Word2KetXS => "word2ketXS",
            EmbeddingKind::Quantized => "quantized",
            EmbeddingKind::LowRank => "lowrank",
            EmbeddingKind::Hashed => "hashed",
            EmbeddingKind::QuantizedKet => "quantizedket",
        }
    }
}

/// Embedding hyper-parameters (paper "Order/Rank" columns).
#[derive(Debug, Clone, PartialEq)]
pub struct EmbeddingConfig {
    pub kind: EmbeddingKind,
    /// Tensor order n (number of factors). 1 for regular.
    pub order: usize,
    /// Tensor rank r (number of summed simple tensors).
    pub rank: usize,
    /// LayerNorm at balanced-tree internal nodes (§2.3).
    pub layernorm: bool,
    /// Quantization bits (Quantized baseline only).
    pub bits: usize,
    /// Factorization rank (LowRank baseline only).
    pub lowrank_dim: usize,
    /// Bucket count (Hashed baseline only).
    pub buckets: usize,
}

impl Default for EmbeddingConfig {
    fn default() -> Self {
        EmbeddingConfig {
            kind: EmbeddingKind::Regular,
            order: 1,
            rank: 1,
            layernorm: true,
            bits: 8,
            lowrank_dim: 16,
            buckets: 1 << 14,
        }
    }
}

/// Model dimensions (seq2seq or QA reader).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Hidden width of RNN layers.
    pub hidden: usize,
    /// Embedding dimensionality p (must be q^order for tensorized kinds).
    pub emb_dim: usize,
    /// Vocabulary size d (shared source/target in our synthetic tasks).
    pub vocab: usize,
    pub max_src_len: usize,
    pub max_tgt_len: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig { hidden: 64, emb_dim: 64, vocab: 1024, max_src_len: 24, max_tgt_len: 12 }
    }
}

/// Synthetic corpus generation parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct CorpusConfig {
    pub seed: u64,
    pub train: usize,
    pub valid: usize,
    pub test: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig { seed: 2020, train: 2000, valid: 200, test: 200 }
    }
}

/// Optimization schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub steps: usize,
    pub batch_size: usize,
    pub lr: f64,
    pub warmup: usize,
    /// Gradient global-norm clip (0 disables; applied inside the HLO).
    pub clip: f64,
    pub eval_every: usize,
    pub seed: u64,
    pub checkpoint_dir: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            batch_size: 16,
            lr: 3e-3,
            warmup: 30,
            clip: 1.0,
            eval_every: 50,
            seed: 7,
            checkpoint_dir: "checkpoints".into(),
        }
    }
}

/// Embedding-server listener settings. Batching/caching knobs live in
/// [`ServingConfig`] (`[serving]`); this section only picks the socket.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    pub addr: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:7878".into() }
    }
}

/// Which k-NN index structure serves similarity queries (see `index/`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IndexKind {
    /// Exact scan of the whole vocabulary (factored scoring when possible).
    Brute,
    /// Inverted-file approximate index: k-means coarse quantizer, probe the
    /// `nprobe` nearest of `nlist` cells, exact re-rank of their members.
    Ivf,
}

impl IndexKind {
    pub fn parse(s: &str) -> Result<IndexKind> {
        match s.to_ascii_lowercase().replace('-', "").as_str() {
            "brute" | "bruteforce" | "flat" | "exact" => Ok(IndexKind::Brute),
            "ivf" => Ok(IndexKind::Ivf),
            other => Err(Error::Config(format!("unknown index kind '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            IndexKind::Brute => "brute",
            IndexKind::Ivf => "ivf",
        }
    }
}

/// Similarity-search settings for the server's `KNN` request path
/// (`[index]` in the experiment TOML; see `index/`).
#[derive(Debug, Clone, PartialEq)]
pub struct IndexConfig {
    pub kind: IndexKind,
    /// IVF coarse cells (clamped to the vocabulary size at build).
    pub nlist: usize,
    /// IVF cells probed per query (clamped to `nlist` at build).
    pub nprobe: usize,
    /// Rank by cosine similarity instead of raw dot product (per-word norms
    /// are precomputed at index build).
    pub cosine: bool,
    /// Scan-team size for brute-force sweeps and IVF re-ranks: 0 = auto
    /// (available parallelism, the default), 1 = single-threaded, N = at
    /// most N workers. Results are bit-identical at any setting (exact
    /// per-worker top-k heaps merged through `merge_top_k`).
    pub scan_threads: usize,
}

impl Default for IndexConfig {
    fn default() -> Self {
        IndexConfig { kind: IndexKind::Brute, nlist: 64, nprobe: 8, cosine: false, scan_threads: 0 }
    }
}

/// Serving-path settings: the sharded hot-row cache and worker pool that sit
/// between the TCP listener and the embedding store (see `serving/`).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    /// Cache/queue shard count; also the worker-pool size (one worker drains
    /// each shard queue).
    pub shards: usize,
    /// Total cached rows across all shards. 0 disables the cache.
    pub cache_rows: usize,
    /// Micro-batching window per worker, in microseconds.
    pub batch_window_us: u64,
    /// Bounded per-shard queue depth; submits beyond this are rejected
    /// (backpressure) instead of growing the queue without limit.
    pub queue_depth: usize,
    /// Max jobs drained per batch by one worker.
    pub max_batch: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            shards: 4,
            cache_rows: 4096,
            batch_window_us: 200,
            queue_depth: 1024,
            max_batch: 64,
        }
    }
}

/// Snapshot persistence settings (`[snapshot]`; see `snapshot/`).
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotConfig {
    /// Snapshot file the server boots from (empty = build from RNG+config).
    pub path: String,
    /// Memory-map snapshot loads (zero-copy) instead of heap-buffering.
    pub mmap: bool,
    /// Payload codec used when *writing* snapshots (`f32`, `f16`, `int8`).
    pub codec: Codec,
}

impl Default for SnapshotConfig {
    fn default() -> Self {
        SnapshotConfig { path: String::new(), mmap: true, codec: Codec::F32 }
    }
}

/// Complete experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub task: TaskKind,
    pub embedding: EmbeddingConfig,
    pub model: ModelConfig,
    pub corpus: CorpusConfig,
    pub train: TrainConfig,
    pub server: ServerConfig,
    pub serving: ServingConfig,
    pub index: IndexConfig,
    pub snapshot: SnapshotConfig,
    /// `[net]` — which connection driver the listener runs on plus its
    /// timeouts (see `net/`).
    pub net: NetConfig,
    /// `[obs]` — metrics plane: enable switch, slow-query ring length,
    /// stage-histogram toggle (see `obs/`).
    pub obs: ObsConfig,
    pub artifacts_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "experiment".into(),
            task: TaskKind::Summarization,
            embedding: EmbeddingConfig::default(),
            model: ModelConfig::default(),
            corpus: CorpusConfig::default(),
            train: TrainConfig::default(),
            server: ServerConfig::default(),
            serving: ServingConfig::default(),
            index: IndexConfig::default(),
            snapshot: SnapshotConfig::default(),
            net: NetConfig::default(),
            obs: ObsConfig::default(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<ExperimentConfig> {
        let d = ExperimentConfig::default();
        let task = match doc.get("task.kind") {
            Some(v) => TaskKind::parse(v.as_str().unwrap_or(""))?,
            None => d.task,
        };
        let kind = match doc.get("embedding.kind") {
            Some(v) => EmbeddingKind::parse(v.as_str().unwrap_or(""))?,
            None => d.embedding.kind,
        };
        let cfg = ExperimentConfig {
            name: doc.str_or("name", &d.name),
            task,
            embedding: EmbeddingConfig {
                kind,
                order: doc.usize_or("embedding.order", d.embedding.order),
                rank: doc.usize_or("embedding.rank", d.embedding.rank),
                layernorm: doc.bool_or("embedding.layernorm", d.embedding.layernorm),
                bits: doc.usize_or("embedding.bits", d.embedding.bits),
                lowrank_dim: doc.usize_or("embedding.lowrank_dim", d.embedding.lowrank_dim),
                buckets: doc.usize_or("embedding.buckets", d.embedding.buckets),
            },
            model: ModelConfig {
                hidden: doc.usize_or("model.hidden", d.model.hidden),
                emb_dim: doc.usize_or("model.emb_dim", d.model.emb_dim),
                vocab: doc.usize_or("model.vocab", d.model.vocab),
                max_src_len: doc.usize_or("model.max_src_len", d.model.max_src_len),
                max_tgt_len: doc.usize_or("model.max_tgt_len", d.model.max_tgt_len),
            },
            corpus: CorpusConfig {
                seed: doc.usize_or("corpus.seed", d.corpus.seed as usize) as u64,
                train: doc.usize_or("corpus.train", d.corpus.train),
                valid: doc.usize_or("corpus.valid", d.corpus.valid),
                test: doc.usize_or("corpus.test", d.corpus.test),
            },
            train: TrainConfig {
                steps: doc.usize_or("train.steps", d.train.steps),
                batch_size: doc.usize_or("train.batch_size", d.train.batch_size),
                lr: doc.f64_or("train.lr", d.train.lr),
                warmup: doc.usize_or("train.warmup", d.train.warmup),
                clip: doc.f64_or("train.clip", d.train.clip),
                eval_every: doc.usize_or("train.eval_every", d.train.eval_every),
                seed: doc.usize_or("train.seed", d.train.seed as usize) as u64,
                checkpoint_dir: doc.str_or("train.checkpoint_dir", &d.train.checkpoint_dir),
            },
            server: ServerConfig { addr: doc.str_or("server.addr", &d.server.addr) },
            index: IndexConfig {
                kind: match doc.get("index.kind") {
                    Some(v) => IndexKind::parse(v.as_str().unwrap_or(""))?,
                    None => d.index.kind,
                },
                nlist: doc.usize_or("index.nlist", d.index.nlist),
                nprobe: doc.usize_or("index.nprobe", d.index.nprobe),
                cosine: doc.bool_or("index.cosine", d.index.cosine),
                scan_threads: doc.usize_or("index.scan_threads", d.index.scan_threads),
            },
            serving: ServingConfig {
                shards: doc.usize_or("serving.shards", d.serving.shards),
                cache_rows: doc.usize_or("serving.cache_rows", d.serving.cache_rows),
                batch_window_us: doc
                    .usize_or("serving.batch_window_us", d.serving.batch_window_us as usize)
                    as u64,
                queue_depth: doc.usize_or("serving.queue_depth", d.serving.queue_depth),
                max_batch: doc.usize_or("serving.max_batch", d.serving.max_batch),
            },
            snapshot: SnapshotConfig {
                path: doc.str_or("snapshot.path", &d.snapshot.path),
                mmap: doc.bool_or("snapshot.mmap", d.snapshot.mmap),
                codec: match doc.get("snapshot.codec") {
                    Some(v) => Codec::parse(v.as_str().unwrap_or(""))?,
                    None => d.snapshot.codec,
                },
            },
            net: NetConfig::from_doc(doc),
            obs: ObsConfig::from_doc(doc),
            artifacts_dir: doc.str_or("artifacts_dir", &d.artifacts_dir),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks tying the pieces together.
    pub fn validate(&self) -> Result<()> {
        let e = &self.embedding;
        if e.order == 0 || e.rank == 0 {
            return Err(Error::Config("embedding order/rank must be >= 1".into()));
        }
        match e.kind {
            EmbeddingKind::QuantizedKet => {
                if e.order < 2 {
                    return Err(Error::Config(format!(
                        "quantizedket needs order >= 2 (got {})",
                        e.order
                    )));
                }
                if ![1usize, 2, 4, 8].contains(&e.bits) {
                    return Err(Error::Config(format!(
                        "quantizedket bits must be 1, 2, 4 or 8 (got {})",
                        e.bits
                    )));
                }
                if e.layernorm {
                    return Err(Error::Config(
                        "quantizedket requires embedding.layernorm = false (quantized-domain \
                         scoring needs raw CP leaves)"
                            .into(),
                    ));
                }
            }
            EmbeddingKind::Word2Ket | EmbeddingKind::Word2KetXS => {
                if e.order < 2 {
                    return Err(Error::Config(format!(
                        "{} needs order >= 2 (got {})",
                        e.kind.name(),
                        e.order
                    )));
                }
                if e.kind == EmbeddingKind::Word2KetXS && e.order > 8 {
                    // The XS lazy-reconstruction fast path uses fixed 8-slot
                    // digit buffers (see word2ketxs.rs).
                    return Err(Error::Config(format!(
                        "word2ketXS supports order <= 8 (got {})",
                        e.order
                    )));
                }
                // emb_dim must admit q = ceil(p^(1/n)) with q^n >= p; always true,
                // but guard against degenerate q < 2.
                let q = crate::util::ceil_root(self.model.emb_dim, e.order as u32);
                if q < 2 {
                    return Err(Error::Config(format!(
                        "emb_dim {} too small for order {}",
                        self.model.emb_dim, e.order
                    )));
                }
            }
            EmbeddingKind::Quantized => {
                if !(1..=16).contains(&e.bits) {
                    return Err(Error::Config(format!("bits {} outside 1..=16", e.bits)));
                }
            }
            _ => {}
        }
        if self.train.batch_size == 0 {
            return Err(Error::Config("batch_size must be >= 1".into()));
        }
        let s = &self.serving;
        if s.shards == 0 {
            return Err(Error::Config("serving.shards must be >= 1".into()));
        }
        if s.queue_depth == 0 || s.max_batch == 0 {
            return Err(Error::Config("serving.queue_depth/max_batch must be >= 1".into()));
        }
        if self.index.nlist == 0 || self.index.nprobe == 0 {
            return Err(Error::Config("index.nlist/nprobe must be >= 1".into()));
        }
        if self.net.handlers == 0 {
            return Err(Error::Config("net.handlers must be >= 1".into()));
        }
        if self.obs.slow_log_len > 1 << 16 {
            return Err(Error::Config("obs.slow_log_len must be <= 65536".into()));
        }
        if !(0.0..=1.0).contains(&self.obs.trace_sample) {
            return Err(Error::Config(format!(
                "obs.trace_sample must be in [0, 1] (got {})",
                self.obs.trace_sample
            )));
        }
        if self.obs.trace_ring_len > 1 << 16 {
            return Err(Error::Config("obs.trace_ring_len must be <= 65536".into()));
        }
        Ok(())
    }

    /// Artifact base name for this (task, embedding) pair, matching aot.py.
    pub fn artifact_prefix(&self) -> String {
        let e = &self.embedding;
        match e.kind {
            EmbeddingKind::Regular => format!("{}_regular", self.task.tag()),
            EmbeddingKind::Word2Ket => {
                format!("{}_w2k_o{}r{}", self.task.tag(), e.order, e.rank)
            }
            EmbeddingKind::Word2KetXS => {
                format!("{}_xs_o{}r{}", self.task.tag(), e.order, e.rank)
            }
            other => format!("{}_{}", self.task.tag(), other.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn full_doc_roundtrip() {
        let src = r#"
name = "tbl1-xs"
[task]
kind = "summarization"
[embedding]
kind = "word2ketxs"
order = 2
rank = 10
layernorm = false
[model]
hidden = 32
emb_dim = 64
vocab = 512
[train]
steps = 10
batch_size = 4
lr = 0.001
"#;
        let doc = TomlDoc::parse(src).unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.name, "tbl1-xs");
        assert_eq!(cfg.task, TaskKind::Summarization);
        assert_eq!(cfg.embedding.kind, EmbeddingKind::Word2KetXS);
        assert_eq!(cfg.embedding.rank, 10);
        assert!(!cfg.embedding.layernorm);
        assert_eq!(cfg.model.vocab, 512);
        assert_eq!(cfg.train.lr, 0.001);
        assert_eq!(cfg.artifact_prefix(), "sum_xs_o2r10");
    }

    #[test]
    fn serving_section_parses_and_validates() {
        let src = r#"
[serving]
shards = 8
cache_rows = 65536
batch_window_us = 50
queue_depth = 256
"#;
        let doc = TomlDoc::parse(src).unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.serving.shards, 8);
        assert_eq!(cfg.serving.cache_rows, 65536);
        assert_eq!(cfg.serving.batch_window_us, 50);
        assert_eq!(cfg.serving.queue_depth, 256);
        assert_eq!(cfg.serving.max_batch, ServingConfig::default().max_batch);

        let mut bad = ExperimentConfig::default();
        bad.serving.shards = 0;
        assert!(bad.validate().is_err());
        let mut bad = ExperimentConfig::default();
        bad.serving.queue_depth = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn index_section_parses_and_validates() {
        let src = r#"
[index]
kind = "ivf"
nlist = 32
nprobe = 4
cosine = true
scan_threads = 2
"#;
        let doc = TomlDoc::parse(src).unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.index.kind, IndexKind::Ivf);
        assert_eq!(cfg.index.nlist, 32);
        assert_eq!(cfg.index.nprobe, 4);
        assert!(cfg.index.cosine);
        assert_eq!(cfg.index.scan_threads, 2);

        // Defaults: brute-force, dot product, auto-sized scan team.
        let d = ExperimentConfig::default();
        assert_eq!(d.index.kind, IndexKind::Brute);
        assert!(!d.index.cosine);
        assert_eq!(d.index.scan_threads, 0, "0 = available parallelism");

        assert_eq!(IndexKind::parse("brute-force").unwrap(), IndexKind::Brute);
        assert_eq!(IndexKind::parse("IVF").unwrap(), IndexKind::Ivf);
        assert!(IndexKind::parse("kdtree").is_err());

        let mut bad = ExperimentConfig::default();
        bad.index.nprobe = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn snapshot_section_parses() {
        let src = r#"
[snapshot]
path = "models/current.snap"
mmap = false
codec = "int8"
"#;
        let doc = TomlDoc::parse(src).unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.snapshot.path, "models/current.snap");
        assert!(!cfg.snapshot.mmap);
        assert_eq!(cfg.snapshot.codec, Codec::Int8);

        // Defaults: no path, mmap on, exact payloads.
        let d = ExperimentConfig::default();
        assert!(d.snapshot.path.is_empty());
        assert!(d.snapshot.mmap);
        assert_eq!(d.snapshot.codec, Codec::F32);

        // Bad codec is a config error at parse time.
        let bad = TomlDoc::parse("[snapshot]\ncodec = \"f64\"\n").unwrap();
        assert!(ExperimentConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn net_section_parses_and_validates() {
        let src = r#"
[net]
driver = "epoll"
handlers = 2
idle_timeout_ms = 5000
drain_ms = 500
"#;
        let doc = TomlDoc::parse(src).unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.net.driver, crate::net::NetDriver::Epoll);
        assert_eq!(cfg.net.handlers, 2);
        assert_eq!(cfg.net.idle_timeout_ms, 5000);
        assert_eq!(cfg.net.drain_ms, 500);
        assert_eq!(cfg.net.read_timeout_ms, NetConfig::default().read_timeout_ms);

        // Defaults: blocking threads driver.
        let d = ExperimentConfig::default();
        assert_eq!(d.net.driver, crate::net::NetDriver::Threads);

        let mut bad = ExperimentConfig::default();
        bad.net.handlers = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn obs_section_parses_and_validates() {
        let src = r#"
[obs]
enable = false
slow_log_len = 8
trace_sample = 0.25
trace_ring_len = 16
trace_slow_us = 5000
"#;
        let doc = TomlDoc::parse(src).unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert!(!cfg.obs.enable);
        assert_eq!(cfg.obs.slow_log_len, 8);
        assert_eq!(cfg.obs.stage_histograms, ObsConfig::default().stage_histograms);
        assert!((cfg.obs.trace_sample - 0.25).abs() < 1e-12);
        assert_eq!(cfg.obs.trace_ring_len, 16);
        assert_eq!(cfg.obs.trace_slow_us, 5000);

        // Defaults: metrics on, 32-entry slow ring, tracing off (sample 0)
        // with a 64-entry trace ring armed for propagated contexts.
        let d = ExperimentConfig::default();
        assert!(d.obs.enable);
        assert_eq!(d.obs.slow_log_len, 32);
        assert_eq!(d.obs.trace_sample, 0.0);
        assert_eq!(d.obs.trace_ring_len, 64);
        assert_eq!(d.obs.trace_slow_us, 100_000);

        let mut bad = ExperimentConfig::default();
        bad.obs.slow_log_len = (1 << 16) + 1;
        assert!(bad.validate().is_err());

        let mut bad = ExperimentConfig::default();
        bad.obs.trace_sample = 1.5;
        assert!(bad.validate().is_err());
        bad.obs.trace_sample = -0.1;
        assert!(bad.validate().is_err());

        let mut bad = ExperimentConfig::default();
        bad.obs.trace_ring_len = (1 << 16) + 1;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn validation_rejects_bad_order() {
        let mut cfg = ExperimentConfig::default();
        cfg.embedding.kind = EmbeddingKind::Word2Ket;
        cfg.embedding.order = 1;
        assert!(cfg.validate().is_err());
        cfg.embedding.order = 2;
        cfg.validate().unwrap();

        // The XS fast path caps order at 8 (fixed digit buffers).
        cfg.embedding.kind = EmbeddingKind::Word2KetXS;
        cfg.embedding.order = 9;
        cfg.model.emb_dim = 512; // q = 2, 2^9 = 512: would otherwise pass
        assert!(cfg.validate().is_err());
        cfg.embedding.order = 8;
        cfg.model.emb_dim = 256;
        cfg.validate().unwrap();
    }

    #[test]
    fn task_and_kind_parsing() {
        assert_eq!(TaskKind::parse("SQUAD").unwrap(), TaskKind::Qa);
        assert_eq!(TaskKind::parse("mt").unwrap(), TaskKind::Translation);
        assert!(TaskKind::parse("poetry").is_err());
        assert_eq!(EmbeddingKind::parse("W2K").unwrap(), EmbeddingKind::Word2Ket);
        assert_eq!(EmbeddingKind::parse("word2ketXS").unwrap(), EmbeddingKind::Word2KetXS);
    }
}
