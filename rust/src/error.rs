//! Unified error type for the library.
//!
//! Hand-rolled Display/Error impls: `thiserror` is not available in this
//! offline build environment.

use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    Shape(String),
    Config(String),
    Cli(String),
    Artifact(String),
    Runtime(String),
    Data(String),
    Checkpoint(String),
    Server(String),
    Snapshot(String),
    Json(crate::util::json::JsonError),
    Io(std::io::Error),
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Cli(m) => write!(f, "cli error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            Error::Checkpoint(m) => write!(f, "checkpoint error: {m}"),
            Error::Server(m) => write!(f, "server error: {m}"),
            Error::Snapshot(m) => write!(f, "snapshot error: {m}"),
            Error::Json(e) => write!(f, "json error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Json(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::Json(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
