//! Unified error type for the library.

use thiserror::Error;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Error, Debug)]
pub enum Error {
    #[error("shape error: {0}")]
    Shape(String),

    #[error("config error: {0}")]
    Config(String),

    #[error("cli error: {0}")]
    Cli(String),

    #[error("artifact error: {0}")]
    Artifact(String),

    #[error("runtime error: {0}")]
    Runtime(String),

    #[error("data error: {0}")]
    Data(String),

    #[error("checkpoint error: {0}")]
    Checkpoint(String),

    #[error("server error: {0}")]
    Server(String),

    #[error("json error: {0}")]
    Json(#[from] crate::util::json::JsonError),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
