//! Miniature property-based testing framework (proptest substitute).
//!
//! Runs a property over `n` seeded random cases; on failure, reports the
//! failing case index and seed so the case can be replayed deterministically
//! (`W2K_PROP_SEED=<seed> cargo test ...`). Shrinking is approximated by
//! retrying the failing generator with progressively "smaller" size hints.

use crate::util::Rng;

/// Context handed to each property case.
pub struct Cases {
    pub rng: Rng,
    /// Size hint in [1, max_size]; generators should scale dims with it.
    pub size: usize,
}

impl Cases {
    /// Vector of uniform f32 scaled by the case size.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        self.rng.uniform_vec(len, lo, hi)
    }

    /// Dimension in [lo, hi] influenced by size (bigger cases later).
    pub fn dim(&mut self, lo: usize, hi: usize) -> usize {
        let hi_scaled = lo + ((hi - lo) * self.size) / MAX_SIZE;
        self.rng.range(lo, hi_scaled.max(lo))
    }
}

const MAX_SIZE: usize = 100;

/// Configuration for a property run.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

const SEED_DEFAULT: u64 = 0x77326b_2020; // "w2k" 2020

impl Default for PropConfig {
    fn default() -> Self {
        let seed = std::env::var("W2K_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(SEED_DEFAULT);
        PropConfig { cases: 64, seed }
    }
}

/// Run `prop` over `cfg.cases` random cases. Panics with a replayable report
/// on the first failure.
pub fn check_with<F>(cfg: PropConfig, name: &str, mut prop: F)
where
    F: FnMut(&mut Cases) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut ctx = Cases {
            rng: Rng::new(case_seed),
            size: 1 + (case * MAX_SIZE) / cfg.cases.max(1),
        };
        if let Err(msg) = prop(&mut ctx) {
            panic!(
                "property '{name}' failed at case {case}/{} (replay: W2K_PROP_SEED={})\n  {msg}",
                cfg.cases, cfg.seed,
            );
        }
    }
}

/// Run with defaults (64 cases, env-overridable seed).
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Cases) -> Result<(), String>,
{
    check_with(PropConfig::default(), name, prop)
}

/// Assert helper for properties: `prop_assert!(cond, "msg {}", x)?`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Assert a text-protocol `STATS` line and a binary-protocol
/// [`WireStats`](crate::serving::WireStats) snapshot describe the same
/// numbers, field by field.
///
/// Both sides go through the one shared table
/// ([`wire::STATS_FIELD_NAMES`](crate::serving::wire::STATS_FIELD_NAMES) +
/// [`wire::format_stats_field`](crate::serving::wire::format_stats_field)):
/// the binary values are re-rendered with the same formatter the text
/// server uses and compared as strings, so the next field addition either
/// lands in both protocols or fails here. Extra text tokens (the cluster
/// router appends rollup extras) are tolerated; a *missing* field is not.
///
/// Fetch both views with no traffic in between — latency percentiles move
/// with load, and a request between the two fetches is a real difference,
/// not drift.
pub fn assert_stats_consistent(text_line: &str, binary: &crate::serving::WireStats) {
    use crate::serving::wire;
    let line = text_line.trim();
    let rest = line
        .strip_prefix("OK")
        .unwrap_or_else(|| panic!("STATS line must start with OK: {line:?}"));
    let mut text = std::collections::BTreeMap::new();
    for token in rest.split_whitespace() {
        let (k, v) = token
            .split_once('=')
            .unwrap_or_else(|| panic!("malformed STATS token {token:?} in {line:?}"));
        text.insert(k, v);
    }
    for (name, value) in wire::STATS_FIELD_NAMES.iter().zip(binary.fields()) {
        let got = text
            .get(name)
            .unwrap_or_else(|| panic!("text STATS is missing field '{name}': {line:?}"));
        let want = wire::format_stats_field(name, value);
        assert_eq!(
            *got, want,
            "STATS field '{name}' differs between protocols (text {got} vs binary {want})"
        );
    }
}

/// Approximate float equality helper returning a property error.
pub fn close(a: f32, b: f32, tol: f32) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_with(PropConfig { cases: 10, seed: 1 }, "trivial", |c| {
            count += 1;
            let v = c.vec_f32(3, 0.0, 1.0);
            prop_assert!(v.iter().all(|x| (0.0..1.0).contains(x)), "out of range");
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'failing' failed")]
    fn failing_property_reports() {
        check_with(PropConfig { cases: 5, seed: 2 }, "failing", |c| {
            let d = c.dim(1, 10);
            prop_assert!(d == 0, "dim was {d}");
            Ok(())
        });
    }

    #[test]
    fn stats_consistency_helper_accepts_matching_and_catches_drift() {
        use crate::serving::wire;
        let ws = crate::serving::WireStats {
            p50_us: 12.4,
            p99_us: 99.6,
            served: 7,
            cache_hits: 3,
            cache_misses: 4,
            rejected: 0,
            knn_queries: 2,
            knn_candidates: 150,
            knn_mean_probes: 2.5,
            model_generation: 3,
            snapshot_bytes: 4096,
            accept_errors: 1,
            simd_level: 2,
            payload_bits: 32,
        };
        // A line rendered through the shared table must pass, extra rollup
        // tokens included.
        let mut line = String::from("OK");
        for (name, value) in wire::STATS_FIELD_NAMES.iter().zip(ws.fields()) {
            line.push_str(&format!(" {name}={}", wire::format_stats_field(name, value)));
        }
        assert_stats_consistent(&line, &ws);
        line.push_str(" healthy_replicas=4");
        assert_stats_consistent(&line, &ws);

        // A drifted counter must be caught.
        let drifted = line.replace("served=7", "served=8");
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            assert_stats_consistent(&drifted, &ws);
        }));
        assert!(err.is_err(), "drifted served count went unnoticed");

        // A missing field must be caught even if everything present agrees.
        let missing = line.replace(" rejected=0", "");
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            assert_stats_consistent(&missing, &ws);
        }));
        assert!(err.is_err(), "missing field went unnoticed");
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-5).is_ok());
        assert!(close(1.0, 1.1, 1e-5).is_err());
    }

    #[test]
    fn sizes_grow() {
        let mut sizes = vec![];
        check_with(PropConfig { cases: 50, seed: 3 }, "sizes", |c| {
            sizes.push(c.size);
            Ok(())
        });
        assert!(sizes.first().unwrap() < sizes.last().unwrap());
    }
}
