//! Miniature property-based testing framework (proptest substitute).
//!
//! Runs a property over `n` seeded random cases; on failure, reports the
//! failing case index and seed so the case can be replayed deterministically
//! (`W2K_PROP_SEED=<seed> cargo test ...`). Shrinking is approximated by
//! retrying the failing generator with progressively "smaller" size hints.

use crate::util::Rng;

/// Context handed to each property case.
pub struct Cases {
    pub rng: Rng,
    /// Size hint in [1, max_size]; generators should scale dims with it.
    pub size: usize,
}

impl Cases {
    /// Vector of uniform f32 scaled by the case size.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        self.rng.uniform_vec(len, lo, hi)
    }

    /// Dimension in [lo, hi] influenced by size (bigger cases later).
    pub fn dim(&mut self, lo: usize, hi: usize) -> usize {
        let hi_scaled = lo + ((hi - lo) * self.size) / MAX_SIZE;
        self.rng.range(lo, hi_scaled.max(lo))
    }
}

const MAX_SIZE: usize = 100;

/// Configuration for a property run.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

const SEED_DEFAULT: u64 = 0x77326b_2020; // "w2k" 2020

impl Default for PropConfig {
    fn default() -> Self {
        let seed = std::env::var("W2K_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(SEED_DEFAULT);
        PropConfig { cases: 64, seed }
    }
}

/// Run `prop` over `cfg.cases` random cases. Panics with a replayable report
/// on the first failure.
pub fn check_with<F>(cfg: PropConfig, name: &str, mut prop: F)
where
    F: FnMut(&mut Cases) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut ctx = Cases {
            rng: Rng::new(case_seed),
            size: 1 + (case * MAX_SIZE) / cfg.cases.max(1),
        };
        if let Err(msg) = prop(&mut ctx) {
            panic!(
                "property '{name}' failed at case {case}/{} (replay: W2K_PROP_SEED={})\n  {msg}",
                cfg.cases, cfg.seed,
            );
        }
    }
}

/// Run with defaults (64 cases, env-overridable seed).
pub fn check<F>(name: &str, prop: F)
where
    F: FnMut(&mut Cases) -> Result<(), String>,
{
    check_with(PropConfig::default(), name, prop)
}

/// Assert helper for properties: `prop_assert!(cond, "msg {}", x)?`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate float equality helper returning a property error.
pub fn close(a: f32, b: f32, tol: f32) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_with(PropConfig { cases: 10, seed: 1 }, "trivial", |c| {
            count += 1;
            let v = c.vec_f32(3, 0.0, 1.0);
            prop_assert!(v.iter().all(|x| (0.0..1.0).contains(x)), "out of range");
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'failing' failed")]
    fn failing_property_reports() {
        check_with(PropConfig { cases: 5, seed: 2 }, "failing", |c| {
            let d = c.dim(1, 10);
            prop_assert!(d == 0, "dim was {d}");
            Ok(())
        });
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-7, 1e-5).is_ok());
        assert!(close(1.0, 1.1, 1e-5).is_err());
    }

    #[test]
    fn sizes_grow() {
        let mut sizes = vec![];
        check_with(PropConfig { cases: 50, seed: 3 }, "sizes", |c| {
            sizes.push(c.size);
            Ok(())
        });
        assert!(sizes.first().unwrap() < sizes.last().unwrap());
    }
}
