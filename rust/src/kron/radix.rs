//! Mixed-radix index codec.
//!
//! A row index `i` of `⊗_j A_j` decomposes into per-factor digits
//! `(i_1, …, i_n)` with radices `rows(A_j)`, most-significant first. This is
//! the addressing scheme behind the paper's lazy row reconstruction (§3.2)
//! and is shared by the Rust serving path and the manifest the Pallas kernel
//! consumes.

/// Positional codec for a fixed sequence of radices (most significant first).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MixedRadix {
    radices: Vec<usize>,
    /// weight[j] = product of radices after j.
    weights: Vec<usize>,
}

impl MixedRadix {
    pub fn new(radices: Vec<usize>) -> MixedRadix {
        assert!(!radices.is_empty(), "need at least one radix");
        assert!(radices.iter().all(|&r| r > 0), "radices must be positive");
        let n = radices.len();
        let mut weights = vec![1usize; n];
        for j in (0..n - 1).rev() {
            weights[j] = weights[j + 1] * radices[j + 1];
        }
        MixedRadix { radices, weights }
    }

    /// Uniform radix constructor: n digits of base t (capacity t^n).
    pub fn uniform(t: usize, n: usize) -> MixedRadix {
        MixedRadix::new(vec![t; n])
    }

    /// Total capacity = product of radices.
    pub fn capacity(&self) -> usize {
        self.weights[0] * self.radices[0]
    }

    pub fn num_digits(&self) -> usize {
        self.radices.len()
    }

    pub fn radices(&self) -> &[usize] {
        &self.radices
    }

    /// Decompose an index into digits (most significant first).
    pub fn decode(&self, mut i: usize) -> Vec<usize> {
        debug_assert!(i < self.capacity(), "index {} out of capacity {}", i, self.capacity());
        let mut digits = Vec::with_capacity(self.radices.len());
        for &w in &self.weights {
            digits.push(i / w);
            i %= w;
        }
        digits
    }

    /// Decode into a caller-provided buffer (allocation-free hot path).
    #[inline]
    pub fn decode_into(&self, mut i: usize, out: &mut [usize]) {
        debug_assert_eq!(out.len(), self.radices.len());
        for (d, &w) in out.iter_mut().zip(self.weights.iter()) {
            *d = i / w;
            i %= w;
        }
    }

    /// Recompose digits into an index.
    pub fn encode(&self, digits: &[usize]) -> usize {
        debug_assert_eq!(digits.len(), self.radices.len());
        debug_assert!(digits.iter().zip(self.radices.iter()).all(|(&d, &r)| d < r));
        digits.iter().zip(self.weights.iter()).map(|(&d, &w)| d * w).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn binary_decode() {
        let r = MixedRadix::uniform(2, 3);
        assert_eq!(r.capacity(), 8);
        assert_eq!(r.decode(0), vec![0, 0, 0]);
        assert_eq!(r.decode(5), vec![1, 0, 1]);
        assert_eq!(r.decode(7), vec![1, 1, 1]);
    }

    #[test]
    fn mixed_radices() {
        // radices [3, 2, 5]: weights [10, 5, 1], capacity 30
        let r = MixedRadix::new(vec![3, 2, 5]);
        assert_eq!(r.capacity(), 30);
        assert_eq!(r.decode(0), vec![0, 0, 0]);
        assert_eq!(r.decode(29), vec![2, 1, 4]);
        assert_eq!(r.decode(17), vec![1, 1, 2]);
    }

    #[test]
    fn roundtrip_exhaustive() {
        let r = MixedRadix::new(vec![4, 3, 2]);
        for i in 0..r.capacity() {
            assert_eq!(r.encode(&r.decode(i)), i);
        }
    }

    #[test]
    fn roundtrip_random_large() {
        let mut rng = Rng::new(9);
        let r = MixedRadix::uniform(19, 4); // SQuAD order-4 vocab codec: 19^4
        assert_eq!(r.capacity(), 130_321);
        for _ in 0..1000 {
            let i = rng.below(r.capacity());
            assert_eq!(r.encode(&r.decode(i)), i);
        }
    }

    #[test]
    fn decode_into_matches_decode() {
        let r = MixedRadix::new(vec![5, 7, 3]);
        let mut buf = [0usize; 3];
        for i in [0usize, 1, 52, 104] {
            r.decode_into(i, &mut buf);
            assert_eq!(buf.to_vec(), r.decode(i));
        }
    }
}
