//! Kronecker / tensor-product algebra (§2–§3 of the paper).
//!
//! This module implements, in pure Rust:
//!  * dense Kronecker products of vectors and matrices,
//!  * the mixed-radix index codec behind *lazy* Kronecker row access
//!    (`(A ⊗ B)_{ij} = a_{⌊i/p⌋,⌊j/q⌋} · b_{i mod p, j mod q}`, §3.2),
//!  * CP-format tensors `v = Σ_{k=1..r} ⊗_{j=1..n} v_jk` (eq. 3) with the
//!    balanced product tree of Fig. 1 and the factored inner product of §2.3.
//!
//! The same algebra is implemented as Pallas kernels on the compute path
//! (python/compile/kernels); this Rust mirror powers the serving path,
//! baselines, parameter accounting, and acts as an independent oracle for the
//! kernel tests.

mod cp;
mod radix;

pub use cp::CpTensor;
pub(crate) use cp::tree_term;
pub use radix::MixedRadix;

use crate::tensor::Tensor;

/// Dense Kronecker product of two vectors: `out[i*|b| + j] = a[i] * b[j]`.
pub fn kron_vec(a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(a.len() * b.len());
    for &x in a {
        if x == 0.0 {
            out.extend(std::iter::repeat(0.0).take(b.len()));
        } else {
            out.extend(b.iter().map(|&y| x * y));
        }
    }
    out
}

/// Dense Kronecker product of a chain of vectors, left-associated.
pub fn kron_chain(vs: &[&[f32]]) -> Vec<f32> {
    assert!(!vs.is_empty());
    let mut acc: Vec<f32> = vs[0].to_vec();
    for v in &vs[1..] {
        acc = kron_vec(&acc, v);
    }
    acc
}

/// Dense Kronecker product of a chain of vectors using the *balanced tree*
/// arrangement of Fig. 1: pairs are combined level by level. Produces the same
/// vector as [`kron_chain`] (tensor product is associative) but with
/// `O(log n)` sequential depth.
pub fn kron_tree(vs: &[&[f32]]) -> Vec<f32> {
    assert!(!vs.is_empty());
    let mut level: Vec<Vec<f32>> = vs.iter().map(|v| v.to_vec()).collect();
    while level.len() > 1 {
        let mut next = Vec::with_capacity((level.len() + 1) / 2);
        let mut it = level.chunks(2);
        while let Some(pair) = it.next() {
            if pair.len() == 2 {
                next.push(kron_vec(&pair[0], &pair[1]));
            } else {
                next.push(pair[0].clone());
            }
        }
        level = next;
    }
    level.pop().unwrap()
}

/// Reusable scratch buffers for allocation-free Kronecker accumulation
/// (the serving hot path; see `Word2KetXS::lookup_into`).
#[derive(Debug, Default)]
pub struct KronScratch {
    a: Vec<f32>,
    b: Vec<f32>,
}

impl KronScratch {
    pub fn new() -> KronScratch {
        KronScratch::default()
    }
}

/// `acc += ⊗_j parts[j]` without allocating (beyond scratch growth).
///
/// `acc` may be *shorter* than the full `Π|parts_j|` product — only the
/// prefix is accumulated (word2ketXS truncates `q^n ≥ p` to `p`). The chain
/// prefix `⊗ parts[..n-1]` is built by ping-ponging between the two scratch
/// buffers; the final level is fused into the accumulation so the full-width
/// term vector is never materialized (the Rust mirror of the kernel-side
/// rank-sum fusion, DESIGN.md §Hardware-Adaptation).
pub fn kron_accumulate(parts: &[&[f32]], acc: &mut [f32], s: &mut KronScratch) {
    match parts.len() {
        0 => {}
        1 => {
            debug_assert!(acc.len() <= parts[0].len());
            crate::repr::kernels::add_assign(acc, parts[0]);
        }
        _ => {
            let last = parts[parts.len() - 1];
            s.a.clear();
            s.a.extend_from_slice(parts[0]);
            for p in &parts[1..parts.len() - 1] {
                s.b.clear();
                s.b.reserve(s.a.len() * p.len());
                for &x in &s.a {
                    if x == 0.0 {
                        s.b.extend(std::iter::repeat(0.0).take(p.len()));
                    } else {
                        s.b.extend(p.iter().map(|&y| x * y));
                    }
                }
                std::mem::swap(&mut s.a, &mut s.b);
            }
            debug_assert!(acc.len() <= s.a.len() * last.len());
            crate::repr::kernels::kron2_accumulate(&s.a, last, acc);
        }
    }
}

/// Dense Kronecker product of two matrices, shapes (m×n) ⊗ (p×q) → (mp×nq).
pub fn kron_mat(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let (p, q) = (b.shape()[0], b.shape()[1]);
    let mut out = Tensor::zeros(vec![m * p, n * q]);
    for i in 0..m {
        for j in 0..n {
            let aij = a.at2(i, j);
            if aij == 0.0 {
                continue;
            }
            for k in 0..p {
                for l in 0..q {
                    out.set2(i * p + k, j * q + l, aij * b.at2(k, l));
                }
            }
        }
    }
    out
}

/// Lazily evaluated single entry of `⊗_j A_j` (matrices), without
/// materializing anything (§3.2 lazy-tensor identity, generalized to order n).
///
/// `factors` are the matrices `A_1 .. A_n`; the full operator has
/// `Π rows(A_j)` rows and `Π cols(A_j)` columns.
pub fn kron_entry(factors: &[&Tensor], mut i: usize, mut j: usize) -> f32 {
    // Decompose (i, j) into per-factor (i_k, j_k) digits, most significant
    // digit first (factor 0 is the most significant block).
    let mut prod = 1.0f32;
    // Compute digit weights right-to-left.
    for f in factors.iter().rev() {
        let (r, c) = (f.shape()[0], f.shape()[1]);
        let (di, dj) = (i % r, j % c);
        i /= r;
        j /= c;
        prod *= f.at2(di, dj);
        if prod == 0.0 {
            return 0.0;
        }
    }
    prod
}

/// Lazily reconstruct row `i` of `⊗_j A_j` — touches only one row of each
/// factor (this is the key word2ketXS serving primitive). Output length is
/// `Π cols(A_j)`.
pub fn kron_row(factors: &[&Tensor], i: usize) -> Vec<f32> {
    let radix = MixedRadix::new(factors.iter().map(|f| f.shape()[0]).collect());
    let digits = radix.decode(i);
    let rows: Vec<&[f32]> = factors
        .iter()
        .zip(digits.iter())
        .map(|(f, &d)| f.row(d))
        .collect();
    kron_tree(&rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn kron_vec_known() {
        let a = [1.0, 2.0];
        let b = [3.0, 4.0];
        assert_eq!(kron_vec(&a, &b), vec![3.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn kron_chain_and_tree_agree() {
        let mut rng = Rng::new(1);
        for n in 1..=5 {
            let vs: Vec<Vec<f32>> = (0..n).map(|_| rng.uniform_vec(4, -1.0, 1.0)).collect();
            let refs: Vec<&[f32]> = vs.iter().map(|v| v.as_slice()).collect();
            let chain = kron_chain(&refs);
            let tree = kron_tree(&refs);
            assert_eq!(chain.len(), tree.len());
            for (a, b) in chain.iter().zip(tree.iter()) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn kron_bilinearity() {
        // (u+v) ⊗ w == u⊗w + v⊗w
        let u = [1.0f32, -2.0];
        let v = [0.5f32, 3.0];
        let w = [2.0f32, 0.0, 1.0];
        let lhs = kron_vec(&[u[0] + v[0], u[1] + v[1]], &w);
        let uw = kron_vec(&u, &w);
        let vw = kron_vec(&v, &w);
        for k in 0..lhs.len() {
            assert!((lhs[k] - (uw[k] + vw[k])).abs() < 1e-6);
        }
    }

    #[test]
    fn kron_norm_is_product_of_norms() {
        // ‖v ⊗ w‖ = ‖v‖·‖w‖ (paper §2.1)
        let mut rng = Rng::new(2);
        let v = rng.uniform_vec(8, -1.0, 1.0);
        let w = rng.uniform_vec(5, -1.0, 1.0);
        let vw = kron_vec(&v, &w);
        let nv: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nw: f32 = w.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nvw: f32 = vw.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((nvw - nv * nw).abs() < 1e-4);
    }

    #[test]
    fn kron_mat_known_blocks() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(vec![2, 2], vec![0., 5., 6., 7.]).unwrap();
        let k = kron_mat(&a, &b);
        assert_eq!(k.shape(), &[4, 4]);
        // top-left block = 1*B
        assert_eq!(k.at2(0, 1), 5.0);
        assert_eq!(k.at2(1, 0), 6.0);
        // top-right block = 2*B
        assert_eq!(k.at2(0, 3), 10.0);
        // bottom-right block = 4*B
        assert_eq!(k.at2(3, 3), 28.0);
    }

    #[test]
    fn kron_entry_and_row_match_chain_tree_oracles() {
        // Randomized factor shapes — including order 1 and non-square
        // factors — with the dense chain/tree materializations as oracles
        // for both lazy accessors.
        let mut rng = Rng::new(41);
        for case in 0..12usize {
            let order = 1 + case % 3;
            let factors: Vec<Tensor> = (0..order)
                .map(|_| {
                    let r = rng.range(1, 4);
                    let c = rng.range(1, 4);
                    Tensor::new(vec![r, c], rng.uniform_vec(r * c, -1.0, 1.0)).unwrap()
                })
                .collect();
            let refs: Vec<&Tensor> = factors.iter().collect();
            let rows: usize = factors.iter().map(|f| f.shape()[0]).product();
            let cols: usize = factors.iter().map(|f| f.shape()[1]).product();
            let radix = MixedRadix::new(factors.iter().map(|f| f.shape()[0]).collect());
            for i in 0..rows {
                let digits = radix.decode(i);
                let factor_rows: Vec<&[f32]> =
                    refs.iter().zip(&digits).map(|(f, &d)| f.row(d)).collect();
                let chain = kron_chain(&factor_rows);
                let tree = kron_tree(&factor_rows);
                let lazy = kron_row(&refs, i);
                assert_eq!(lazy.len(), cols, "case {case} row {i}");
                for j in 0..cols {
                    assert!(
                        (chain[j] - tree[j]).abs() < 1e-5,
                        "case {case} ({i},{j}): chain {} vs tree {}",
                        chain[j],
                        tree[j]
                    );
                    assert!(
                        (lazy[j] - chain[j]).abs() < 1e-5,
                        "case {case} ({i},{j}): kron_row {} vs chain {}",
                        lazy[j],
                        chain[j]
                    );
                    let entry = kron_entry(&refs, i, j);
                    assert!(
                        (entry - chain[j]).abs() < 1e-5,
                        "case {case} ({i},{j}): kron_entry {entry} vs chain {}",
                        chain[j]
                    );
                }
            }
        }
    }

    #[test]
    fn kron_entry_matches_dense() {
        let mut rng = Rng::new(3);
        let a = Tensor::new(vec![2, 3], rng.uniform_vec(6, -1.0, 1.0)).unwrap();
        let b = Tensor::new(vec![3, 2], rng.uniform_vec(6, -1.0, 1.0)).unwrap();
        let c = Tensor::new(vec![2, 2], rng.uniform_vec(4, -1.0, 1.0)).unwrap();
        let dense = kron_mat(&kron_mat(&a, &b), &c);
        let factors = [&a, &b, &c];
        for i in 0..dense.shape()[0] {
            for j in 0..dense.shape()[1] {
                let lazy = kron_entry(&factors, i, j);
                assert!(
                    (lazy - dense.at2(i, j)).abs() < 1e-5,
                    "entry ({i},{j}): {lazy} vs {}",
                    dense.at2(i, j)
                );
            }
        }
    }

    #[test]
    fn kron_row_matches_dense() {
        let mut rng = Rng::new(4);
        let a = Tensor::new(vec![3, 2], rng.uniform_vec(6, -1.0, 1.0)).unwrap();
        let b = Tensor::new(vec![2, 4], rng.uniform_vec(8, -1.0, 1.0)).unwrap();
        let dense = kron_mat(&a, &b);
        for i in 0..6 {
            let lazy = kron_row(&[&a, &b], i);
            assert_eq!(lazy.len(), 8);
            for j in 0..8 {
                assert!((lazy[j] - dense.at2(i, j)).abs() < 1e-5);
            }
        }
    }
}
