//! CP-format (canonical polyadic) tensors: `v = Σ_{k=1..r} ⊗_{j=1..n} v_jk`
//! (paper eq. 3). A rank-`r`, order-`n` tensor over leaf dimension `q`
//! represents a vector of dimension `q^n` using only `r·n·q` parameters.

use super::kron_vec;
#[cfg(test)]
use super::kron_tree;
use crate::tensor::layernorm_slices;
use crate::util::Rng;

/// Balanced-tree Kronecker product of one rank term's leaves (Fig. 1),
/// optionally LayerNorm-ing every internal node. Shared by
/// [`CpTensor::reconstruct`] and the snapshot store's mapped reconstruction
/// so both produce bit-identical rows from the same leaves.
pub(crate) fn tree_term(leaves: &[&[f32]], layernorm: bool) -> Vec<f32> {
    let mut level: Vec<Vec<f32>> = leaves.iter().map(|l| l.to_vec()).collect();
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        for pair in level.chunks(2) {
            if pair.len() == 2 {
                let mut node = kron_vec(&pair[0], &pair[1]);
                if layernorm {
                    let w = node.len();
                    node = layernorm_slices(&node, w).expect("layernorm node");
                }
                next.push(node);
            } else {
                next.push(pair[0].clone());
            }
        }
        level = next;
    }
    level.pop().unwrap()
}

/// A single entangled-tensor vector in CP format.
///
/// Leaves are stored as `factors[k][j]` = `v_{j,k}` ∈ R^q for rank index `k`
/// and order index `j`. All leaves share the dimension `q` (the paper uses
/// uniform leaf dimensions; `q ≥ 4` per §2.3).
#[derive(Debug, Clone, PartialEq)]
pub struct CpTensor {
    rank: usize,
    order: usize,
    leaf_dim: usize,
    /// Flattened leaves: `leaves[(k * order + j) * leaf_dim ..][..leaf_dim]`.
    leaves: Vec<f32>,
    /// Apply LayerNorm at internal tree nodes during reconstruction (§2.3:
    /// "at each node in the balanced tensor product tree we use LayerNorm").
    pub layernorm_nodes: bool,
}

impl CpTensor {
    pub fn zeros(rank: usize, order: usize, leaf_dim: usize) -> CpTensor {
        assert!(rank >= 1 && order >= 1 && leaf_dim >= 1);
        CpTensor {
            rank,
            order,
            leaf_dim,
            leaves: vec![0.0; rank * order * leaf_dim],
            layernorm_nodes: false,
        }
    }

    /// Random init: leaves ~ U(-a, a) with `a = (1/q)^{1/n}`-ish scaling so the
    /// reconstructed vector has O(1) component scale after n-fold products.
    pub fn random(rank: usize, order: usize, leaf_dim: usize, rng: &mut Rng) -> CpTensor {
        let mut t = CpTensor::zeros(rank, order, leaf_dim);
        // Each output component is a sum over r of products of n leaf entries.
        // For the product to have unit-ish scale, each leaf entry should scale
        // like (1/sqrt(q r^{1/n}))^... — we use the simpler heuristic
        // a = (3 / (q * r^(1/n)))^(1/2) per-leaf bound behaving well in practice.
        let a = (3.0 / (leaf_dim as f32 * (rank as f32).powf(1.0 / order as f32))).sqrt();
        for x in t.leaves.iter_mut() {
            *x = rng.uniform(-a, a);
        }
        t
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn order(&self) -> usize {
        self.order
    }

    pub fn leaf_dim(&self) -> usize {
        self.leaf_dim
    }

    /// Dimension of the represented vector: `q^n`.
    pub fn dim(&self) -> usize {
        self.leaf_dim.pow(self.order as u32)
    }

    /// Number of trainable parameters: `r·n·q`.
    pub fn num_params(&self) -> usize {
        self.leaves.len()
    }

    /// Leaf `v_{j,k}` as a slice.
    pub fn leaf(&self, k: usize, j: usize) -> &[f32] {
        let off = (k * self.order + j) * self.leaf_dim;
        &self.leaves[off..off + self.leaf_dim]
    }

    pub fn leaf_mut(&mut self, k: usize, j: usize) -> &mut [f32] {
        let off = (k * self.order + j) * self.leaf_dim;
        &mut self.leaves[off..off + self.leaf_dim]
    }

    pub fn leaves(&self) -> &[f32] {
        &self.leaves
    }

    pub fn leaves_mut(&mut self) -> &mut [f32] {
        &mut self.leaves
    }

    /// Reconstruct the dense `q^n`-dimensional vector, summing rank terms.
    ///
    /// Uses the balanced tree of Fig. 1; if `layernorm_nodes` is set, every
    /// internal tree node output is LayerNorm-ed (matching the training-time
    /// architecture; off by default for pure algebra uses).
    pub fn reconstruct(&self) -> Vec<f32> {
        // Perf note (EXPERIMENTS.md §Perf): a fused chain-accumulate variant
        // was tried here and measured *slower* than the balanced tree on
        // x86 (the 16-wide final tree level vectorizes better than the
        // 4-wide fused tail), so the tree path stays.
        let mut out = vec![0.0f32; self.dim()];
        for k in 0..self.rank {
            let term = self.reconstruct_term(k);
            for (o, t) in out.iter_mut().zip(term.iter()) {
                *o += t;
            }
        }
        out
    }

    /// Reconstruct a single rank term ⊗_j v_jk via the balanced tree.
    fn reconstruct_term(&self, k: usize) -> Vec<f32> {
        let leaves: Vec<&[f32]> = (0..self.order).map(|j| self.leaf(k, j)).collect();
        tree_term(&leaves, self.layernorm_nodes)
    }

    /// Factored inner product (§2.3):
    /// `⟨v, w⟩ = Σ_{k,k'} Π_j ⟨v_jk, w_jk'⟩` — `O(r² n q)` time, `O(1)` space,
    /// never materializing the `q^n` vectors. Requires identical (order, q)
    /// and no LayerNorm (the identity only holds for the raw CP form).
    pub fn inner(&self, other: &CpTensor) -> f32 {
        assert_eq!(self.order, other.order);
        assert_eq!(self.leaf_dim, other.leaf_dim);
        assert!(
            !self.layernorm_nodes && !other.layernorm_nodes,
            "factored inner product requires raw CP form"
        );
        crate::repr::kernels::rank_pair_sum(self.rank, other.rank, |k, k2| {
            crate::repr::kernels::product_of_dots(
                (0..self.order).map(|j| (self.leaf(k, j), other.leaf(k2, j))),
            )
        })
    }

    /// Squared L2 norm via the factored inner product.
    pub fn norm_sq(&self) -> f32 {
        self.inner(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dims_and_param_counts() {
        // Fig. 1 example: 256-dim vector as rank 5, order 4 over q=4 → 20
        // leaves of 4 numbers = 80 parameters.
        let t = CpTensor::zeros(5, 4, 4);
        assert_eq!(t.dim(), 256);
        assert_eq!(t.num_params(), 80);
    }

    #[test]
    fn rank1_reconstruct_equals_kron_chain() {
        let mut rng = Rng::new(10);
        let t = CpTensor::random(1, 3, 4, &mut rng);
        let chain = kron_tree(&[t.leaf(0, 0), t.leaf(0, 1), t.leaf(0, 2)]);
        let rec = t.reconstruct();
        assert_eq!(rec.len(), 64);
        for (a, b) in rec.iter().zip(chain.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rank_sums_add() {
        let mut rng = Rng::new(11);
        let t = CpTensor::random(3, 2, 5, &mut rng);
        // Manually sum the three rank-1 reconstructions.
        let mut manual = vec![0.0f32; t.dim()];
        for k in 0..3 {
            let term = kron_vec(t.leaf(k, 0), t.leaf(k, 1));
            for (m, x) in manual.iter_mut().zip(term.iter()) {
                *m += x;
            }
        }
        let rec = t.reconstruct();
        for (a, b) in rec.iter().zip(manual.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn factored_inner_matches_dense() {
        let mut rng = Rng::new(12);
        for (r1, r2, n, q) in [(1, 1, 2, 4), (2, 3, 3, 4), (5, 2, 4, 3)] {
            let a = CpTensor::random(r1, n, q, &mut rng);
            let b = CpTensor::random(r2, n, q, &mut rng);
            let dense: f32 = a
                .reconstruct()
                .iter()
                .zip(b.reconstruct().iter())
                .map(|(x, y)| x * y)
                .sum();
            let fast = a.inner(&b);
            assert!(
                (dense - fast).abs() < 1e-3 * dense.abs().max(1.0),
                "r={r1}/{r2} n={n} q={q}: {dense} vs {fast}"
            );
        }
    }

    #[test]
    fn norm_sq_nonnegative() {
        let mut rng = Rng::new(13);
        for _ in 0..10 {
            let t = CpTensor::random(3, 3, 4, &mut rng);
            assert!(t.norm_sq() >= -1e-4);
        }
    }

    #[test]
    fn entangled_rank2_not_representable_as_rank1() {
        // The Bell-state-like tensor (ψ0⊗φ0 + ψ1⊗φ1)/√2 of §2.2 has rank 2:
        // verify our rank-2 reconstruction produces it, and that it cannot be
        // written as an outer product (determinant test for order 2).
        let mut t = CpTensor::zeros(2, 2, 2);
        let s = 1.0 / 2.0f32.sqrt();
        t.leaf_mut(0, 0).copy_from_slice(&[s, 0.0]);
        t.leaf_mut(0, 1).copy_from_slice(&[1.0, 0.0]);
        t.leaf_mut(1, 0).copy_from_slice(&[0.0, s]);
        t.leaf_mut(1, 1).copy_from_slice(&[0.0, 1.0]);
        let v = t.reconstruct(); // [s, 0, 0, s] viewed as 2x2 matrix = s·I
        assert!((v[0] - s).abs() < 1e-6 && (v[3] - s).abs() < 1e-6);
        assert!(v[1].abs() < 1e-6 && v[2].abs() < 1e-6);
        // Rank-1 order-2 tensors have zero "determinant" v00*v11 - v01*v10.
        let det = v[0] * v[3] - v[1] * v[2];
        assert!(det.abs() > 0.4, "entangled tensor must have nonzero det");
    }

    #[test]
    fn layernorm_nodes_change_scale_only_sanely() {
        let mut rng = Rng::new(14);
        let mut t = CpTensor::random(2, 4, 4, &mut rng);
        let raw = t.reconstruct();
        t.layernorm_nodes = true;
        let ln = t.reconstruct();
        assert_eq!(raw.len(), ln.len());
        // LayerNorm-ed reconstruction is finite and non-degenerate.
        assert!(ln.iter().all(|x| x.is_finite()));
        let norm: f32 = ln.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(norm > 1e-3);
    }
}
