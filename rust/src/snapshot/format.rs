//! On-disk snapshot container: layout constants, CRC32, f16/int8 codecs,
//! and the writer.
//!
//! ## File layout (all integers/floats little-endian)
//!
//! ```text
//! 0x00  magic        8 bytes  "W2KSNAP1"
//! 0x08  version      u32
//! 0x0c  kind         u32      store kind tag (see [`StoreKind`])
//! 0x10  vocab        u64
//! 0x18  dim          u64
//! 0x20  order        u32
//! 0x24  rank         u32
//! 0x28  flags        u32      bit 0 layernorm, bit 1 has-index, bit 2
//!                             cosine, bit 3 has-norms
//! 0x2c  n_sections   u32
//! 0x30  meta         6 × u64  kind-specific (leaf dims, bits, seeds, nlist)
//! 0x60  header_crc   u32      CRC32 over bytes 0x00..0x60
//! 0x64  section table: n_sections × 44-byte entries
//!       id u32, dtype u32, count u64, chunk u64, offset u64, byte_len u64,
//!       crc u32
//! ....  payloads, each 8-byte aligned, CRC32-checksummed independently
//! ```
//!
//! Payload encodings per [`Dtype`]:
//! * `F32` — `count × 4` bytes, raw little-endian f32 (zero-copy view on
//!   load).
//! * `F16` — `count × 2` bytes, IEEE half precision (Word2Bits-style
//!   mantissa trade; decoded on access).
//! * `I8`  — `n_chunks × 4` bytes of per-chunk f32 scales followed by
//!   `count` symmetric int8 codes (`value = code · scale`, scale =
//!   max-abs/127 per `chunk` elements — one chunk per factor/row so a single
//!   outlier cannot wreck the whole tensor's precision).
//! * `U32` — `count × 4` bytes (bit-packed quantized codes, IVF id lists).

use crate::error::{Error, Result};
use std::path::Path;

/// File magic: identifies a word2ket snapshot, version baked into the tag.
pub const MAGIC: [u8; 8] = *b"W2KSNAP1";

/// Format version; bumped on incompatible layout changes.
pub const VERSION: u32 = 1;

/// Fixed header size in bytes (magic through `header_crc`).
pub const HEADER_BYTES: usize = 0x64;

/// Encoded size of one section-table entry.
pub const SECTION_ENTRY_BYTES: usize = 44;

/// Upper bound on the section count (a valid snapshot uses at most a
/// handful; a corrupt header must not drive a huge table allocation).
pub const MAX_SECTIONS: u32 = 64;

/// `flags` bit 0: LayerNorm applied at CP tree nodes (word2ket only).
pub const FLAG_LAYERNORM: u32 = 1;
/// `flags` bit 1: the snapshot embeds serialized IVF centroids/lists.
pub const FLAG_HAS_INDEX: u32 = 1 << 1;
/// `flags` bit 2: the embedded IVF index was built for cosine ranking.
pub const FLAG_INDEX_COSINE: u32 = 1 << 2;
/// `flags` bit 3: the snapshot embeds per-word L2 norms
/// ([`SEC_NORMS`], one f32 per vocabulary entry), letting a cosine-mode
/// scorer skip its construction-time norm pass after a load/hot-swap.
/// Readers older than this flag ignore both the bit and the section —
/// the section registry tolerates unknown ids — so the format version
/// stays unchanged; this flag *is* the gate.
pub const FLAG_HAS_NORMS: u32 = 1 << 3;
/// `flags` bit 4: the snapshot is one shard of a sharded vocabulary and
/// carries a [`SEC_SHARD_RANGE`] section describing which slice of the
/// global id space it owns (see [`ShardRange`]). Same compatibility story
/// as [`FLAG_HAS_NORMS`]: older readers ignore bit and section.
pub const FLAG_HAS_SHARD_RANGE: u32 = 1 << 4;

// Section ids (fixed registry; unknown ids are ignored on load so future
// versions can add sections without breaking old readers).
pub const SEC_REGULAR_DATA: u32 = 1;
pub const SEC_W2K_LEAVES: u32 = 2;
pub const SEC_XS_FACTORS: u32 = 3;
pub const SEC_QUANT_CODES: u32 = 4;
pub const SEC_QUANT_SCALES: u32 = 5;
pub const SEC_QUANT_OFFSETS: u32 = 6;
pub const SEC_LOWRANK_U: u32 = 7;
pub const SEC_LOWRANK_VT: u32 = 8;
pub const SEC_HASHED_WEIGHTS: u32 = 9;
pub const SEC_IVF_CENTROIDS: u32 = 10;
pub const SEC_IVF_LIST_LENS: u32 = 11;
pub const SEC_IVF_LIST_IDS: u32 = 12;
/// Optional per-word L2 norms (always f32-exact; see [`FLAG_HAS_NORMS`]).
pub const SEC_NORMS: u32 = 13;
/// Optional shard-assignment metadata (see [`ShardRange`] /
/// [`FLAG_HAS_SHARD_RANGE`]): which slice of a sharded global vocabulary
/// this snapshot's local ids map to.
pub const SEC_SHARD_RANGE: u32 = 14;
/// Quantized-ket bit-packed leaf codes (U32; see [`crate::quant`] for the
/// packing). One `⌈q·bits/32⌉`-word block per leaf, leaves in
/// word-major/rank-major/position order.
pub const SEC_QKET_CODES: u32 = 15;
/// Quantized-ket per-leaf dequantization scales (F32, one per leaf).
pub const SEC_QKET_SCALES: u32 = 16;

/// Human-readable section name for `snapshot info`.
pub fn section_name(id: u32) -> &'static str {
    match id {
        SEC_REGULAR_DATA => "regular.data",
        SEC_W2K_LEAVES => "word2ket.leaves",
        SEC_XS_FACTORS => "word2ketxs.factors",
        SEC_QUANT_CODES => "quantized.codes",
        SEC_QUANT_SCALES => "quantized.scales",
        SEC_QUANT_OFFSETS => "quantized.offsets",
        SEC_LOWRANK_U => "lowrank.u",
        SEC_LOWRANK_VT => "lowrank.vt",
        SEC_HASHED_WEIGHTS => "hashed.weights",
        SEC_IVF_CENTROIDS => "ivf.centroids",
        SEC_IVF_LIST_LENS => "ivf.list_lens",
        SEC_IVF_LIST_IDS => "ivf.list_ids",
        SEC_NORMS => "norms",
        SEC_SHARD_RANGE => "shard_range",
        SEC_QKET_CODES => "quantized_ket.codes",
        SEC_QKET_SCALES => "quantized_ket.scales",
        _ => "unknown",
    }
}

// Meta slot assignments (header `meta: [u64; 6]`).
/// word2ket: leaf dimension q. word2ketXS: leaf q. quantized_ket: leaf q.
pub const META_Q: usize = 0;
/// word2ketXS: leaf t. hashed: seed. quantized_ket: code bits.
pub const META_T_OR_SEED: usize = 1;
/// quantized: bits. lowrank: k. hashed: buckets (also meta[0] for those
/// kinds — each kind owns slot 0 for its primary hyper-parameter).
pub const META_PRIMARY: usize = 0;
/// IVF: nlist (only meaningful with [`FLAG_HAS_INDEX`]).
pub const META_IVF_NLIST: usize = 4;

/// Which concrete store a snapshot holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreKind {
    Regular,
    Word2Ket,
    Word2KetXS,
    Quantized,
    LowRank,
    Hashed,
    /// Sub-byte quantized word2ket factors plus f16 refinement leaves
    /// (see [`crate::quant::QuantizedKet`]).
    QuantizedKet,
}

impl StoreKind {
    pub fn tag(&self) -> u32 {
        match self {
            StoreKind::Regular => 0,
            StoreKind::Word2Ket => 1,
            StoreKind::Word2KetXS => 2,
            StoreKind::Quantized => 3,
            StoreKind::LowRank => 4,
            StoreKind::Hashed => 5,
            StoreKind::QuantizedKet => 6,
        }
    }

    pub fn from_tag(tag: u32) -> Result<StoreKind> {
        Ok(match tag {
            0 => StoreKind::Regular,
            1 => StoreKind::Word2Ket,
            2 => StoreKind::Word2KetXS,
            3 => StoreKind::Quantized,
            4 => StoreKind::LowRank,
            5 => StoreKind::Hashed,
            6 => StoreKind::QuantizedKet,
            other => return Err(Error::Snapshot(format!("unknown store kind tag {other}"))),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            StoreKind::Regular => "regular",
            StoreKind::Word2Ket => "word2ket",
            StoreKind::Word2KetXS => "word2ketXS",
            StoreKind::Quantized => "quantized",
            StoreKind::LowRank => "lowrank",
            StoreKind::Hashed => "hashed",
            StoreKind::QuantizedKet => "quantized_ket",
        }
    }
}

/// Payload element encoding of one section.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F16,
    I8,
    U32,
}

impl Dtype {
    pub fn tag(&self) -> u32 {
        match self {
            Dtype::F32 => 0,
            Dtype::F16 => 1,
            Dtype::I8 => 2,
            Dtype::U32 => 3,
        }
    }

    pub fn from_tag(tag: u32) -> Result<Dtype> {
        Ok(match tag {
            0 => Dtype::F32,
            1 => Dtype::F16,
            2 => Dtype::I8,
            3 => Dtype::U32,
            other => return Err(Error::Snapshot(format!("unknown dtype tag {other}"))),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F16 => "f16",
            Dtype::I8 => "i8",
            Dtype::U32 => "u32",
        }
    }
}

/// How float payloads are written (`[snapshot] codec` / `--payload`).
///
/// `F32`/`F16`/`Int8` re-encode each float section element-wise and keep
/// the snapshot's store kind. The sub-byte codecs (`Int4`/`B2`/`B1`) are
/// only meaningful for word2ket stores: saving converts the store into a
/// [`StoreKind::QuantizedKet`] snapshot whose factors live in the
/// quantized domain (bit-packed codes + per-leaf scales + f16 refinement
/// leaves; see [`crate::quant`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Codec {
    /// Exact 32-bit floats (bit-exact round trip).
    #[default]
    F32,
    /// IEEE half precision: 2× smaller, ~1e-3 relative error.
    F16,
    /// Symmetric per-chunk int8: 4× smaller, ~1e-2 relative error.
    Int8,
    /// Symmetric per-leaf int4 factor codes (word2ket → quantized_ket).
    Int4,
    /// 2-bit odd-level factor codes {-3,-1,+1,+3}·scale (word2ket only).
    B2,
    /// 1-bit sign factor codes ±scale (word2ket only).
    B1,
}

impl Codec {
    pub fn parse(s: &str) -> Result<Codec> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "none" | "exact" => Ok(Codec::F32),
            "f16" | "half" => Ok(Codec::F16),
            "int8" | "i8" => Ok(Codec::Int8),
            "int4" | "i4" => Ok(Codec::Int4),
            "b2" | "2bit" => Ok(Codec::B2),
            "b1" | "1bit" => Ok(Codec::B1),
            other => Err(Error::Config(format!(
                "unknown snapshot codec '{other}' (expected f32|f16|int8|int4|b2|b1)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Codec::F32 => "f32",
            Codec::F16 => "f16",
            Codec::Int8 => "int8",
            Codec::Int4 => "int4",
            Codec::B2 => "b2",
            Codec::B1 => "b1",
        }
    }

    /// Bits per stored factor value under this codec.
    pub fn bits(&self) -> usize {
        match self {
            Codec::F32 => 32,
            Codec::F16 => 16,
            Codec::Int8 => 8,
            Codec::Int4 => 4,
            Codec::B2 => 2,
            Codec::B1 => 1,
        }
    }

    /// True for the codecs that force a word2ket store into the
    /// quantized-ket snapshot layout instead of element-wise re-encoding.
    pub fn is_sub_byte(&self) -> bool {
        matches!(self, Codec::Int4 | Codec::B2 | Codec::B1)
    }
}

// ---- shard assignment ------------------------------------------------------

/// [`ShardRange::strategy`] tag: contiguous global-id ranges
/// (`[start, end)` owned by this shard; local id = global − start).
pub const SHARD_STRATEGY_RANGE: u32 = 0;
/// [`ShardRange::strategy`] tag: interleaved hash sharding
/// (`shard = global mod n_shards`, local id = global ÷ n_shards; `start`
/// and `end` are unused and stored as 0). Spreads the Zipf head across
/// shards instead of concentrating it on whichever shard owns the low ids.
pub const SHARD_STRATEGY_HASH: u32 = 1;

/// Which slice of a sharded global vocabulary a shard snapshot owns —
/// the topology fact a shard server needs about *itself*, embedded in the
/// snapshot ([`SEC_SHARD_RANGE`]) so a node can be booted from its shard
/// file alone and the router can verify it deployed the right slice.
///
/// Payload encoding: nine u32s,
/// `[strategy, shard, n_shards, global_vocab.lo, global_vocab.hi,
///   start.lo, start.hi, end.lo, end.hi]` (u64s split little-end first,
/// matching the header's u64 fields).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardRange {
    /// [`SHARD_STRATEGY_RANGE`] or [`SHARD_STRATEGY_HASH`].
    pub strategy: u32,
    /// This shard's index in `0..n_shards`.
    pub shard: u32,
    pub n_shards: u32,
    /// Size of the *global* (unsharded) vocabulary.
    pub global_vocab: u64,
    /// Range strategy only: owned global-id range `[start, end)`.
    pub start: u64,
    pub end: u64,
}

/// Encoded element count of a [`SEC_SHARD_RANGE`] payload.
pub const SHARD_RANGE_U32S: usize = 9;

impl ShardRange {
    pub fn encode(&self) -> [u32; SHARD_RANGE_U32S] {
        let split = |x: u64| (x as u32, (x >> 32) as u32);
        let (gv_lo, gv_hi) = split(self.global_vocab);
        let (s_lo, s_hi) = split(self.start);
        let (e_lo, e_hi) = split(self.end);
        [self.strategy, self.shard, self.n_shards, gv_lo, gv_hi, s_lo, s_hi, e_lo, e_hi]
    }

    pub fn decode(xs: &[u32]) -> Result<ShardRange> {
        if xs.len() != SHARD_RANGE_U32S {
            return Err(Error::Snapshot(format!(
                "shard_range section has {} u32s (expected {SHARD_RANGE_U32S})",
                xs.len()
            )));
        }
        let join = |lo: u32, hi: u32| (lo as u64) | ((hi as u64) << 32);
        Ok(ShardRange {
            strategy: xs[0],
            shard: xs[1],
            n_shards: xs[2],
            global_vocab: join(xs[3], xs[4]),
            start: join(xs[5], xs[6]),
            end: join(xs[7], xs[8]),
        })
    }

    /// How many global ids this assignment maps onto the shard — must equal
    /// the snapshot's own `vocab` for the file to be coherent.
    pub fn local_count(&self) -> u64 {
        match self.strategy {
            SHARD_STRATEGY_RANGE => self.end.saturating_sub(self.start),
            // Ids in 0..global_vocab congruent to `shard` mod n_shards.
            _ => {
                let (v, s, n) = (self.global_vocab, self.shard as u64, self.n_shards as u64);
                if s >= v || n == 0 {
                    0
                } else {
                    (v - s).div_ceil(n)
                }
            }
        }
    }

    /// Semantic validation against the snapshot's local vocabulary size; a
    /// hostile or stale section yields a typed error, never a bad mapping.
    pub fn validate(&self, local_vocab: u64) -> Result<()> {
        let fail = |m: String| Err(Error::Snapshot(format!("shard_range: {m}")));
        if self.strategy != SHARD_STRATEGY_RANGE && self.strategy != SHARD_STRATEGY_HASH {
            return fail(format!("unknown strategy tag {}", self.strategy));
        }
        if self.n_shards == 0 || self.shard >= self.n_shards {
            return fail(format!("shard {} outside 0..{}", self.shard, self.n_shards));
        }
        if self.strategy == SHARD_STRATEGY_RANGE
            && (self.start > self.end || self.end > self.global_vocab)
        {
            return fail(format!(
                "range [{}, {}) outside global vocabulary {}",
                self.start, self.end, self.global_vocab
            ));
        }
        if self.local_count() != local_vocab {
            return fail(format!(
                "assignment covers {} ids but the snapshot holds {local_vocab}",
                self.local_count()
            ));
        }
        Ok(())
    }

    pub fn strategy_name(&self) -> &'static str {
        if self.strategy == SHARD_STRATEGY_HASH {
            "hash"
        } else {
            "range"
        }
    }
}

/// Parsed fixed header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Header {
    pub kind: StoreKind,
    pub vocab: u64,
    pub dim: u64,
    pub order: u32,
    pub rank: u32,
    pub flags: u32,
    pub meta: [u64; 6],
}

/// One encoded section, ready to be laid out by the writer.
#[derive(Debug, Clone)]
pub struct SectionData {
    pub id: u32,
    pub dtype: Dtype,
    /// Logical element count (codes for I8, not counting the scales prefix).
    pub count: u64,
    /// I8 only: elements per quantization chunk (one f32 scale each).
    pub chunk: u64,
    pub bytes: Vec<u8>,
}

/// Expected payload byte length for a (dtype, count, chunk) triple; the
/// reader rejects sections whose stored length disagrees. All arithmetic is
/// checked — a hostile header with a near-u64::MAX count must produce a
/// typed error, not an overflow panic.
pub fn expected_byte_len(dtype: Dtype, count: u64, chunk: u64) -> Result<u64> {
    let overflow = || Error::Snapshot("section size overflows".into());
    Ok(match dtype {
        Dtype::F32 | Dtype::U32 => count.checked_mul(4).ok_or_else(overflow)?,
        Dtype::F16 => count.checked_mul(2).ok_or_else(overflow)?,
        Dtype::I8 => {
            if count > 0 && chunk == 0 {
                return Err(Error::Snapshot("i8 section with zero chunk size".into()));
            }
            let n_chunks = if count == 0 { 0 } else { count.div_ceil(chunk) };
            n_chunks
                .checked_mul(4)
                .and_then(|s| s.checked_add(count))
                .ok_or_else(overflow)?
        }
    })
}

// ---- CRC32 (IEEE, table-driven) --------------------------------------------

/// Byte-at-a-time lookup table, built at compile time. Sections can be
/// large (a snapshotted *regular* table is vocab×dim×4 bytes, and every
/// `open` — including the live-reload path — re-checksums each section), so
/// the bitwise form's 8 steps/byte would turn hot swaps into multi-second
/// stalls on big models.
const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// CRC-32/IEEE over `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = CRC_TABLE[((crc ^ b as u32) & 0xff) as usize] ^ (crc >> 8);
    }
    !crc
}

// ---- half-precision codec --------------------------------------------------

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (keep NaN-ness with a quiet bit).
        return sign | 0x7c00 | if frac != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → inf
    }
    if e <= 0 {
        // Subnormal half (or underflow to zero).
        if e < -10 {
            return sign;
        }
        let full = frac | 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let mut f = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if rem > half || (rem == half && (f & 1) == 1) {
            f += 1;
        }
        return sign | f as u16;
    }
    let mut f = frac >> 13;
    let mut e = e as u32;
    let rem = frac & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (f & 1) == 1) {
        f += 1;
        if f == 0x400 {
            f = 0;
            e += 1;
            if e >= 0x1f {
                return sign | 0x7c00;
            }
        }
    }
    sign | ((e as u16) << 10) | f as u16
}

/// IEEE 754 binary16 bits → f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign
        } else {
            // Subnormal: renormalize into f32's much wider exponent range.
            let mut e: u32 = 127 - 15 + 1;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((f & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (frac << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

// ---- section encoding ------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

/// Encode an f32 tensor section under `codec`. `chunk` is the per-scale
/// granularity for int8 (clamped to `1..=len`; pass 0 for one chunk per
/// section).
pub fn encode_f32s(id: u32, data: &[f32], codec: Codec, chunk: usize) -> SectionData {
    match codec {
        // Sub-byte codecs restructure the whole store into quantized_ket
        // sections instead of re-encoding float sections element-wise; a
        // float section reaching here under one of them (norms, IVF
        // centroids) stays exact.
        Codec::Int4 | Codec::B2 | Codec::B1 => encode_f32s(id, data, Codec::F32, chunk),
        Codec::F32 => {
            let mut bytes = Vec::with_capacity(data.len() * 4);
            for &x in data {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            SectionData { id, dtype: Dtype::F32, count: data.len() as u64, chunk: 0, bytes }
        }
        Codec::F16 => {
            let mut bytes = Vec::with_capacity(data.len() * 2);
            for &x in data {
                bytes.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
            }
            SectionData { id, dtype: Dtype::F16, count: data.len() as u64, chunk: 0, bytes }
        }
        Codec::Int8 => {
            let chunk = if chunk == 0 { data.len().max(1) } else { chunk.min(data.len().max(1)) };
            let n_chunks = data.len().div_ceil(chunk);
            let mut scales = Vec::with_capacity(n_chunks);
            for c in data.chunks(chunk) {
                let max_abs = c.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                scales.push(if max_abs > 0.0 { max_abs / 127.0 } else { 0.0 });
            }
            let mut bytes = Vec::with_capacity(n_chunks * 4 + data.len());
            for &s in &scales {
                bytes.extend_from_slice(&s.to_le_bytes());
            }
            for (i, &x) in data.iter().enumerate() {
                let s = scales[i / chunk];
                let code = if s > 0.0 { (x / s).round().clamp(-127.0, 127.0) as i8 } else { 0 };
                bytes.push(code as u8);
            }
            SectionData { id, dtype: Dtype::I8, count: data.len() as u64, chunk: chunk as u64, bytes }
        }
    }
}

/// Encode a u32 section (bit-packed codes, IVF id lists) — always exact.
pub fn encode_u32s(id: u32, data: &[u32]) -> SectionData {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for &x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    SectionData { id, dtype: Dtype::U32, count: data.len() as u64, chunk: 0, bytes }
}

// ---- writer ----------------------------------------------------------------

fn align8(x: usize) -> usize {
    x.div_ceil(8) * 8
}

/// Serialize header + sections and write the file **atomically**: the
/// bytes go to a temp file in the same directory, then `rename(2)` over the
/// target. Two failure modes this closes: a crash mid-write can never
/// destroy the previous good snapshot, and overwriting a snapshot a live
/// server currently serves by mmap keeps the old *inode* (and therefore the
/// old mapping) intact — truncating it in place would SIGBUS the server.
/// Returns the total byte count on disk.
pub fn write_snapshot(path: &Path, header: &Header, sections: &[SectionData]) -> Result<u64> {
    if sections.len() as u32 > MAX_SECTIONS {
        return Err(Error::Snapshot(format!("too many sections ({})", sections.len())));
    }
    // Header bytes (without the trailing CRC yet).
    let mut head = Vec::with_capacity(HEADER_BYTES);
    head.extend_from_slice(&MAGIC);
    put_u32(&mut head, VERSION);
    put_u32(&mut head, header.kind.tag());
    put_u64(&mut head, header.vocab);
    put_u64(&mut head, header.dim);
    put_u32(&mut head, header.order);
    put_u32(&mut head, header.rank);
    put_u32(&mut head, header.flags);
    put_u32(&mut head, sections.len() as u32);
    for &m in &header.meta {
        put_u64(&mut head, m);
    }
    let hcrc = crc32(&head);
    put_u32(&mut head, hcrc);
    debug_assert_eq!(head.len(), HEADER_BYTES);

    // Lay out payload offsets (8-aligned) and build the table.
    let table_end = HEADER_BYTES + sections.len() * SECTION_ENTRY_BYTES;
    let mut offset = align8(table_end);
    let mut table = Vec::with_capacity(sections.len() * SECTION_ENTRY_BYTES);
    let mut payload_end = offset;
    for s in sections {
        let want = expected_byte_len(s.dtype, s.count, s.chunk)?;
        if want != s.bytes.len() as u64 {
            return Err(Error::Snapshot(format!(
                "section {} encoded length {} != expected {}",
                section_name(s.id),
                s.bytes.len(),
                want
            )));
        }
        put_u32(&mut table, s.id);
        put_u32(&mut table, s.dtype.tag());
        put_u64(&mut table, s.count);
        put_u64(&mut table, s.chunk);
        put_u64(&mut table, offset as u64);
        put_u64(&mut table, s.bytes.len() as u64);
        put_u32(&mut table, crc32(&s.bytes));
        payload_end = offset + s.bytes.len();
        offset = align8(payload_end);
    }

    let total = if sections.is_empty() { table_end } else { payload_end };
    let mut file = vec![0u8; total];
    file[..HEADER_BYTES].copy_from_slice(&head);
    file[HEADER_BYTES..table_end].copy_from_slice(&table);
    // Payloads (recompute the same offsets).
    let mut off = align8(table_end);
    for s in sections {
        file[off..off + s.bytes.len()].copy_from_slice(&s.bytes);
        off = align8(off + s.bytes.len());
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, &file)
        .map_err(|e| Error::Snapshot(format!("write {}: {e}", tmp.display())))?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        std::fs::remove_file(&tmp).ok();
        return Err(Error::Snapshot(format!(
            "rename {} -> {}: {e}",
            tmp.display(),
            path.display()
        )));
    }
    Ok(total as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Standard test vector: CRC32("123456789") = 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn f16_roundtrip_exact_for_representables() {
        for x in [0.0f32, 1.0, -1.0, 0.5, -0.25, 2.0, 1024.0, -0.125] {
            let h = f32_to_f16_bits(x);
            assert_eq!(f16_bits_to_f32(h), x, "{x}");
        }
    }

    #[test]
    fn f16_relative_error_bounded() {
        // Half precision has 11 significand bits: relative error < 2^-11.
        let mut x = 1e-3f32;
        while x < 1e3 {
            for s in [1.0f32, -1.0] {
                let v = s * x;
                let back = f16_bits_to_f32(f32_to_f16_bits(v));
                assert!(
                    (back - v).abs() <= v.abs() * 5e-4 + 1e-7,
                    "{v} -> {back}"
                );
            }
            x *= 1.7;
        }
    }

    #[test]
    fn f16_specials() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Overflow saturates to inf; tiny underflows to (signed) zero.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-12)), 0.0);
        // Subnormal half survives the round trip.
        let sub = 2.0f32.powi(-15);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(sub)), sub);
    }

    #[test]
    fn i8_encode_error_bounded_per_chunk() {
        let data: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.01).collect();
        let s = encode_f32s(7, &data, Codec::Int8, 16);
        assert_eq!(s.dtype, Dtype::I8);
        assert_eq!(s.count, 64);
        assert_eq!(s.chunk, 16);
        assert_eq!(s.bytes.len() as u64, expected_byte_len(Dtype::I8, 64, 16).unwrap());
        // Decode manually and check error bound scale/2 per element.
        let n_chunks = 4;
        for (i, &x) in data.iter().enumerate() {
            let c = i / 16;
            let scale =
                f32::from_le_bytes(s.bytes[c * 4..c * 4 + 4].try_into().unwrap());
            let code = s.bytes[n_chunks * 4 + i] as i8;
            let back = code as f32 * scale;
            assert!((back - x).abs() <= scale / 2.0 + 1e-7, "{i}: {x} vs {back}");
        }
    }

    #[test]
    fn shard_range_encode_decode_validate() {
        let sr = ShardRange {
            strategy: SHARD_STRATEGY_RANGE,
            shard: 1,
            n_shards: 4,
            global_vocab: 5_000_000_000, // u64 halves must survive the split
            start: 1_250_000_000,
            end: 2_500_000_000,
        };
        let back = ShardRange::decode(&sr.encode()).unwrap();
        assert_eq!(back, sr);
        back.validate(1_250_000_000).unwrap();
        assert!(back.validate(7).is_err(), "local vocab mismatch must fail");
        assert!(ShardRange::decode(&[1, 2, 3]).is_err(), "short payload");

        // Hash strategy: local_count is the congruence-class size.
        let h = ShardRange {
            strategy: SHARD_STRATEGY_HASH,
            shard: 2,
            n_shards: 3,
            global_vocab: 10,
            start: 0,
            end: 0,
        };
        assert_eq!(h.local_count(), 3); // ids 2, 5, 8
        h.validate(3).unwrap();

        // Hostile values: bad strategy, shard out of range, inverted range.
        let mut bad = sr;
        bad.strategy = 9;
        assert!(bad.validate(sr.local_count()).is_err());
        let mut bad = sr;
        bad.shard = 4;
        assert!(bad.validate(sr.local_count()).is_err());
        let mut bad = sr;
        bad.start = bad.end + 1;
        assert!(bad.validate(sr.local_count()).is_err());
    }

    #[test]
    fn codec_parse_names() {
        assert_eq!(Codec::parse("f32").unwrap(), Codec::F32);
        assert_eq!(Codec::parse("F16").unwrap(), Codec::F16);
        assert_eq!(Codec::parse("int8").unwrap(), Codec::Int8);
        assert_eq!(Codec::parse("int4").unwrap(), Codec::Int4);
        assert_eq!(Codec::parse("i4").unwrap(), Codec::Int4);
        assert_eq!(Codec::parse("2bit").unwrap(), Codec::B2);
        assert_eq!(Codec::parse("B1").unwrap(), Codec::B1);
        assert!(Codec::parse("f64").is_err());
        // The error must enumerate every accepted codec so a typo'd config
        // is self-diagnosing.
        let msg = Codec::parse("f64").unwrap_err().to_string();
        for name in ["f32", "f16", "int8", "int4", "b2", "b1"] {
            assert!(msg.contains(name), "error {msg:?} misses {name}");
        }
        for c in [Codec::F32, Codec::F16, Codec::Int8, Codec::Int4, Codec::B2, Codec::B1] {
            assert_eq!(Codec::parse(c.name()).unwrap(), c, "name must re-parse");
        }
        assert_eq!(Codec::Int4.bits(), 4);
        assert_eq!(Codec::B1.bits(), 1);
        assert!(Codec::B2.is_sub_byte() && !Codec::Int8.is_sub_byte());
    }

    #[test]
    fn sub_byte_codec_keeps_float_sections_exact() {
        // Norms / IVF centroids saved under --payload int4 must stay f32.
        let data = [1.5f32, -0.25, 3.0e-5];
        let s = encode_f32s(3, &data, Codec::Int4, 0);
        assert_eq!(s.dtype, Dtype::F32);
        assert_eq!(s.bytes.len(), 12);
    }

    #[test]
    fn kind_and_dtype_tags_roundtrip() {
        for k in [
            StoreKind::Regular,
            StoreKind::Word2Ket,
            StoreKind::Word2KetXS,
            StoreKind::Quantized,
            StoreKind::LowRank,
            StoreKind::Hashed,
            StoreKind::QuantizedKet,
        ] {
            assert_eq!(StoreKind::from_tag(k.tag()).unwrap(), k);
        }
        for d in [Dtype::F32, Dtype::F16, Dtype::I8, Dtype::U32] {
            assert_eq!(Dtype::from_tag(d.tag()).unwrap(), d);
        }
        assert!(StoreKind::from_tag(99).is_err());
        assert!(Dtype::from_tag(99).is_err());
    }

    /// Every one of the 65536 half patterns must decode to the f32 the
    /// IEEE 754 mapping defines and (for non-NaN) re-encode to itself —
    /// `f16_bits_to_f32` and `f32_to_f16_bits` are each other's inverse on
    /// the representable set. An independent from-scratch decode (plain
    /// `2^(e-15) · (1 + frac/1024)` arithmetic, no bit tricks shared with
    /// the production code) pins the semantics.
    #[test]
    fn f16_codec_exhaustive_all_65536_patterns() {
        for h in 0..=u16::MAX {
            let got = f16_bits_to_f32(h);
            let sign = if h & 0x8000 != 0 { -1.0f64 } else { 1.0 };
            let exp = ((h >> 10) & 0x1f) as i32;
            let frac = (h & 0x3ff) as f64;
            if exp == 0x1f {
                if frac == 0.0 {
                    assert_eq!(got, (sign as f32) * f32::INFINITY, "{h:#06x}");
                } else {
                    assert!(got.is_nan(), "{h:#06x} must decode NaN, got {got}");
                    continue; // NaN payloads need not roundtrip bit-exactly…
                }
            } else {
                let want = if exp == 0 {
                    sign * frac * 2.0f64.powi(-24) // subnormal: frac · 2^-24
                } else {
                    sign * (1.0 + frac / 1024.0) * 2.0f64.powi(exp - 15)
                };
                assert_eq!(got as f64, want, "{h:#06x}");
            }
            // …but every non-NaN pattern must, including both zeros, both
            // infinities, and all 2048 subnormals.
            let back = f32_to_f16_bits(got);
            assert_eq!(back, h, "{h:#06x} -> {got} -> {back:#06x}");
        }
    }

    /// Round-to-nearest-even at the exact halfway points, both directions.
    #[test]
    fn f16_encode_rounding_tie_goldens() {
        // Half spacing at 1.0 is 1/1024, so ties sit at odd multiples of
        // 1/2048. 1 + 3/2048 is exactly between 0x3c01 (1+1/1024) and
        // 0x3c02 (1+2/1024): ties-to-even picks the even code 0x3c02.
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 / 2048.0), 0x3c02);
        // 1 + 1/2048 ties between 0x3c00 and 0x3c01 → even 0x3c00.
        assert_eq!(f32_to_f16_bits(1.0 + 1.0 / 2048.0), 0x3c00);
        // Just above/below a tie resolves toward nearest, not toward even.
        assert_eq!(f32_to_f16_bits(f32::from_bits((1.0f32 + 1.0 / 2048.0).to_bits() + 1)), 0x3c01);
        assert_eq!(f32_to_f16_bits(f32::from_bits((1.0f32 + 3.0 / 2048.0).to_bits() - 1)), 0x3c01);
        // Tie with mantissa carry: 1 + 2047/2048 ties the largest mantissa
        // 0x3fff against 2.0, and the even side carries into the next
        // exponent (frac overflows 10 bits → 0x4000).
        assert_eq!(f32_to_f16_bits(1.0 + 2047.0 / 2048.0), 0x4000);
        // Tie at the very top of the range overflows to infinity: 65520 is
        // halfway between 65504 (max finite half) and 65536.
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00);
        assert_eq!(f32_to_f16_bits(65519.99), 0x7bff);
        // Subnormal tie: 1.5 · 2^-24 is halfway between subnormal codes 1
        // and 2 → even code 2; 0.5 · 2^-24 ties between 0 and 1 → 0.
        assert_eq!(f32_to_f16_bits(1.5 * 2.0f32.powi(-24)), 0x0002);
        assert_eq!(f32_to_f16_bits(0.5 * 2.0f32.powi(-24)), 0x0000);
        // Negative mirrors the positive cases with the sign bit set.
        assert_eq!(f32_to_f16_bits(-(1.0 + 1.0 / 4096.0)), 0xbc00);
    }
}
