//! Snapshot reading: memory-mapped (or heap-buffered) container access with
//! full validation, payload decoding, and heap reconstruction of concrete
//! stores.
//!
//! Every `open` fully validates the file before any accessor exists: magic,
//! version, header CRC, section-table bounds, per-section CRC32, and
//! dtype-consistent byte lengths. Corrupted or truncated snapshots are
//! rejected with [`Error::Snapshot`] — never a panic, never a partially
//! usable handle.
//!
//! Zero-copy: `F32`/`U32` payloads are 8-byte aligned in the file and the
//! mapping base is page-aligned, so [`Snapshot::f32_view`] /
//! [`Snapshot::u32_view`] hand out slices straight into the mapping (the
//! file format is little-endian; big-endian hosts are rejected at open and
//! would need the decoding path).

use super::format::*;
use crate::embedding::{
    EmbeddingStore, HashedEmbedding, LowRankEmbedding, QuantizedEmbedding, RegularEmbedding,
    Word2Ket, Word2KetXS,
};
use crate::error::{Error, Result};
use std::path::Path;

// ---- platform mmap ---------------------------------------------------------

#[cfg(unix)]
mod sys {
    use core::ffi::{c_int, c_void};
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    const PROT_READ: c_int = 1;
    const MAP_PRIVATE: c_int = 2;

    /// Read-only private mapping of a whole file. The pointer is page-
    /// aligned, so any 8-aligned file offset stays 8-aligned in memory.
    pub struct Mmap {
        ptr: *mut c_void,
        len: usize,
    }

    // The mapping is read-only and owned exclusively by this handle.
    unsafe impl Send for Mmap {}
    unsafe impl Sync for Mmap {}

    impl Mmap {
        pub fn map(file: &File, len: usize) -> io::Result<Mmap> {
            if len == 0 {
                return Err(io::Error::new(io::ErrorKind::InvalidData, "empty file"));
            }
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
            };
            if ptr as isize == -1 {
                return Err(io::Error::last_os_error());
            }
            Ok(Mmap { ptr, len })
        }

        pub fn bytes(&self) -> &[u8] {
            unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
        }
    }

    impl Drop for Mmap {
        fn drop(&mut self) {
            unsafe {
                munmap(self.ptr, self.len);
            }
        }
    }
}

/// File bytes: a real mapping on unix, or an 8-aligned heap buffer (the
/// heap path backs `mmap = false` loads and non-unix hosts).
enum Backing {
    #[cfg(unix)]
    Mapped(sys::Mmap),
    /// `Vec<u64>` storage guarantees 8-byte base alignment for zero-copy
    /// f32/u32 views; `usize` is the real byte length.
    Heap(Vec<u64>, usize),
}

impl Backing {
    fn bytes(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            Backing::Mapped(m) => m.bytes(),
            Backing::Heap(words, len) => {
                // u64 → u8 reinterpretation is always valid (alignment only
                // ever decreases).
                let all =
                    unsafe { std::slice::from_raw_parts(words.as_ptr() as *const u8, words.len() * 8) };
                &all[..*len]
            }
        }
    }
}

fn read_heap(path: &Path) -> Result<Backing> {
    let data = std::fs::read(path)
        .map_err(|e| Error::Snapshot(format!("read {}: {e}", path.display())))?;
    let len = data.len();
    let mut words = vec![0u64; len.div_ceil(8)];
    unsafe {
        std::ptr::copy_nonoverlapping(data.as_ptr(), words.as_mut_ptr() as *mut u8, len);
    }
    Ok(Backing::Heap(words, len))
}

#[cfg(unix)]
fn map_file(path: &Path) -> Result<Backing> {
    let file = std::fs::File::open(path)
        .map_err(|e| Error::Snapshot(format!("open {}: {e}", path.display())))?;
    let len = file
        .metadata()
        .map_err(|e| Error::Snapshot(format!("stat {}: {e}", path.display())))?
        .len() as usize;
    Ok(Backing::Mapped(
        sys::Mmap::map(&file, len)
            .map_err(|e| Error::Snapshot(format!("mmap {}: {e}", path.display())))?,
    ))
}

/// Non-unix hosts have no mmap syscall wrapper; fall back to the aligned
/// heap buffer (same validation, same zero-copy views, just not shared).
#[cfg(not(unix))]
fn map_file(path: &Path) -> Result<Backing> {
    read_heap(path)
}

// ---- parsed sections -------------------------------------------------------

/// One validated section of an open snapshot.
#[derive(Debug, Clone, Copy)]
pub struct Section {
    pub id: u32,
    pub dtype: Dtype,
    /// Logical element count.
    pub count: u64,
    /// I8: elements per quantization chunk.
    pub chunk: u64,
    /// Payload byte offset (8-aligned).
    pub offset: u64,
    pub byte_len: u64,
    pub crc: u32,
}

fn get_u32(bytes: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(bytes[off..off + 4].try_into().expect("bounds checked"))
}

fn get_u64(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().expect("bounds checked"))
}

// ---- snapshot handle -------------------------------------------------------

/// An open, fully validated snapshot file.
pub struct Snapshot {
    backing: Backing,
    header: Header,
    sections: Vec<Section>,
    shard_range: Option<ShardRange>,
    path: String,
}

impl Snapshot {
    /// Open and validate. `mmap = true` maps the file (zero-copy serving);
    /// `false` reads it into an aligned heap buffer. Non-unix hosts always
    /// take the heap path.
    pub fn open(path: &Path, mmap: bool) -> Result<Snapshot> {
        let backing = if mmap { map_file(path)? } else { read_heap(path)? };
        Self::parse(backing, path)
    }

    fn parse(backing: Backing, path: &Path) -> Result<Snapshot> {
        if cfg!(target_endian = "big") {
            return Err(Error::Snapshot(
                "snapshot format is little-endian; big-endian hosts unsupported".into(),
            ));
        }
        let bytes = backing.bytes();
        if bytes.len() < HEADER_BYTES {
            return Err(Error::Snapshot(format!(
                "truncated snapshot: {} bytes < {HEADER_BYTES}-byte header",
                bytes.len()
            )));
        }
        if bytes[..8] != MAGIC {
            return Err(Error::Snapshot("bad magic: not a word2ket snapshot".into()));
        }
        let version = get_u32(bytes, 0x08);
        if version != VERSION {
            return Err(Error::Snapshot(format!(
                "unsupported snapshot version {version} (expected {VERSION})"
            )));
        }
        let stored_hcrc = get_u32(bytes, HEADER_BYTES - 4);
        let actual_hcrc = crc32(&bytes[..HEADER_BYTES - 4]);
        if stored_hcrc != actual_hcrc {
            return Err(Error::Snapshot(format!(
                "header CRC mismatch: stored {stored_hcrc:#010x}, computed {actual_hcrc:#010x}"
            )));
        }
        let kind = StoreKind::from_tag(get_u32(bytes, 0x0c))?;
        let vocab = get_u64(bytes, 0x10);
        let dim = get_u64(bytes, 0x18);
        let order = get_u32(bytes, 0x20);
        let rank = get_u32(bytes, 0x24);
        let flags = get_u32(bytes, 0x28);
        let n_sections = get_u32(bytes, 0x2c);
        if n_sections > MAX_SECTIONS {
            return Err(Error::Snapshot(format!("section count {n_sections} exceeds cap")));
        }
        let mut meta = [0u64; 6];
        for (i, m) in meta.iter_mut().enumerate() {
            *m = get_u64(bytes, 0x30 + i * 8);
        }
        let header = Header { kind, vocab, dim, order, rank, flags, meta };

        let table_end = HEADER_BYTES + n_sections as usize * SECTION_ENTRY_BYTES;
        if bytes.len() < table_end {
            return Err(Error::Snapshot(format!(
                "truncated snapshot: section table needs {table_end} bytes, file has {}",
                bytes.len()
            )));
        }
        let mut sections = Vec::with_capacity(n_sections as usize);
        for i in 0..n_sections as usize {
            let off = HEADER_BYTES + i * SECTION_ENTRY_BYTES;
            let sec = Section {
                id: get_u32(bytes, off),
                dtype: Dtype::from_tag(get_u32(bytes, off + 4))?,
                count: get_u64(bytes, off + 8),
                chunk: get_u64(bytes, off + 16),
                offset: get_u64(bytes, off + 24),
                byte_len: get_u64(bytes, off + 32),
                crc: get_u32(bytes, off + 40),
            };
            let name = section_name(sec.id);
            if sec.offset % 8 != 0 {
                return Err(Error::Snapshot(format!("section {name}: unaligned offset")));
            }
            let end = sec
                .offset
                .checked_add(sec.byte_len)
                .ok_or_else(|| Error::Snapshot(format!("section {name}: offset overflow")))?;
            if end > bytes.len() as u64 {
                return Err(Error::Snapshot(format!(
                    "truncated snapshot: section {name} ends at {end}, file has {} bytes",
                    bytes.len()
                )));
            }
            let want = expected_byte_len(sec.dtype, sec.count, sec.chunk)?;
            if want != sec.byte_len {
                return Err(Error::Snapshot(format!(
                    "section {name}: byte length {} inconsistent with dtype/count ({want})",
                    sec.byte_len
                )));
            }
            let payload = &bytes[sec.offset as usize..end as usize];
            let actual = crc32(payload);
            if actual != sec.crc {
                return Err(Error::Snapshot(format!(
                    "section {name}: CRC mismatch (stored {:#010x}, computed {actual:#010x})",
                    sec.crc
                )));
            }
            // Quantization scales are trusted multipliers on every decode
            // path; a NaN/inf/negative scale smuggled into a CRC-valid file
            // would silently poison each row it covers (and NaN defeats
            // every downstream comparison), so hostile scales fail the open
            // itself. Covers the i8 chunk-scale prefix and the
            // quantized-ket per-leaf scale section.
            let scale_prefix = match sec.dtype {
                Dtype::I8 => {
                    let n = sec.count as usize;
                    if n == 0 { 0 } else { n.div_ceil(sec.chunk as usize) }
                }
                Dtype::F32 if sec.id == SEC_QKET_SCALES => sec.count as usize,
                _ => 0,
            };
            for i in 0..scale_prefix {
                let s = f32::from_le_bytes(
                    payload[i * 4..i * 4 + 4].try_into().expect("bounds checked"),
                );
                if !s.is_finite() || s < 0.0 {
                    return Err(Error::Snapshot(format!(
                        "section {name}: quantization scale [{i}] = {s} \
                         (must be finite and non-negative)"
                    )));
                }
            }
            sections.push(sec);
        }
        // Shard-assignment metadata: flag and section must agree, and the
        // assignment must cover exactly this snapshot's vocabulary — a
        // stale or hostile section fails the open, never silently misroutes
        // ids later.
        let shard_range = if flags & FLAG_HAS_SHARD_RANGE != 0 {
            let sec = sections
                .iter()
                .find(|s| s.id == SEC_SHARD_RANGE)
                .ok_or_else(|| {
                    Error::Snapshot("shard-range flag set but section missing".into())
                })?;
            if sec.dtype != Dtype::U32 {
                return Err(Error::Snapshot("shard_range section is not u32-typed".into()));
            }
            let payload = &bytes[sec.offset as usize..(sec.offset + sec.byte_len) as usize];
            let xs: Vec<u32> = payload
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().expect("chunked by 4")))
                .collect();
            let sr = ShardRange::decode(&xs)?;
            sr.validate(vocab)?;
            Some(sr)
        } else {
            None
        };
        Ok(Snapshot { backing, header, sections, shard_range, path: path.display().to_string() })
    }

    /// The shard of a sharded global vocabulary this snapshot holds, when
    /// it was written as one ([`crate::snapshot::SaveOptions::shard_range`];
    /// validated against the local vocabulary at open).
    pub fn shard_range(&self) -> Option<ShardRange> {
        self.shard_range
    }

    pub fn header(&self) -> &Header {
        &self.header
    }

    pub fn kind(&self) -> StoreKind {
        self.header.kind
    }

    /// Total bytes on disk.
    pub fn file_len(&self) -> u64 {
        self.backing.bytes().len() as u64
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    pub fn sections(&self) -> &[Section] {
        &self.sections
    }

    pub fn section(&self, id: u32) -> Option<&Section> {
        self.sections.iter().find(|s| s.id == id)
    }

    fn require(&self, id: u32) -> Result<&Section> {
        self.section(id).ok_or_else(|| {
            Error::Snapshot(format!(
                "snapshot {} is missing section {}",
                self.path,
                section_name(id)
            ))
        })
    }

    fn payload(&self, s: &Section) -> &[u8] {
        &self.backing.bytes()[s.offset as usize..(s.offset + s.byte_len) as usize]
    }

    /// Zero-copy f32 view; `None` unless the section is raw F32.
    pub fn f32_view(&self, s: &Section) -> Option<&[f32]> {
        if s.dtype != Dtype::F32 {
            return None;
        }
        Some(self.f32s_at(s.offset as usize, s.count as usize))
    }

    /// Zero-copy u32 view; `None` unless the section dtype is U32.
    pub fn u32_view(&self, s: &Section) -> Option<&[u32]> {
        if s.dtype != Dtype::U32 {
            return None;
        }
        let b = &self.backing.bytes()[s.offset as usize..(s.offset + s.byte_len) as usize];
        debug_assert_eq!(b.as_ptr() as usize % 4, 0);
        Some(unsafe { std::slice::from_raw_parts(b.as_ptr() as *const u32, s.count as usize) })
    }

    /// Reinterpret `count` f32s at a validated, 8-aligned byte offset.
    /// Callers only pass offsets derived from validated sections.
    pub(crate) fn f32s_at(&self, byte_off: usize, count: usize) -> &[f32] {
        let b = &self.backing.bytes()[byte_off..byte_off + count * 4];
        debug_assert_eq!(b.as_ptr() as usize % 4, 0);
        unsafe { std::slice::from_raw_parts(b.as_ptr() as *const f32, count) }
    }

    /// Same for u32s (bit-packed quantization codes).
    pub(crate) fn u32s_at(&self, byte_off: usize, count: usize) -> &[u32] {
        let b = &self.backing.bytes()[byte_off..byte_off + count * 4];
        debug_assert_eq!(b.as_ptr() as usize % 4, 0);
        unsafe { std::slice::from_raw_parts(b.as_ptr() as *const u32, count) }
    }

    /// Decode a float section into a heap vector, whatever its payload
    /// dtype (F32 pass-through, F16/I8 dequantized).
    pub fn read_f32s(&self, s: &Section) -> Result<Vec<f32>> {
        let bytes = self.payload(s);
        let n = s.count as usize;
        Ok(match s.dtype {
            Dtype::F32 => {
                self.f32_view(s).map(|v| v.to_vec()).unwrap_or_default()
            }
            Dtype::F16 => {
                let mut out = Vec::with_capacity(n);
                for i in 0..n {
                    let h = u16::from_le_bytes([bytes[i * 2], bytes[i * 2 + 1]]);
                    out.push(f16_bits_to_f32(h));
                }
                out
            }
            Dtype::I8 => {
                let chunk = s.chunk as usize;
                let n_chunks = if n == 0 { 0 } else { n.div_ceil(chunk) };
                let codes = &bytes[n_chunks * 4..];
                let mut out = Vec::with_capacity(n);
                for (i, &c) in codes.iter().enumerate().take(n) {
                    let ci = i / chunk;
                    let scale =
                        f32::from_le_bytes(bytes[ci * 4..ci * 4 + 4].try_into().expect("scales"));
                    out.push(c as i8 as f32 * scale);
                }
                out
            }
            Dtype::U32 => {
                return Err(Error::Snapshot(format!(
                    "section {} holds u32 data, not floats",
                    section_name(s.id)
                )))
            }
        })
    }

    /// Decode a u32 section into a heap vector.
    pub fn read_u32s(&self, s: &Section) -> Result<Vec<u32>> {
        self.u32_view(s).map(|v| v.to_vec()).ok_or_else(|| {
            Error::Snapshot(format!("section {} is not u32-typed", section_name(s.id)))
        })
    }

    /// Human-readable summary for `w2k snapshot info`.
    pub fn describe(&self) -> String {
        let h = &self.header;
        let mut s = format!(
            "snapshot {} (v{VERSION}, {} bytes)\n  kind={} vocab={} dim={} order={} rank={} \
             layernorm={} index={}\n",
            self.path,
            self.file_len(),
            h.kind.name(),
            h.vocab,
            h.dim,
            h.order,
            h.rank,
            h.flags & FLAG_LAYERNORM != 0,
            if h.flags & FLAG_HAS_INDEX != 0 {
                if h.flags & FLAG_INDEX_COSINE != 0 {
                    "ivf/cosine"
                } else {
                    "ivf/dot"
                }
            } else {
                "none"
            },
        );
        if let Some(sr) = self.shard_range {
            s.push_str(&format!(
                "  shard {}/{} of a {}-word vocabulary ({} sharding{})\n",
                sr.shard,
                sr.n_shards,
                sr.global_vocab,
                sr.strategy_name(),
                if sr.strategy == SHARD_STRATEGY_RANGE {
                    format!(", global ids [{}, {})", sr.start, sr.end)
                } else {
                    String::new()
                },
            ));
        }
        for sec in &self.sections {
            s.push_str(&format!(
                "  section {:<20} dtype={:<3} count={:<10} bytes={:<10} crc={:#010x}\n",
                section_name(sec.id),
                sec.dtype.name(),
                sec.count,
                sec.byte_len,
                sec.crc
            ));
        }
        let materialized = h.vocab * h.dim * 4;
        if materialized > 0 {
            s.push_str(&format!(
                "  on-disk vs materialized f32 table: {} / {} bytes ({:.1}x smaller)",
                self.file_len(),
                materialized,
                materialized as f64 / self.file_len() as f64
            ));
        }
        s
    }
}

// ---- heap store reconstruction ---------------------------------------------

/// Rebuild the concrete in-memory store a snapshot was saved from. All
/// payload codecs are accepted (F16/I8 dequantize on load); with F32
/// payloads every row is bit-exact with the original store.
pub fn load_store(snap: &Snapshot) -> Result<Box<dyn EmbeddingStore>> {
    let h = *snap.header();
    let vocab = h.vocab as usize;
    let dim = h.dim as usize;
    let order = h.order as usize;
    let rank = h.rank as usize;
    Ok(match h.kind {
        StoreKind::Regular => {
            let data = snap.read_f32s(snap.require(SEC_REGULAR_DATA)?)?;
            let want = vocab
                .checked_mul(dim)
                .ok_or_else(|| Error::Snapshot("regular geometry overflows".into()))?;
            if data.len() != want {
                return Err(Error::Snapshot(format!(
                    "regular data has {} values, expected {want}",
                    data.len()
                )));
            }
            Box::new(RegularEmbedding::new(vocab, dim, data))
        }
        StoreKind::Word2Ket => {
            let leaves = snap.read_f32s(snap.require(SEC_W2K_LEAVES)?)?;
            let q = h.meta[META_Q] as usize;
            let layernorm = h.flags & FLAG_LAYERNORM != 0;
            Box::new(Word2Ket::from_leaves(vocab, dim, order, rank, q, layernorm, &leaves)?)
        }
        StoreKind::Word2KetXS => {
            let blob = snap.read_f32s(snap.require(SEC_XS_FACTORS)?)?;
            let q = h.meta[META_Q] as usize;
            let t = h.meta[META_T_OR_SEED] as usize;
            let per = t
                .checked_mul(q)
                .ok_or_else(|| Error::Snapshot("word2ketXS geometry overflows".into()))?;
            let want = rank
                .checked_mul(order)
                .and_then(|x| x.checked_mul(per))
                .ok_or_else(|| Error::Snapshot("word2ketXS geometry overflows".into()))?;
            if per == 0 || blob.len() != want {
                return Err(Error::Snapshot(format!(
                    "word2ketXS factor blob has {} values, expected {want}",
                    blob.len()
                )));
            }
            let factors: Vec<Vec<f32>> =
                blob.chunks(per).map(|c| c.to_vec()).collect();
            Box::new(Word2KetXS::from_factors(vocab, dim, order, rank, q, t, factors)?)
        }
        StoreKind::Quantized => {
            let codes = snap.read_u32s(snap.require(SEC_QUANT_CODES)?)?;
            let scales = snap.read_f32s(snap.require(SEC_QUANT_SCALES)?)?;
            let offsets = snap.read_f32s(snap.require(SEC_QUANT_OFFSETS)?)?;
            let bits = h.meta[META_PRIMARY] as usize;
            Box::new(QuantizedEmbedding::from_parts(vocab, dim, bits, codes, scales, offsets)?)
        }
        StoreKind::LowRank => {
            let u = snap.read_f32s(snap.require(SEC_LOWRANK_U)?)?;
            let vt = snap.read_f32s(snap.require(SEC_LOWRANK_VT)?)?;
            let k = h.meta[META_PRIMARY] as usize;
            Box::new(LowRankEmbedding::from_parts(vocab, dim, k, u, vt)?)
        }
        StoreKind::Hashed => {
            let weights = snap.read_f32s(snap.require(SEC_HASHED_WEIGHTS)?)?;
            let buckets = h.meta[META_PRIMARY] as usize;
            let seed = h.meta[META_T_OR_SEED];
            Box::new(HashedEmbedding::from_parts(vocab, dim, buckets, seed, weights)?)
        }
        StoreKind::QuantizedKet => {
            let codes = snap.read_u32s(snap.require(SEC_QKET_CODES)?)?;
            let scales = snap.read_f32s(snap.require(SEC_QKET_SCALES)?)?;
            let leaves = snap.read_f32s(snap.require(SEC_W2K_LEAVES)?)?;
            let q = h.meta[META_Q] as usize;
            let bits = h.meta[META_T_OR_SEED] as usize;
            // from_parts re-validates everything a hostile header could
            // skew: bits ∈ {1,2,4,8}, the q^order/dim envelope, section
            // lengths against the derived leaf count, scale finiteness, and
            // zero padding bits in the packed codes.
            Box::new(crate::quant::QuantizedKet::from_parts(
                vocab, dim, order, rank, q, bits, codes, scales, leaves,
            )?)
        }
    })
}

// ---- serialized index ------------------------------------------------------

/// Deserialized IVF payload: everything needed to rebuild the coarse
/// quantizer without re-running k-means.
pub struct IndexPayload {
    pub cosine: bool,
    pub nlist: usize,
    /// `nlist × dim` row-major centroids.
    pub centroids: Vec<f32>,
    /// Per-cell member id lists (a partition of the vocabulary).
    pub lists: Vec<Vec<u32>>,
}

/// Extract the embedded IVF index, if the snapshot carries one.
pub fn load_index_payload(snap: &Snapshot) -> Result<Option<IndexPayload>> {
    let h = snap.header();
    if h.flags & FLAG_HAS_INDEX == 0 {
        return Ok(None);
    }
    let nlist = h.meta[META_IVF_NLIST] as usize;
    let centroids = snap.read_f32s(snap.require(SEC_IVF_CENTROIDS)?)?;
    let lens = snap.read_u32s(snap.require(SEC_IVF_LIST_LENS)?)?;
    let ids = snap.read_u32s(snap.require(SEC_IVF_LIST_IDS)?)?;
    if nlist == 0 || lens.len() != nlist {
        return Err(Error::Snapshot(format!(
            "ivf payload: {} cell lengths for nlist={nlist}",
            lens.len()
        )));
    }
    let want_centroids = nlist
        .checked_mul(h.dim as usize)
        .ok_or_else(|| Error::Snapshot("ivf payload geometry overflows".into()))?;
    if centroids.len() != want_centroids {
        return Err(Error::Snapshot(format!(
            "ivf payload: {} centroid values, expected {want_centroids}",
            centroids.len()
        )));
    }
    let total: u64 = lens.iter().map(|&l| l as u64).sum();
    if total != ids.len() as u64 {
        return Err(Error::Snapshot(format!(
            "ivf payload: list lengths sum to {total}, {} ids present",
            ids.len()
        )));
    }
    let mut lists = Vec::with_capacity(nlist);
    let mut off = 0usize;
    for &l in &lens {
        let l = l as usize;
        lists.push(ids[off..off + l].to_vec());
        off += l;
    }
    Ok(Some(IndexPayload { cosine: h.flags & FLAG_INDEX_COSINE != 0, nlist, centroids, lists }))
}
