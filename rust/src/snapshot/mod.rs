//! Snapshot subsystem: versioned on-disk persistence, zero-copy load, and
//! the save half of live model hot-swap.
//!
//! The paper's whole argument is that a Kronecker-factored embedding table
//! is tiny enough to store and ship anywhere — this module is where it
//! actually gets stored. A snapshot is a single binary container
//! (`format.rs`): magic + CRC-checked header + CRC-checked sections holding
//! the factor tensors of any [`crate::config::EmbeddingKind`], optionally
//! with f16/int8-quantized payloads (Word2Bits-style: trade mantissa bits
//! for another 2–4× on top of the paper's 100×) and optionally with the
//! serving IVF index's centroids and cell lists so a reloaded server skips
//! k-means retraining. The sub-byte codecs (`int4`/`b2`/`b1`) go further:
//! they convert a word2ket store into a [`crate::quant::QuantizedKet`]
//! snapshot whose packed factors are scored directly in the quantized
//! domain on load.
//!
//! Loading has two paths:
//! * [`load_store`] — rebuild the concrete in-memory store (bit-exact for
//!   f32 payloads).
//! * [`SnapshotStore`] — serve straight off a memory-mapped file, zero-copy
//!   for f32 payloads, factored k-NN scoring intact (`reader.rs`,
//!   `store.rs`).
//!
//! The serving layer (`crate::serving`) builds model generations from these
//! and atomically swaps them under live traffic (`OP_RELOAD` / `RELOAD`).

pub mod format;
pub mod reader;
mod store;

pub use format::{
    crc32, section_name, Codec, Dtype, Header, SectionData, ShardRange, StoreKind,
    SHARD_STRATEGY_HASH, SHARD_STRATEGY_RANGE,
};
pub use reader::{load_index_payload, load_store, IndexPayload, Section, Snapshot};
pub use store::SnapshotStore;

use crate::embedding::EmbeddingStore;
use crate::error::{Error, Result};
use crate::index::IvfIndex;
use crate::repr::{unwrap_wrappers, Repr};
use format::*;
use std::path::Path;

/// Write-side options.
#[derive(Debug, Clone, Copy, Default)]
pub struct SaveOptions {
    /// Payload codec for factor tensors (quantized-store codes, IVF
    /// centroids, and norms always stay exact).
    pub codec: Codec,
    /// Embed per-word L2 norms ([`format::SEC_NORMS`]) so a cosine-mode
    /// scorer loading this snapshot skips its norm pass. Norms are also
    /// embedded automatically when the snapshot carries a cosine IVF
    /// index (the reloading server is then guaranteed to want them).
    /// Honored only with the exact f32 codec: a lossy payload serves
    /// dequantized rows on load, so pre-quantization norms would make the
    /// loader's cosine denominators inconsistent — lossy saves always let
    /// the loader recompute.
    pub norms: bool,
    /// Mark the snapshot as one shard of a sharded global vocabulary
    /// ([`format::SEC_SHARD_RANGE`] + [`format::FLAG_HAS_SHARD_RANGE`]): a
    /// shard server booted from the file knows which global ids it owns,
    /// and the cluster router can verify it deployed the right slice. The
    /// assignment is validated against the store's vocabulary at save *and*
    /// open.
    pub shard_range: Option<ShardRange>,
}

/// What a save produced.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotInfo {
    /// Total bytes written to disk.
    pub bytes: u64,
    /// Number of sections in the container.
    pub sections: usize,
    /// Whether a norms section was embedded (requested or implied norms
    /// can be skipped for lossy payloads — callers report from this field
    /// instead of re-deriving the eligibility rule).
    pub norms_embedded: bool,
}

/// Save any embedding store to `path`. Equivalent to
/// [`save_store_with_index`] with no index payload.
pub fn save_store(
    store: &dyn EmbeddingStore,
    path: &Path,
    opts: &SaveOptions,
) -> Result<SnapshotInfo> {
    save_store_with_index(store, None, path, opts)
}

/// Save an embedding store — plus, optionally, a trained IVF index so the
/// loading server can skip k-means — to a versioned, checksummed snapshot.
///
/// A sub-byte codec (`int4`/`b2`/`b1`) does not re-encode sections
/// element-wise: it converts a word2ket store into a
/// [`crate::quant::QuantizedKet`] and writes a `quantized_ket` snapshot
/// (packed codes + scales + f16 refinement leaves). Sub-byte codecs on any
/// other store kind are a typed error; a store that is *already* a
/// quantized-ket ignores the codec (its sections have fixed dtypes).
pub fn save_store_with_index(
    store: &dyn EmbeddingStore,
    index: Option<&IvfIndex>,
    path: &Path,
    opts: &SaveOptions,
) -> Result<SnapshotInfo> {
    let store = unwrap_wrappers(store);
    if opts.codec.is_sub_byte() {
        let sub = SaveOptions { codec: Codec::F32, ..*opts };
        return match store.repr() {
            Repr::Word2Ket(e) => {
                let qk = crate::quant::QuantizedKet::from_word2ket(e, opts.codec.bits())?;
                // Any cached scorer norms describe the *original* rows;
                // the converted store serves f16-refined rows, so norms
                // must be recomputed from it.
                save_impl(&qk, index, path, &sub, true)
            }
            Repr::QuantizedKet(_) => save_impl(store, index, path, &sub, false),
            _ => Err(Error::Snapshot(format!(
                "codec '{}' quantizes word2ket factors; store '{}' is not word2ket",
                opts.codec.name(),
                store.describe()
            ))),
        };
    }
    save_impl(store, index, path, opts, false)
}

/// The save body. `recompute_norms` forces the norms section (when
/// embedded) to be derived from `store`'s rows instead of trusting the
/// index scorer's cache — required when `store` is a lossy conversion of
/// the store the scorer was built over.
fn save_impl(
    store: &dyn EmbeddingStore,
    index: Option<&IvfIndex>,
    path: &Path,
    opts: &SaveOptions,
    recompute_norms: bool,
) -> Result<SnapshotInfo> {
    let vocab = store.vocab_size();
    let dim = store.dim();
    let codec = opts.codec;

    let mut header = Header {
        kind: StoreKind::Regular,
        vocab: vocab as u64,
        dim: dim as u64,
        order: 1,
        rank: 1,
        flags: 0,
        meta: [0u64; 6],
    };
    let mut sections: Vec<SectionData> = Vec::new();

    // Serialization dispatches on the store's typed representation — the
    // same `Repr` the index scorer resolves, so a store is snapshottable
    // exactly when it names itself.
    match store.repr() {
        Repr::Regular(e) => {
            header.kind = StoreKind::Regular;
            sections.push(encode_f32s(SEC_REGULAR_DATA, e.data(), codec, dim));
        }
        Repr::Word2Ket(e) => {
            header.kind = StoreKind::Word2Ket;
            header.order = e.order() as u32;
            header.rank = e.rank() as u32;
            header.meta[META_Q] = e.leaf_dim() as u64;
            if e.layernorm() {
                header.flags |= FLAG_LAYERNORM;
            }
            let per_word = e.rank() * e.order() * e.leaf_dim();
            let mut leaves = Vec::with_capacity(vocab * per_word);
            for w in 0..vocab {
                leaves.extend_from_slice(e.word(w).leaves());
            }
            sections.push(encode_f32s(SEC_W2K_LEAVES, &leaves, codec, per_word));
        }
        Repr::Word2KetXS(e) => {
            header.kind = StoreKind::Word2KetXS;
            header.order = e.order() as u32;
            header.rank = e.rank() as u32;
            header.meta[META_Q] = e.leaf_q() as u64;
            header.meta[META_T_OR_SEED] = e.leaf_t() as u64;
            let per_factor = e.leaf_t() * e.leaf_q();
            let mut blob = Vec::with_capacity(e.rank() * e.order() * per_factor);
            for f in e.factors() {
                blob.extend_from_slice(f);
            }
            sections.push(encode_f32s(SEC_XS_FACTORS, &blob, codec, per_factor));
        }
        Repr::Quantized(e) => {
            header.kind = StoreKind::Quantized;
            header.meta[META_PRIMARY] = e.bits() as u64;
            // The codes are already the quantized payload; re-quantizing
            // them (or their row scales/offsets) would corrupt
            // reconstruction, so all three sections stay exact regardless
            // of `codec`.
            sections.push(encode_u32s(SEC_QUANT_CODES, e.codes()));
            sections.push(encode_f32s(SEC_QUANT_SCALES, e.scales(), Codec::F32, 0));
            sections.push(encode_f32s(SEC_QUANT_OFFSETS, e.offsets(), Codec::F32, 0));
        }
        Repr::LowRank(e) => {
            header.kind = StoreKind::LowRank;
            header.meta[META_PRIMARY] = e.k() as u64;
            sections.push(encode_f32s(SEC_LOWRANK_U, e.u(), codec, e.k()));
            sections.push(encode_f32s(SEC_LOWRANK_VT, e.vt(), codec, e.k()));
        }
        Repr::Hashed(e) => {
            header.kind = StoreKind::Hashed;
            header.meta[META_PRIMARY] = e.buckets() as u64;
            header.meta[META_T_OR_SEED] = e.seed();
            sections.push(encode_f32s(SEC_HASHED_WEIGHTS, e.weights(), codec, 0));
        }
        Repr::QuantizedKet(e) => {
            header.kind = StoreKind::QuantizedKet;
            header.order = e.order() as u32;
            header.rank = e.rank() as u32;
            header.meta[META_Q] = e.leaf_dim() as u64;
            header.meta[META_T_OR_SEED] = e.bits() as u64;
            // Codes and scales *are* the quantized payload (exact u32/f32
            // sections), and the refined leaves are f16-valued by
            // construction, so the f16 leaf section is lossless too:
            // quantized_ket snapshots round-trip bit-exactly regardless of
            // the requested codec.
            sections.push(encode_u32s(SEC_QKET_CODES, e.codes()));
            sections.push(encode_f32s(SEC_QKET_SCALES, e.scales(), Codec::F32, 0));
            sections.push(encode_f32s(SEC_W2K_LEAVES, e.leaves(), Codec::F16, 0));
        }
        Repr::Snapshot(_) | Repr::Cached(_) | Repr::Opaque => {
            return Err(Error::Snapshot(format!(
                "store '{}' has no snapshot serializer",
                store.describe()
            )));
        }
    }

    // Optional norms section: requested explicitly, or implied by a cosine
    // index (whose scorer already computed exactly these values). Exact
    // payloads only: with a lossy codec the loader serves dequantized rows,
    // and norms of the *original* rows would skew its cosine denominators
    // (self-similarity ≠ 1) — lossy saves let the loader recompute instead.
    // Quantized and quantized-ket stores write byte-exact sections
    // regardless of the requested codec (see above), so their rows — and
    // thus these norms — survive any codec unchanged.
    let payload_exact = codec == Codec::F32
        || matches!(header.kind, StoreKind::Quantized | StoreKind::QuantizedKet);
    let norms_embedded =
        payload_exact && (opts.norms || index.is_some_and(|ivf| ivf.scorer().cosine()));
    if norms_embedded {
        let cached = index.and_then(|ivf| ivf.scorer().norms()).filter(|_| !recompute_norms);
        let norms = match cached {
            Some(n) => n.to_vec(),
            None => crate::index::scorer::compute_norms(store),
        };
        header.flags |= FLAG_HAS_NORMS;
        sections.push(encode_f32s(SEC_NORMS, &norms, Codec::F32, 0));
    }

    if let Some(sr) = opts.shard_range {
        sr.validate(vocab as u64)?;
        header.flags |= FLAG_HAS_SHARD_RANGE;
        sections.push(encode_u32s(SEC_SHARD_RANGE, &sr.encode()));
    }

    if let Some(ivf) = index {
        header.flags |= FLAG_HAS_INDEX;
        if ivf.scorer().cosine() {
            header.flags |= FLAG_INDEX_COSINE;
        }
        header.meta[META_IVF_NLIST] = ivf.nlist() as u64;
        // Centroids stay f32: they are nlist×dim — negligible next to any
        // materialized table — and probe geometry is precision-sensitive.
        sections.push(encode_f32s(SEC_IVF_CENTROIDS, ivf.centroids(), Codec::F32, 0));
        let lists = ivf.lists();
        let lens: Vec<u32> = lists.iter().map(|l| l.len() as u32).collect();
        let mut ids = Vec::with_capacity(vocab);
        for l in lists {
            ids.extend_from_slice(l);
        }
        sections.push(encode_u32s(SEC_IVF_LIST_LENS, &lens));
        sections.push(encode_u32s(SEC_IVF_LIST_IDS, &ids));
    }

    let n = sections.len();
    let bytes = write_snapshot(path, &header, &sections)?;
    Ok(SnapshotInfo { bytes, sections: n, norms_embedded })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EmbeddingConfig, EmbeddingKind};
    use crate::embedding::{build, materialize, QuantizedEmbedding, Word2Ket, Word2KetXS};
    use crate::serving::ShardedCache;
    use crate::util::Rng;
    use std::path::PathBuf;
    use std::sync::Arc;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("w2k_snap_test_{}_{}.snap", std::process::id(), name))
    }

    fn all_kind_cfgs() -> Vec<(EmbeddingKind, EmbeddingConfig)> {
        [
            EmbeddingKind::Regular,
            EmbeddingKind::Word2Ket,
            EmbeddingKind::Word2KetXS,
            EmbeddingKind::Quantized,
            EmbeddingKind::LowRank,
            EmbeddingKind::Hashed,
            EmbeddingKind::QuantizedKet,
        ]
        .into_iter()
        .map(|kind| {
            (kind, EmbeddingConfig { kind, order: 2, rank: 2, ..Default::default() })
        })
        .collect()
    }

    /// Acceptance: save → load reproduces every row bit-exactly for f32
    /// payloads, on both the heap and the mmap path, for every kind.
    #[test]
    fn roundtrip_bit_exact_all_kinds() {
        for (kind, cfg) in all_kind_cfgs() {
            let mut rng = Rng::new(11);
            let store = build(&cfg, 60, 16, &mut rng);
            let path = tmp(&format!("rt_{}", cfg.kind.name()));
            let info = save_store(store.as_ref(), &path, &SaveOptions::default()).unwrap();
            assert!(info.bytes > 0 && info.sections >= 1);

            let want = materialize(store.as_ref());

            // Heap path: concrete store reconstruction.
            let snap = Snapshot::open(&path, false).unwrap();
            let loaded = load_store(&snap).unwrap();
            assert_eq!(loaded.vocab_size(), 60, "{kind:?}");
            assert_eq!(loaded.dim(), 16, "{kind:?}");
            assert_eq!(loaded.num_params(), store.num_params(), "{kind:?}");
            let got = materialize(loaded.as_ref());
            assert_eq!(want.data(), got.data(), "{kind:?} heap path not bit-exact");

            // Mmap path: zero-copy SnapshotStore.
            let snap = Arc::new(Snapshot::open(&path, true).unwrap());
            let mm = SnapshotStore::open(snap).unwrap();
            assert_eq!(mm.vocab_size(), 60);
            assert_eq!(mm.dim(), 16);
            assert_eq!(mm.num_params(), store.num_params(), "{kind:?}");
            let got = materialize(&mm);
            assert_eq!(want.data(), got.data(), "{kind:?} mmap path not bit-exact");

            std::fs::remove_file(&path).ok();
        }
    }

    /// word2ket with LayerNorm-ed tree nodes round-trips bit-exactly too
    /// (the flag travels in the header).
    #[test]
    fn roundtrip_word2ket_layernorm() {
        let mut rng = Rng::new(12);
        let mut e = Word2Ket::random(30, 16, 2, 2, &mut rng);
        e.set_layernorm(true);
        let path = tmp("w2k_ln");
        save_store(&e, &path, &SaveOptions::default()).unwrap();
        let snap = Arc::new(Snapshot::open(&path, true).unwrap());
        assert_eq!(snap.header().flags & FLAG_LAYERNORM, FLAG_LAYERNORM);
        let mm = SnapshotStore::open(snap.clone()).unwrap();
        for id in [0usize, 7, 29] {
            assert_eq!(e.lookup(id), mm.lookup(id), "id {id}");
        }
        assert!(!mm.factored(), "layernorm must disable the factored identity");
        let heap = load_store(&snap).unwrap();
        assert_eq!(e.lookup(13), heap.lookup(13));
        std::fs::remove_file(&path).ok();
    }

    /// Quantized payloads (f16, int8): rows agree with the original within
    /// 1e-2 cosine, on both load paths.
    #[test]
    fn quantized_payloads_close_in_cosine() {
        for codec in [Codec::F16, Codec::Int8] {
            for kind in [EmbeddingKind::Word2Ket, EmbeddingKind::Word2KetXS, EmbeddingKind::Regular]
            {
                let cfg = EmbeddingConfig { kind, order: 2, rank: 2, ..Default::default() };
                let mut rng = Rng::new(13);
                let store = build(&cfg, 50, 16, &mut rng);
                let path = tmp(&format!("q_{}_{}", codec.name(), kind.name()));
                let opts = SaveOptions { codec, ..Default::default() };
                save_store(store.as_ref(), &path, &opts).unwrap();
                let snap = Arc::new(Snapshot::open(&path, true).unwrap());
                let mm = SnapshotStore::open(snap.clone()).unwrap();
                let heap = load_store(&snap).unwrap();
                for id in 0..50 {
                    let a = store.lookup(id);
                    for b in [mm.lookup(id), heap.lookup(id)] {
                        let (mut ab, mut aa, mut bb) = (0.0f64, 0.0f64, 0.0f64);
                        for (x, y) in a.iter().zip(b.iter()) {
                            ab += (*x as f64) * (*y as f64);
                            aa += (*x as f64) * (*x as f64);
                            bb += (*y as f64) * (*y as f64);
                        }
                        let cos = ab / (aa.sqrt() * bb.sqrt()).max(1e-30);
                        assert!(
                            cos > 0.99,
                            "{codec:?}/{kind:?} id {id}: cosine {cos}"
                        );
                    }
                }
                std::fs::remove_file(&path).ok();
            }
        }
    }

    /// f16/int8 payloads actually shrink the file.
    #[test]
    fn quantized_payloads_shrink_disk() {
        let cfg = EmbeddingConfig {
            kind: EmbeddingKind::Word2KetXS,
            order: 2,
            rank: 4,
            ..Default::default()
        };
        let mut rng = Rng::new(14);
        let store = build(&cfg, 1000, 64, &mut rng);
        let p32 = tmp("sz32");
        let p16 = tmp("sz16");
        let p8 = tmp("sz8");
        let save = |path: &std::path::Path, codec: Codec| {
            save_store(store.as_ref(), path, &SaveOptions { codec, ..Default::default() })
                .unwrap()
                .bytes
        };
        let b32 = save(&p32, Codec::F32);
        let b16 = save(&p16, Codec::F16);
        let b8 = save(&p8, Codec::Int8);
        assert!(b16 < b32, "f16 {b16} !< f32 {b32}");
        assert!(b8 < b16, "int8 {b8} !< f16 {b16}");
        for p in [p32, p16, p8] {
            std::fs::remove_file(&p).ok();
        }
    }

    /// Corrupted and truncated snapshots are rejected with typed errors —
    /// never panics, never a half-valid handle.
    #[test]
    fn corruption_and_truncation_rejected() {
        let cfg = EmbeddingConfig {
            kind: EmbeddingKind::Word2KetXS,
            order: 2,
            rank: 2,
            ..Default::default()
        };
        let mut rng = Rng::new(15);
        let store = build(&cfg, 40, 16, &mut rng);
        let path = tmp("corrupt");
        save_store(store.as_ref(), &path, &SaveOptions::default()).unwrap();
        let good = std::fs::read(&path).unwrap();

        let expect_snapshot_err = |bytes: &[u8], what: &str| {
            std::fs::write(&path, bytes).unwrap();
            for mmap in [false, true] {
                match Snapshot::open(&path, mmap) {
                    Err(Error::Snapshot(_)) => {}
                    Err(other) => panic!("{what} (mmap={mmap}): wrong error kind {other}"),
                    Ok(_) => panic!("{what} (mmap={mmap}): accepted"),
                }
            }
        };

        // Flip one payload byte (breaks a section CRC).
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x5a;
        expect_snapshot_err(&bad, "payload corruption");

        // Flip a header byte (breaks the header CRC).
        let mut bad = good.clone();
        bad[0x20] ^= 0xff;
        expect_snapshot_err(&bad, "header corruption");

        // Truncate mid-payload and mid-header.
        expect_snapshot_err(&good[..good.len() - 7], "payload truncation");
        expect_snapshot_err(&good[..40], "header truncation");

        // Not a snapshot at all.
        expect_snapshot_err(b"definitely not a snapshot file", "bad magic");

        std::fs::remove_file(&path).ok();
    }

    /// Factored inner products from a mapped snapshot are bit-identical to
    /// the original store's (the k-NN swap guarantee).
    #[test]
    fn snapshot_inner_bit_exact() {
        let mut rng = Rng::new(16);
        let xs = Word2KetXS::random(80, 16, 2, 3, &mut rng);
        let path = tmp("inner_xs");
        save_store(&xs, &path, &SaveOptions::default()).unwrap();
        let mm = SnapshotStore::open(Arc::new(Snapshot::open(&path, true).unwrap())).unwrap();
        assert!(mm.factored());
        for (a, b) in [(0usize, 1usize), (7, 7), (63, 12), (79, 0)] {
            assert_eq!(
                xs.inner(a, b).to_bits(),
                mm.inner(a, b).to_bits(),
                "xs inner ({a},{b})"
            );
        }
        std::fs::remove_file(&path).ok();

        let w2k = Word2Ket::random(40, 16, 2, 2, &mut rng);
        let path = tmp("inner_w2k");
        save_store(&w2k, &path, &SaveOptions::default()).unwrap();
        let mm = SnapshotStore::open(Arc::new(Snapshot::open(&path, true).unwrap())).unwrap();
        assert!(mm.factored());
        for (a, b) in [(0usize, 1usize), (5, 5), (39, 2)] {
            assert_eq!(
                w2k.inner(a, b).to_bits(),
                mm.inner(a, b).to_bits(),
                "w2k inner ({a},{b})"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    /// Norms section round-trip: `--with-norms` saves exactly the values
    /// the scorer would compute, flag-gated, listed by `info`.
    #[test]
    fn norms_section_roundtrip() {
        let mut rng = Rng::new(21);
        let xs = Word2KetXS::random(70, 16, 2, 2, &mut rng);
        let want = crate::index::scorer::compute_norms(&xs);
        let path = tmp("norms_rt");
        save_store(&xs, &path, &SaveOptions { norms: true, ..Default::default() }).unwrap();
        let snap = Arc::new(Snapshot::open(&path, true).unwrap());
        assert_eq!(snap.header().flags & FLAG_HAS_NORMS, FLAG_HAS_NORMS);
        assert!(snap.describe().contains("norms"), "{}", snap.describe());
        let mm = SnapshotStore::open(snap).unwrap();
        let got = mm.norms().expect("norms embedded");
        assert_eq!(got.len(), 70);
        for (id, (w, g)) in want.iter().zip(got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "norm {id}");
        }
        // A plain save carries no norms.
        save_store(&xs, &path, &SaveOptions::default()).unwrap();
        let snap = Arc::new(Snapshot::open(&path, true).unwrap());
        assert_eq!(snap.header().flags & FLAG_HAS_NORMS, 0);
        assert!(SnapshotStore::open(snap).unwrap().norms().is_none());
        // Neither does a lossy save, even when asked: the loader serves
        // dequantized rows, so it must recompute norms to stay consistent.
        let lossy_norms = SaveOptions { codec: Codec::F16, norms: true, ..Default::default() };
        save_store(&xs, &path, &lossy_norms).unwrap();
        let snap = Arc::new(Snapshot::open(&path, true).unwrap());
        assert_eq!(snap.header().flags & FLAG_HAS_NORMS, 0, "lossy codec must not embed norms");
        // A quantized store's sections are byte-exact under any codec, so
        // its norms still embed.
        let mut rng = Rng::new(24);
        let q = QuantizedEmbedding::random(30, 16, 8, &mut rng);
        save_store(&q, &path, &lossy_norms).unwrap();
        let snap = Arc::new(Snapshot::open(&path, true).unwrap());
        assert_eq!(snap.header().flags & FLAG_HAS_NORMS, FLAG_HAS_NORMS);
        std::fs::remove_file(&path).ok();
    }

    /// A cosine IVF save embeds norms automatically (the scorer already
    /// computed them), and a cosine scorer over the reloaded store skips
    /// its norm pass: zero store reads through the cache at construction.
    #[test]
    fn cosine_ivf_save_embeds_norms_and_scorer_skips_pass() {
        use crate::index::Scorer;
        let mut rng = Rng::new(22);
        let xs = Word2KetXS::random(120, 16, 2, 2, &mut rng);
        let arc: Arc<dyn EmbeddingStore> = Arc::new(xs.clone());
        let direct = Scorer::new(arc.clone(), true);
        let ivf = crate::index::IvfIndex::build(Scorer::new(arc, true), 4, 2, 1);
        let path = tmp("norms_ivf");
        save_store_with_index(&xs, Some(&ivf), &path, &SaveOptions::default()).unwrap();

        let snap = Arc::new(Snapshot::open(&path, true).unwrap());
        assert_eq!(snap.header().flags & FLAG_HAS_NORMS, FLAG_HAS_NORMS);
        let mm = SnapshotStore::open(snap).unwrap();
        let cached = ShardedCache::new(Box::new(mm), 2, 64);
        let reloaded = Scorer::new(Arc::new(cached), true);
        // Cosine scores bit-identical to the pre-snapshot scorer: same
        // factored kernels, same (embedded) norms.
        for (a, b) in [(0usize, 1usize), (7, 7), (119, 42)] {
            assert_eq!(
                direct.score_pair(a, b).to_bits(),
                reloaded.score_pair(a, b).to_bits(),
                "({a},{b})"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    /// The embedded-norms fast path really skips the pass: a cosine scorer
    /// over a *dense* snapshot (no factored shortcut) reads zero rows when
    /// norms are embedded, and the whole vocabulary when they are not.
    #[test]
    fn embedded_norms_skip_dense_norm_pass() {
        use crate::index::Scorer;
        let mut rng = Rng::new(23);
        let e = crate::embedding::RegularEmbedding::random(50, 8, &mut rng);
        let path = tmp("norms_skip");
        for with_norms in [false, true] {
            save_store(&e, &path, &SaveOptions { norms: with_norms, ..Default::default() })
                .unwrap();
            let mm =
                SnapshotStore::open(Arc::new(Snapshot::open(&path, true).unwrap())).unwrap();
            let cached = Arc::new(ShardedCache::new(Box::new(mm), 1, 64));
            let probe = cached.clone();
            let _scorer = Scorer::new(cached, true);
            let stats = probe.stats();
            let reads = stats.hits + stats.misses;
            if with_norms {
                assert_eq!(reads, 0, "norm pass must be skipped with embedded norms");
            } else {
                assert_eq!(reads, 50, "dense norm pass reads every row once");
            }
        }
        std::fs::remove_file(&path).ok();
    }

    /// A CRC-valid snapshot pairing lossy-coded factors with the norms
    /// flag is rejected: the writer never produces it, and accepting it
    /// would score cosine queries against inconsistent denominators.
    #[test]
    fn lossy_factors_with_norms_flag_rejected() {
        let mut rng = Rng::new(25);
        let xs = Word2KetXS::random(9, 4, 2, 2, &mut rng); // t = 3, q = 2
        let mut blob = Vec::new();
        for f in xs.factors() {
            blob.extend_from_slice(f);
        }
        let mut meta = [0u64; 6];
        meta[META_Q] = 2;
        meta[META_T_OR_SEED] = 3;
        let header = Header {
            kind: StoreKind::Word2KetXS,
            vocab: 9,
            dim: 4,
            order: 2,
            rank: 2,
            flags: FLAG_HAS_NORMS,
            meta,
        };
        let sections = vec![
            encode_f32s(SEC_XS_FACTORS, &blob, Codec::F16, 6),
            encode_f32s(SEC_NORMS, &[1.0f32; 9], Codec::F32, 0),
        ];
        let path = tmp("norms_lossy");
        write_snapshot(&path, &header, &sections).unwrap();
        let snap = Arc::new(Snapshot::open(&path, false).unwrap());
        match SnapshotStore::open(snap) {
            Err(crate::Error::Snapshot(msg)) => assert!(msg.contains("norms"), "{msg}"),
            other => panic!("lossy factors + norms flag accepted: {:?}", other.map(|_| ())),
        }
        std::fs::remove_file(&path).ok();
    }

    /// A CRC-valid snapshot with hostile norms (NaN) is rejected at open.
    #[test]
    fn non_finite_norms_rejected() {
        let header = Header {
            kind: StoreKind::Regular,
            vocab: 4,
            dim: 2,
            order: 1,
            rank: 1,
            flags: FLAG_HAS_NORMS,
            meta: [0u64; 6],
        };
        let data: Vec<f32> = (0..8).map(|x| x as f32).collect();
        let norms = [1.0f32, f32::NAN, 2.0, 3.0];
        let sections = vec![
            encode_f32s(SEC_REGULAR_DATA, &data, Codec::F32, 0),
            encode_f32s(SEC_NORMS, &norms, Codec::F32, 0),
        ];
        let path = tmp("norms_nan");
        write_snapshot(&path, &header, &sections).unwrap();
        let snap = Arc::new(Snapshot::open(&path, false).unwrap());
        match SnapshotStore::open(snap) {
            Err(crate::Error::Snapshot(msg)) => assert!(msg.contains("norms"), "{msg}"),
            other => panic!("hostile norms accepted: {:?}", other.map(|_| ())),
        }
        std::fs::remove_file(&path).ok();
    }

    /// A sub-byte codec converts a word2ket store into a `quantized_ket`
    /// snapshot: rows and coarse scores bit-match the in-memory
    /// [`crate::quant::QuantizedKet`] on both load paths, non-word2ket
    /// stores are rejected, and a native quantized-ket store ignores the
    /// codec.
    #[test]
    fn sub_byte_codec_converts_word2ket() {
        use crate::repr::FactoredRepr;
        let mut rng = Rng::new(27);
        let w = Word2Ket::random(40, 16, 2, 2, &mut rng);
        for codec in [Codec::Int4, Codec::B2, Codec::B1] {
            let want = crate::quant::QuantizedKet::from_word2ket(&w, codec.bits()).unwrap();
            let path = tmp(&format!("conv_{}", codec.name()));
            save_store(&w, &path, &SaveOptions { codec, ..Default::default() }).unwrap();
            let snap = Arc::new(Snapshot::open(&path, true).unwrap());
            assert_eq!(snap.kind(), StoreKind::QuantizedKet);
            assert_eq!(snap.header().meta[META_T_OR_SEED], codec.bits() as u64);
            let d = snap.describe();
            assert!(
                d.contains("quantized_ket.codes") && d.contains("quantized_ket.scales"),
                "{d}"
            );
            let mm = SnapshotStore::open(snap.clone()).unwrap();
            let heap = load_store(&snap).unwrap();
            assert!(mm.factored());
            assert_eq!(mm.payload_bits(), codec.bits());
            assert_eq!(mm.num_params(), want.num_params());
            for id in [0usize, 9, 39] {
                assert_eq!(mm.lookup(id), want.lookup(id), "{codec:?} mmap id {id}");
                assert_eq!(heap.lookup(id), want.lookup(id), "{codec:?} heap id {id}");
            }
            for (a, b) in [(0usize, 1usize), (5, 31)] {
                assert_eq!(
                    mm.inner(a, b).to_bits(),
                    FactoredRepr::inner(&want, a, b).to_bits(),
                    "{codec:?} coarse ({a},{b})"
                );
            }
            std::fs::remove_file(&path).ok();
        }

        // Sub-byte codecs only quantize word2ket factors.
        let mut rng = Rng::new(28);
        let xs = Word2KetXS::random(20, 16, 2, 2, &mut rng);
        let path = tmp("conv_bad_kind");
        assert!(matches!(
            save_store(&xs, &path, &SaveOptions { codec: Codec::B1, ..Default::default() }),
            Err(Error::Snapshot(_))
        ));

        // A store that is already quantized-ket keeps its own width; the
        // requested codec is irrelevant to its fixed-dtype sections.
        let native = crate::quant::QuantizedKet::from_word2ket(&w, 2).unwrap();
        save_store(&native, &path, &SaveOptions { codec: Codec::Int4, ..Default::default() })
            .unwrap();
        let snap = Arc::new(Snapshot::open(&path, true).unwrap());
        assert_eq!(snap.kind(), StoreKind::QuantizedKet);
        assert_eq!(snap.header().meta[META_T_OR_SEED], 2);
        std::fs::remove_file(&path).ok();
    }

    /// Norms embedded next to a sub-byte payload describe the *converted*
    /// rows (the rows the loader serves), not the original word2ket rows.
    #[test]
    fn sub_byte_norms_describe_converted_rows() {
        let mut rng = Rng::new(30);
        let w = Word2Ket::random(35, 16, 2, 2, &mut rng);
        let path = tmp("conv_norms");
        let opts = SaveOptions { codec: Codec::Int4, norms: true, ..Default::default() };
        save_store(&w, &path, &opts).unwrap();
        let snap = Arc::new(Snapshot::open(&path, true).unwrap());
        assert_eq!(snap.header().flags & FLAG_HAS_NORMS, FLAG_HAS_NORMS);
        let mm = SnapshotStore::open(snap).unwrap();
        let want = crate::index::scorer::compute_norms(&mm);
        let got = mm.norms().expect("norms embedded");
        for (id, (a, b)) in want.iter().zip(got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "norm {id}");
        }
        std::fs::remove_file(&path).ok();
    }

    /// Sub-byte files shrink with the code width (the refinement payload
    /// sets the floor — b1 and b2 can tie on the one-word-per-leaf floor).
    #[test]
    fn sub_byte_snapshots_shrink_disk() {
        let mut rng = Rng::new(29);
        let w = Word2Ket::random(300, 256, 2, 2, &mut rng);
        let save = |codec: Codec, name: &str| {
            let path = tmp(name);
            let b = save_store(&w, &path, &SaveOptions { codec, ..Default::default() })
                .unwrap()
                .bytes;
            std::fs::remove_file(&path).ok();
            b
        };
        let b32 = save(Codec::F32, "szq32");
        let i4 = save(Codec::Int4, "szq4");
        let b2 = save(Codec::B2, "szq2");
        let b1 = save(Codec::B1, "szq1");
        assert!(i4 < b32, "int4 {i4} !< f32 {b32}");
        assert!(b2 < i4 && b1 <= b2, "b1 {b1} / b2 {b2} / int4 {i4}");
    }

    /// Satellite hardening: CRC-valid quantized-ket files with hostile
    /// scales, padding bits, geometry, or bit widths are rejected with
    /// typed errors on both load paths.
    #[test]
    fn hostile_quantized_ket_snapshots_rejected() {
        let mut rng = Rng::new(26);
        let w = Word2Ket::random(6, 16, 2, 1, &mut rng);
        let qk = crate::quant::QuantizedKet::from_word2ket(&w, 4).unwrap();
        let mut meta = [0u64; 6];
        meta[META_Q] = 4;
        meta[META_T_OR_SEED] = 4;
        let header = Header {
            kind: StoreKind::QuantizedKet,
            vocab: 6,
            dim: 16,
            order: 2,
            rank: 1,
            flags: 0,
            meta,
        };
        let path = tmp("qket_hostile");
        let write = |header: &Header, codes: &[u32], scales: &[f32], leaves: &[f32]| {
            let sections = vec![
                encode_u32s(SEC_QKET_CODES, codes),
                encode_f32s(SEC_QKET_SCALES, scales, Codec::F32, 0),
                encode_f32s(SEC_W2K_LEAVES, leaves, Codec::F16, 0),
            ];
            write_snapshot(&path, header, &sections).unwrap();
        };

        // Baseline: the unmutated file opens on both paths.
        write(&header, qk.codes(), qk.scales(), qk.leaves());
        let snap = Arc::new(Snapshot::open(&path, true).unwrap());
        assert!(SnapshotStore::open(snap.clone()).is_ok());
        assert!(load_store(&snap).is_ok());

        let expect_rejected = |what: &str| {
            // Hostile scales die inside Snapshot::open (parse-time);
            // geometry/padding mutations die when a store is built over
            // the otherwise-valid file — and the two load paths must
            // agree on acceptance.
            let rejected = match Snapshot::open(&path, true) {
                Err(Error::Snapshot(_)) => true,
                Err(other) => panic!("{what}: wrong error kind {other}"),
                Ok(snap) => {
                    let snap = Arc::new(snap);
                    let mm_bad = SnapshotStore::open(snap.clone()).is_err();
                    let heap_bad = load_store(&snap).is_err();
                    assert_eq!(mm_bad, heap_bad, "{what}: load paths disagree");
                    mm_bad
                }
            };
            assert!(rejected, "{what}: hostile snapshot accepted");
        };

        for bad in [f32::NAN, f32::NEG_INFINITY, -0.5] {
            let mut s = qk.scales().to_vec();
            s[2] = bad;
            write(&header, qk.codes(), &s, qk.leaves());
            expect_rejected(&format!("scale {bad}"));
        }
        // Nonzero padding bits (q=4 at 4 bits uses 16 of 32 word bits).
        let mut c = qk.codes().to_vec();
        c[0] |= 1 << 30;
        write(&header, &c, qk.scales(), qk.leaves());
        expect_rejected("nonzero padding bits");
        // Scale-count / geometry mismatch.
        write(&header, qk.codes(), &qk.scales()[1..], qk.leaves());
        expect_rejected("scale count");
        // Unsupported code width in the header.
        let mut h = header;
        h.meta[META_T_OR_SEED] = 3;
        write(&h, qk.codes(), qk.scales(), qk.leaves());
        expect_rejected("bits=3");
        // Hostile q blows the dim envelope (would drive oversized scratch).
        let mut h = header;
        h.meta[META_Q] = 4096;
        write(&h, qk.codes(), qk.scales(), qk.leaves());
        expect_rejected("q envelope");
        std::fs::remove_file(&path).ok();
    }

    /// Saving through a cache wrapper snapshots the wrapped store.
    #[test]
    fn save_unwraps_cache() {
        let mut rng = Rng::new(17);
        let inner = Box::new(Word2KetXS::random(50, 16, 2, 2, &mut rng));
        let want = materialize(inner.as_ref());
        let cache = ShardedCache::new(inner, 2, 64);
        let path = tmp("cache");
        save_store(&cache, &path, &SaveOptions::default()).unwrap();
        let snap = Snapshot::open(&path, false).unwrap();
        assert_eq!(snap.kind(), StoreKind::Word2KetXS);
        let loaded = load_store(&snap).unwrap();
        assert_eq!(want.data(), materialize(loaded.as_ref()).data());
        std::fs::remove_file(&path).ok();
    }

    /// Info/describe renders something useful for every section.
    #[test]
    fn describe_lists_sections() {
        let mut rng = Rng::new(18);
        let e = QuantizedEmbedding::random(30, 16, 8, &mut rng);
        let path = tmp("describe");
        save_store(&e, &path, &SaveOptions::default()).unwrap();
        let snap = Snapshot::open(&path, false).unwrap();
        let d = snap.describe();
        assert!(d.contains("quantized.codes"), "{d}");
        assert!(d.contains("quantized.scales"), "{d}");
        assert!(d.contains("kind=quantized"), "{d}");
        std::fs::remove_file(&path).ok();
    }

    /// Shard-assignment metadata round-trips through the container and is
    /// validated at save *and* open; rows are untouched by the section.
    #[test]
    fn shard_range_section_roundtrip_and_validation() {
        let mut rng = Rng::new(31);
        let e = Word2KetXS::random(25, 16, 2, 2, &mut rng);
        let sr = ShardRange {
            strategy: SHARD_STRATEGY_RANGE,
            shard: 1,
            n_shards: 4,
            global_vocab: 100,
            start: 25,
            end: 50,
        };
        let path = tmp("shard_range");
        let opts = SaveOptions { shard_range: Some(sr), ..Default::default() };
        save_store(&e, &path, &opts).unwrap();

        let snap = Snapshot::open(&path, true).unwrap();
        assert_eq!(snap.header().flags & FLAG_HAS_SHARD_RANGE, FLAG_HAS_SHARD_RANGE);
        assert_eq!(snap.shard_range(), Some(sr));
        assert!(snap.describe().contains("shard 1/4"), "{}", snap.describe());
        let mm = SnapshotStore::open(Arc::new(snap)).unwrap();
        assert_eq!(mm.lookup(3), e.lookup(3), "metadata section must not touch rows");

        // An assignment that does not cover this store's vocabulary is
        // rejected at save time.
        let bad = ShardRange { end: 51, ..sr };
        let opts = SaveOptions { shard_range: Some(bad), ..Default::default() };
        assert!(matches!(save_store(&e, &path, &opts), Err(Error::Snapshot(_))));

        // Unsharded snapshots carry no assignment.
        save_store(&e, &path, &SaveOptions::default()).unwrap();
        assert_eq!(Snapshot::open(&path, true).unwrap().shard_range(), None);
        std::fs::remove_file(&path).ok();
    }
}
