//! `SnapshotStore`: serve factored lookups straight out of an open snapshot
//! (zero-copy for f32 payloads) without heap-materializing any table.
//!
//! Every [`crate::config::EmbeddingKind`] is supported. Reconstruction
//! mirrors the concrete in-memory stores *operation for operation* — same
//! balanced product tree, same fused order-2 outer product, same bit-packed
//! code extraction — so rows and factored inner products are bit-identical
//! to the store the snapshot was saved from (f32 payloads). For f16/int8
//! payloads the factor tensors are dequantized once into a small owned
//! buffer at open (they are the *compressed* representation, so this stays
//! tiny) and reconstruction proceeds identically from there.
//!
//! The index scorer treats a `SnapshotStore` over raw word2ket/word2ketXS
//! factors as a factored backend (see `index::scorer`), so k-NN keeps
//! scoring in `O(r²nq)` after a hot swap.

use super::format::*;
use super::reader::Snapshot;
use crate::embedding::quantized::get_bits;
use crate::embedding::EmbeddingStore;
use crate::error::{Error, Result};
use crate::kron::{kron_accumulate, tree_term, MixedRadix};
use crate::quant::{self, QketView};
use crate::repr::{kernels, FactorGeometry, FactoredRepr, Repr};
use crate::tensor::dot;
use crate::util::rng::splitmix64;
use std::sync::Arc;

/// A float slab: zero-copy offsets into the snapshot (F32 payloads) or a
/// small owned dequantized buffer (F16/I8 payloads).
enum Slab {
    Map { off: usize, count: usize },
    Own(Vec<f32>),
}

/// Same for u32 payloads (bit-packed quantization codes, always exact).
enum SlabU32 {
    Map { off: usize, count: usize },
}

/// Kind-specific resolved view over the snapshot sections.
enum View {
    Regular {
        data: Slab,
    },
    W2k {
        leaves: Slab,
        q: usize,
        layernorm: bool,
    },
    Xs {
        factors: Slab,
        q: usize,
        t: usize,
        radix: MixedRadix,
    },
    Quant {
        codes: SlabU32,
        scales: Slab,
        offsets: Slab,
        bits: usize,
    },
    LowRank {
        u: Slab,
        vt: Slab,
        k: usize,
    },
    Hashed {
        weights: Slab,
        seed: u64,
    },
    /// Quantized-ket: packed codes + per-leaf scales score in the
    /// quantized domain straight off the mapping; the f16 refinement
    /// leaves (decoded once at open) serve rows and exact re-ranks.
    QKet {
        codes: SlabU32,
        scales: Slab,
        leaves: Slab,
        q: usize,
        bits: usize,
    },
}

/// Snapshot-backed embedding store (see module docs).
pub struct SnapshotStore {
    snap: Arc<Snapshot>,
    vocab: usize,
    dim: usize,
    order: usize,
    rank: usize,
    view: View,
    /// Optional embedded per-word L2 norms (`FLAG_HAS_NORMS`): lets a
    /// cosine-mode scorer skip its construction-time norm pass entirely.
    norms: Option<Slab>,
}

/// Overflow-checked product: a CRC-valid but hostile header must yield a
/// typed error, never an arithmetic panic.
fn prod(parts: &[usize]) -> Result<usize> {
    let mut acc = 1usize;
    for &p in parts {
        acc = acc
            .checked_mul(p)
            .ok_or_else(|| Error::Snapshot("snapshot geometry product overflows".into()))?;
    }
    Ok(acc)
}

impl SnapshotStore {
    /// Resolve a float section into a slab: zero-copy for F32, dequantized
    /// once into the heap for F16/I8.
    fn slab_for(snap: &Snapshot, id: u32, expect: usize) -> Result<Slab> {
        let sec = *snap
            .section(id)
            .ok_or_else(|| Error::Snapshot(format!("missing section {}", section_name(id))))?;
        if sec.count as usize != expect {
            return Err(Error::Snapshot(format!(
                "section {} has {} values, expected {expect}",
                section_name(id),
                sec.count
            )));
        }
        match sec.dtype {
            Dtype::F32 => Ok(Slab::Map { off: sec.offset as usize, count: expect }),
            Dtype::F16 | Dtype::I8 => Ok(Slab::Own(snap.read_f32s(&sec)?)),
            Dtype::U32 => Err(Error::Snapshot(format!(
                "section {} is u32-typed, expected floats",
                section_name(id)
            ))),
        }
    }

    fn slab_u32_for(snap: &Snapshot, id: u32, expect: usize) -> Result<SlabU32> {
        let sec = *snap
            .section(id)
            .ok_or_else(|| Error::Snapshot(format!("missing section {}", section_name(id))))?;
        if sec.dtype != Dtype::U32 {
            return Err(Error::Snapshot(format!(
                "section {} must be u32-typed",
                section_name(id)
            )));
        }
        if sec.count as usize != expect {
            return Err(Error::Snapshot(format!(
                "section {} has {} values, expected {expect}",
                section_name(id),
                sec.count
            )));
        }
        Ok(SlabU32::Map { off: sec.offset as usize, count: expect })
    }

    /// Open a store view over a validated snapshot.
    pub fn open(snap: Arc<Snapshot>) -> Result<SnapshotStore> {
        let h = *snap.header();
        let vocab = h.vocab as usize;
        let dim = h.dim as usize;
        let order = h.order as usize;
        let rank = h.rank as usize;
        if vocab == 0 || dim == 0 {
            return Err(Error::Snapshot("snapshot has empty vocab/dim".into()));
        }
        let view = match h.kind {
            StoreKind::Regular => View::Regular {
                data: Self::slab_for(&snap, SEC_REGULAR_DATA, prod(&[vocab, dim])?)?,
            },
            StoreKind::Word2Ket => {
                let q = h.meta[META_Q] as usize;
                if !(2..=16).contains(&order) || rank == 0 || q == 0 {
                    return Err(Error::Snapshot(format!(
                        "bad word2ket geometry: order={order} rank={rank} q={q}"
                    )));
                }
                let full = q
                    .checked_pow(order as u32)
                    .ok_or_else(|| Error::Snapshot("word2ket q^order overflows".into()))?;
                // Lower bound: reconstruction must cover dim. Upper bound:
                // the legit constructor picks minimal q = ⌈dim^(1/n)⌉, so
                // q^n ≤ dim·2^n always; a CRC-valid hostile header with a
                // huge q must not drive a q^n-sized allocation per lookup.
                if full < dim || full > dim.saturating_mul(1usize << order) {
                    return Err(Error::Snapshot(format!(
                        "word2ket q^order = {full} inconsistent with dim {dim}"
                    )));
                }
                View::W2k {
                    leaves: Self::slab_for(
                        &snap,
                        SEC_W2K_LEAVES,
                        prod(&[vocab, rank, order, q])?,
                    )?,
                    q,
                    layernorm: h.flags & FLAG_LAYERNORM != 0,
                }
            }
            StoreKind::Word2KetXS => {
                let q = h.meta[META_Q] as usize;
                let t = h.meta[META_T_OR_SEED] as usize;
                if !(2..=8).contains(&order) || rank == 0 || q == 0 || t == 0 {
                    return Err(Error::Snapshot(format!(
                        "bad word2ketXS geometry: order={order} rank={rank} q={q} t={t}"
                    )));
                }
                let full = q
                    .checked_pow(order as u32)
                    .ok_or_else(|| Error::Snapshot("word2ketXS q^order overflows".into()))?;
                let cap = t
                    .checked_pow(order as u32)
                    .ok_or_else(|| Error::Snapshot("word2ketXS t^order overflows".into()))?;
                // Same bounds as word2ket: minimal-root construction means
                // q^n ≤ dim·2^n and t^n ≤ vocab·2^n (the `.max(2)` floor is
                // covered because dim/vocab ≥ 1 ⇒ 2^n ≤ bound); anything
                // larger is hostile and would blow up per-lookup scratch.
                if full < dim
                    || cap < vocab
                    || full > dim.saturating_mul(1usize << order)
                    || cap > vocab.saturating_mul(1usize << order)
                {
                    return Err(Error::Snapshot(format!(
                        "word2ketXS geometry inconsistent with {vocab}x{dim} (q^n={full}, t^n={cap})"
                    )));
                }
                View::Xs {
                    factors: Self::slab_for(
                        &snap,
                        SEC_XS_FACTORS,
                        prod(&[rank, order, t, q])?,
                    )?,
                    q,
                    t,
                    radix: MixedRadix::uniform(t, order),
                }
            }
            StoreKind::Quantized => {
                let bits = h.meta[META_PRIMARY] as usize;
                if !(1..=16).contains(&bits) {
                    return Err(Error::Snapshot(format!("quantized bits {bits} outside 1..=16")));
                }
                let n_codes = prod(&[vocab, dim, bits])?.div_ceil(32);
                View::Quant {
                    codes: Self::slab_u32_for(&snap, SEC_QUANT_CODES, n_codes)?,
                    scales: Self::slab_for(&snap, SEC_QUANT_SCALES, vocab)?,
                    offsets: Self::slab_for(&snap, SEC_QUANT_OFFSETS, vocab)?,
                    bits,
                }
            }
            StoreKind::LowRank => {
                let k = h.meta[META_PRIMARY] as usize;
                if k == 0 {
                    return Err(Error::Snapshot("lowrank k must be >= 1".into()));
                }
                View::LowRank {
                    u: Self::slab_for(&snap, SEC_LOWRANK_U, prod(&[vocab, k])?)?,
                    vt: Self::slab_for(&snap, SEC_LOWRANK_VT, prod(&[dim, k])?)?,
                    k,
                }
            }
            StoreKind::Hashed => {
                let buckets = h.meta[META_PRIMARY] as usize;
                if buckets == 0 {
                    return Err(Error::Snapshot("hashed buckets must be >= 1".into()));
                }
                View::Hashed {
                    weights: Self::slab_for(&snap, SEC_HASHED_WEIGHTS, buckets)?,
                    seed: h.meta[META_T_OR_SEED],
                }
            }
            StoreKind::QuantizedKet => {
                let q = h.meta[META_Q] as usize;
                let bits = h.meta[META_T_OR_SEED] as usize;
                if !quant::SUPPORTED_BITS.contains(&bits) {
                    return Err(Error::Snapshot(format!(
                        "quantized_ket bits {bits} not one of {:?}",
                        quant::SUPPORTED_BITS
                    )));
                }
                if !(2..=crate::repr::MAX_ORDER).contains(&order)
                    || rank == 0
                    || q == 0
                    || q > quant::MAX_LEAF_DIM
                {
                    return Err(Error::Snapshot(format!(
                        "bad quantized_ket geometry: order={order} rank={rank} q={q}"
                    )));
                }
                // Same q^order envelope as the word2ket arm above: covers
                // the row, truncation bounded, hostile headers can't drive
                // oversized per-lookup scratch.
                let full = q
                    .checked_pow(order as u32)
                    .ok_or_else(|| Error::Snapshot("quantized_ket q^order overflows".into()))?;
                if full < dim || full > dim.saturating_mul(1usize << order) {
                    return Err(Error::Snapshot(format!(
                        "quantized_ket q^order = {full} inconsistent with dim {dim}"
                    )));
                }
                // The writer stores codes as U32, scales as F32, leaves as
                // F16 — exactly. Any other dtype is a hand-crafted file, and
                // accepting (say) i8-coded leaves would break the exactness
                // story lossy_payload() relies on below.
                for (id, want) in [
                    (SEC_QKET_SCALES, Dtype::F32),
                    (SEC_W2K_LEAVES, Dtype::F16),
                ] {
                    let sec = snap.section(id).ok_or_else(|| {
                        Error::Snapshot(format!("missing section {}", section_name(id)))
                    })?;
                    if sec.dtype != want {
                        return Err(Error::Snapshot(format!(
                            "section {} must be {}-typed in a quantized_ket snapshot",
                            section_name(id),
                            want.name()
                        )));
                    }
                }
                let n_leaves = prod(&[vocab, rank, order])?;
                let wpl = quant::words_per_leaf(q, bits);
                let codes = Self::slab_u32_for(&snap, SEC_QKET_CODES, prod(&[n_leaves, wpl])?)?;
                let scales = Self::slab_for(&snap, SEC_QKET_SCALES, n_leaves)?;
                let leaves = Self::slab_for(&snap, SEC_W2K_LEAVES, prod(&[n_leaves, q])?)?;
                // Nonzero padding bits would corrupt the whole-word b1
                // popcount; scale values were already vetted at parse
                // (finite, non-negative) but the packed words were not.
                let used = q * bits - (wpl - 1) * 32;
                if used < 32 {
                    let SlabU32::Map { off, count } = &codes;
                    let words = snap.u32s_at(*off, *count);
                    let pad_mask = !0u32 << used;
                    if (0..n_leaves).any(|l| words[l * wpl + wpl - 1] & pad_mask != 0) {
                        return Err(Error::Snapshot(
                            "quantized_ket codes have nonzero padding bits".into(),
                        ));
                    }
                }
                View::QKet { codes, scales, leaves, q, bits }
            }
        };
        let mut store = SnapshotStore { snap, vocab, dim, order, rank, view, norms: None };
        if h.flags & FLAG_HAS_NORMS != 0 {
            let slab = Self::slab_for(&store.snap, SEC_NORMS, vocab)?;
            // The writer only embeds norms next to exact payloads; enforce
            // the same invariant on read — a hand-crafted file pairing
            // lossy-coded factors (or lossy norms) with this flag would
            // feed cosine scoring denominators inconsistent with the
            // dequantized rows it serves.
            if matches!(slab, Slab::Own(_)) || store.lossy_payload() {
                return Err(Error::Snapshot(
                    "norms section requires exact f32 payloads (lossy-coded factors \
                     would make cosine denominators inconsistent with served rows)"
                        .into(),
                ));
            }
            {
                let norms = store.floats(&slab);
                if norms.iter().any(|n| !n.is_finite() || *n < 0.0) {
                    return Err(Error::Snapshot(
                        "norms section holds non-finite or negative values".into(),
                    ));
                }
            }
            store.norms = Some(slab);
        }
        Ok(store)
    }

    /// True when any float section was dequantized at open (f16/int8
    /// payload), i.e. served rows differ from the rows the writer saw.
    fn lossy_payload(&self) -> bool {
        let own = |s: &Slab| matches!(s, Slab::Own(_));
        match &self.view {
            View::Regular { data } => own(data),
            View::W2k { leaves, .. } => own(leaves),
            View::Xs { factors, .. } => own(factors),
            View::Quant { scales, offsets, .. } => own(scales) || own(offsets),
            View::LowRank { u, vt, .. } => own(u) || own(vt),
            View::Hashed { weights, .. } => own(weights),
            // Codes and scales are exact by the dtype enforcement at open,
            // and the f16 leaves *define* the served rows (the writer
            // computed norms from these same f16-rounded values), so a
            // quantized_ket payload is exact in the sense this gate cares
            // about even though its leaf slab is an owned decode.
            View::QKet { .. } => false,
        }
    }

    /// The shared quantized-ket payload view (see [`crate::quant`]), when
    /// this snapshot holds one. In-memory and mapped quantized-ket serving
    /// both go through this struct, so they are bit-identical.
    fn qket_view(&self) -> Option<QketView<'_>> {
        match &self.view {
            View::QKet { codes, scales, leaves, q, bits } => Some(QketView {
                order: self.order,
                rank: self.rank,
                leaf_dim: *q,
                bits: *bits,
                codes: self.u32s(codes),
                scales: self.floats(scales),
                leaves: self.floats(leaves),
            }),
            _ => None,
        }
    }

    /// Bit width of the factor payload candidate scans score against: the
    /// packed code width for quantized stores, 32 for everything that
    /// scores in (dequantized) f32. The IVF scorer re-ranks through exact
    /// rows whenever this drops below 32, and serving reports it in STATS.
    pub fn payload_bits(&self) -> usize {
        match &self.view {
            View::QKet { bits, .. } => *bits,
            View::Quant { bits, .. } => *bits,
            _ => 32,
        }
    }

    /// The underlying snapshot (generation metadata, file size).
    pub fn snapshot(&self) -> &Arc<Snapshot> {
        &self.snap
    }

    pub fn kind(&self) -> StoreKind {
        self.snap.kind()
    }

    fn floats<'a>(&'a self, slab: &'a Slab) -> &'a [f32] {
        match slab {
            Slab::Map { off, count } => self.snap.f32s_at(*off, *count),
            Slab::Own(v) => v,
        }
    }

    fn u32s<'a>(&'a self, slab: &'a SlabU32) -> &'a [u32] {
        match slab {
            SlabU32::Map { off, count } => self.snap.u32s_at(*off, *count),
        }
    }

    /// True when this snapshot holds raw (no LayerNorm), untruncated
    /// word2ket/word2ketXS factors — i.e. the factored inner-product
    /// identity holds and the index scorer can skip materialization.
    pub fn factored(&self) -> bool {
        match &self.view {
            View::W2k { q, layernorm, .. } => {
                !*layernorm && q.checked_pow(self.order as u32) == Some(self.dim)
            }
            View::Xs { q, .. } => q.checked_pow(self.order as u32) == Some(self.dim),
            // Quantized-ket factored scoring is *coarse* (see
            // `crate::quant` module docs); consumers check `payload_bits`
            // and re-rank through exact rows where it matters.
            View::QKet { q, .. } => q.checked_pow(self.order as u32) == Some(self.dim),
            _ => false,
        }
    }

    /// Leaf slice `v_{j,k}` of word `w` (word2ket view only).
    fn w2k_leaf<'a>(&self, leaves: &'a [f32], q: usize, w: usize, k: usize, j: usize) -> &'a [f32] {
        let per_word = self.rank * self.order * q;
        let off = w * per_word + (k * self.order + j) * q;
        &leaves[off..off + q]
    }

    /// Column `c` of (transposed) factor `F_jk` (word2ketXS view only).
    fn xs_col<'a>(
        &self,
        factors: &'a [f32],
        q: usize,
        t: usize,
        k: usize,
        j: usize,
        c: usize,
    ) -> &'a [f32] {
        let base = (k * self.order + j) * (t * q) + c * q;
        &factors[base..base + q]
    }

    /// Embedded per-word L2 norms, if the snapshot carries them
    /// (`FLAG_HAS_NORMS`): the values `index::scorer::compute_norms` would
    /// produce, stored at save time so a cosine scorer skips the pass.
    pub fn norms(&self) -> Option<&[f32]> {
        self.norms.as_ref().map(|s| self.floats(s))
    }

    /// Factored inner product `⟨row a, row b⟩` without reconstruction.
    /// Runs through the same shared kernels as `Word2Ket::inner` /
    /// `Word2KetXS::inner`, so results are bit-identical to pre-snapshot
    /// scoring. Only meaningful when [`factored`](Self::factored) holds.
    pub fn inner(&self, a: usize, b: usize) -> f32 {
        match &self.view {
            View::W2k { leaves, q, .. } => {
                let leaves = self.floats(leaves);
                kernels::rank_pair_sum(self.rank, self.rank, |k, k2| {
                    kernels::product_of_dots((0..self.order).map(|j| {
                        (
                            self.w2k_leaf(leaves, *q, a, k, j),
                            self.w2k_leaf(leaves, *q, b, k2, j),
                        )
                    }))
                })
            }
            View::Xs { factors, q, t, radix } => {
                let factors = self.floats(factors);
                let mut da = [0usize; 8];
                let mut db = [0usize; 8];
                radix.decode_into(a, &mut da[..self.order]);
                radix.decode_into(b, &mut db[..self.order]);
                kernels::factored_digit_inner(self.rank, self.order, &da, &db, |k, j, c| {
                    self.xs_col(factors, *q, *t, k, j, c)
                })
            }
            View::QKet { .. } => self.qket_view().expect("view matched QKet").inner(a, b),
            _ => {
                // Dense fallback: correctness over speed for non-factored
                // kinds (the scorer never routes them here).
                dot(&self.lookup(a), &self.lookup(b))
            }
        }
    }
}

impl EmbeddingStore for SnapshotStore {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn num_params(&self) -> usize {
        match &self.view {
            View::Regular { .. } => self.vocab * self.dim,
            View::W2k { q, .. } => self.vocab * self.rank * self.order * q,
            View::Xs { q, t, .. } => self.rank * self.order * q * t,
            View::Quant { bits, .. } => (self.vocab * self.dim * bits).div_ceil(32) + 2 * self.vocab,
            View::LowRank { k, .. } => k * (self.vocab + self.dim),
            View::Hashed { weights, .. } => match weights {
                Slab::Map { count, .. } => *count,
                Slab::Own(v) => v.len(),
            },
            // Match QuantizedKet::num_params: 4-byte units stored (code
            // words + f32 scales + f16 leaves at half a unit each).
            View::QKet { q, bits, .. } => {
                let n_leaves = self.vocab * self.rank * self.order;
                n_leaves * quant::words_per_leaf(*q, *bits)
                    + n_leaves
                    + (n_leaves * q).div_ceil(2)
            }
        }
    }

    fn lookup(&self, id: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim];
        self.lookup_into(id, &mut out);
        out
    }

    fn lookup_into(&self, id: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        match &self.view {
            View::Regular { data } => {
                let data = self.floats(data);
                out.copy_from_slice(&data[id * self.dim..(id + 1) * self.dim]);
            }
            View::W2k { leaves, q, layernorm } => {
                // Mirror Word2Ket::lookup_into: balanced tree per rank
                // term, each term accumulated straight into the (possibly
                // truncated) caller buffer.
                let leaves = self.floats(leaves);
                out.fill(0.0);
                let mut refs: [&[f32]; crate::repr::MAX_ORDER] = [&[]; crate::repr::MAX_ORDER];
                for k in 0..self.rank {
                    for (j, leaf) in refs.iter_mut().take(self.order).enumerate() {
                        *leaf = self.w2k_leaf(leaves, *q, id, k, j);
                    }
                    let term = tree_term(&refs[..self.order], *layernorm);
                    kernels::add_assign(out, &term);
                }
            }
            View::Xs { factors, q, t, radix } => {
                // Mirror Word2KetXS::reconstruct_into exactly (fused
                // order-2 kernel, kron_accumulate otherwise) with the
                // shared per-thread scratch.
                let factors = self.floats(factors);
                let mut digits = [0usize; 8];
                radix.decode_into(id, &mut digits[..self.order]);
                out.fill(0.0);
                if self.order == 2 {
                    for k in 0..self.rank {
                        let a = self.xs_col(factors, *q, *t, k, 0, digits[0]);
                        let b = self.xs_col(factors, *q, *t, k, 1, digits[1]);
                        kernels::kron2_accumulate(a, b, out);
                    }
                    return;
                }
                let mut cols: [&[f32]; 8] = [&[]; 8];
                kernels::with_lookup_scratch(|s| {
                    for k in 0..self.rank {
                        for (j, c) in cols.iter_mut().take(self.order).enumerate() {
                            *c = self.xs_col(factors, *q, *t, k, j, digits[j]);
                        }
                        kron_accumulate(&cols[..self.order], out, &mut s.kron);
                    }
                });
            }
            View::Quant { codes, scales, offsets, bits } => {
                let codes = self.u32s(codes);
                let scale = self.floats(scales)[id];
                let off = self.floats(offsets)[id];
                for (c, o) in out.iter_mut().enumerate() {
                    let code = get_bits(codes, (id * self.dim + c) * bits, *bits);
                    *o = off + code as f32 * scale;
                }
            }
            View::LowRank { u, vt, k } => {
                let u = &self.floats(u)[id * k..(id + 1) * k];
                let vt = self.floats(vt);
                for (j, o) in out.iter_mut().enumerate() {
                    *o = dot(u, &vt[j * k..(j + 1) * k]);
                }
            }
            View::Hashed { weights, seed } => {
                let w = self.floats(weights);
                let buckets = w.len();
                for (j, o) in out.iter_mut().enumerate() {
                    let mut h = seed.wrapping_add((id as u64) << 32).wrapping_add(j as u64);
                    let x = splitmix64(&mut h);
                    let sign = if (x >> 63) == 0 { 1.0 } else { -1.0 };
                    *o = sign * w[(x % buckets as u64) as usize];
                }
            }
            View::QKet { .. } => {
                self.qket_view().expect("view matched QKet").write_row(id, out)
            }
        }
    }

    fn repr(&self) -> Repr<'_> {
        Repr::Snapshot(self)
    }

    fn describe(&self) -> String {
        format!(
            "snapshot[{}] {}×{} order={} rank={} ({} params, {} bytes on disk, {:.0}× saving)",
            self.kind().name(),
            self.vocab,
            self.dim,
            self.order,
            self.rank,
            self.num_params(),
            self.snap.file_len(),
            self.space_saving_rate()
        )
    }
}

/// Factored-space contract (see [`crate::repr`]) straight off the mapped
/// file. Handed out by [`Repr::factored`] only when
/// [`SnapshotStore::factored`] holds (raw word2ket/word2ketXS/quantized_ket
/// factors, untruncated); the accessors below are only called under that
/// gate. For the quantized_ket view, `inner`/`block_inner` follow the
/// coarse quantized-domain contract of [`crate::quant`] while `factors`/
/// `write_row` expose the exact refined payload.
impl FactoredRepr for SnapshotStore {
    fn geometry(&self) -> FactorGeometry {
        let leaf_dim = match &self.view {
            View::W2k { q, .. } | View::Xs { q, .. } | View::QKet { q, .. } => *q,
            _ => 0,
        };
        FactorGeometry { order: self.order, rank: self.rank, leaf_dim }
    }

    fn factors<'s>(&'s self, id: usize, k: usize, out: &mut [&'s [f32]]) {
        debug_assert_eq!(out.len(), self.order);
        match &self.view {
            View::W2k { leaves, q, .. } => {
                let leaves = self.floats(leaves);
                for (j, leaf) in out.iter_mut().enumerate() {
                    *leaf = self.w2k_leaf(leaves, *q, id, k, j);
                }
            }
            View::Xs { factors, q, t, radix } => {
                let factors = self.floats(factors);
                let mut digits = [0usize; 8];
                radix.decode_into(id, &mut digits[..self.order]);
                for (j, col) in out.iter_mut().enumerate() {
                    *col = self.xs_col(factors, *q, *t, k, j, digits[j]);
                }
            }
            View::QKet { .. } => {
                // Exact f16-refined leaves — the payload `write_row`
                // reconstructs from, not the coarse codes.
                let v = self.qket_view().expect("view matched QKet");
                for (j, slot) in out.iter_mut().enumerate() {
                    *slot = v.refined_leaf(id, k, j);
                }
            }
            _ => unreachable!("factored repr over a non-factored snapshot view"),
        }
    }

    fn kind_name(&self) -> &'static str {
        "snapshot"
    }

    fn inner(&self, a: usize, b: usize) -> f32 {
        SnapshotStore::inner(self, a, b)
    }

    fn block_inner(&self, a: usize, bs: &[usize], out: &mut [f32]) {
        match &self.view {
            View::Xs { factors, q, t, radix } => {
                // The same shared digit-hoisted block kernel as the
                // in-memory word2ketXS store.
                let factors = self.floats(factors);
                kernels::factored_digit_block(
                    self.rank,
                    self.order,
                    |i, d: &mut [usize; 8]| radix.decode_into(i, &mut d[..self.order]),
                    |k, j, c| self.xs_col(factors, *q, *t, k, j, c),
                    a,
                    bs,
                    out,
                );
            }
            View::QKet { .. } => {
                self.qket_view().expect("view matched QKet").block_inner(a, bs, out)
            }
            _ => {
                for (o, &b) in out.iter_mut().zip(bs) {
                    *o = SnapshotStore::inner(self, a, b);
                }
            }
        }
    }

    fn write_row(&self, id: usize, out: &mut [f32]) {
        EmbeddingStore::lookup_into(self, id, out);
    }
}
