//! Minimal JSON parser and writer.
//!
//! `serde` is not available in this build environment, and the runtime needs
//! to read `artifacts/manifest.json` (written by `python/compile/aot.py`) and
//! emit structured experiment reports. This module implements a small,
//! well-tested JSON subset: all of RFC 8259 except `\u` surrogate pairs are
//! fully supported; numbers are parsed as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use BTreeMap for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|x| if x.fract() == 0.0 { Some(x as i64) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Array element lookup.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        self.as_arr().and_then(|v| v.get(idx))
    }

    // ---- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{}", x);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", word)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.src.len());
                    let chunk = std::str::from_utf8(&self.src[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().at(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("b"), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"w2k","dims":[4,4,4,4],"rank":5,"ok":true,"note":"q\"uote"}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\té ünïcødé""#).unwrap();
        assert_eq!(v.as_str(), Some("A\té ünïcødé"));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos > 0);
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.at(0).unwrap().as_usize(), Some(1));
        assert_eq!(v.at(1).unwrap().as_usize(), None);
        assert_eq!(v.at(2).unwrap().as_i64(), Some(-3));
        assert_eq!(v.at(2).unwrap().as_usize(), None);
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.25).to_string(), "5.25");
    }
}
