//! ASCII table rendering for benchmark reports (paper-vs-measured tables).

/// A simple left/right-aligned column table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    /// Right-align numeric-looking columns automatically.
    title: Option<String>,
}

impl Table {
    pub fn new<S: Into<String>>(columns: Vec<S>) -> Self {
        Table {
            header: columns.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, t: impl Into<String>) -> Self {
        self.title = Some(t.into());
        self
    }

    pub fn add_row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "row width mismatch");
        self.rows.push(row);
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    fn looks_numeric(s: &str) -> bool {
        !s.is_empty()
            && s.chars().all(|c| c.is_ascii_digit() || ",.%-+×xe".contains(c))
            && s.chars().any(|c| c.is_ascii_digit())
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut width = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        // A column is right-aligned if all its body cells look numeric.
        let right: Vec<bool> = (0..ncols)
            .map(|i| {
                !self.rows.is_empty()
                    && self.rows.iter().all(|r| Self::looks_numeric(&r[i]) || r[i].is_empty())
            })
            .collect();

        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &width {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        let fmt_row = |cells: &[String], right: &[bool]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                if right[i] {
                    line.push_str(&format!(" {}{} |", " ".repeat(pad), c));
                } else {
                    line.push_str(&format!(" {}{} |", c, " ".repeat(pad)));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &vec![false; ncols]));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &right));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    /// Render as a GitHub-flavored markdown table (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("**{}**\n\n", t));
        }
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["Embedding", "#Params", "Saving"]);
        t.add_row(vec!["Regular", "7,789,568", "1"]);
        t.add_row(vec!["word2ketXS", "224", "34,775"]);
        let s = t.render();
        assert!(s.contains("| Embedding "));
        assert!(s.contains("7,789,568"));
        // numeric columns right-aligned: the short "224" is padded on the left
        assert!(s.contains("       224"));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(vec!["a", "b"]);
        t.add_row(vec!["1", "2"]);
        let md = t.render_markdown();
        assert!(md.starts_with("| a | b |\n|---|---|\n| 1 | 2 |\n"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.add_row(vec!["only-one"]);
    }
}
