//! Deterministic pseudo-random number generation.
//!
//! The whole reproduction is seeded: corpus synthesis, parameter
//! initialization, batch shuffling and property-test case generation all draw
//! from [`Rng`], a xoshiro256** generator seeded through splitmix64. No
//! external `rand` crate is available in this build environment, so this is a
//! from-scratch substrate with the standard reference algorithms.

/// splitmix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box-Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream, e.g. one per parameter tensor.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.next_f32() * (hi - lo)
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Gaussian f32 with given mean/std.
    pub fn normal(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian() as f32
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Vector of uniform values in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform(lo, hi)).collect()
    }

    /// Vector of normal values.
    pub fn normal_vec(&mut self, n: usize, mean: f32, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal(mean, std)).collect()
    }
}

/// Zipf-distributed index sampler over `[0, n)`: P(i) ∝ 1/(i+1)^s.
///
/// Token-id request streams are heavily head-skewed in production serving;
/// this is the load model used by the serving bench and the
/// `serve_embeddings` load generator. Sampling is an O(log n) binary search
/// over a precomputed CDF, so a sampler is cheap to share per client thread.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, s: f64) -> ZipfSampler {
        assert!(n > 0, "zipf needs a non-empty domain");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        ZipfSampler { cdf }
    }

    /// Draw one index using the caller's RNG stream.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        // partition_point: first index whose cdf exceeds u.
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean_roughly_centered() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(17);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn zipf_is_head_heavy_and_in_range() {
        let z = ZipfSampler::new(1000, 1.0);
        let mut r = Rng::new(21);
        let mut counts = vec![0usize; 1000];
        for _ in 0..20_000 {
            let i = z.sample(&mut r);
            assert!(i < 1000);
            counts[i] += 1;
        }
        // Rank 0 should dominate rank 100 by roughly 100× under s=1.
        assert!(counts[0] > counts[100] * 20, "{} vs {}", counts[0], counts[100]);
        // Head mass: top-10 ids should carry a large share of the stream.
        let head: usize = counts[..10].iter().sum();
        assert!(head > 20_000 / 3, "head {head}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
