//! Tiny leveled logger writing to stderr, controlled by `W2K_LOG`
//! (error|warn|info|debug|trace). Substrate replacement for the `log`/
//! `env_logger` crates, with elapsed-time stamps relative to process start.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => " WARN",
            Level::Info => " INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // default: Info
static START: OnceLock<Instant> = OnceLock::new();
static INIT: OnceLock<()> = OnceLock::new();

/// Initialize from the environment; idempotent, called lazily by `enabled`.
pub fn init() {
    INIT.get_or_init(|| {
        START.get_or_init(Instant::now);
        if let Ok(v) = std::env::var("W2K_LOG") {
            if let Some(l) = Level::parse(&v) {
                LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
    });
}

/// Override the level programmatically (e.g. from --verbose CLI flags).
pub fn set_level(l: Level) {
    init();
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    init();
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{:9.3}s {} {}] {}", t, l.tag(), target, msg);
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
