//! Shared substrates: PRNG, JSON, logging, statistics, tables.
//!
//! None of the usual ecosystem crates (rand, serde, log, criterion) are
//! available in this offline build, so this module provides from-scratch,
//! well-tested equivalents sized for what the rest of the system needs.

pub mod json;
pub mod log;
pub mod rng;
pub mod stats;
pub mod table;

pub use json::Json;
pub use rng::{Rng, ZipfSampler};
pub use stats::{fmt_count, fmt_duration, Summary, Timer};
pub use table::Table;

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Smallest integer `t` with `t^n >= d` (used to size word2ketXS factors).
pub fn ceil_root(d: usize, n: u32) -> usize {
    if d <= 1 {
        return 1;
    }
    let mut t = (d as f64).powf(1.0 / n as f64).floor() as usize;
    // floating point may under- or over-shoot by one
    while t.checked_pow(n).map_or(true, |p| p < d) {
        t += 1;
    }
    while t > 1 && (t - 1).checked_pow(n).map_or(false, |p| p >= d) {
        t -= 1;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(1, 5), 1);
    }

    #[test]
    fn ceil_root_matches_paper_cells() {
        // SQuAD vocab 118,655: order-2 → 345, order-4 → 19 (paper Fig. 3: 19×5)
        assert_eq!(ceil_root(118_655, 2), 345);
        assert_eq!(ceil_root(118_655, 4), 19);
        // embedding dim 300: order-2 → 18 (18² = 324), order-4 → 5 (5⁴ = 625)
        assert_eq!(ceil_root(300, 2), 18);
        assert_eq!(ceil_root(300, 4), 5);
        // GIGAWORD vocab 30,428: order-4 → 14 (14⁴ = 38,416)
        assert_eq!(ceil_root(30_428, 4), 14);
        assert_eq!(ceil_root(30_428, 2), 175);
    }

    #[test]
    fn ceil_root_edges() {
        assert_eq!(ceil_root(1, 3), 1);
        assert_eq!(ceil_root(8, 3), 2);
        assert_eq!(ceil_root(9, 3), 3);
        assert_eq!(ceil_root(256, 4), 4);
        assert_eq!(ceil_root(257, 4), 5);
    }
}
