//! Summary statistics and timing helpers for benchmarks and the server.

use std::time::{Duration, Instant};

/// Online summary of a sample of f64 observations (latencies, scores, ...).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { samples: Vec::new() }
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    /// Fold another summary's samples into this one (e.g. merging per-worker
    /// latency summaries for a pool-wide STATS view).
    pub fn merge(&mut self, other: &Summary) {
        self.samples.extend_from_slice(&other.samples);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    /// Percentile via linear interpolation on the sorted sample; p in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            xs[lo]
        } else {
            let frac = rank - lo as f64;
            xs[lo] * (1.0 - frac) + xs[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Wall-clock timer scoped to a label.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e6
    }
}

/// Format a duration compactly, e.g. "1.23ms", "4.5s".
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

/// Format a large count with thousands separators: 7789568 → "7,789,568".
pub fn fmt_count(n: u64) -> String {
    let digits = n.to_string();
    let mut out = String::new();
    for (i, c) in digits.chars().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811).abs() < 1e-3);
        assert_eq!(s.p50(), 3.0);
    }

    #[test]
    fn merge_combines_samples() {
        let mut a = Summary::new();
        a.add(1.0);
        let mut b = Summary::new();
        b.add(3.0);
        b.add(5.0);
        a.merge(&b);
        assert_eq!(a.len(), 3);
        assert_eq!(a.mean(), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let mut s = Summary::new();
        s.add(0.0);
        s.add(10.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn count_formatting() {
        assert_eq!(fmt_count(0), "0");
        assert_eq!(fmt_count(999), "999");
        assert_eq!(fmt_count(1000), "1,000");
        assert_eq!(fmt_count(7_789_568), "7,789,568");
        assert_eq!(fmt_count(243_424_000), "243,424,000");
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(Duration::from_millis(1230)), "1.23s");
        assert!(fmt_duration(Duration::from_micros(42)).ends_with("µs"));
    }
}
