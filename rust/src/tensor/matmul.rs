//! Cache-blocked matrix multiplication.
//!
//! Used by the pure-Rust serving path (embedding × projection) and test
//! oracles. Not intended to compete with XLA's CPU backend — training matmuls
//! run inside AOT executables — but the blocking keeps the serving benches
//! honest.

use super::Tensor;
use crate::error::{Error, Result};

const BLOCK: usize = 64;

/// C = A(m×k) · B(k×n), row-major, i-k-j loop order with k-blocking.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.ndim() != 2 || b.ndim() != 2 {
        return Err(Error::Shape("matmul expects 2-D operands".into()));
    }
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    if k != k2 {
        return Err(Error::Shape(format!(
            "matmul inner-dim mismatch: {:?} × {:?}",
            a.shape(),
            b.shape()
        )));
    }
    let mut c = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for kb in (0..k).step_by(BLOCK) {
        let kend = (kb + BLOCK).min(k);
        for i in 0..m {
            let arow = &ad[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for kk in kb..kend {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &bd[kk * n..(kk + 1) * n];
                for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                    *cj += aik * bj;
                }
            }
        }
    }
    Tensor::new(vec![m, n], c)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut c = Tensor::zeros(vec![m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(kk, j);
                }
                c.set2(i, j, s);
            }
        }
        c
    }

    #[test]
    fn small_known_product() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(vec![2, 2], vec![5., 6., 7., 8.]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn identity_is_noop() {
        let mut eye = Tensor::zeros(vec![3, 3]);
        for i in 0..3 {
            eye.set2(i, i, 1.0);
        }
        let a = Tensor::new(vec![3, 3], (0..9).map(|x| x as f32).collect()).unwrap();
        assert_eq!(matmul(&a, &eye).unwrap(), a);
        assert_eq!(matmul(&eye, &a).unwrap(), a);
    }

    #[test]
    fn blocked_matches_naive_nonsquare() {
        use crate::util::Rng;
        let mut rng = Rng::new(123);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 70, 5), (65, 130, 17), (8, 8, 8)] {
            let a = Tensor::new(vec![m, k], rng.uniform_vec(m * k, -1.0, 1.0)).unwrap();
            let b = Tensor::new(vec![k, n], rng.uniform_vec(k * n, -1.0, 1.0)).unwrap();
            let fast = matmul(&a, &b).unwrap();
            let slow = naive(&a, &b);
            assert!(fast.allclose(&slow, 1e-4, 1e-5), "mismatch at {m}x{k}x{n}");
        }
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(vec![2, 3]);
        let b = Tensor::zeros(vec![4, 2]);
        assert!(matmul(&a, &b).is_err());
        assert!(matmul(&a, &Tensor::zeros(vec![6])).is_err());
    }
}
