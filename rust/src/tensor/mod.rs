//! Dense f32 tensors with a small set of NumPy-style operations.
//!
//! This is the host-side math substrate used by the pure-Rust mirror of the
//! paper's embedding algebra (serving path, baselines, property tests). The
//! heavy training math runs inside AOT-compiled XLA executables — this module
//! only needs to be correct and reasonably fast for embedding reconstruction,
//! metric computation and test oracles.

mod matmul;

pub use matmul::matmul;

use crate::error::{Error, Result};

/// A dense, row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn ones(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![1.0; n] }
    }

    pub fn filled(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    pub fn from_vec(data: Vec<f32>) -> Tensor {
        Tensor { shape: vec![data.len()], data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    // ---- accessors ---------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Element access for 2-D tensors.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        self.data[i * cols + j] = v;
    }

    /// Row slice of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        &mut self.data[i * cols..(i + 1) * cols]
    }

    // ---- shape ops ---------------------------------------------------------

    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {:?} ({} elems) to {:?}",
                self.shape,
                self.data.len(),
                shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    /// 2-D transpose.
    pub fn transpose(&self) -> Tensor {
        assert_eq!(self.ndim(), 2, "transpose needs a matrix");
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor { shape: vec![c, r], data: out }
    }

    // ---- elementwise -------------------------------------------------------

    fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "elementwise shape mismatch: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        let data = self
            .data
            .iter()
            .zip(other.data.iter())
            .map(|(&a, &b)| f(a, b))
            .collect();
        Ok(Tensor { shape: self.shape.clone(), data })
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, c: f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| x * c).collect(),
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    // ---- reductions / norms -------------------------------------------------

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn dot(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            return Err(Error::Shape("dot shape mismatch".into()));
        }
        Ok(dot(&self.data, &other.data))
    }

    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |a - b| across all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        debug_assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// allclose with both tolerances, NumPy-style.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(other.data.iter())
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    // ---- NN primitives (used for serving-side math and test oracles) -------

    /// Softmax over the last axis.
    pub fn softmax(&self) -> Tensor {
        let cols = *self.shape.last().expect("softmax needs >=1 dim");
        let mut out = self.data.clone();
        for chunk in out.chunks_mut(cols) {
            let max = chunk.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for x in chunk.iter_mut() {
                *x = (*x - max).exp();
                sum += *x;
            }
            for x in chunk.iter_mut() {
                *x /= sum;
            }
        }
        Tensor { shape: self.shape.clone(), data: out }
    }

    /// LayerNorm over the last axis (no learned affine), eps = 1e-5.
    pub fn layernorm(&self) -> Tensor {
        layernorm_slices(&self.data, *self.shape.last().expect("layernorm needs >=1 dim"))
            .map(|data| Tensor { shape: self.shape.clone(), data })
            .expect("layernorm")
    }
}

/// Plain dot product over slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    // The unrolled implementation lives with the other shared lookup
    // kernels; this alias keeps the historical call sites working.
    crate::repr::kernels::dot(a, b)
}

/// LayerNorm each contiguous `width`-sized slice of `data` (eps=1e-5).
pub fn layernorm_slices(data: &[f32], width: usize) -> Result<Vec<f32>> {
    if width == 0 || data.len() % width != 0 {
        return Err(Error::Shape(format!(
            "layernorm width {} does not divide len {}",
            width,
            data.len()
        )));
    }
    let mut out = Vec::with_capacity(data.len());
    for chunk in data.chunks(width) {
        let mean = chunk.iter().sum::<f32>() / width as f32;
        let var = chunk.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / width as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        out.extend(chunk.iter().map(|x| (x - mean) * inv));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_shape_check() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let t = Tensor::zeros(vec![4, 5]);
        assert_eq!(t.len(), 20);
        assert_eq!(t.shape(), &[4, 5]);
    }

    #[test]
    fn elementwise_and_scale() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(vec![10.0, 20.0, 30.0]);
        assert_eq!(a.add(&b).unwrap().data(), &[11.0, 22.0, 33.0]);
        assert_eq!(b.sub(&a).unwrap().data(), &[9.0, 18.0, 27.0]);
        assert_eq!(a.mul(&b).unwrap().data(), &[10.0, 40.0, 90.0]);
        assert_eq!(a.scale(2.0).data(), &[2.0, 4.0, 6.0]);
        assert!(a.add(&Tensor::zeros(vec![2])).is_err());
    }

    #[test]
    fn transpose_matrix() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let tt = t.transpose();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.data(), &[1., 4., 2., 5., 3., 6.]);
        assert_eq!(tt.transpose(), t);
    }

    #[test]
    fn dot_and_norm() {
        let a = Tensor::from_vec(vec![3.0, 4.0]);
        assert_eq!(a.norm(), 5.0);
        let b = Tensor::from_vec(vec![1.0, 2.0]);
        assert_eq!(a.dot(&b).unwrap(), 11.0);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32) * 0.37 - 7.0).collect();
        let b: Vec<f32> = (0..103).map(|i| (i as f32) * -0.11 + 3.0).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-2 * naive.abs().max(1.0));
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 0., 0., 0.]).unwrap();
        let s = t.softmax();
        let r0: f32 = s.row(0).iter().sum();
        let r1: f32 = s.row(1).iter().sum();
        assert!((r0 - 1.0).abs() < 1e-6);
        assert!((r1 - 1.0).abs() < 1e-6);
        assert!((s.row(1)[0] - 1.0 / 3.0).abs() < 1e-6);
        // monotone: bigger logit → bigger prob
        assert!(s.row(0)[2] > s.row(0)[1]);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let t = Tensor::new(vec![2, 4], vec![1., 2., 3., 4., -5., 0., 5., 10.]).unwrap();
        let n = t.layernorm();
        for i in 0..2 {
            let row = n.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn rows_and_at2() {
        let mut t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        assert_eq!(t.row(1), &[3., 4.]);
        assert_eq!(t.at2(0, 1), 2.0);
        t.set2(0, 1, 9.0);
        assert_eq!(t.at2(0, 1), 9.0);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::from_vec(vec![1.0, 2.0]);
        let b = Tensor::from_vec(vec![1.0 + 1e-6, 2.0 - 1e-6]);
        assert!(a.allclose(&b, 1e-5, 1e-5));
        let c = Tensor::from_vec(vec![1.1, 2.0]);
        assert!(!a.allclose(&c, 1e-5, 1e-5));
    }
}
