//! Exact similarity scoring over any [`EmbeddingStore`], through *factored
//! space* when the store is tensorized.
//!
//! The paper's representation makes inner products cheap without ever
//! materializing rows: `⟨Σ_k ⊗_j u_jk, Σ_k' ⊗_j v_jk'⟩ = Σ_{k,k'} Π_j
//! ⟨u_jk, v_jk'⟩` (§2.3), an `O(r² n q)` computation against the `O(q^n)`
//! dense dot product. The scorer resolves once, at construction, whether the
//! store underneath (unwrapping [`ShardedCache`]) is a [`Word2Ket`] or
//! [`Word2KetXS`] in raw, untruncated form; if so every pair score runs
//! through the factors, otherwise it falls back to materialized rows served
//! through the store (and thus through the hot-row cache when present).
//!
//! Cosine mode caches per-word L2 norms at construction — computed in
//! factored space too (`‖v‖² = ⟨v, v⟩`), so even the norm pass never
//! reconstructs a row on tensorized stores.

use crate::embedding::{EmbeddingStore, Word2Ket, Word2KetXS};
use crate::serving::cache::unwrap_cached;
use crate::snapshot::SnapshotStore;
use crate::tensor::dot;
use std::sync::Arc;

/// How pair scores are computed, resolved once at construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Backend {
    /// Per-word CP tensors: factored inner via `Word2Ket::inner`.
    Word2Ket,
    /// Shared-factor operator: factored inner via `Word2KetXS::inner`.
    Word2KetXS,
    /// Snapshot-backed factors (post-hot-swap): `SnapshotStore::inner`.
    Snapshot,
    /// Materialized rows through the store (cache-aware when wrapped).
    Dense,
}

/// Decide the scoring backend. The factored identities only hold for raw
/// (no LayerNorm) CP form over the full `q^n` tensor, so truncated or
/// LayerNorm-ed stores score densely.
fn sniff(store: &dyn EmbeddingStore) -> Backend {
    let inner = unwrap_cached(store);
    if let Some(any) = inner.as_any() {
        if let Some(w) = any.downcast_ref::<Word2Ket>() {
            if !w.layernorm() && w.exact_dim() {
                return Backend::Word2Ket;
            }
        }
        if let Some(xs) = any.downcast_ref::<Word2KetXS>() {
            if xs.exact_dim() {
                return Backend::Word2KetXS;
            }
        }
        // A snapshot-backed model (after `save → load → swap`) exposes the
        // same factored identities straight off the mapped file; without
        // this arm a hot reload would silently demote k-NN to dense scans.
        if let Some(snap) = any.downcast_ref::<SnapshotStore>() {
            if snap.factored() {
                return Backend::Snapshot;
            }
        }
    }
    Backend::Dense
}

/// Exact dot/cosine scorer over a store (see module docs).
pub struct Scorer {
    store: Arc<dyn EmbeddingStore>,
    backend: Backend,
    cosine: bool,
    /// Per-word L2 norms; populated only in cosine mode.
    norms: Vec<f32>,
}

impl Scorer {
    pub fn new(store: Arc<dyn EmbeddingStore>, cosine: bool) -> Scorer {
        let backend = sniff(store.as_ref());
        let mut scorer = Scorer { store, backend, cosine, norms: Vec::new() };
        if cosine {
            let vocab = scorer.vocab_size();
            let mut norms = Vec::with_capacity(vocab);
            {
                let pairs = scorer.pair_scorer();
                for id in 0..vocab {
                    norms.push(pairs.raw_inner(id, id).max(0.0).sqrt());
                }
            }
            scorer.norms = norms;
        }
        scorer
    }

    pub fn vocab_size(&self) -> usize {
        self.store.vocab_size()
    }

    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    pub fn cosine(&self) -> bool {
        self.cosine
    }

    /// True when pair scores go through factored space.
    pub fn is_factored(&self) -> bool {
        self.backend != Backend::Dense
    }

    /// Materialize row `id` through the store (cache-aware when wrapped).
    pub fn row(&self, id: usize) -> Vec<f32> {
        self.store.lookup(id)
    }

    fn w2k(&self) -> &Word2Ket {
        unwrap_cached(self.store.as_ref())
            .as_any()
            .and_then(|a| a.downcast_ref::<Word2Ket>())
            .expect("scorer backend resolved to word2ket")
    }

    fn xs(&self) -> &Word2KetXS {
        unwrap_cached(self.store.as_ref())
            .as_any()
            .and_then(|a| a.downcast_ref::<Word2KetXS>())
            .expect("scorer backend resolved to word2ketXS")
    }

    fn snap(&self) -> &SnapshotStore {
        unwrap_cached(self.store.as_ref())
            .as_any()
            .and_then(|a| a.downcast_ref::<SnapshotStore>())
            .expect("scorer backend resolved to snapshot store")
    }

    /// Resolve a per-scan scoring handle: the concrete store reference is
    /// looked up once here instead of once per pair — the downcast chain
    /// through the cache wrapper costs on the order of the factored kernel
    /// itself at small rank, so scans must not pay it in the inner loop.
    pub fn pair_scorer(&self) -> PairScorer<'_> {
        let backend = match self.backend {
            Backend::Word2Ket => ResolvedBackend::Word2Ket(self.w2k()),
            Backend::Word2KetXS => ResolvedBackend::Word2KetXS(self.xs()),
            Backend::Snapshot => ResolvedBackend::Snapshot(self.snap()),
            Backend::Dense => ResolvedBackend::Dense,
        };
        PairScorer { backend, store: self.store.as_ref(), cosine: self.cosine, norms: &self.norms }
    }

    /// Raw inner product `⟨row a, row b⟩` — factored when available.
    /// One-shot convenience; scans should use [`Self::pair_scorer`].
    pub fn raw_inner(&self, a: usize, b: usize) -> f32 {
        self.pair_scorer().raw_inner(a, b)
    }

    /// `‖row id‖`: cached in cosine mode, computed (factored) on demand
    /// otherwise.
    pub fn norm(&self, id: usize) -> f32 {
        match self.norms.get(id) {
            Some(&n) => n,
            None => self.raw_inner(id, id).max(0.0).sqrt(),
        }
    }

    /// Ranking score between two stored rows: dot product, or cosine using
    /// the cached norms. One-shot convenience; scans should use
    /// [`Self::pair_scorer`].
    pub fn score_pair(&self, a: usize, b: usize) -> f32 {
        self.pair_scorer().score(a, b)
    }

    /// Ranking score between an external query vector and stored row `b`.
    /// `q_norm` is `‖q‖`, ignored unless in cosine mode.
    pub fn score_vec(&self, q: &[f32], q_norm: f32, b: usize) -> f32 {
        let ip = dot(q, &self.store.lookup(b));
        if self.cosine {
            let denom = q_norm * self.norm(b);
            if denom > 0.0 {
                ip / denom
            } else {
                0.0
            }
        } else {
            ip
        }
    }

    pub fn describe(&self) -> String {
        let metric = if self.cosine { "cosine" } else { "dot" };
        let path = match self.backend {
            Backend::Word2Ket => "factored(word2ket)",
            Backend::Word2KetXS => "factored(word2ketXS)",
            Backend::Snapshot => "factored(snapshot)",
            Backend::Dense => "materialized",
        };
        format!("{metric}/{path}")
    }
}

/// Concrete per-scan store access (see [`Scorer::pair_scorer`]).
enum ResolvedBackend<'a> {
    Word2Ket(&'a Word2Ket),
    Word2KetXS(&'a Word2KetXS),
    Snapshot(&'a SnapshotStore),
    Dense,
}

/// Pair-scoring handle with the backend resolved once per scan.
///
/// Borrows the [`Scorer`]; create one per query/scan and call
/// [`score`](Self::score) (or [`raw_inner`](Self::raw_inner)) in the loop.
pub struct PairScorer<'a> {
    backend: ResolvedBackend<'a>,
    store: &'a dyn EmbeddingStore,
    cosine: bool,
    norms: &'a [f32],
}

impl PairScorer<'_> {
    /// Raw inner product `⟨row a, row b⟩` — factored when available.
    #[inline]
    pub fn raw_inner(&self, a: usize, b: usize) -> f32 {
        match &self.backend {
            ResolvedBackend::Word2Ket(w) => w.inner(a, b),
            ResolvedBackend::Word2KetXS(xs) => xs.inner(a, b),
            ResolvedBackend::Snapshot(s) => s.inner(a, b),
            ResolvedBackend::Dense => {
                let va = self.store.lookup(a);
                if a == b {
                    // Norm computations hit this: don't reconstruct twice.
                    dot(&va, &va)
                } else {
                    dot(&va, &self.store.lookup(b))
                }
            }
        }
    }

    /// Ranking score, same contract as [`Scorer::score_pair`].
    #[inline]
    pub fn score(&self, a: usize, b: usize) -> f32 {
        let ip = self.raw_inner(a, b);
        if self.cosine {
            let denom = self.norms[a] * self.norms[b];
            if denom > 0.0 {
                ip / denom
            } else {
                0.0
            }
        } else {
            ip
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::ShardedCache;
    use crate::util::Rng;

    fn w2k(vocab: usize, dim: usize, order: usize, rank: usize) -> Arc<dyn EmbeddingStore> {
        let mut rng = Rng::new(3);
        Arc::new(Word2Ket::random(vocab, dim, order, rank, &mut rng))
    }

    #[test]
    fn factored_backends_detected() {
        // 4^2 == 16: exact → factored.
        assert!(Scorer::new(w2k(30, 16, 2, 2), false).is_factored());
        let mut rng = Rng::new(4);
        let xs: Arc<dyn EmbeddingStore> = Arc::new(Word2KetXS::random(30, 16, 2, 2, &mut rng));
        assert!(Scorer::new(xs, false).is_factored());
    }

    #[test]
    fn truncated_or_layernormed_stores_score_densely() {
        // 18² = 324 > 300: truncated reconstruction → dense fallback.
        assert!(!Scorer::new(w2k(30, 300, 2, 1), false).is_factored());
        let mut rng = Rng::new(5);
        let mut w = Word2Ket::random(30, 16, 2, 1, &mut rng);
        w.set_layernorm(true);
        let store: Arc<dyn EmbeddingStore> = Arc::new(w);
        let s = Scorer::new(store, false);
        assert!(!s.is_factored());
        // Dense scoring still works (no factored-identity assert tripped).
        assert!(s.score_pair(0, 1).is_finite());
    }

    #[test]
    fn factored_scores_match_dense_rows() {
        let store = w2k(40, 16, 2, 3);
        let scorer = Scorer::new(store.clone(), false);
        assert!(scorer.is_factored());
        for (a, b) in [(0usize, 1usize), (5, 5), (39, 7)] {
            let va = store.lookup(a);
            let vb = store.lookup(b);
            let dense = dot(&va, &vb);
            let fast = scorer.score_pair(a, b);
            assert!(
                (dense - fast).abs() < 1e-5 * dense.abs().max(1.0),
                "({a},{b}): {dense} vs {fast}"
            );
        }
    }

    #[test]
    fn cosine_scores_normalized_and_consistent() {
        let store = w2k(40, 16, 2, 2);
        let scorer = Scorer::new(store.clone(), true);
        for (a, b) in [(0usize, 3usize), (11, 29)] {
            let c = scorer.score_pair(a, b);
            assert!((-1.0 - 1e-4..=1.0 + 1e-4).contains(&c), "cosine {c} out of range");
            let va = store.lookup(a);
            let vb = store.lookup(b);
            let want = dot(&va, &vb) / (dot(&va, &va).sqrt() * dot(&vb, &vb).sqrt());
            assert!((c - want).abs() < 1e-4, "({a},{b}): {c} vs {want}");
        }
        // Self-similarity is 1.
        assert!((scorer.score_pair(7, 7) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn scoring_reaches_through_the_cache() {
        let mut rng = Rng::new(8);
        let inner = Box::new(Word2Ket::random(30, 16, 2, 2, &mut rng));
        let cached: Arc<dyn EmbeddingStore> = Arc::new(ShardedCache::new(inner, 2, 64));
        let scorer = Scorer::new(cached, false);
        assert!(scorer.is_factored(), "cache wrapper must be transparent to the sniff");
        assert!(scorer.score_pair(1, 2).is_finite());
    }

    #[test]
    fn snapshot_store_sniffed_factored_through_cache() {
        // Satellite: a SnapshotStore-backed model (the post-reload state)
        // must keep factored-space scoring, including under the cache
        // wrapper, with scores bit-identical to the original store's.
        let mut rng = Rng::new(9);
        let xs = Word2KetXS::random(60, 16, 2, 2, &mut rng);
        let path = std::env::temp_dir()
            .join(format!("w2k_scorer_snap_{}.snap", std::process::id()));
        crate::snapshot::save_store(&xs, &path, &Default::default()).unwrap();
        let snap =
            Arc::new(crate::snapshot::Snapshot::open(&path, true).unwrap());
        let mm = SnapshotStore::open(snap).unwrap();
        let cached: Arc<dyn EmbeddingStore> =
            Arc::new(ShardedCache::new(Box::new(mm), 2, 64));
        let scorer = Scorer::new(cached, false);
        assert!(scorer.is_factored(), "snapshot store must keep factored scoring");
        assert!(scorer.describe().contains("factored(snapshot)"), "{}", scorer.describe());
        let direct = Scorer::new(Arc::new(xs) as Arc<dyn EmbeddingStore>, false);
        for (a, b) in [(0usize, 1usize), (5, 5), (59, 17)] {
            assert_eq!(
                direct.score_pair(a, b).to_bits(),
                scorer.score_pair(a, b).to_bits(),
                "({a},{b})"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn score_vec_matches_pair_on_materialized_query() {
        let store = w2k(30, 16, 2, 2);
        let scorer = Scorer::new(store.clone(), true);
        let q = store.lookup(4);
        let qn = dot(&q, &q).sqrt();
        for b in [0usize, 9, 21] {
            let by_vec = scorer.score_vec(&q, qn, b);
            let by_pair = scorer.score_pair(4, b);
            assert!((by_vec - by_pair).abs() < 1e-4, "b={b}: {by_vec} vs {by_pair}");
        }
    }
}
