//! Exact similarity scoring over any [`EmbeddingStore`], through *factored
//! space* when the representation layer offers it.
//!
//! The paper's representation makes inner products cheap without ever
//! materializing rows: `⟨Σ_k ⊗_j u_jk, Σ_k' ⊗_j v_jk'⟩ = Σ_{k,k'} Π_j
//! ⟨u_jk, v_jk'⟩` (§2.3), an `O(r² n q)` computation against the `O(q^n)`
//! dense dot product. The scorer asks the store for its
//! [`Repr`](crate::repr::Repr) once per scan (and once at construction for
//! the cosine norm pass):
//! [`Repr::resolve`](crate::repr::Repr::resolve) peels cache wrappers and
//! [`Repr::factored`](crate::repr::Repr::factored) hands back a
//! [`FactoredRepr`] handle exactly when the identity holds (raw CP form, no
//! LayerNorm, untruncated `q^n == p`) — for in-memory word2ket/word2ketXS
//! stores *and* for snapshot-mapped stores after a hot swap, with no
//! per-type sniffing here. Everything else falls back to materialized rows
//! served through the store (and thus through the hot-row cache when
//! present).
//!
//! Scans resolve a [`PairScorer`] once per query and score candidates in
//! blocks ([`PairScorer::score_block`] → [`FactoredRepr::block_inner`]), so
//! neither representation dispatch nor query-word factor resolution sits in
//! the per-candidate loop.
//!
//! Cosine mode caches per-word L2 norms at construction — computed in
//! factored space on tensorized stores (`‖v‖² = ⟨v, v⟩`), batched through a
//! reused arena otherwise, and skipped entirely when a snapshot-backed
//! store already embeds a norms section (see `snapshot::SaveOptions`).

use crate::embedding::EmbeddingStore;
use crate::repr::{FactoredRepr, Repr};
use crate::tensor::dot;
use std::sync::Arc;

/// Exact dot/cosine scorer over a store (see module docs).
pub struct Scorer {
    store: Arc<dyn EmbeddingStore>,
    cosine: bool,
    /// Per-word L2 norms; populated only in cosine mode.
    norms: Vec<f32>,
}

/// `‖row id‖` for every word of `store`, the way the scorer computes them:
/// `⟨v, v⟩` in factored space when the representation allows, dense dots
/// over arena-batched rows otherwise. Snapshot saving calls this to embed
/// norms so a reloading server can skip the pass.
pub fn compute_norms(store: &dyn EmbeddingStore) -> Vec<f32> {
    let vocab = store.vocab_size();
    let repr = Repr::resolve(store);
    // Sub-byte payloads score coarsely in factored space (`inner` is a
    // quantized-domain approximation — see `crate::quant`), so `⟨v, v⟩`
    // there is *not* the served row's norm. Norms always describe the
    // exact materialized rows.
    let factored = if repr.payload_bits() >= 32 { repr.factored() } else { None };
    if let Some(f) = factored {
        return (0..vocab).map(|id| f.inner(id, id).max(0.0).sqrt()).collect();
    }
    // Dense fallback: chunk rows through one reused arena (cache-aware when
    // the store is wrapped) instead of allocating a Vec per row.
    let dim = store.dim();
    let mut norms = Vec::with_capacity(vocab);
    let mut ids: Vec<usize> = Vec::new();
    let mut rows: Vec<f32> = Vec::new();
    const CHUNK: usize = 256;
    let mut start = 0usize;
    while start < vocab {
        let end = (start + CHUNK).min(vocab);
        ids.clear();
        ids.extend(start..end);
        store.lookup_batch_into(&ids, &mut rows);
        for row in rows.chunks_exact(dim) {
            norms.push(dot(row, row).max(0.0).sqrt());
        }
        start = end;
    }
    norms
}

impl Scorer {
    pub fn new(store: Arc<dyn EmbeddingStore>, cosine: bool) -> Scorer {
        let norms = if cosine {
            // A snapshot that embeds a norms section makes the whole pass
            // unnecessary — the values were computed by this same code
            // before saving.
            match Repr::resolve(store.as_ref()) {
                Repr::Snapshot(s) => s
                    .norms()
                    .map(<[f32]>::to_vec)
                    .unwrap_or_else(|| compute_norms(store.as_ref())),
                _ => compute_norms(store.as_ref()),
            }
        } else {
            Vec::new()
        };
        Scorer { store, cosine, norms }
    }

    pub fn vocab_size(&self) -> usize {
        self.store.vocab_size()
    }

    pub fn dim(&self) -> usize {
        self.store.dim()
    }

    pub fn cosine(&self) -> bool {
        self.cosine
    }

    /// True when pair scores go through factored space. Resolved from the
    /// store's representation on demand (cheap: wrapper peeling plus the
    /// precondition checks), so there is exactly one source of truth — the
    /// same resolution [`Scorer::pair_scorer`] performs.
    pub fn is_factored(&self) -> bool {
        Repr::resolve(self.store.as_ref()).factored().is_some()
    }

    /// Stored precision of the backing factor payload in bits per value
    /// ([`Repr::payload_bits`] on the resolved representation): 32 for
    /// float stores, the packed code width for quantized payloads. The IVF
    /// index treats `< 32` as "factored scores are coarse — re-rank the
    /// top candidates through exact rows", and serving surfaces report it
    /// (STATS `payload_bits` / the `w2k_payload_bits` gauge).
    pub fn payload_bits(&self) -> usize {
        Repr::resolve(self.store.as_ref()).payload_bits()
    }

    /// The cached per-word norms (cosine mode only): snapshot saving embeds
    /// these so a reload skips the norm pass.
    pub fn norms(&self) -> Option<&[f32]> {
        if self.cosine {
            Some(&self.norms)
        } else {
            None
        }
    }

    /// Materialize row `id` through the store (cache-aware when wrapped).
    pub fn row(&self, id: usize) -> Vec<f32> {
        self.store.lookup(id)
    }

    /// Resolve a per-scan scoring handle: the representation is resolved
    /// once here instead of once per pair — wrapper peeling and the
    /// factored-precondition checks cost on the order of the factored
    /// kernel itself at small rank, so scans must not pay them in the
    /// inner loop.
    pub fn pair_scorer(&self) -> PairScorer<'_> {
        PairScorer {
            factored: Repr::resolve(self.store.as_ref()).factored(),
            store: self.store.as_ref(),
            cosine: self.cosine,
            norms: &self.norms,
        }
    }

    /// Raw inner product `⟨row a, row b⟩` — factored when available.
    /// One-shot convenience; scans should use [`Self::pair_scorer`].
    pub fn raw_inner(&self, a: usize, b: usize) -> f32 {
        self.pair_scorer().raw_inner(a, b)
    }

    /// `‖row id‖`: cached in cosine mode, computed (factored) on demand
    /// otherwise.
    pub fn norm(&self, id: usize) -> f32 {
        match self.norms.get(id) {
            Some(&n) => n,
            None => self.raw_inner(id, id).max(0.0).sqrt(),
        }
    }

    /// Ranking score between two stored rows: dot product, or cosine using
    /// the cached norms. One-shot convenience; scans should use
    /// [`Self::pair_scorer`].
    pub fn score_pair(&self, a: usize, b: usize) -> f32 {
        self.pair_scorer().score(a, b)
    }

    /// Ranking score between an external query vector and stored row `b`.
    /// `q_norm` is `‖q‖`, ignored unless in cosine mode.
    pub fn score_vec(&self, q: &[f32], q_norm: f32, b: usize) -> f32 {
        let ip = dot(q, &self.store.lookup(b));
        if self.cosine {
            let denom = q_norm * self.norm(b);
            if denom > 0.0 {
                ip / denom
            } else {
                0.0
            }
        } else {
            ip
        }
    }

    pub fn describe(&self) -> String {
        let metric = if self.cosine { "cosine" } else { "dot" };
        let repr = Repr::resolve(self.store.as_ref());
        match repr.factored() {
            Some(f) if repr.payload_bits() < 32 => {
                format!("{metric}/coarse({}, {}b)", f.kind_name(), repr.payload_bits())
            }
            Some(f) => format!("{metric}/factored({})", f.kind_name()),
            None => format!("{metric}/materialized"),
        }
    }
}

/// Pair-scoring handle with the representation resolved once per scan.
///
/// Borrows the [`Scorer`]; create one per query/scan and call
/// [`score`](Self::score) / [`score_block`](Self::score_block) (or
/// [`raw_inner`](Self::raw_inner)) in the loop.
pub struct PairScorer<'a> {
    factored: Option<&'a dyn FactoredRepr>,
    store: &'a dyn EmbeddingStore,
    cosine: bool,
    norms: &'a [f32],
}

impl PairScorer<'_> {
    /// Raw inner product `⟨row a, row b⟩` — factored when available.
    #[inline]
    pub fn raw_inner(&self, a: usize, b: usize) -> f32 {
        match self.factored {
            Some(f) => f.inner(a, b),
            None => {
                let va = self.store.lookup(a);
                if a == b {
                    // Norm computations hit this: don't reconstruct twice.
                    dot(&va, &va)
                } else {
                    dot(&va, &self.store.lookup(b))
                }
            }
        }
    }

    /// Ranking score, same contract as [`Scorer::score_pair`].
    #[inline]
    pub fn score(&self, a: usize, b: usize) -> f32 {
        let ip = self.raw_inner(a, b);
        self.finish(a, b, ip)
    }

    /// Block scoring: `out[i] = score(a, bs[i])`, bitwise identical to the
    /// pairwise calls. On factored backends this runs through
    /// [`FactoredRepr::block_inner`], which hoists the query word's factor
    /// resolution out of the candidate loop — index scans feed whole
    /// cells/blocks through here.
    pub fn score_block(&self, a: usize, bs: &[usize], out: &mut [f32]) {
        debug_assert_eq!(bs.len(), out.len());
        match self.factored {
            Some(f) => {
                f.block_inner(a, bs, out);
                if self.cosine {
                    for (o, &b) in out.iter_mut().zip(bs) {
                        *o = self.finish(a, b, *o);
                    }
                }
            }
            None => {
                // Dense fallback: materialize the query row once per block,
                // not once per candidate; per-pair arithmetic (including the
                // a == b self-dot) is identical to `score`.
                let va = self.store.lookup(a);
                for (o, &b) in out.iter_mut().zip(bs) {
                    let ip =
                        if a == b { dot(&va, &va) } else { dot(&va, &self.store.lookup(b)) };
                    *o = self.finish(a, b, ip);
                }
            }
        }
    }

    /// Apply the metric to a raw inner product.
    #[inline]
    fn finish(&self, a: usize, b: usize, ip: f32) -> f32 {
        if self.cosine {
            let denom = self.norms[a] * self.norms[b];
            if denom > 0.0 {
                ip / denom
            } else {
                0.0
            }
        } else {
            ip
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{Word2Ket, Word2KetXS};
    use crate::serving::ShardedCache;
    use crate::snapshot::SnapshotStore;
    use crate::util::Rng;

    fn w2k(vocab: usize, dim: usize, order: usize, rank: usize) -> Arc<dyn EmbeddingStore> {
        let mut rng = Rng::new(3);
        Arc::new(Word2Ket::random(vocab, dim, order, rank, &mut rng))
    }

    #[test]
    fn factored_backends_detected() {
        // 4^2 == 16: exact → factored.
        assert!(Scorer::new(w2k(30, 16, 2, 2), false).is_factored());
        let mut rng = Rng::new(4);
        let xs: Arc<dyn EmbeddingStore> = Arc::new(Word2KetXS::random(30, 16, 2, 2, &mut rng));
        assert!(Scorer::new(xs, false).is_factored());
    }

    #[test]
    fn truncated_or_layernormed_stores_score_densely() {
        // 18² = 324 > 300: truncated reconstruction → dense fallback.
        assert!(!Scorer::new(w2k(30, 300, 2, 1), false).is_factored());
        let mut rng = Rng::new(5);
        let mut w = Word2Ket::random(30, 16, 2, 1, &mut rng);
        w.set_layernorm(true);
        let store: Arc<dyn EmbeddingStore> = Arc::new(w);
        let s = Scorer::new(store, false);
        assert!(!s.is_factored());
        // Dense scoring still works (no factored-identity assert tripped).
        assert!(s.score_pair(0, 1).is_finite());
    }

    #[test]
    fn factored_scores_match_dense_rows() {
        let store = w2k(40, 16, 2, 3);
        let scorer = Scorer::new(store.clone(), false);
        assert!(scorer.is_factored());
        for (a, b) in [(0usize, 1usize), (5, 5), (39, 7)] {
            let va = store.lookup(a);
            let vb = store.lookup(b);
            let dense = dot(&va, &vb);
            let fast = scorer.score_pair(a, b);
            assert!(
                (dense - fast).abs() < 1e-5 * dense.abs().max(1.0),
                "({a},{b}): {dense} vs {fast}"
            );
        }
    }

    #[test]
    fn cosine_scores_normalized_and_consistent() {
        let store = w2k(40, 16, 2, 2);
        let scorer = Scorer::new(store.clone(), true);
        for (a, b) in [(0usize, 3usize), (11, 29)] {
            let c = scorer.score_pair(a, b);
            assert!((-1.0 - 1e-4..=1.0 + 1e-4).contains(&c), "cosine {c} out of range");
            let va = store.lookup(a);
            let vb = store.lookup(b);
            let want = dot(&va, &vb) / (dot(&va, &va).sqrt() * dot(&vb, &vb).sqrt());
            assert!((c - want).abs() < 1e-4, "({a},{b}): {c} vs {want}");
        }
        // Self-similarity is 1.
        assert!((scorer.score_pair(7, 7) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn scoring_reaches_through_the_cache() {
        let mut rng = Rng::new(8);
        let inner = Box::new(Word2Ket::random(30, 16, 2, 2, &mut rng));
        let cached: Arc<dyn EmbeddingStore> = Arc::new(ShardedCache::new(inner, 2, 64));
        let scorer = Scorer::new(cached, false);
        assert!(scorer.is_factored(), "cache wrapper must be transparent to the repr");
        assert!(scorer.score_pair(1, 2).is_finite());
    }

    #[test]
    fn snapshot_store_resolves_factored_through_cache() {
        // A SnapshotStore-backed model (the post-reload state) must keep
        // factored-space scoring, including under the cache wrapper, with
        // scores bit-identical to the original store's.
        let mut rng = Rng::new(9);
        let xs = Word2KetXS::random(60, 16, 2, 2, &mut rng);
        let path = std::env::temp_dir()
            .join(format!("w2k_scorer_snap_{}.snap", std::process::id()));
        crate::snapshot::save_store(&xs, &path, &Default::default()).unwrap();
        let snap =
            Arc::new(crate::snapshot::Snapshot::open(&path, true).unwrap());
        let mm = SnapshotStore::open(snap).unwrap();
        let cached: Arc<dyn EmbeddingStore> =
            Arc::new(ShardedCache::new(Box::new(mm), 2, 64));
        let scorer = Scorer::new(cached, false);
        assert!(scorer.is_factored(), "snapshot store must keep factored scoring");
        assert!(scorer.describe().contains("factored(snapshot)"), "{}", scorer.describe());
        let direct = Scorer::new(Arc::new(xs) as Arc<dyn EmbeddingStore>, false);
        for (a, b) in [(0usize, 1usize), (5, 5), (59, 17)] {
            assert_eq!(
                direct.score_pair(a, b).to_bits(),
                scorer.score_pair(a, b).to_bits(),
                "({a},{b})"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn score_vec_matches_pair_on_materialized_query() {
        let store = w2k(30, 16, 2, 2);
        let scorer = Scorer::new(store.clone(), true);
        let q = store.lookup(4);
        let qn = dot(&q, &q).sqrt();
        for b in [0usize, 9, 21] {
            let by_vec = scorer.score_vec(&q, qn, b);
            let by_pair = scorer.score_pair(4, b);
            assert!((by_vec - by_pair).abs() < 1e-4, "b={b}: {by_vec} vs {by_pair}");
        }
    }

    #[test]
    fn score_block_matches_pairwise() {
        for cosine in [false, true] {
            // Factored arm (4² == 16, exact) and dense arm (18² = 324 >
            // 300, truncated): both must be bitwise equal to per-pair
            // scoring, including the repeated and a == b entries.
            for store in [w2k(40, 16, 2, 2), w2k(40, 300, 2, 1)] {
                let scorer = Scorer::new(store, cosine);
                let pairs = scorer.pair_scorer();
                let bs: Vec<usize> = vec![1, 5, 5, 7, 39, 0];
                let mut block = vec![0.0f32; bs.len()];
                pairs.score_block(7, &bs, &mut block);
                for (i, &b) in bs.iter().enumerate() {
                    assert_eq!(
                        pairs.score(7, b).to_bits(),
                        block[i].to_bits(),
                        "cosine={cosine} factored={} b={b}",
                        scorer.is_factored()
                    );
                }
            }
        }
    }

    /// Sub-byte stores are factored but *coarse*: their norms must come
    /// from the served rows, never from the quantized-domain self-inner
    /// (which differs grossly at 2 bits).
    #[test]
    fn quantized_store_norms_come_from_rows() {
        let mut rng = Rng::new(13);
        let w2k = Word2Ket::random(20, 16, 2, 2, &mut rng);
        let qk = crate::quant::QuantizedKet::from_word2ket(&w2k, 2).unwrap();
        assert!(Repr::resolve(&qk).factored().is_some());
        let norms = compute_norms(&qk);
        assert_eq!(norms.len(), 20);
        for (id, &n) in norms.iter().enumerate() {
            let v = qk.lookup(id);
            assert_eq!(n.to_bits(), dot(&v, &v).max(0.0).sqrt().to_bits(), "id {id}");
        }
        let scorer = Scorer::new(Arc::new(qk) as Arc<dyn EmbeddingStore>, false);
        assert_eq!(scorer.payload_bits(), 2);
        assert!(scorer.describe().contains("coarse"), "{}", scorer.describe());
    }

    #[test]
    fn compute_norms_dense_matches_factored() {
        // Same store scored through the factored path and through a dense
        // wrapper (LayerNorm off but truncated ⇒ dense): factored norms
        // must equal dense norms on an exact-dim twin of itself.
        let mut rng = Rng::new(11);
        let xs = Word2KetXS::random(30, 16, 2, 2, &mut rng);
        let factored = compute_norms(&xs);
        // Dense route: compute from materialized rows directly.
        let dense: Vec<f32> = (0..30)
            .map(|id| {
                let v = xs.lookup(id);
                dot(&v, &v).max(0.0).sqrt()
            })
            .collect();
        assert_eq!(factored.len(), dense.len());
        for (id, (f, d)) in factored.iter().zip(&dense).enumerate() {
            assert!((f - d).abs() < 1e-3 * d.max(1.0), "id {id}: {f} vs {d}");
        }
    }
}
