//! Inverted-file (IVF) approximate k-NN index.
//!
//! A k-means coarse quantizer over *reconstructed* rows partitions the
//! vocabulary into `nlist` cells; a query ranks the cell centroids, probes
//! the best `nprobe` cells, and exactly re-ranks only their members through
//! the [`Scorer`] — the expensive exact pass touches `≈ nprobe/nlist` of the
//! vocabulary instead of all of it, and for id queries it still runs in
//! factored space. Training is Lloyd's algorithm on a bounded random sample
//! (spherical k-means in cosine mode: rows and centroids kept unit-norm),
//! followed by one streaming full-vocabulary assignment pass; everything is
//! seeded and deterministic.

use super::{
    effective_scan_threads, scan_blocked, scan_parallel, KnnIndex, KnnResult, Neighbor, Query,
    QueryStats, Scorer, TopK,
};
use crate::tensor::dot;
use crate::util::Rng;

/// Lloyd iterations over the training sample. Coarse quantization does not
/// need convergence to the last decimal; candidate recall saturates early.
const KMEANS_ITERS: usize = 8;

/// Upper bound on k-means training rows (keeps index builds on 100k+ vocabs
/// from scaling with vocabulary size; assignment still sees every row once).
const MAX_TRAIN_ROWS: usize = 16_384;

/// Coarse-scan survivor count when the store serves a sub-byte payload
/// (`Scorer::payload_bits() < 32`): the quantized-domain scan keeps this
/// many candidates and only they are re-scored exactly. `8k` floored at 64
/// buys back the quantization error — at int4 the exact top-10 sits inside
/// the coarse top-64 on the standard configs — while the exact pass stays
/// `O(k)` materialized rows instead of `O(vocab)`.
fn rerank_depth(k: usize) -> usize {
    (k * 8).max(64)
}

/// IVF index: coarse centroids plus per-cell id lists (see module docs).
pub struct IvfIndex {
    scorer: Scorer,
    dim: usize,
    nprobe: usize,
    /// `nlist × dim` row-major; unit-norm in cosine mode.
    centroids: Vec<f32>,
    /// `lists[c]` holds the word ids whose rows quantize to centroid `c`.
    lists: Vec<Vec<u32>>,
    /// `scan_threads` knob for the re-rank: 0 = auto, 1 = single-threaded
    /// (the default for directly-constructed indexes), N = at most N
    /// workers.
    scan_threads: usize,
}

#[inline]
fn l2_normalize(row: &mut [f32]) {
    let n = dot(row, row).sqrt();
    if n > 0.0 {
        for x in row.iter_mut() {
            *x /= n;
        }
    }
}

/// Squared L2 distance — the one quantizer metric, shared by training
/// assignment and query-time probing so the two can never disagree.
#[inline]
fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    let mut d = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let t = x - y;
        d += t * t;
    }
    d
}

/// Index of the centroid closest (L2) to `row`. With unit-norm rows and
/// centroids this is equivalently the argmax-cosine centroid.
fn nearest_centroid(centroids: &[f32], dim: usize, row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for (c, cent) in centroids.chunks_exact(dim).enumerate() {
        let d = l2_sq(row, cent);
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

impl IvfIndex {
    /// Train the coarse quantizer and assign every word to a cell.
    /// `nlist`/`nprobe` are clamped to sane ranges (`1 ≤ nprobe ≤ nlist ≤
    /// vocab`).
    pub fn build(scorer: Scorer, nlist: usize, nprobe: usize, seed: u64) -> IvfIndex {
        let vocab = scorer.vocab_size();
        let dim = scorer.dim();
        assert!(vocab > 0, "cannot index an empty vocabulary");
        let nlist = nlist.clamp(1, vocab);
        let nprobe = nprobe.clamp(1, nlist);
        let cosine = scorer.cosine();
        let mut rng = Rng::new(seed ^ 0x1df3_a9c4_77b1_02e5);

        // Bounded training sample: a random subset of distinct ids (partial
        // Fisher-Yates), reconstructed once into a flat matrix. At least
        // nlist rows (centroid init needs them), at most MAX_TRAIN_ROWS
        // unless nlist itself is larger.
        let sample_n = (nlist * 64).min(MAX_TRAIN_ROWS).max(nlist).min(vocab);
        let mut ids: Vec<usize> = (0..vocab).collect();
        for i in 0..sample_n {
            let j = rng.range(i, vocab - 1);
            ids.swap(i, j);
        }
        let mut rows = Vec::with_capacity(sample_n * dim);
        for &id in &ids[..sample_n] {
            let mut row = scorer.row(id);
            if cosine {
                l2_normalize(&mut row);
            }
            rows.extend_from_slice(&row);
        }

        // Init: the first nlist sampled rows (already a uniform draw).
        let mut centroids = rows[..nlist * dim].to_vec();
        let mut assign = vec![usize::MAX; sample_n];
        for _ in 0..KMEANS_ITERS {
            let mut changed = false;
            for (i, row) in rows.chunks_exact(dim).enumerate() {
                let c = nearest_centroid(&centroids, dim, row);
                if assign[i] != c {
                    assign[i] = c;
                    changed = true;
                }
            }
            if !changed {
                break;
            }
            let mut counts = vec![0usize; nlist];
            let mut sums = vec![0.0f32; nlist * dim];
            for (i, row) in rows.chunks_exact(dim).enumerate() {
                let c = assign[i];
                counts[c] += 1;
                for (s, &x) in sums[c * dim..(c + 1) * dim].iter_mut().zip(row) {
                    *s += x;
                }
            }
            for c in 0..nlist {
                let dst = &mut centroids[c * dim..(c + 1) * dim];
                if counts[c] == 0 {
                    // Dead cell: reseed on a random training row so every
                    // centroid keeps pulling its share of the vocabulary.
                    let r = rng.below(sample_n);
                    dst.copy_from_slice(&rows[r * dim..(r + 1) * dim]);
                } else {
                    let inv = 1.0 / counts[c] as f32;
                    for (d, &s) in dst.iter_mut().zip(&sums[c * dim..(c + 1) * dim]) {
                        *d = s * inv;
                    }
                }
                if cosine {
                    l2_normalize(&mut centroids[c * dim..(c + 1) * dim]);
                }
            }
        }

        // Release the training buffers before the (long) assignment pass;
        // only the centroids are needed from here on.
        drop(rows);
        drop(assign);
        drop(ids);

        // Streaming full-vocabulary assignment: one reconstructed row in
        // flight at a time.
        let mut lists: Vec<Vec<u32>> = vec![Vec::new(); nlist];
        for id in 0..vocab {
            let mut row = scorer.row(id);
            if cosine {
                l2_normalize(&mut row);
            }
            lists[nearest_centroid(&centroids, dim, &row)].push(id as u32);
        }
        IvfIndex { scorer, dim, nprobe, centroids, lists, scan_threads: 1 }
    }

    /// Set the `[index] scan_threads` knob for the exact re-rank: 0 = auto
    /// (available parallelism), 1 = today's single-threaded pass, N = at
    /// most N workers. Small probe sets stay single-threaded regardless
    /// (each worker must be worth at least `MIN_SCAN_SPAN` candidates).
    pub fn with_scan_threads(mut self, knob: usize) -> IvfIndex {
        self.scan_threads = knob;
        self
    }

    /// Rebuild an index from serialized parts (snapshot loading), skipping
    /// k-means training entirely. Validates the payload instead of
    /// asserting: a server falls back to retraining on a bad payload rather
    /// than panicking mid-reload.
    pub fn from_parts(
        scorer: Scorer,
        nprobe: usize,
        centroids: Vec<f32>,
        lists: Vec<Vec<u32>>,
    ) -> crate::Result<IvfIndex> {
        let dim = scorer.dim();
        let vocab = scorer.vocab_size();
        let nlist = lists.len();
        if nlist == 0 || centroids.len() != nlist * dim {
            return Err(crate::Error::Snapshot(format!(
                "ivf parts mismatch: {} centroid values for nlist={nlist} dim={dim}",
                centroids.len()
            )));
        }
        let mut seen = vec![false; vocab];
        for list in &lists {
            for &id in list {
                let id = id as usize;
                if id >= vocab || seen[id] {
                    return Err(crate::Error::Snapshot(format!(
                        "ivf parts: id {id} out of range or duplicated (vocab {vocab})"
                    )));
                }
                seen[id] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err(crate::Error::Snapshot(
                "ivf parts: cell lists do not cover the vocabulary".into(),
            ));
        }
        let nprobe = nprobe.clamp(1, nlist);
        Ok(IvfIndex { scorer, dim, nprobe, centroids, lists, scan_threads: 1 })
    }

    pub fn nlist(&self) -> usize {
        self.lists.len()
    }

    pub fn nprobe(&self) -> usize {
        self.nprobe
    }

    pub fn scorer(&self) -> &Scorer {
        &self.scorer
    }

    /// `nlist × dim` row-major centroids (snapshot serialization).
    pub fn centroids(&self) -> &[f32] {
        &self.centroids
    }

    /// Per-cell member id lists (a partition of the vocabulary).
    pub fn lists(&self) -> &[Vec<u32>] {
        &self.lists
    }

    /// Exact pass over the coarse-scan survivors: re-score each against
    /// the already-materialized query row (f32 dots over served rows; the
    /// scorer's cosine norms are exact row norms) and keep the true top
    /// `k`. The selection rule is the same total order as the coarse
    /// [`TopK`], so the result is deterministic and thread-count
    /// independent.
    fn exact_rerank(
        &self,
        q: &[f32],
        q_norm: f32,
        coarse: Vec<Neighbor>,
        k: usize,
    ) -> Vec<Neighbor> {
        let mut top = TopK::new(k);
        for n in coarse {
            top.push(n.id, self.scorer.score_vec(q, q_norm, n.id));
        }
        top.into_sorted()
    }
}

impl KnnIndex for IvfIndex {
    fn top_k(&self, query: &Query, k: usize) -> KnnResult {
        // Materialize the query vector once (through the cache for ids); the
        // *re-rank* below still scores id queries in factored space.
        let owned;
        let q: &[f32] = match query {
            Query::Id(id) => {
                owned = self.scorer.row(*id);
                &owned
            }
            Query::Vector(v) => v.as_slice(),
        };
        let exclude = match query {
            Query::Id(id) => Some(*id),
            Query::Vector(_) => None,
        };
        let q_norm = if self.scorer.cosine() { dot(q, q).sqrt() } else { 0.0 };

        // Coarse ranking: probe the cells whose centroids are L2-closest to
        // the query — the same geometry assignment used, so a candidate's
        // cell ranks exactly by how close the candidate's neighborhood is.
        // (In cosine mode centroids are unit-norm, making this monotone-
        // equivalent to ranking by dot/cosine; in dot mode, dot-ranked
        // probing would systematically skip cells whose *mean* is small
        // even when their members score high.)
        let mut cells = TopK::new(self.nprobe);
        for (c, cent) in self.centroids.chunks_exact(self.dim).enumerate() {
            cells.push(c, -l2_sq(q, cent));
        }
        let probed = cells.into_sorted();

        // Exact re-rank of the probed cells' members: for id queries on
        // tensorized stores, whole cells feed through block-resolved
        // factored scoring (representation resolved once per query, query
        // factors hoisted per block); dense dots against the
        // already-materialized query vector otherwise.
        let factored_id = matches!(query, Query::Id(_)) && self.scorer.is_factored();

        // Quantization-aware serving: on a sub-byte payload the factored
        // scan scores in the quantized domain (cheap, coarse), so it keeps
        // `rerank_depth(k)` survivors instead of `k` and a second pass
        // re-scores just those against exact materialized rows. Dense
        // scans (vector queries, non-factored stores) are exact already.
        let coarse = factored_id && self.scorer.payload_bits() < 32;
        let fetch_k = if coarse { rerank_depth(k) } else { k };

        // Thread-parallel re-rank when the probed candidate set is big
        // enough: flatten the probed cells' members (same order as the
        // sequential pass) and chunk them across a scoped scan team. The
        // exact merge keeps results bit-identical to `scan_threads = 1`.
        let total_members: usize = probed.iter().map(|cell| self.lists[cell.id].len()).sum();
        let threads = effective_scan_threads(self.scan_threads, total_members);
        if threads > 1 {
            let cands: Vec<usize> = probed
                .iter()
                .flat_map(|cell| self.lists[cell.id].iter().map(|&cand| cand as usize))
                .filter(|&b| Some(b) != exclude)
                .collect();
            let (neighbors, scanned) = match (factored_id, exclude) {
                (true, Some(a)) => scan_parallel(cands.len(), fetch_k, threads, |lo, hi, top| {
                    // Each worker resolves its own factored view; the
                    // scorer itself is shared read-only.
                    let pairs = self.scorer.pair_scorer();
                    scan_blocked(&pairs, a, cands[lo..hi].iter().copied(), top)
                }),
                _ => scan_parallel(cands.len(), fetch_k, threads, |lo, hi, top| {
                    for &b in &cands[lo..hi] {
                        top.push(b, self.scorer.score_vec(q, q_norm, b));
                    }
                    hi - lo
                }),
            };
            let neighbors = if coarse {
                self.exact_rerank(q, q_norm, neighbors, k)
            } else {
                neighbors
            };
            return (neighbors, QueryStats { candidates: scanned, probes: probed.len() });
        }

        let pairs = self.scorer.pair_scorer();
        let mut top = TopK::new(fetch_k);
        let mut scanned = 0usize;
        match query {
            Query::Id(a) if factored_id => {
                // One blocked scan over all probed cells' members (same
                // candidate order as the per-cell loops), so blocks stay
                // full-size across cell boundaries and the query factors
                // are hoisted once per block, not once per small cell.
                scanned += scan_blocked(
                    &pairs,
                    *a,
                    probed.iter().flat_map(|cell| {
                        self.lists[cell.id]
                            .iter()
                            .map(|&cand| cand as usize)
                            .filter(|&b| Some(b) != exclude)
                    }),
                    &mut top,
                );
            }
            _ => {
                for cell in &probed {
                    for &cand in &self.lists[cell.id] {
                        let b = cand as usize;
                        if Some(b) == exclude {
                            continue;
                        }
                        top.push(b, self.scorer.score_vec(q, q_norm, b));
                        scanned += 1;
                    }
                }
            }
        }
        let neighbors = if coarse {
            self.exact_rerank(q, q_norm, top.into_sorted(), k)
        } else {
            top.into_sorted()
        };
        (neighbors, QueryStats { candidates: scanned, probes: probed.len() })
    }

    fn describe(&self) -> String {
        format!(
            "ivf[nlist={} nprobe={} {}] over {} words",
            self.lists.len(),
            self.nprobe,
            self.scorer.describe(),
            self.scorer.vocab_size()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::{EmbeddingStore, Word2Ket};
    use crate::index::BruteForce;
    use std::sync::Arc;

    fn store(vocab: usize) -> Arc<dyn EmbeddingStore> {
        let mut rng = Rng::new(23);
        Arc::new(Word2Ket::random(vocab, 16, 2, 2, &mut rng))
    }

    #[test]
    fn lists_partition_the_vocabulary() {
        let ivf = IvfIndex::build(Scorer::new(store(500), false), 8, 2, 1);
        let mut seen = vec![false; 500];
        for list in &ivf.lists {
            for &id in list {
                assert!(!seen[id as usize], "id {id} in two cells");
                seen[id as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some id unassigned");
    }

    #[test]
    fn probing_every_cell_is_exact() {
        // nprobe == nlist scans every cell, so IVF must reproduce brute
        // force exactly (the cells partition the vocabulary).
        let s = store(400);
        let ivf = IvfIndex::build(Scorer::new(s.clone(), false), 10, 10, 2);
        let brute = BruteForce::new(Scorer::new(s, false));
        for &query in &[0usize, 123, 399] {
            let (approx, stats) = ivf.top_k(&Query::Id(query), 8);
            let (exact, _) = brute.top_k(&Query::Id(query), 8);
            assert_eq!(stats.probes, 10);
            assert_eq!(stats.candidates, 399, "all non-query ids scanned");
            let a_ids: Vec<usize> = approx.iter().map(|n| n.id).collect();
            let e_ids: Vec<usize> = exact.iter().map(|n| n.id).collect();
            assert_eq!(a_ids, e_ids, "query {query}");
        }
    }

    #[test]
    fn partial_probe_is_sublinear_with_reasonable_recall() {
        let s = store(1000);
        let ivf = IvfIndex::build(Scorer::new(s.clone(), true), 16, 6, 3);
        let brute = BruteForce::new(Scorer::new(s, true));
        let k = 10;
        let mut hits = 0usize;
        let mut total = 0usize;
        for query in (0..1000).step_by(97) {
            let (approx, stats) = ivf.top_k(&Query::Id(query), k);
            assert!(stats.candidates < 999, "probe scanned the whole vocab");
            assert_eq!(stats.probes, 6);
            let (exact, _) = brute.top_k(&Query::Id(query), k);
            let approx_ids: std::collections::HashSet<usize> =
                approx.iter().map(|n| n.id).collect();
            hits += exact.iter().filter(|n| approx_ids.contains(&n.id)).count();
            total += k;
        }
        let recall = hits as f64 / total as f64;
        assert!(recall > 0.2, "recall {recall:.2} suspiciously low");
    }

    #[test]
    fn from_parts_reproduces_built_index() {
        let s = store(300);
        let built = IvfIndex::build(Scorer::new(s.clone(), false), 8, 3, 9);
        let rebuilt = IvfIndex::from_parts(
            Scorer::new(s.clone(), false),
            3,
            built.centroids().to_vec(),
            built.lists().to_vec(),
        )
        .unwrap();
        assert_eq!(rebuilt.nlist(), built.nlist());
        for &q in &[0usize, 99, 299] {
            let (a, sa) = built.top_k(&Query::Id(q), 7);
            let (b, sb) = rebuilt.top_k(&Query::Id(q), 7);
            assert_eq!(sa, sb, "query {q} stats differ");
            let aids: Vec<usize> = a.iter().map(|n| n.id).collect();
            let bids: Vec<usize> = b.iter().map(|n| n.id).collect();
            assert_eq!(aids, bids, "query {q}");
        }
        // Bad payloads are typed errors, not panics.
        assert!(IvfIndex::from_parts(Scorer::new(s.clone(), false), 3, vec![0.0; 5], vec![])
            .is_err());
        // A list set that drops ids must be rejected too.
        let mut lists = built.lists().to_vec();
        let dropped = lists[0].pop();
        assert!(dropped.is_some());
        assert!(IvfIndex::from_parts(
            Scorer::new(s, false),
            3,
            built.centroids().to_vec(),
            lists
        )
        .is_err());
    }

    /// Tentpole identity: the thread-parallel re-rank returns the same ids
    /// and score bits as the single-threaded pass (same index, same probes).
    #[test]
    fn parallel_rerank_is_bit_identical() {
        let vocab = 4096;
        let mut rng = Rng::new(31);
        let s: Arc<dyn EmbeddingStore> = Arc::new(Word2Ket::random(vocab, 16, 2, 2, &mut rng));
        // nprobe == nlist: every member re-ranked, so the candidate set is
        // large enough for 4 workers to actually engage.
        let ivf = IvfIndex::build(Scorer::new(s.clone(), false), 4, 4, 7);
        let mut want = Vec::new();
        for &q in &[0usize, 777, 4095] {
            want.push(ivf.top_k(&Query::Id(q), 9));
        }
        let ivf = ivf.with_scan_threads(4);
        for (i, &q) in [0usize, 777, 4095].iter().enumerate() {
            let (got, gs) = ivf.top_k(&Query::Id(q), 9);
            let (exp, es) = &want[i];
            assert_eq!(*es, gs, "stats differ for query {q}");
            assert_eq!(exp.len(), got.len());
            for (w, g) in exp.iter().zip(&got) {
                assert_eq!((w.id, w.score.to_bits()), (g.id, g.score.to_bits()), "query {q}");
            }
        }
    }

    /// Tentpole: on a sub-byte store the IVF scan runs coarse in the
    /// quantized domain, then exactly re-ranks `rerank_depth(k)` survivors
    /// — recovering the exact top-k over the *served* rows with high
    /// recall, returning exact (not coarse) scores, and staying
    /// bit-identical under thread-parallel scans.
    #[test]
    fn quantized_store_reranks_to_exact_scores() {
        let vocab = 2048;
        let mut rng = Rng::new(41);
        let w2k = Word2Ket::random(vocab, 16, 2, 2, &mut rng);
        let qk: Arc<dyn EmbeddingStore> =
            Arc::new(crate::quant::QuantizedKet::from_word2ket(&w2k, 4).unwrap());
        // Probe every cell so any recall gap is purely quantization error.
        let ivf = IvfIndex::build(Scorer::new(qk.clone(), false), 8, 8, 6);
        assert!(ivf.scorer().is_factored());
        assert_eq!(ivf.scorer().payload_bits(), 4);
        assert!(ivf.describe().contains("coarse"), "{}", ivf.describe());
        let par = IvfIndex::from_parts(
            Scorer::new(qk.clone(), false),
            8,
            ivf.centroids().to_vec(),
            ivf.lists().to_vec(),
        )
        .unwrap()
        .with_scan_threads(4);

        let k = 10;
        let rows: Vec<Vec<f32>> = (0..vocab).map(|id| qk.lookup(id)).collect();
        let (mut hits, mut total) = (0usize, 0usize);
        for query in (0..vocab).step_by(173) {
            let (got, stats) = ivf.top_k(&Query::Id(query), k);
            assert_eq!(stats.candidates, vocab - 1);
            assert_eq!(got.len(), k);

            // Exact ground truth over the served (f16-refined) rows.
            let mut truth = TopK::new(k);
            for b in 0..vocab {
                if b != query {
                    truth.push(b, dot(&rows[query], &rows[b]));
                }
            }
            let want: std::collections::HashSet<usize> =
                truth.into_sorted().iter().map(|n| n.id).collect();
            hits += got.iter().filter(|n| want.contains(&n.id)).count();
            total += k;

            // Returned scores are the exact dense scores, not coarse ones.
            for n in &got {
                let exact = dot(&rows[query], &rows[n.id]);
                assert_eq!(n.score.to_bits(), exact.to_bits(), "query {query} id {}", n.id);
            }

            // Thread-parallel coarse scan + re-rank is bit-identical.
            let (par_got, par_stats) = par.top_k(&Query::Id(query), k);
            assert_eq!(stats, par_stats, "query {query}");
            for (w, g) in got.iter().zip(&par_got) {
                assert_eq!((w.id, w.score.to_bits()), (g.id, g.score.to_bits()), "q {query}");
            }
        }
        let recall = hits as f64 / total as f64;
        assert!(recall >= 0.95, "int4 rerank recall {recall:.3} below 0.95");
    }

    #[test]
    fn nlist_larger_than_vocab_clamps() {
        let ivf = IvfIndex::build(Scorer::new(store(12), false), 64, 64, 4);
        assert!(ivf.nlist() <= 12);
        let (ns, _) = ivf.top_k(&Query::Id(3), 5);
        assert_eq!(ns.len(), 5);
    }

    #[test]
    fn vector_queries_supported() {
        // Cosine + exhaustive probing: a word's own row has similarity
        // exactly 1, the maximum, so it must come back first.
        let s = store(300);
        let ivf = IvfIndex::build(Scorer::new(s.clone(), true), 8, 8, 5);
        let q = s.lookup(42);
        let (ns, _) = ivf.top_k(&Query::Vector(q), 3);
        assert_eq!(ns.len(), 3);
        assert_eq!(ns[0].id, 42, "{ns:?}");
        assert!((ns[0].score - 1.0).abs() < 1e-4);
    }
}
