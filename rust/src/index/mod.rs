//! Similarity search (k-NN) over any [`EmbeddingStore`].
//!
//! The retrieval-side payoff of the paper's representation: because rows are
//! sums of Kronecker products, inner products run in factored space
//! (`O(r² n q)` per pair, [`Scorer`]) instead of over materialized rows
//! (`O(q^n)`), so the compressed table is *faster* to search, not just
//! smaller to store. Two index structures sit behind one trait:
//!
//! * [`BruteForce`] — exact scan of the whole vocabulary through the scorer.
//! * [`IvfIndex`] — inverted-file approximate index: a k-means coarse
//!   quantizer over reconstructed rows partitions the vocabulary into
//!   `nlist` cells; queries probe the `nprobe` closest cells and exactly
//!   re-rank only their members (sub-linear candidate scans at large vocab).
//!
//! Both serve [`KnnIndex::top_k`] for queries by word id (fully factored
//! path) or by external vector, returning per-query [`QueryStats`]. The
//! server dispatches `KNN` requests here through the serving worker pool
//! (`OP_KNN` on the binary wire, `KNN <id> <k>` in text); configuration
//! comes from the `[index]` section ([`crate::config::IndexConfig`]).

pub mod ivf;
pub mod scorer;

pub use ivf::IvfIndex;
pub use scorer::{PairScorer, Scorer};

use crate::config::{IndexConfig, IndexKind};
use crate::embedding::EmbeddingStore;
use crate::tensor::dot;
use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;
use std::sync::Arc;

/// One search result: a word id and its similarity to the query.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub id: usize,
    pub score: f32,
}

/// A k-NN query: a word already in the store (scored in factored space when
/// the store supports it) or an external dense vector.
#[derive(Debug, Clone, PartialEq)]
pub enum Query {
    Id(usize),
    Vector(Vec<f32>),
}

/// Per-query accounting, aggregated into the server's `STATS` counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// Candidates exactly scored (vocab size for brute force; probed-list
    /// members for IVF).
    pub candidates: usize,
    /// Coarse cells probed (0 for brute force).
    pub probes: usize,
}

/// Result alias shared with the serving pool's reply channels.
pub type KnnResult = (Vec<Neighbor>, QueryStats);

/// A top-k similarity index over an embedding store.
pub trait KnnIndex: Send + Sync {
    /// Up to `k` nearest neighbors, best first (descending score, ties by
    /// ascending id). For [`Query::Id`] the query word itself is excluded.
    fn top_k(&self, query: &Query, k: usize) -> KnnResult;

    /// Human-readable description for logs and reports.
    fn describe(&self) -> String;
}

/// Candidate ids scored per [`PairScorer::score_block`] call during
/// factored scans (brute-force sweeps and IVF re-ranks): big enough to
/// amortize query-word factor resolution, small enough to stay on the
/// stack.
pub(crate) const SCAN_BLOCK: usize = 128;

/// Minimum candidates each scan worker must have before another thread is
/// worth spawning; below this, thread startup dwarfs the scoring work.
pub(crate) const MIN_SCAN_SPAN: usize = 512;

/// Resolve the `[index] scan_threads` knob for a scan over `candidates`
/// ids: `0` means auto (available parallelism), `1` is the single-threaded
/// scan, and any request is capped so each worker keeps at least
/// [`MIN_SCAN_SPAN`] candidates.
pub(crate) fn effective_scan_threads(knob: usize, candidates: usize) -> usize {
    let want = if knob == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        knob
    };
    want.min(candidates / MIN_SCAN_SPAN).max(1)
}

/// `SCAN_BLOCK`-aligned contiguous chunks covering `0..total`, at most
/// `threads` of them. Alignment keeps parallel flush boundaries on the same
/// block grid a single-threaded sweep uses.
fn scan_chunks(total: usize, threads: usize) -> Vec<(usize, usize)> {
    let chunk = total.div_ceil(threads).div_ceil(SCAN_BLOCK).max(1) * SCAN_BLOCK;
    (0..total).step_by(chunk).map(|lo| (lo, (lo + chunk).min(total))).collect()
}

/// Run `work` over contiguous chunks of `0..total` on a scoped thread team,
/// each worker filling its own exact [`TopK`], and merge the partial lists
/// through [`merge_top_k`]. Because the selection rule is a total order
/// (descending score, ties by ascending id), the top-k *set* does not
/// depend on how the candidate space is partitioned — the merged result is
/// bit-identical to the `threads == 1` scan, which runs inline with no
/// thread spawned (today's behavior). Returns the merged neighbors plus the
/// summed per-worker scanned counts.
fn scan_parallel<F>(total: usize, k: usize, threads: usize, work: F) -> (Vec<Neighbor>, usize)
where
    F: Fn(usize, usize, &mut TopK) -> usize + Sync,
{
    if threads <= 1 || total == 0 {
        let mut top = TopK::new(k);
        let scanned = work(0, total, &mut top);
        return (top.into_sorted(), scanned);
    }
    let chunks = scan_chunks(total, threads);
    let results: Vec<(Vec<Neighbor>, usize)> = std::thread::scope(|s| {
        let work = &work;
        let handles: Vec<_> = chunks
            .iter()
            .map(|&(lo, hi)| {
                s.spawn(move || {
                    let mut top = TopK::new(k);
                    let scanned = work(lo, hi, &mut top);
                    (top.into_sorted(), scanned)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("scan worker panicked")).collect()
    });
    let mut scanned = 0usize;
    let mut lists = Vec::with_capacity(results.len());
    for (list, n) in results {
        scanned += n;
        lists.push(list);
    }
    (merge_top_k(k, lists), scanned)
}

/// Feed every id yielded by `candidates` through block-resolved factored
/// scoring into `top`, returning how many candidates were scored. Shared by
/// the brute-force sweep and the IVF cell re-rank so both batch the same
/// way.
pub(crate) fn scan_blocked(
    pairs: &PairScorer<'_>,
    a: usize,
    candidates: impl Iterator<Item = usize>,
    top: &mut TopK,
) -> usize {
    let mut ids = [0usize; SCAN_BLOCK];
    let mut scores = [0.0f32; SCAN_BLOCK];
    let mut flush = |ids: &[usize], scores: &mut [f32], top: &mut TopK| {
        pairs.score_block(a, ids, scores);
        for (&id, &s) in ids.iter().zip(scores.iter()) {
            top.push(id, s);
        }
        ids.len()
    };
    let mut n = 0usize;
    let mut scanned = 0usize;
    for b in candidates {
        ids[n] = b;
        n += 1;
        if n == SCAN_BLOCK {
            scanned += flush(&ids[..n], &mut scores[..n], top);
            n = 0;
        }
    }
    if n > 0 {
        scanned += flush(&ids[..n], &mut scores[..n], top);
    }
    scanned
}

/// Merge per-shard partial top-k lists into one exact global top-k.
///
/// The scatter-gather half of cluster KNN: each shard returns its own
/// best-first list over a disjoint vocabulary slice; pushing every partial
/// result through one `TopK` applies the same selection rule a
/// single-node scan uses (descending score, ties broken by ascending id),
/// so the merged answer is *identical* to scanning the unsharded store —
/// provided each input list carried at least `k` entries or was exhaustive
/// for its shard. Tolerates empty lists (an empty shard, or a shard whose
/// slice is smaller than `k`) and a `k` larger than the global vocabulary
/// (the result is simply every candidate, sorted).
pub fn merge_top_k(
    k: usize,
    lists: impl IntoIterator<Item = Vec<Neighbor>>,
) -> Vec<Neighbor> {
    let mut top = TopK::new(k);
    for list in lists {
        for n in list {
            top.push(n.id, n.score);
        }
    }
    top.into_sorted()
}

/// Heap entry ordering: higher score is better; ties prefer the smaller id
/// so results are deterministic.
struct Entry(Neighbor);

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.score.total_cmp(&other.0.score).then_with(|| other.0.id.cmp(&self.0.id))
    }
}

/// Bounded top-k selector: a size-k min-heap, `O(n log k)` over a scan.
pub(crate) struct TopK {
    k: usize,
    heap: BinaryHeap<Reverse<Entry>>,
}

impl TopK {
    pub(crate) fn new(k: usize) -> TopK {
        TopK { k, heap: BinaryHeap::with_capacity(k + 1) }
    }

    #[inline]
    pub(crate) fn push(&mut self, id: usize, score: f32) {
        if self.k == 0 {
            return;
        }
        let entry = Entry(Neighbor { id, score });
        if self.heap.len() < self.k {
            self.heap.push(Reverse(entry));
        } else if let Some(worst) = self.heap.peek() {
            if entry > worst.0 {
                self.heap.pop();
                self.heap.push(Reverse(entry));
            }
        }
    }

    /// Drain best-first.
    pub(crate) fn into_sorted(self) -> Vec<Neighbor> {
        let mut out: Vec<Neighbor> = self.heap.into_iter().map(|Reverse(e)| e.0).collect();
        out.sort_by(|a, b| b.score.total_cmp(&a.score).then_with(|| a.id.cmp(&b.id)));
        out
    }
}

/// Exact index: score every word in the vocabulary through the [`Scorer`].
pub struct BruteForce {
    scorer: Scorer,
    /// `scan_threads` knob: 0 = auto, 1 = single-threaded (the default for
    /// directly-constructed indexes), N = at most N scan workers.
    scan_threads: usize,
}

impl BruteForce {
    pub fn new(scorer: Scorer) -> BruteForce {
        BruteForce { scorer, scan_threads: 1 }
    }

    /// Set the `[index] scan_threads` knob: 0 = auto (available
    /// parallelism), 1 = today's single-threaded scan, N = at most N
    /// workers. Small vocabularies stay single-threaded regardless (each
    /// worker must be worth at least `MIN_SCAN_SPAN` candidates).
    pub fn with_scan_threads(mut self, knob: usize) -> BruteForce {
        self.scan_threads = knob;
        self
    }

    pub fn scorer(&self) -> &Scorer {
        &self.scorer
    }
}

impl KnnIndex for BruteForce {
    fn top_k(&self, query: &Query, k: usize) -> KnnResult {
        let vocab = self.scorer.vocab_size();
        let threads = effective_scan_threads(self.scan_threads, vocab);
        let (neighbors, scanned) = match query {
            Query::Id(a) if self.scorer.is_factored() => {
                let a = *a;
                scan_parallel(vocab, k, threads, |lo, hi, top| {
                    // Resolve the factored representation once per worker
                    // and sweep its chunk in blocks; neither dispatch nor
                    // the query word's factor resolution runs per pair.
                    let pairs = self.scorer.pair_scorer();
                    scan_blocked(&pairs, a, (lo..hi).filter(|b| *b != a), top)
                })
            }
            Query::Id(a) => {
                // Dense fallback: materialize the query row once instead of
                // on every pair; workers share it read-only.
                let a = *a;
                let q = self.scorer.row(a);
                let q_norm = if self.scorer.cosine() { self.scorer.norm(a) } else { 0.0 };
                scan_parallel(vocab, k, threads, |lo, hi, top| {
                    let mut scanned = 0usize;
                    for b in lo..hi {
                        if b == a {
                            continue;
                        }
                        top.push(b, self.scorer.score_vec(&q, q_norm, b));
                        scanned += 1;
                    }
                    scanned
                })
            }
            Query::Vector(q) => {
                let q_norm = if self.scorer.cosine() { dot(q, q).sqrt() } else { 0.0 };
                scan_parallel(vocab, k, threads, |lo, hi, top| {
                    for b in lo..hi {
                        top.push(b, self.scorer.score_vec(q, q_norm, b));
                    }
                    hi - lo
                })
            }
        };
        (neighbors, QueryStats { candidates: scanned, probes: 0 })
    }

    fn describe(&self) -> String {
        format!("brute-force[{}] over {} words", self.scorer.describe(), self.scorer.vocab_size())
    }
}

/// Build the configured index over `store`. IVF construction runs k-means
/// over reconstructed rows, so it does real work at startup; brute force is
/// free (cosine mode precomputes per-word norms either way).
pub fn build_index(
    cfg: &IndexConfig,
    store: Arc<dyn EmbeddingStore>,
    seed: u64,
) -> Box<dyn KnnIndex> {
    let scorer = Scorer::new(store, cfg.cosine);
    match cfg.kind {
        IndexKind::Brute => Box::new(BruteForce::new(scorer).with_scan_threads(cfg.scan_threads)),
        IndexKind::Ivf => Box::new(
            IvfIndex::build(scorer, cfg.nlist, cfg.nprobe, seed)
                .with_scan_threads(cfg.scan_threads),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::Word2Ket;
    use crate::prop_assert;
    use crate::util::Rng;

    fn factored_brute(vocab: usize, dim: usize, order: usize, rank: usize) -> BruteForce {
        let mut rng = Rng::new(17);
        let store: Arc<dyn EmbeddingStore> =
            Arc::new(Word2Ket::random(vocab, dim, order, rank, &mut rng));
        let b = BruteForce::new(Scorer::new(store, false));
        assert!(b.scorer().is_factored());
        b
    }

    /// Acceptance: factored top-k identical to brute force over materialized
    /// rows on a seeded 10k-vocab store (scores within 1e-5; positions where
    /// the two orderings differ must be genuine score ties).
    #[test]
    fn factored_top_k_matches_materialized_10k() {
        let vocab = 10_000;
        let dim = 16; // q = 4, 4² = 16: exact reconstruction
        let index = factored_brute(vocab, dim, 2, 2);
        let rows: Vec<Vec<f32>> = (0..vocab).map(|id| index.scorer().row(id)).collect();
        let k = 10;
        for &query in &[0usize, 137, 4242, 9999] {
            let (fast, stats) = index.top_k(&Query::Id(query), k);
            assert_eq!(stats.candidates, vocab - 1);
            // Materialized baseline: same selection rule, dense dot scores.
            let mut baseline = TopK::new(k);
            for b in 0..vocab {
                if b != query {
                    baseline.push(b, dot(&rows[query], &rows[b]));
                }
            }
            let slow = baseline.into_sorted();
            assert_eq!(fast.len(), k);
            assert_eq!(slow.len(), k);
            for (f, s) in fast.iter().zip(slow.iter()) {
                assert!(
                    (f.score - s.score).abs() < 1e-5 * f.score.abs().max(1.0),
                    "query {query}: factored {f:?} vs materialized {s:?}"
                );
                // Differing ids at the same position are only acceptable as
                // exact-precision ties (scores within float noise).
                if f.id != s.id {
                    let dense_f = dot(&rows[query], &rows[f.id]);
                    assert!(
                        (dense_f - s.score).abs() < 1e-5 * s.score.abs().max(1.0),
                        "query {query}: ids {} vs {} differ beyond a tie",
                        f.id,
                        s.id
                    );
                }
            }
        }
    }

    #[test]
    fn results_sorted_and_exclude_query() {
        let index = factored_brute(200, 16, 2, 2);
        let (ns, stats) = index.top_k(&Query::Id(42), 12);
        assert_eq!(ns.len(), 12);
        assert_eq!(stats.candidates, 199);
        assert_eq!(stats.probes, 0);
        assert!(ns.iter().all(|n| n.id != 42), "query id must be excluded");
        for w in ns.windows(2) {
            assert!(w[0].score >= w[1].score, "not sorted: {ns:?}");
        }
    }

    #[test]
    fn vector_query_agrees_with_id_query() {
        let index = factored_brute(150, 16, 2, 2);
        let q = index.scorer().row(7);
        let (by_id, _) = index.top_k(&Query::Id(7), 5);
        let (by_vec, _) = index.top_k(&Query::Vector(q), 6);
        // The vector query sees word 7 itself (it cannot know); drop it.
        let by_vec: Vec<&Neighbor> = by_vec.iter().filter(|n| n.id != 7).collect();
        for (a, b) in by_id.iter().zip(by_vec.iter()) {
            // Factored vs dense scoring may swap float-noise ties; scores
            // must agree either way.
            assert!(
                a.id == b.id || (a.score - b.score).abs() < 1e-4,
                "{by_id:?} vs {by_vec:?}"
            );
            assert!((a.score - b.score).abs() < 1e-4, "{} vs {}", a.score, b.score);
        }
    }

    #[test]
    fn k_larger_than_vocab_and_k_zero() {
        let index = factored_brute(8, 16, 2, 1);
        let (ns, _) = index.top_k(&Query::Id(0), 50);
        assert_eq!(ns.len(), 7, "everything except the query itself");
        let (empty, _) = index.top_k(&Query::Id(0), 0);
        assert!(empty.is_empty());
    }

    #[test]
    fn tie_break_prefers_smaller_id() {
        let mut top = TopK::new(3);
        top.push(9, 1.0);
        top.push(2, 1.0);
        top.push(5, 1.0);
        top.push(7, 1.0);
        let out = top.into_sorted();
        assert_eq!(out.iter().map(|n| n.id).collect::<Vec<_>>(), vec![2, 5, 7]);
    }

    /// Satellite: merge edge cases — duplicate scores across shards, k
    /// larger than the global vocabulary, empty shard responses, k == 0.
    #[test]
    fn merge_top_k_edge_cases() {
        let n = |id: usize, score: f32| Neighbor { id, score };
        let a = vec![n(5, 1.0), n(9, 0.5)];
        let b = vec![n(2, 1.0), n(7, 1.0)];

        // Duplicate scores across shards: the global tie rule (ascending
        // id) applies across lists, exactly as one TopK scan would.
        let ids: Vec<usize> =
            merge_top_k(3, [a.clone(), b.clone()]).iter().map(|x| x.id).collect();
        assert_eq!(ids, vec![2, 5, 7]);

        // k larger than everything the shards returned: every candidate,
        // fully sorted.
        let all = merge_top_k(50, [a.clone(), b.clone()]);
        assert_eq!(all.iter().map(|x| x.id).collect::<Vec<_>>(), vec![2, 5, 7, 9]);
        for w in all.windows(2) {
            assert!(
                w[0].score > w[1].score || (w[0].score == w[1].score && w[0].id < w[1].id),
                "{all:?}"
            );
        }

        // Empty shard responses (an empty shard, a shard smaller than k)
        // are tolerated, not an error.
        let merged = merge_top_k(2, [Vec::new(), a.clone(), Vec::new()]);
        assert_eq!(merged, a);

        // k == 0 is an empty answer.
        assert!(merge_top_k(0, [a]).is_empty());
    }

    #[test]
    fn effective_scan_threads_resolves_knob() {
        // 1 is always exactly one worker; explicit requests are honored
        // while the candidate count can feed them.
        assert_eq!(effective_scan_threads(1, 1_000_000), 1);
        assert_eq!(effective_scan_threads(4, 4 * MIN_SCAN_SPAN), 4);
        // Small scans never spawn, whatever was asked for.
        assert_eq!(effective_scan_threads(8, MIN_SCAN_SPAN - 1), 1);
        assert_eq!(effective_scan_threads(0, 10), 1);
        // Auto resolves to at least one worker.
        assert!(effective_scan_threads(0, usize::MAX / 2) >= 1);
    }

    #[test]
    fn scan_chunks_align_to_blocks_and_cover() {
        for (total, threads) in [(4096, 4), (4097, 4), (1000, 3), (129, 2), (128, 2)] {
            let chunks = scan_chunks(total, threads);
            assert!(chunks.len() <= threads, "total={total} threads={threads}");
            let mut expect = 0usize;
            for &(lo, hi) in &chunks {
                assert_eq!(lo, expect, "gap at {lo} (total={total} threads={threads})");
                assert!(hi > lo);
                assert_eq!(lo % SCAN_BLOCK, 0, "chunk start off the block grid");
                expect = hi;
            }
            assert_eq!(expect, total, "chunks must cover 0..total");
        }
    }

    /// Tentpole identity: the thread-parallel blocked scan returns the same
    /// ids *and the same score bits* as the single-threaded scan, on the
    /// factored fast path.
    #[test]
    fn parallel_factored_scan_is_bit_identical() {
        let vocab = 4096; // 4 workers × MIN_SCAN_SPAN and change
        let mut rng = Rng::new(91);
        let store: Arc<dyn EmbeddingStore> =
            Arc::new(Word2Ket::random(vocab, 16, 2, 2, &mut rng));
        let single = BruteForce::new(Scorer::new(store.clone(), false));
        assert!(single.scorer().is_factored());
        for &threads in &[2usize, 4] {
            let parallel =
                BruteForce::new(Scorer::new(store.clone(), false)).with_scan_threads(threads);
            for &query in &[0usize, 1234, 4095] {
                let (want, ws) = single.top_k(&Query::Id(query), 10);
                let (got, gs) = parallel.top_k(&Query::Id(query), 10);
                assert_eq!(ws, gs, "stats differ (threads={threads} query={query})");
                assert_eq!(want.len(), got.len());
                for (w, g) in want.iter().zip(&got) {
                    assert_eq!(
                        (w.id, w.score.to_bits()),
                        (g.id, g.score.to_bits()),
                        "threads={threads} query={query}"
                    );
                }
            }
        }
    }

    /// Same identity on the dense arms, with heavy *exact* score ties:
    /// every 64th row is identical, so the ascending-id tie rule is what
    /// decides the result — partitioning must not disturb it.
    #[test]
    fn parallel_dense_scan_identical_under_score_ties() {
        use crate::embedding::RegularEmbedding;
        let (vocab, dim) = (3072usize, 8usize);
        let mut rng = Rng::new(92);
        let base: Vec<Vec<f32>> =
            (0..64).map(|_| (0..dim).map(|_| rng.uniform(-0.5, 0.5)).collect()).collect();
        let mut rows = Vec::with_capacity(vocab * dim);
        for id in 0..vocab {
            rows.extend_from_slice(&base[id % 64]);
        }
        let store: Arc<dyn EmbeddingStore> = Arc::new(RegularEmbedding::new(vocab, dim, rows));
        let single = BruteForce::new(Scorer::new(store.clone(), false));
        let parallel = BruteForce::new(Scorer::new(store.clone(), false)).with_scan_threads(4);
        let probe: Vec<f32> = base[7].clone();
        for query in [Query::Id(7), Query::Id(2048), Query::Vector(probe)] {
            // k = 130 straddles many tie groups (each distinct row repeats
            // 48 times with exactly equal scores).
            let (want, _) = single.top_k(&query, 130);
            let (got, _) = parallel.top_k(&query, 130);
            assert_eq!(want.len(), got.len());
            for (w, g) in want.iter().zip(&got) {
                assert_eq!((w.id, w.score.to_bits()), (g.id, g.score.to_bits()), "{query:?}");
            }
        }
    }

    /// Satellite property: scatter-gather over range-sharded slices of a
    /// store, merged with [`merge_top_k`], is *bit-identical* (ids and
    /// scores) to a single-node [`BruteForce`] over the unsharded store.
    /// Dense rows on both sides, so even the float noise matches.
    #[test]
    fn merged_scatter_gather_matches_unsharded_brute_force() {
        use crate::embedding::RegularEmbedding;
        crate::testing::check("scatter-gather knn merge", |c| {
            let vocab = c.dim(8, 400);
            let dim = [4usize, 8, 16][c.rng.below(3)];
            let n_shards = 1 + c.rng.below(5);
            let k = 1 + c.rng.below(vocab + 4); // may exceed the vocabulary
            let query = c.rng.below(vocab);
            let store: Arc<dyn EmbeddingStore> =
                Arc::new(RegularEmbedding::random(vocab, dim, &mut c.rng));

            let truth = BruteForce::new(Scorer::new(store.clone(), false));
            let (want, _) = truth.top_k(&Query::Id(query), k);

            // Balanced contiguous ranges, one BruteForce per slice; each
            // shard scores the caller-supplied query row (the wire's
            // KNN_VEC path) and cannot exclude the query word itself, so
            // it is asked for k+1 and the router-side filter drops it.
            let q_row = store.lookup(query);
            let (base, rem) = (vocab / n_shards, vocab % n_shards);
            let mut lists = Vec::with_capacity(n_shards);
            let mut start = 0usize;
            for s in 0..n_shards {
                let len = base + usize::from(s < rem);
                if len == 0 {
                    lists.push(Vec::new());
                    continue;
                }
                let mut rows = Vec::with_capacity(len * dim);
                for id in start..start + len {
                    rows.extend_from_slice(&store.lookup(id));
                }
                let slice: Arc<dyn EmbeddingStore> =
                    Arc::new(RegularEmbedding::new(len, dim, rows));
                let shard_index = BruteForce::new(Scorer::new(slice, false));
                let (locals, _) = shard_index.top_k(&Query::Vector(q_row.clone()), k + 1);
                lists.push(
                    locals
                        .into_iter()
                        .map(|n| Neighbor { id: n.id + start, score: n.score })
                        .filter(|n| n.id != query)
                        .collect(),
                );
                start += len;
            }
            let got = merge_top_k(k, lists);

            prop_assert!(
                got.len() == want.len(),
                "length {} vs {} (vocab {vocab} shards {n_shards} k {k})",
                got.len(),
                want.len()
            );
            for (g, w) in got.iter().zip(want.iter()) {
                prop_assert!(
                    g.id == w.id && g.score == w.score,
                    "{g:?} vs {w:?} (vocab {vocab} shards {n_shards} k {k} query {query})"
                );
            }
            Ok(())
        });
    }
}
