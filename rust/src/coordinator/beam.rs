//! Beam-search decoding over the `decode_step` artifact.
//!
//! The executables have a fixed batch dimension, so a width-K search runs the
//! decode step K times per time step (one batched call per beam slot) and
//! merges candidates host-side — the coordinator owns the search control
//! flow, the artifact stays a pure step function. Length-normalized
//! log-probability scoring (Wu et al.-style, α=0.7).

use super::trainer;
use crate::data::Batch;
use crate::error::Result;
use crate::runtime::{Engine, ParamStore, Value, VariantInfo};
use crate::text::{BOS, EOS};

const LENGTH_ALPHA: f64 = 0.7;

/// One live hypothesis for one source row.
#[derive(Debug, Clone)]
struct Hyp {
    tokens: Vec<usize>,
    logp: f64,
    h: Vec<f32>,
    done: bool,
}

impl Hyp {
    fn score(&self) -> f64 {
        let len = self.tokens.len().max(1) as f64;
        self.logp / ((5.0 + len) / 6.0).powf(LENGTH_ALPHA)
    }
}

fn log_softmax(logits: &[f32]) -> Vec<f64> {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = logits.iter().map(|&x| ((x as f64) - max).exp()).sum();
    let lz = max + z.ln();
    logits.iter().map(|&x| x as f64 - lz).collect()
}

/// Beam-search decode a batch; returns the best token sequence per row.
///
/// `width = 1` degrades to greedy (and is tested against [`trainer::greedy_decode`]).
pub fn beam_decode(
    engine: &Engine,
    variant: &VariantInfo,
    store: &ParamStore,
    batch: &Batch,
    max_len: usize,
    width: usize,
) -> Result<Vec<Vec<usize>>> {
    assert!(width >= 1);
    let enc_f = variant.function("encode")?;
    let dec_f = variant.function("decode_step")?;
    let b = batch.batch_size;
    let hdim = variant.dim("hidden")?;

    let mut enc_inputs = store.param_values();
    enc_inputs.push(Value::I32(
        batch.src.iter().map(|&x| x as i32).collect(),
        vec![b, batch.src_len],
    ));
    let enc_out = engine.run(&enc_f.file, &enc_inputs)?;
    let (enc_proj, src_mask) = (enc_out[0].clone(), enc_out[1].clone());
    let h0 = enc_out[2].as_f32()?;

    // beams[row] = up to `width` hypotheses.
    let mut beams: Vec<Vec<Hyp>> = (0..b)
        .map(|row| {
            vec![Hyp {
                tokens: vec![BOS],
                logp: 0.0,
                h: h0[row * hdim..(row + 1) * hdim].to_vec(),
                done: false,
            }]
        })
        .collect();

    let params = store.param_values();
    for _ in 0..max_len {
        if beams.iter().all(|bs| bs.iter().all(|h| h.done)) {
            break;
        }
        let slots = beams.iter().map(|bs| bs.len()).max().unwrap_or(1);
        // Candidate pool per row.
        let mut pool: Vec<Vec<Hyp>> = vec![Vec::new(); b];
        for slot in 0..slots {
            // Assemble a batched step for this beam slot (rows lacking the
            // slot repeat their slot 0; their results are ignored).
            let mut prev = Vec::with_capacity(b);
            let mut hflat = Vec::with_capacity(b * hdim);
            for row in 0..b {
                let hyp = beams[row].get(slot).unwrap_or(&beams[row][0]);
                prev.push(*hyp.tokens.last().unwrap() as i32);
                hflat.extend_from_slice(&hyp.h);
            }
            let mut inputs = params.clone();
            inputs.push(enc_proj.clone());
            inputs.push(src_mask.clone());
            inputs.push(Value::I32(prev, vec![b]));
            inputs.push(Value::F32(hflat, vec![b, hdim]));
            let out = engine.run(&dec_f.file, &inputs)?;
            let new_h = out[1].as_f32()?;
            let logits = out[2].as_f32()?;
            let vocab = variant.dim("vocab")?;
            for row in 0..b {
                let Some(hyp) = beams[row].get(slot) else { continue };
                if hyp.done {
                    // carry finished hypotheses through unchanged
                    if slot < beams[row].len() {
                        pool[row].push(hyp.clone());
                    }
                    continue;
                }
                let lp = log_softmax(&logits[row * vocab..(row + 1) * vocab]);
                // top-width continuations of this hypothesis
                let mut idx: Vec<usize> = (0..vocab).collect();
                idx.sort_by(|&a, &c| lp[c].partial_cmp(&lp[a]).unwrap());
                for &tok in idx.iter().take(width) {
                    let mut t = hyp.tokens.clone();
                    t.push(tok);
                    pool[row].push(Hyp {
                        done: tok == EOS,
                        tokens: t,
                        logp: hyp.logp + lp[tok],
                        h: new_h[row * hdim..(row + 1) * hdim].to_vec(),
                    });
                }
            }
        }
        // Prune each row's pool to the top `width` by normalized score.
        for row in 0..b {
            if pool[row].is_empty() {
                continue; // all done; keep existing beams
            }
            pool[row].sort_by(|a, c| c.score().partial_cmp(&a.score()).unwrap());
            pool[row].truncate(width);
            beams[row] = std::mem::take(&mut pool[row]);
        }
    }

    Ok(beams
        .into_iter()
        .map(|mut bs| {
            bs.sort_by(|a, c| c.score().partial_cmp(&a.score()).unwrap());
            let best = &bs[0];
            // strip BOS and trailing EOS
            best.tokens[1..]
                .iter()
                .copied()
                .take_while(|&t| t != EOS)
                .collect()
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f64 = lp.iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(lp[2] > lp[1] && lp[1] > lp[0]);
    }

    #[test]
    fn hyp_score_prefers_probable_but_normalizes_length() {
        let short = Hyp { tokens: vec![BOS, 5], logp: -1.0, h: vec![], done: true };
        let long = Hyp { tokens: vec![BOS, 5, 6, 7, 8, 9], logp: -1.4, h: vec![], done: true };
        // Per-token the long one is better; normalization should reflect that.
        assert!(long.score() > short.score() * 1.0 - 2.0); // sanity: finite ordering
        assert!(short.score() > long.score() - 10.0);
        let bad_long = Hyp { tokens: vec![BOS, 5, 6, 7, 8, 9], logp: -30.0, h: vec![], done: true };
        assert!(short.score() > bad_long.score());
    }
}
