//! Learning-rate schedules.

/// Linear warmup followed by inverse-sqrt decay (the standard seq2seq
/// schedule, scaled to our short CPU runs), or constant when warmup = 0.
#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub base: f64,
    pub warmup: usize,
}

impl LrSchedule {
    pub fn new(base: f64, warmup: usize) -> LrSchedule {
        LrSchedule { base, warmup }
    }

    /// LR at 0-based step index.
    pub fn at(&self, step: usize) -> f64 {
        if self.warmup == 0 {
            return self.base;
        }
        let s = (step + 1) as f64;
        let w = self.warmup as f64;
        if s < w {
            self.base * s / w
        } else {
            self.base * (w / s).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_when_no_warmup() {
        let s = LrSchedule::new(1e-3, 0);
        assert_eq!(s.at(0), 1e-3);
        assert_eq!(s.at(1000), 1e-3);
    }

    #[test]
    fn warms_up_then_decays() {
        let s = LrSchedule::new(1.0, 10);
        assert!(s.at(0) < s.at(5));
        assert!(s.at(5) < s.at(9));
        let peak = s.at(9);
        assert!((peak - 1.0).abs() < 0.01);
        assert!(s.at(40) < peak);
        // inverse sqrt: at 4x warmup, lr = base/2
        assert!((s.at(39) - 0.5).abs() < 0.01);
    }
}
