//! Embedding lookup server: serves compressed-embedding rows over TCP with
//! cross-connection micro-batching — the serving-side argument of the paper
//! (a word2ketXS table small enough to live in cache, reconstructed lazily
//! per request).
//!
//! Protocol (line-oriented text):
//!   `LOOKUP <id> [<id> ...]\n` → `OK <dim> <f32> <f32> ...\n` (per id, one line)
//!   `DOT <id a> <id b>\n`      → `OK <f32>\n` (factored inner product path)
//!   `STATS\n`                  → `OK p50_us=<..> p99_us=<..> served=<..>\n`
//!   `QUIT\n`                   → closes the connection.
//!
//! Requests from all connections funnel into one worker that drains the queue
//! every `batch_window_us` and reconstructs rows in one batch — the same
//! pattern a vLLM-style router uses for dynamic batching.

use crate::config::ExperimentConfig;
use crate::embedding::{self, EmbeddingStore};
use crate::error::{Error, Result};
use crate::util::{Rng, Summary};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// One queued lookup request.
struct Job {
    ids: Vec<usize>,
    enqueued: Instant,
    reply: mpsc::Sender<Vec<Vec<f32>>>,
}

/// Shared server state.
pub struct ServerState {
    store: Box<dyn EmbeddingStore>,
    queue: Mutex<Vec<Job>>,
    latencies_us: Mutex<Summary>,
    served: AtomicU64,
    stop: AtomicBool,
    batch_window: Duration,
    max_batch: usize,
}

impl ServerState {
    pub fn new(cfg: &ExperimentConfig) -> ServerState {
        let mut rng = Rng::new(cfg.train.seed);
        let store = embedding::build(
            &cfg.embedding,
            cfg.model.vocab,
            cfg.model.emb_dim,
            &mut rng,
        );
        crate::info!("serving {}", store.describe());
        ServerState {
            store,
            queue: Mutex::new(Vec::new()),
            latencies_us: Mutex::new(Summary::new()),
            served: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            batch_window: Duration::from_micros(cfg.server.batch_window_us),
            max_batch: cfg.server.max_batch,
        }
    }

    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    pub fn shutdown(&self) {
        self.stop.atomic_store();
    }

    fn stats_line(&self) -> String {
        let lat = self.latencies_us.lock().unwrap();
        format!(
            "OK p50_us={:.0} p99_us={:.0} served={}\n",
            lat.p50(),
            lat.p99(),
            self.served()
        )
    }
}

trait AtomicStoreExt {
    fn atomic_store(&self);
}

impl AtomicStoreExt for AtomicBool {
    fn atomic_store(&self) {
        self.store(true, Ordering::SeqCst);
    }
}

/// The batching worker: drain queue → batched lookup → reply.
fn batch_worker(state: Arc<ServerState>) {
    while !state.stop.load(Ordering::SeqCst) {
        std::thread::sleep(state.batch_window);
        let jobs: Vec<Job> = {
            let mut q = state.queue.lock().unwrap();
            let take = q.len().min(state.max_batch);
            q.drain(..take).collect()
        };
        if jobs.is_empty() {
            continue;
        }
        // One flat batch over all ids of all jobs.
        let mut all_ids = Vec::new();
        for j in &jobs {
            all_ids.extend_from_slice(&j.ids);
        }
        let tensor = state.store.lookup_batch(&all_ids);
        let dim = state.store.dim();
        let mut row = 0usize;
        let now = Instant::now();
        for j in jobs {
            let mut rows = Vec::with_capacity(j.ids.len());
            for _ in 0..j.ids.len() {
                rows.push(tensor.data()[row * dim..(row + 1) * dim].to_vec());
                row += 1;
            }
            let us = now.duration_since(j.enqueued).as_secs_f64() * 1e6;
            state.latencies_us.lock().unwrap().add(us);
            state.served.fetch_add(j.ids.len() as u64, Ordering::Relaxed);
            let _ = j.reply.send(rows);
        }
    }
}

fn handle_conn(stream: TcpStream, state: Arc<ServerState>) {
    let peer = stream.peer_addr().ok();
    crate::debug!("connection from {peer:?}");
    let mut reader = BufReader::new(stream.try_clone().expect("clone stream"));
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        let response = match parts.as_slice() {
            [] => continue,
            ["QUIT"] => break,
            ["STATS"] => state.stats_line(),
            ["LOOKUP", rest @ ..] if !rest.is_empty() => {
                match rest.iter().map(|s| s.parse::<usize>()).collect::<std::result::Result<Vec<_>, _>>() {
                    Ok(ids) if ids.iter().all(|&i| i < state.store.vocab_size()) => {
                        let (tx, rx) = mpsc::channel();
                        state.queue.lock().unwrap().push(Job {
                            ids,
                            enqueued: Instant::now(),
                            reply: tx,
                        });
                        match rx.recv_timeout(Duration::from_secs(5)) {
                            Ok(rows) => {
                                let mut s = String::new();
                                for r in rows {
                                    s.push_str(&format!("OK {}", r.len()));
                                    for x in r {
                                        s.push_str(&format!(" {x}"));
                                    }
                                    s.push('\n');
                                }
                                s
                            }
                            Err(_) => "ERR timeout\n".to_string(),
                        }
                    }
                    Ok(_) => "ERR id out of range\n".to_string(),
                    Err(_) => "ERR bad id\n".to_string(),
                }
            }
            ["DOT", a, b] => match (a.parse::<usize>(), b.parse::<usize>()) {
                (Ok(a), Ok(b))
                    if a < state.store.vocab_size() && b < state.store.vocab_size() =>
                {
                    let va = state.store.lookup(a);
                    let vb = state.store.lookup(b);
                    let d = crate::tensor::dot(&va, &vb);
                    format!("OK {d}\n")
                }
                _ => "ERR bad ids\n".to_string(),
            },
            _ => "ERR unknown command\n".to_string(),
        };
        if writer.write_all(response.as_bytes()).is_err() {
            break;
        }
    }
}

/// Run the server until the process is killed (the `w2k serve` subcommand).
pub fn serve_blocking(cfg: &ExperimentConfig) -> Result<()> {
    let (state, listener, _worker) = spawn(cfg)?;
    crate::info!("listening on {}", cfg.server.addr);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let st = state.clone();
                std::thread::spawn(move || handle_conn(s, st));
            }
            Err(e) => crate::warn!("accept error: {e}"),
        }
    }
    Ok(())
}

/// Start listener + worker without blocking (tests, serve_embeddings example).
/// Returns (state, listener, worker handle); the caller accepts connections.
pub fn spawn(
    cfg: &ExperimentConfig,
) -> Result<(Arc<ServerState>, TcpListener, std::thread::JoinHandle<()>)> {
    let state = Arc::new(ServerState::new(cfg));
    let listener = TcpListener::bind(&cfg.server.addr)
        .map_err(|e| Error::Server(format!("bind {}: {e}", cfg.server.addr)))?;
    let worker_state = state.clone();
    let worker = std::thread::spawn(move || batch_worker(worker_state));
    Ok((state, listener, worker))
}

/// Accept-loop helper for examples/tests: serve until `state.stop` flips.
pub fn accept_loop(listener: TcpListener, state: Arc<ServerState>) {
    listener.set_nonblocking(true).ok();
    while !state.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((s, _)) => {
                let st = state.clone();
                std::thread::spawn(move || handle_conn(s, st));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EmbeddingKind, ExperimentConfig};
    use std::io::{BufRead, BufReader, Write};

    fn test_cfg(port: u16) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.embedding.kind = EmbeddingKind::Word2KetXS;
        cfg.embedding.order = 2;
        cfg.embedding.rank = 2;
        cfg.model.vocab = 100;
        cfg.model.emb_dim = 16;
        cfg.server.addr = format!("127.0.0.1:{port}");
        cfg.server.batch_window_us = 100;
        cfg
    }

    fn request(addr: &str, line: &str) -> Vec<String> {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(line.as_bytes()).unwrap();
        let mut out = Vec::new();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let expect = line.split_whitespace().count().saturating_sub(1).max(1);
        for _ in 0..if line.starts_with("LOOKUP") { expect } else { 1 } {
            let mut l = String::new();
            r.read_line(&mut l).unwrap();
            out.push(l.trim().to_string());
        }
        s.write_all(b"QUIT\n").ok();
        out
    }

    #[test]
    fn lookup_dot_stats_roundtrip() {
        let cfg = test_cfg(17871);
        let (state, listener, _worker) = spawn(&cfg).unwrap();
        let st = state.clone();
        let acc = std::thread::spawn(move || accept_loop(listener, st));

        let addr = &cfg.server.addr;
        // single lookup
        let resp = request(addr, "LOOKUP 42\n");
        assert!(resp[0].starts_with("OK 16 "), "{resp:?}");
        let vals: Vec<f32> = resp[0]
            .split_whitespace()
            .skip(2)
            .map(|x| x.parse().unwrap())
            .collect();
        assert_eq!(vals.len(), 16);

        // multi lookup: one OK line per id
        let resp = request(addr, "LOOKUP 1 2 3\n");
        assert_eq!(resp.len(), 3);

        // dot equals dot of lookups
        let resp = request(addr, "DOT 1 2\n");
        assert!(resp[0].starts_with("OK "));

        // errors
        let resp = request(addr, "LOOKUP 5000\n");
        assert!(resp[0].starts_with("ERR"));
        let resp = request(addr, "NONSENSE\n");
        assert!(resp[0].starts_with("ERR"));

        // stats
        let resp = request(addr, "STATS\n");
        assert!(resp[0].contains("served="), "{resp:?}");

        state.shutdown();
        acc.join().unwrap();
    }
}
