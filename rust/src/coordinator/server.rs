//! Embedding lookup server: serves compressed-embedding rows over TCP — the
//! serving-side argument of the paper (a word2ketXS table small enough to
//! live in cache, reconstructed lazily per request).
//!
//! This module is the *listener and text protocol* only; the production
//! request path (sharded hot-row cache, worker pool, binary framing) lives
//! in [`crate::serving`] and is shared by both protocols. A connection whose
//! first byte is `serving::wire::MAGIC[0]` speaks the binary protocol; any
//! other first byte gets the line-oriented text protocol:
//!
//!   `LOOKUP <id> [<id> ...]\n` → `OK <dim> <f32> <f32> ...\n` (per id)
//!   `DOT <id a> <id b>\n`      → `OK <f32>\n` (cache-served inner product)
//!   `KNN <id> <k>\n`           → `OK <n> <id> <score> ...\n` (top-n
//!                                 neighbors, best first, query excluded)
//!   `RELOAD <path>\n`          → `OK generation=<g>\n` (hot-swap the model
//!                                 to the snapshot at the server-side path)
//!   `PING\n`                   → `OK\n` (status-only liveness probe, used
//!                                 by the cluster health prober)
//!   `STATS\n`                  → `OK p50_us=.. p99_us=.. served=..
//!                                 cache_hits=.. cache_misses=.. rejected=..
//!                                 knn_queries=.. knn_candidates=..
//!                                 knn_mean_probes=.. model_generation=..
//!                                 snapshot_bytes=.. accept_errors=..\n`
//!   `METRICS\n`                → Prometheus-style exposition text
//!                                 (counters, per-stage latency histograms,
//!                                 cache occupancy), terminated by `# EOF`
//!   `METRICS?slow\n`           → the bounded slow-query ring in the same
//!                                 format (rank/op/stage labels, per-stage
//!                                 latency breakdown per entry)
//!   `TRACE <id>\n`             → every stored span of trace `<id>` (hex)
//!                                 with per-stage lines, `# EOF`-terminated
//!   `TRACE?slow\n`             → the completed-trace ring, one span
//!                                 summary line per record, oldest first
//!   `QUIT\n`                   → closes the connection.
//!
//! Malformed input (bad ids, out-of-range ids, empty LOOKUP, unknown
//! commands) always yields an `ERR ...` line, never a panic or a dropped
//! connection; `STATS` before any traffic reports zeros. A server started
//! with `[snapshot] path` boots from that snapshot (optionally memory-
//! mapped) instead of building the store from RNG + config.

use crate::config::ExperimentConfig;
use crate::embedding::{self, EmbeddingStore};
use crate::error::{Error, Result};
use crate::index::{KnnIndex, Query};
use crate::net::{self, Lifecycle, NetConfig, TextAction};
use crate::serving::{wire, LookupError, ServingState};
use crate::util::Rng;
use std::net::TcpListener;
use std::sync::Arc;

/// Shared server state: the serving layer plus listener lifecycle flags.
pub struct ServerState {
    serving: ServingState,
    net: NetConfig,
    lifecycle: Arc<Lifecycle>,
}

impl ServerState {
    pub fn new(cfg: &ExperimentConfig) -> Result<ServerState> {
        let mut serving = if cfg.snapshot.path.is_empty() {
            let mut rng = Rng::new(cfg.train.seed);
            let store = embedding::build(
                &cfg.embedding,
                cfg.model.vocab,
                cfg.model.emb_dim,
                &mut rng,
            );
            ServingState::new_with_obs(store, &cfg.serving, &cfg.index, &cfg.obs)
        } else {
            ServingState::from_snapshot_with_obs(
                std::path::Path::new(&cfg.snapshot.path),
                &cfg.serving,
                &cfg.index,
                cfg.snapshot.mmap,
                &cfg.obs,
            )?
        };
        // RELOADs honor the same [snapshot] mmap preference as boot.
        serving.set_reload_mmap(cfg.snapshot.mmap);
        crate::info!("serving {}", serving.store().describe());
        crate::info!("knn via {}", serving.index().describe());
        Ok(ServerState { serving, net: cfg.net, lifecycle: Lifecycle::new() })
    }

    /// The serving layer (cache + pool) behind both protocols.
    pub fn serving(&self) -> &ServingState {
        &self.serving
    }

    pub fn served(&self) -> u64 {
        self.serving.served()
    }

    /// Begin graceful shutdown: the accept loop stops taking connections,
    /// drains in-flight requests up to `net.drain_ms`, closes every
    /// connection, and returns; the serving pool is torn down last (by the
    /// thread running [`accept_loop`], after the drain completes).
    pub fn shutdown(&self) {
        self.lifecycle.begin_shutdown();
    }

    /// The listener's shutdown/drain handle.
    pub fn lifecycle(&self) -> &Arc<Lifecycle> {
        &self.lifecycle
    }

    fn stats_line(&self) -> String {
        // Rendered from the shared field table (`wire::STATS_FIELD_NAMES`),
        // the same array the binary protocol serializes — field additions
        // land in both protocols or neither.
        format!("{}\n", wire::format_stats_line(&self.serving.stats().fields()))
    }
}

fn err_line(e: LookupError) -> String {
    format!("ERR {e}\n")
}

// Text-protocol response rendering, shared with the cluster router's
// listener (`crate::cluster::server`): the router promises to be
// indistinguishable from a single node on the wire, so these formats must
// exist exactly once.

/// One `OK <dim> <f32> ...` line per row.
pub(crate) fn rows_lines(rows: impl IntoIterator<Item = Vec<f32>>) -> String {
    let mut s = String::new();
    for r in rows {
        s.push_str(&format!("OK {}", r.len()));
        for x in r {
            s.push_str(&format!(" {x}"));
        }
        s.push('\n');
    }
    s
}

/// `OK <n> <id> <score> ...` (top-n neighbors, best first).
pub(crate) fn neighbors_line(neighbors: &[(u32, f32)]) -> String {
    let mut s = format!("OK {}", neighbors.len());
    for (id, score) in neighbors {
        s.push_str(&format!(" {id} {score}"));
    }
    s.push('\n');
    s
}

/// Dispatch one text-protocol line to a response. Both network drivers
/// funnel through this one function (via the [`net::Service`] impl), which
/// is what keeps the text protocol byte-identical across drivers.
fn dispatch_text(state: &ServerState, line: &str) -> TextAction {
    let parts: Vec<&str> = line.split_whitespace().collect();
    let response = match parts.as_slice() {
        [] => String::new(),
        ["QUIT"] => return TextAction::Quit,
        // Status-only liveness probe, mirroring binary OP_PING.
        ["PING"] => "OK\n".to_string(),
        ["PING", ..] => "ERR PING takes no arguments\n".to_string(),
        ["STATS"] => state.stats_line(),
        // Metrics plane: full exposition and the slow-query ring. The
        // `?slow` suffix is part of the token (no whitespace), mirroring
        // the path-style query a Prometheus scraper would send.
        ["METRICS"] => state.serving.metrics_text(),
        ["METRICS?slow"] => state.serving.metrics_slow_text(),
        ["METRICS" | "METRICS?slow", ..] => "ERR METRICS takes no arguments\n".to_string(),
        // Trace plane: the completed-span ring and single-trace dumps.
        ["TRACE?slow"] => state.serving.trace_slow_text(),
        ["TRACE", id] => match crate::obs::TraceContext::parse_hex(id) {
            Some(t) => state.serving.trace_text(t),
            None => "ERR bad trace id\n".to_string(),
        },
        ["TRACE" | "TRACE?slow", ..] => "ERR TRACE takes <trace id>\n".to_string(),
        ["LOOKUP"] => err_line(LookupError::Empty),
        // Same allocation cap as the binary protocol's MAX_IDS: one text
        // line must not be able to force a multi-GB reply buffer.
        ["LOOKUP", rest @ ..] if rest.len() > wire::MAX_IDS as usize => {
            "ERR too many ids\n".to_string()
        }
        ["LOOKUP", rest @ ..] => {
            match rest
                .iter()
                .map(|s| s.parse::<usize>())
                .collect::<std::result::Result<Vec<_>, _>>()
            {
                Ok(ids) => match state.serving.lookup_rows(ids) {
                    Ok(rows) => rows_lines(rows),
                    Err(e) => err_line(e),
                },
                Err(_) => "ERR bad id\n".to_string(),
            }
        }
        ["DOT", a, b] => match (a.parse::<usize>(), b.parse::<usize>()) {
            (Ok(a), Ok(b)) => match state.serving.dot(a, b) {
                Ok(d) => format!("OK {d}\n"),
                Err(e) => err_line(e),
            },
            _ => "ERR bad id\n".to_string(),
        },
        ["DOT", ..] => "ERR DOT takes exactly two ids\n".to_string(),
        // No k cap here: the serving layer clamps k to the vocabulary
        // size (same as the binary protocol).
        ["KNN", id, k] => match (id.parse::<usize>(), k.parse::<usize>()) {
            (Ok(id), Ok(k)) => match state.serving.knn(Query::Id(id), k) {
                Ok(neighbors) => {
                    let pairs: Vec<(u32, f32)> =
                        neighbors.iter().map(|n| (n.id as u32, n.score)).collect();
                    neighbors_line(&pairs)
                }
                Err(e) => err_line(e),
            },
            _ => "ERR bad id\n".to_string(),
        },
        ["KNN", ..] => "ERR KNN takes <query id> <k>\n".to_string(),
        ["RELOAD", path] => {
            match state.serving.reload_snapshot(std::path::Path::new(path)) {
                Ok(generation) => format!("OK generation={generation}\n"),
                Err(e) => format!("ERR reload: {e}\n"),
            }
        }
        ["RELOAD", ..] => "ERR RELOAD takes <path>\n".to_string(),
        _ => "ERR unknown command\n".to_string(),
    };
    TextAction::Reply(response)
}

/// The coordinator's protocol brain: both network drivers dispatch every
/// text line and binary frame through this one impl.
impl net::Service for ServerState {
    fn hello_dim(&self) -> Option<u32> {
        Some(self.serving.dim() as u32)
    }

    fn text(&self, line: &str) -> TextAction {
        dispatch_text(self, line)
    }

    fn binary(&self, req: wire::BinRequest, out: &mut Vec<u8>) -> bool {
        wire::respond_binary(&self.serving, req, out)
    }

    fn note_accept_error(&self) {
        self.serving.note_accept_error();
    }

    fn obs(&self) -> Option<Arc<crate::obs::Obs>> {
        Some(self.serving.obs())
    }
}

/// Run the server until shutdown (the `w2k serve` subcommand).
pub fn serve_blocking(cfg: &ExperimentConfig) -> Result<()> {
    let (state, listener, addr) = spawn(cfg)?;
    crate::info!(
        "listening on {addr} ({} driver, text + binary protocols)",
        state.net.driver
    );
    accept_loop(listener, state);
    Ok(())
}

/// Start state + listener without blocking (tests, serve_embeddings
/// example). Returns (state, listener, bound address) — the address matters
/// when `cfg.server.addr` uses port 0; the caller runs [`accept_loop`].
pub fn spawn(cfg: &ExperimentConfig) -> Result<(Arc<ServerState>, TcpListener, String)> {
    let state = Arc::new(ServerState::new(cfg)?);
    let listener = TcpListener::bind(&cfg.server.addr)
        .map_err(|e| Error::Server(format!("bind {}: {e}", cfg.server.addr)))?;
    let addr = listener
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| cfg.server.addr.clone());
    Ok((state, listener, addr))
}

/// Serve until [`ServerState::shutdown`] is called, then drain in-flight
/// requests, close connections, join handler threads, and tear down the
/// serving pool. Runs on the configured `[net]` driver.
pub fn accept_loop(listener: TcpListener, state: Arc<ServerState>) {
    let cfg = state.net;
    let lifecycle = state.lifecycle.clone();
    let svc: Arc<dyn net::Service> = state.clone();
    net::serve(listener, svc, &cfg, lifecycle);
    state.serving.shutdown();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EmbeddingKind, ExperimentConfig};
    use crate::serving::BinaryClient;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    fn test_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.embedding.kind = EmbeddingKind::Word2KetXS;
        cfg.embedding.order = 2;
        cfg.embedding.rank = 2;
        cfg.model.vocab = 100;
        cfg.model.emb_dim = 16;
        cfg.server.addr = "127.0.0.1:0".into(); // OS-assigned port per test
        cfg.serving.batch_window_us = 100;
        cfg.serving.shards = 2;
        cfg.serving.cache_rows = 64;
        cfg
    }

    /// Start a server; returns (state, bound addr, accept-thread handle).
    fn start() -> (Arc<ServerState>, String, std::thread::JoinHandle<()>) {
        let cfg = test_cfg();
        let (state, listener, addr) = spawn(&cfg).unwrap();
        let st = state.clone();
        let acc = std::thread::spawn(move || accept_loop(listener, st));
        (state, addr, acc)
    }

    fn request(addr: &str, line: &str, expect_lines: usize) -> Vec<String> {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(line.as_bytes()).unwrap();
        let mut out = Vec::new();
        let mut r = BufReader::new(s.try_clone().unwrap());
        for _ in 0..expect_lines {
            let mut l = String::new();
            r.read_line(&mut l).unwrap();
            out.push(l.trim().to_string());
        }
        s.write_all(b"QUIT\n").ok();
        out
    }

    #[test]
    fn text_lookup_dot_stats_roundtrip() {
        let (state, addr, acc) = start();
        let addr = addr.as_str();

        let resp = request(addr, "LOOKUP 42\n", 1);
        assert!(resp[0].starts_with("OK 16 "), "{resp:?}");
        let vals: Vec<f32> = resp[0]
            .split_whitespace()
            .skip(2)
            .map(|x| x.parse().unwrap())
            .collect();
        assert_eq!(vals.len(), 16);

        // multi lookup: one OK line per id; repeated id rows identical
        let resp = request(addr, "LOOKUP 1 2 1\n", 3);
        assert_eq!(resp.len(), 3);
        assert_eq!(resp[0], resp[2]);

        let resp = request(addr, "DOT 1 2\n", 1);
        assert!(resp[0].starts_with("OK "));

        let resp = request(addr, "STATS\n", 1);
        assert!(resp[0].contains("served="), "{resp:?}");
        assert!(resp[0].contains("cache_hits="), "{resp:?}");

        state.shutdown();
        acc.join().unwrap();
    }

    #[test]
    fn text_protocol_rejects_malformed_input() {
        let (state, addr, acc) = start();
        let addr = addr.as_str();

        // Every malformed request must yield an ERR line, never a panic.
        for (req, frag) in [
            ("LOOKUP\n", "empty"),
            ("LOOKUP abc\n", "bad id"),
            ("LOOKUP 1 two 3\n", "bad id"),
            ("LOOKUP 5000\n", "range"),
            ("LOOKUP 99 100\n", "range"),
            ("DOT 1\n", "two ids"),
            ("DOT 1 2 3\n", "two ids"),
            ("DOT a b\n", "bad id"),
            ("DOT 0 5000\n", "range"),
            ("NONSENSE\n", "unknown"),
        ] {
            let resp = request(addr, req, 1);
            assert!(resp[0].starts_with("ERR"), "{req:?} -> {resp:?}");
            assert!(resp[0].contains(frag), "{req:?} -> {resp:?}");
        }
        // The server survives all of the above and still serves.
        let resp = request(addr, "LOOKUP 0\n", 1);
        assert!(resp[0].starts_with("OK"), "{resp:?}");

        state.shutdown();
        acc.join().unwrap();
    }

    #[test]
    fn stats_before_traffic_is_zeros() {
        let (state, addr, acc) = start();
        let resp = request(&addr, "STATS\n", 1);
        // Generated from the shared field table instead of a hand-written
        // literal: adding a STATS field updates this expectation
        // automatically, while renames/reorders still fail loudly.
        let mut expected = String::from("OK");
        for name in wire::STATS_FIELD_NAMES {
            // Not every field starts at zero: the generation is 1 after
            // assemble, and simd_level reports the process's kernel set.
            let value = match name {
                "model_generation" => 1.0,
                "simd_level" => crate::simd::level().code() as f64,
                // Float store: full-precision serving payload.
                "payload_bits" => 32.0,
                _ => 0.0,
            };
            expected.push(' ');
            expected.push_str(name);
            expected.push('=');
            expected.push_str(&wire::format_stats_field(name, value));
        }
        assert_eq!(resp[0], expected);
        // Drift guard: the generated line went through the real formatter
        // (float fields keep their fixed precision, counters render bare).
        assert!(expected.contains("knn_mean_probes=0.00"), "{expected}");
        assert!(expected.contains("p50_us=0 "), "{expected}");
        state.shutdown();
        acc.join().unwrap();
    }

    /// Tentpole: the text METRICS verb serves a `# EOF`-terminated
    /// Prometheus-style exposition, including the transport-stage
    /// histograms the threads driver records, and `METRICS?slow` serves
    /// the slow-query ring.
    #[test]
    fn text_metrics_exposition_roundtrip() {
        let (state, addr, acc) = start();
        request(&addr, "LOOKUP 1 2\n", 2);

        let read_exposition = |verb: &str| -> String {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(verb.as_bytes()).unwrap();
            let mut r = BufReader::new(s.try_clone().unwrap());
            let mut text = String::new();
            loop {
                let mut l = String::new();
                if r.read_line(&mut l).unwrap() == 0 {
                    break;
                }
                let done = l == "# EOF\n";
                text.push_str(&l);
                if done {
                    break;
                }
            }
            s.write_all(b"QUIT\n").ok();
            text
        };

        let text = read_exposition("METRICS\n");
        assert!(text.contains("w2k_served_total 2"), "{text}");
        assert!(text.contains("w2k_stage_us_count{stage=\"parse\"}"), "{text}");
        assert!(text.contains("w2k_request_us_count"), "{text}");
        assert!(text.ends_with("# EOF\n"), "{text}");

        let slow = read_exposition("METRICS?slow\n");
        assert!(slow.contains("w2k_slow_total_us"), "{slow}");
        assert!(slow.ends_with("# EOF\n"), "{slow}");

        let resp = request(&addr, "METRICS now\n", 1);
        assert!(resp[0].starts_with("ERR"), "{resp:?}");

        state.shutdown();
        acc.join().unwrap();
    }

    /// Satellite: graceful shutdown drains and actually terminates — the
    /// accept thread joins even with idle connections parked on the server
    /// (close_all must unblock their reader threads), the listener socket
    /// is released, and parked clients observe EOF.
    #[test]
    fn graceful_shutdown_unblocks_idle_conns_and_releases_listener() {
        let (state, addr, acc) = start();

        // Park one idle text connection and one idle binary session.
        let mut idle_text = TcpStream::connect(&addr).unwrap();
        idle_text.write_all(b"PING\n").unwrap();
        let mut r = BufReader::new(idle_text.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line, "OK\n");
        let mut idle_bin = BinaryClient::connect(&addr).unwrap();
        idle_bin.ping().unwrap();

        state.shutdown();
        // The accept thread must join without any client sending QUIT: the
        // drain sees zero busy requests, close_all() unblocks both parked
        // handler threads, and every handler is joined before serve returns.
        acc.join().expect("accept loop did not terminate on shutdown");

        // Parked clients observe EOF (or a reset), never a hang.
        let mut probe = [0u8; 1];
        idle_text
            .set_read_timeout(Some(std::time::Duration::from_secs(2)))
            .unwrap();
        match std::io::Read::read(&mut r, &mut probe) {
            Ok(0) | Err(_) => {}
            Ok(n) => panic!("expected EOF after shutdown, read {n} bytes"),
        }

        // The listener socket is gone: a fresh connection cannot complete a
        // request round-trip (connect may land in a dead backlog, but the
        // first read sees EOF/reset).
        if let Ok(mut s) = TcpStream::connect(&addr) {
            s.set_read_timeout(Some(std::time::Duration::from_secs(2))).unwrap();
            s.write_all(b"PING\n").ok();
            let mut buf = [0u8; 8];
            match std::io::Read::read(&mut s, &mut buf) {
                Ok(0) | Err(_) => {}
                Ok(n) => panic!("server still answered after shutdown ({n} bytes)"),
            }
        }
    }

    #[test]
    fn text_knn_serves_and_counts() {
        let (state, addr, acc) = start();
        let addr = addr.as_str();

        let resp = request(addr, "KNN 42 5\n", 1);
        let parts: Vec<&str> = resp[0].split_whitespace().collect();
        assert_eq!(parts[0], "OK", "{resp:?}");
        assert_eq!(parts[1], "5");
        // 5 neighbors = 5 (id, score) pairs after "OK 5".
        assert_eq!(parts.len(), 2 + 10, "{resp:?}");
        let ids: Vec<usize> = parts[2..].chunks(2).map(|c| c[0].parse().unwrap()).collect();
        let scores: Vec<f32> = parts[2..].chunks(2).map(|c| c[1].parse().unwrap()).collect();
        assert!(ids.iter().all(|&id| id != 42 && id < 100), "{ids:?}");
        for w in scores.windows(2) {
            assert!(w[0] >= w[1], "scores not descending: {scores:?}");
        }

        // Oversized k clamps to the vocabulary (99 non-query words), same
        // as the binary protocol — not an error.
        let resp = request(addr, "KNN 3 999999\n", 1);
        assert!(resp[0].starts_with("OK 99 "), "{resp:?}");

        // Malformed KNN requests: always ERR, never a panic.
        for (req, frag) in [
            ("KNN\n", "KNN takes"),
            ("KNN 1\n", "KNN takes"),
            ("KNN 1 2 3\n", "KNN takes"),
            ("KNN x 5\n", "bad id"),
            ("KNN 5000 5\n", "range"),
            ("KNN 1 0\n", "bad query"),
        ] {
            let resp = request(addr, req, 1);
            assert!(resp[0].starts_with("ERR"), "{req:?} -> {resp:?}");
            assert!(resp[0].contains(frag), "{req:?} -> {resp:?}");
        }

        // The counters saw exactly the two successful queries (k=5 and the
        // clamped k), 99 candidates each; failed requests counted nothing.
        let stats = request(addr, "STATS\n", 1);
        assert!(stats[0].contains("knn_queries=2"), "{stats:?}");
        assert!(stats[0].contains("knn_candidates=198"), "{stats:?}");

        state.shutdown();
        acc.join().unwrap();
    }

    #[test]
    fn binary_and_text_agree_on_one_listener() {
        let (state, addr, acc) = start();
        let addr = addr.as_str();

        // Binary client and text client hit the same live server; rows must
        // be identical to the last bit (text f32 formatting round-trips).
        let mut bin = BinaryClient::connect(addr).unwrap();
        assert_eq!(bin.dim, 16);
        let ids = [0u32, 7, 42, 7, 99];
        let bin_rows = bin.lookup(&ids).unwrap();
        assert_eq!(bin_rows.len(), ids.len());

        for (row, &id) in bin_rows.iter().zip(&ids) {
            let text = request(addr, &format!("LOOKUP {id}\n"), 1);
            let text_row: Vec<f32> = text[0]
                .split_whitespace()
                .skip(2)
                .map(|x| x.parse().unwrap())
                .collect();
            assert_eq!(row, &text_row, "id {id}: binary vs text rows differ");
        }

        let bd = bin.dot(1, 2).unwrap();
        let td: f32 = request(addr, "DOT 1 2\n", 1)[0]
            .split_whitespace()
            .nth(1)
            .unwrap()
            .parse()
            .unwrap();
        assert_eq!(bd, td);

        let stats = bin.stats().unwrap();
        assert!(stats.served > 0);
        bin.quit().unwrap();

        state.shutdown();
        acc.join().unwrap();
    }

    #[test]
    fn binary_knn_end_to_end() {
        // Acceptance: OP_KNN through the binary wire client against a live
        // server, agreeing with the server-side serving state and the text
        // protocol, with STATS knn counters tracking the traffic.
        let (state, addr, acc) = start();
        let mut bin = BinaryClient::connect(&addr).unwrap();

        let before = bin.stats().unwrap();
        assert_eq!(before.knn_queries, 0);
        assert_eq!(before.knn_candidates, 0);
        assert_eq!(before.knn_mean_probes, 0.0);

        let k = 7u32;
        let neighbors = bin.knn(42, k).unwrap();
        assert_eq!(neighbors.len(), k as usize);
        assert!(neighbors.iter().all(|&(id, _)| id != 42 && (id as usize) < 100));
        for w in neighbors.windows(2) {
            assert!(w[0].1 >= w[1].1, "not best-first: {neighbors:?}");
        }
        // Scores are real dot products of served rows: recompute client-side
        // from wire lookups.
        let q_rows = bin.lookup(&[42]).unwrap();
        for &(id, score) in &neighbors {
            let n_rows = bin.lookup(&[id]).unwrap();
            let dense: f32 = q_rows[0].iter().zip(n_rows[0].iter()).map(|(x, y)| x * y).sum();
            assert!(
                (dense - score).abs() < 1e-4 * dense.abs().max(1.0),
                "id {id}: wire score {score} vs recomputed {dense}"
            );
        }

        // Text protocol sees the same top neighbor.
        let text = request(&addr, "KNN 42 1\n", 1);
        let text_best: usize =
            text[0].split_whitespace().nth(2).unwrap().parse().unwrap();
        assert_eq!(text_best, neighbors[0].0 as usize, "{text:?}");

        // Errors: out-of-range query id, k == 0, wrong id count.
        match bin.knn(5000, 3) {
            Err(crate::serving::WireError::Status(s)) => assert_eq!(s, wire::STATUS_RANGE),
            other => panic!("expected range error, got {other:?}"),
        }
        match bin.knn(1, 0) {
            Err(crate::serving::WireError::Status(s)) => assert_eq!(s, wire::STATUS_BAD_FRAME),
            other => panic!("expected bad frame, got {other:?}"),
        }

        // Counters: 2 successful knn queries (binary + text), 99 candidates
        // each under the default brute index.
        let after = bin.stats().unwrap();
        assert_eq!(after.knn_queries, 2);
        assert_eq!(after.knn_candidates, 198);
        assert_eq!(after.knn_mean_probes, 0.0, "brute force probes no cells");
        bin.quit().unwrap();

        state.shutdown();
        acc.join().unwrap();
    }

    #[test]
    fn binary_knn_ivf_configured_server() {
        // Same path with an IVF index from the [index] config section.
        let mut cfg = test_cfg();
        cfg.index.kind = crate::config::IndexKind::Ivf;
        cfg.index.nlist = 4;
        cfg.index.nprobe = 2;
        let (state, listener, addr) = spawn(&cfg).unwrap();
        let st = state.clone();
        let acc = std::thread::spawn(move || accept_loop(listener, st));

        let mut bin = BinaryClient::connect(&addr).unwrap();
        let neighbors = bin.knn(7, 3).unwrap();
        assert!(!neighbors.is_empty() && neighbors.len() <= 3);
        let stats = bin.stats().unwrap();
        assert_eq!(stats.knn_queries, 1);
        // Typically well under 99 with 2 of 4 cells probed; `<=` because
        // k-means balance on a tiny vocab is not guaranteed.
        assert!(stats.knn_candidates <= 99, "{}", stats.knn_candidates);
        assert!(stats.knn_candidates > 0);
        assert!((stats.knn_mean_probes - 2.0).abs() < 1e-9);
        bin.quit().unwrap();

        state.shutdown();
        acc.join().unwrap();
    }

    fn tmp_snap(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("w2k_server_{}_{}.snap", std::process::id(), name))
    }

    /// Build the exact store the test server serves (same config, same
    /// seed) so snapshots of it are interchangeable with the live model.
    fn server_twin_store() -> Box<dyn crate::embedding::EmbeddingStore> {
        let cfg = test_cfg();
        let mut rng = crate::util::Rng::new(cfg.train.seed);
        embedding::build(&cfg.embedding, cfg.model.vocab, cfg.model.emb_dim, &mut rng)
    }

    /// Acceptance: OP_RELOAD under concurrent binary-protocol load — zero
    /// failed requests, model_generation increments, snapshot_bytes set,
    /// and factored k-NN results identical before/after save→load→swap.
    #[test]
    fn hot_swap_under_concurrent_load() {
        let (state, addr, acc) = start();
        let path = tmp_snap("hot_swap");
        crate::snapshot::save_store(
            server_twin_store().as_ref(),
            &path,
            &crate::snapshot::SaveOptions::default(),
        )
        .unwrap();

        let mut bin = BinaryClient::connect(&addr).unwrap();
        let knn_before = bin.knn(42, 5).unwrap();
        let rows_before = bin.lookup(&[0, 7, 99]).unwrap();

        // Hammer the server from four client threads while the reload
        // happens mid-flight; every single request must succeed.
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let workers: Vec<_> = (0..4u32)
            .map(|w| {
                let addr = addr.clone();
                let stop = stop.clone();
                std::thread::spawn(move || -> u64 {
                    let mut c = BinaryClient::connect(&addr).unwrap();
                    let mut ok = 0u64;
                    let mut i = 0u32;
                    while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                        let ids = [(i + w) % 100, (i * 7 + 3) % 100];
                        let rows = c.lookup(&ids).expect("lookup failed during hot swap");
                        assert_eq!(rows.len(), 2);
                        if i % 5 == 0 {
                            let ns = c.knn(ids[0], 3).expect("knn failed during hot swap");
                            assert!(!ns.is_empty());
                        }
                        ok += 1;
                        i += 1;
                    }
                    c.quit().ok();
                    ok
                })
            })
            .collect();

        // Let traffic build up, swap, then let it drain over the new model.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let generation = bin.reload(path.to_str().unwrap()).unwrap();
        assert_eq!(generation, 2);
        std::thread::sleep(std::time::Duration::from_millis(50));
        stop.store(true, std::sync::atomic::Ordering::SeqCst);
        let mut total = 0u64;
        for h in workers {
            total += h.join().expect("worker panicked (a request failed during the swap)");
        }
        assert!(total > 0, "load generator never got a request through");

        // Same weights ⇒ bit-identical rows and identical k-NN answers.
        let rows_after = bin.lookup(&[0, 7, 99]).unwrap();
        assert_eq!(rows_before, rows_after, "rows changed across an identical-model swap");
        let knn_after = bin.knn(42, 5).unwrap();
        assert_eq!(knn_before, knn_after, "top-k changed across save→load→swap");

        let stats = bin.stats().unwrap();
        assert_eq!(stats.model_generation, 2);
        assert!(stats.snapshot_bytes > 0);
        assert_eq!(stats.rejected, 0, "requests were rejected during the swap");
        bin.quit().unwrap();

        state.shutdown();
        acc.join().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn text_reload_and_failure_modes() {
        let (state, addr, acc) = start();
        let path = tmp_snap("text_reload");
        crate::snapshot::save_store(
            server_twin_store().as_ref(),
            &path,
            &crate::snapshot::SaveOptions::default(),
        )
        .unwrap();

        let resp = request(&addr, &format!("RELOAD {}\n", path.display()), 1);
        assert_eq!(resp[0], "OK generation=2", "{resp:?}");

        // Failure paths: missing file, malformed command — ERR, not panic,
        // and the generation stays put.
        let resp = request(&addr, "RELOAD /nonexistent/nope.snap\n", 1);
        assert!(resp[0].starts_with("ERR reload:"), "{resp:?}");
        let resp = request(&addr, "RELOAD\n", 1);
        assert!(resp[0].contains("RELOAD takes"), "{resp:?}");
        let stats = request(&addr, "STATS\n", 1);
        assert!(stats[0].contains("model_generation=2"), "{stats:?}");

        state.shutdown();
        acc.join().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn server_boots_from_snapshot_config() {
        // [snapshot] path: the server starts from the file (mmap), serving
        // rows bit-identical to the store that was saved.
        let store = server_twin_store();
        let path = tmp_snap("boot");
        crate::snapshot::save_store(
            store.as_ref(),
            &path,
            &crate::snapshot::SaveOptions::default(),
        )
        .unwrap();

        let mut cfg = test_cfg();
        cfg.snapshot.path = path.display().to_string();
        let (state, listener, addr) = spawn(&cfg).unwrap();
        let st = state.clone();
        let acc = std::thread::spawn(move || accept_loop(listener, st));

        let mut bin = BinaryClient::connect(&addr).unwrap();
        let rows = bin.lookup(&[3, 42]).unwrap();
        assert_eq!(rows[0], store.lookup(3));
        assert_eq!(rows[1], store.lookup(42));
        let stats = bin.stats().unwrap();
        assert!(stats.snapshot_bytes > 0, "snapshot-backed server must report file size");
        bin.quit().unwrap();

        // A dangling snapshot path fails server construction with a typed
        // error instead of serving garbage.
        let mut bad = test_cfg();
        bad.snapshot.path = "/nonexistent/nope.snap".into();
        assert!(spawn(&bad).is_err());

        state.shutdown();
        acc.join().unwrap();
        std::fs::remove_file(&path).ok();
    }

    /// Satellite regression: an adversarial header claiming 4Gi ids (and a
    /// zero-k KNN) must come back STATUS_BAD_FRAME without the server
    /// allocating or panicking, and the listener must keep serving.
    #[test]
    fn binary_rejects_adversarial_count_header() {
        let (state, addr, acc) = start();

        // Raw socket: handshake, then a hostile LOOKUP frame with
        // count = u32::MAX (a 4 GiB id buffer if it were believed).
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&wire::MAGIC).unwrap();
        let mut hello = [0u8; 8];
        let mut r = BufReader::new(s.try_clone().unwrap());
        std::io::Read::read_exact(&mut r, &mut hello).unwrap();
        assert_eq!(hello[..4], wire::MAGIC);
        let mut frame = Vec::new();
        frame.extend_from_slice(&wire::OP_LOOKUP.to_le_bytes());
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        s.write_all(&frame).unwrap();
        let mut resp = [0u8; 8];
        std::io::Read::read_exact(&mut r, &mut resp).unwrap();
        let status = u32::from_le_bytes(resp[0..4].try_into().unwrap());
        let count = u32::from_le_bytes(resp[4..8].try_into().unwrap());
        assert_eq!(status, wire::STATUS_BAD_FRAME);
        assert_eq!(count, 0);
        // The stream is untrustworthy after a hostile header: server closes.
        let mut probe = [0u8; 1];
        assert_eq!(std::io::Read::read(&mut r, &mut probe).unwrap(), 0, "conn must close");

        // Oversized RELOAD path length gets the same treatment.
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&wire::MAGIC).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        std::io::Read::read_exact(&mut r, &mut hello).unwrap();
        let mut frame = Vec::new();
        frame.extend_from_slice(&wire::OP_RELOAD.to_le_bytes());
        frame.extend_from_slice(&(wire::MAX_PATH_BYTES + 1).to_le_bytes());
        s.write_all(&frame).unwrap();
        std::io::Read::read_exact(&mut r, &mut resp).unwrap();
        assert_eq!(u32::from_le_bytes(resp[0..4].try_into().unwrap()), wire::STATUS_BAD_FRAME);

        // Zero-k KNN through the client: bad frame, session stays usable.
        let mut bin = BinaryClient::connect(&addr).unwrap();
        match bin.knn(1, 0) {
            Err(crate::serving::WireError::Status(st)) => {
                assert_eq!(st, wire::STATUS_BAD_FRAME)
            }
            other => panic!("expected bad frame, got {other:?}"),
        }
        let rows = bin.lookup(&[1]).unwrap();
        assert_eq!(rows.len(), 1);
        bin.quit().unwrap();

        // And the server still serves fresh connections.
        let resp = request(&addr, "LOOKUP 0\n", 1);
        assert!(resp[0].starts_with("OK"), "{resp:?}");

        state.shutdown();
        acc.join().unwrap();
    }

    /// Satellite: PING on both protocols — status-only success, bad-request
    /// rejection when ids are attached, and the session survives both.
    #[test]
    fn ping_both_protocols() {
        let (state, addr, acc) = start();

        // Text: bare PING is OK, PING with arguments is an error.
        let resp = request(&addr, "PING\n", 1);
        assert_eq!(resp[0], "OK", "{resp:?}");
        let resp = request(&addr, "PING 3\n", 1);
        assert!(resp[0].starts_with("ERR"), "{resp:?}");

        // Binary: ping round-trips, and a PING frame carrying ids comes
        // back STATUS_BAD_REQUEST with the session still usable.
        let mut bin = BinaryClient::connect(&addr).unwrap();
        bin.ping().unwrap();
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&wire::MAGIC).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut hello = [0u8; 8];
        std::io::Read::read_exact(&mut r, &mut hello).unwrap();
        let mut frame = Vec::new();
        frame.extend_from_slice(&wire::OP_PING.to_le_bytes());
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.extend_from_slice(&7u32.to_le_bytes());
        s.write_all(&frame).unwrap();
        let mut resp = [0u8; 8];
        std::io::Read::read_exact(&mut r, &mut resp).unwrap();
        assert_eq!(
            u32::from_le_bytes(resp[0..4].try_into().unwrap()),
            wire::STATUS_BAD_REQUEST
        );
        // PING touches no serving state: still all-zero counters.
        let stats = bin.stats().unwrap();
        assert_eq!(stats.served, 0);
        bin.ping().unwrap();
        bin.quit().unwrap();

        state.shutdown();
        acc.join().unwrap();
    }

    /// OP_KNN_VEC: an external query vector is scored exactly like the same
    /// row queried by id, minus the self-exclusion the server cannot infer.
    #[test]
    fn binary_knn_vec_matches_id_query() {
        let (state, addr, acc) = start();
        let mut bin = BinaryClient::connect(&addr).unwrap();

        let q = bin.lookup(&[42]).unwrap().remove(0);
        let by_vec = bin.knn_vec(&q, 6).unwrap();
        let by_id = bin.knn(42, 5).unwrap();
        // The vector query sees word 42 itself; after dropping it the two
        // answers agree. Id queries score in factored space and vector
        // queries over dense rows, so scores match within float noise and
        // position swaps are only acceptable as exact-precision ties.
        let filtered: Vec<(u32, f32)> =
            by_vec.iter().copied().filter(|&(id, _)| id != 42).collect();
        assert!(filtered.len() >= 5, "{by_vec:?}");
        for (a, b) in filtered[..5].iter().zip(by_id.iter()) {
            assert!(
                (a.1 - b.1).abs() < 1e-4 * b.1.abs().max(1.0),
                "vector vs id scores diverge: {a:?} vs {b:?}"
            );
            assert!(a.0 == b.0 || (a.1 - b.1).abs() < 1e-4, "{filtered:?} vs {by_id:?}");
        }

        // Errors: zero k and a wrong-dimension vector are rejected with the
        // session intact.
        match bin.knn_vec(&q, 0) {
            Err(crate::serving::WireError::Status(s)) => {
                assert_eq!(s, wire::STATUS_BAD_REQUEST)
            }
            other => panic!("expected bad request, got {other:?}"),
        }
        match bin.knn_vec(&q[..q.len() - 1], 3) {
            Err(crate::serving::WireError::Status(s)) => {
                assert_eq!(s, wire::STATUS_BAD_FRAME)
            }
            other => panic!("expected bad frame, got {other:?}"),
        }
        assert_eq!(bin.lookup(&[1]).unwrap().len(), 1);
        bin.quit().unwrap();

        state.shutdown();
        acc.join().unwrap();
    }

    /// Satellite: the text and binary STATS views are asserted field by
    /// field through the one shared helper — a field added to only one
    /// protocol fails here.
    #[test]
    fn stats_text_and_binary_cannot_drift() {
        let (state, addr, acc) = start();
        let mut bin = BinaryClient::connect(&addr).unwrap();

        // Quiescent server: both views identical at zero.
        crate::testing::assert_stats_consistent(
            &request(&addr, "STATS\n", 1)[0],
            &bin.stats().unwrap(),
        );

        // And again after real mixed traffic (every counter nonzero-able).
        bin.lookup(&[1, 2, 3, 2]).unwrap();
        bin.knn(7, 4).unwrap();
        bin.lookup(&[1]).unwrap();
        let text = request(&addr, "STATS\n", 1);
        let binary = bin.stats().unwrap();
        assert!(binary.served > 0);
        crate::testing::assert_stats_consistent(&text[0], &binary);
        bin.quit().unwrap();

        state.shutdown();
        acc.join().unwrap();
    }

    #[test]
    fn binary_rejects_bad_requests_and_keeps_session() {
        let (state, addr, acc) = start();
        let mut bin = BinaryClient::connect(&addr).unwrap();

        // Out-of-range id.
        match bin.lookup(&[5000]) {
            Err(crate::serving::WireError::Status(s)) => assert_eq!(s, wire::STATUS_RANGE),
            other => panic!("expected range error, got {other:?}"),
        }
        // Empty lookup is a bad frame.
        match bin.lookup(&[]) {
            Err(crate::serving::WireError::Status(s)) => assert_eq!(s, wire::STATUS_BAD_FRAME),
            other => panic!("expected bad frame, got {other:?}"),
        }
        // The session is still usable afterwards.
        let rows = bin.lookup(&[1, 2]).unwrap();
        assert_eq!(rows.len(), 2);
        bin.quit().unwrap();

        state.shutdown();
        acc.join().unwrap();
    }
}
