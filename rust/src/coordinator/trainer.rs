//! Generic training loop over a train_step artifact, plus the greedy
//! decoding / span-prediction drivers used for evaluation.

use super::schedule::LrSchedule;
use crate::data::{Batch, QaBatch};
use crate::error::Result;
use crate::runtime::{Engine, ParamStore, Value, VariantInfo};
use crate::text::EOS;
use crate::util::{Summary, Timer};

/// Orchestrates train steps against one variant's artifacts.
pub struct Trainer<'a> {
    pub engine: &'a Engine,
    pub variant: &'a VariantInfo,
    pub schedule: LrSchedule,
    /// Wall-clock per step (for the training-overhead bench).
    pub step_times: Summary,
    pub losses: Vec<f32>,
}

impl<'a> Trainer<'a> {
    pub fn new(engine: &'a Engine, variant: &'a VariantInfo, schedule: LrSchedule) -> Trainer<'a> {
        Trainer { engine, variant, schedule, step_times: Summary::new(), losses: Vec::new() }
    }

    /// One seq2seq train step; returns the loss.
    pub fn step_seq2seq(&mut self, store: &mut ParamStore, batch: &Batch) -> Result<f32> {
        let f = self.variant.function("train_step")?;
        let lr = self.schedule.at(store.step as usize) as f32;
        let mut inputs = store.train_values();
        inputs.push(Value::I32(
            batch.src.iter().map(|&x| x as i32).collect(),
            vec![batch.batch_size, batch.src_len],
        ));
        inputs.push(Value::I32(
            batch.tgt.iter().map(|&x| x as i32).collect(),
            vec![batch.batch_size, batch.tgt_len],
        ));
        inputs.push(Value::F32(
            batch.tgt_mask.clone(),
            vec![batch.batch_size, batch.tgt_len],
        ));
        inputs.push(Value::scalar_f32(store.step as f32 + 1.0));
        inputs.push(Value::scalar_f32(lr));
        self.run_train(f, store, inputs)
    }

    /// One QA train step; returns the loss.
    pub fn step_qa(&mut self, store: &mut ParamStore, batch: &QaBatch) -> Result<f32> {
        let f = self.variant.function("train_step")?;
        let lr = self.schedule.at(store.step as usize) as f32;
        let mut inputs = store.train_values();
        inputs.push(Value::I32(
            batch.context.iter().map(|&x| x as i32).collect(),
            vec![batch.batch_size, batch.ctx_len],
        ));
        inputs.push(Value::I32(
            batch.question.iter().map(|&x| x as i32).collect(),
            vec![batch.batch_size, batch.q_len],
        ));
        inputs.push(Value::I32(
            batch.start.iter().map(|&x| x as i32).collect(),
            vec![batch.batch_size],
        ));
        inputs.push(Value::I32(
            batch.end.iter().map(|&x| x as i32).collect(),
            vec![batch.batch_size],
        ));
        inputs.push(Value::scalar_f32(store.step as f32 + 1.0));
        inputs.push(Value::scalar_f32(lr));
        self.run_train(f, store, inputs)
    }

    fn run_train(
        &mut self,
        f: &crate::runtime::FunctionInfo,
        store: &mut ParamStore,
        inputs: Vec<Value>,
    ) -> Result<f32> {
        let t = Timer::start();
        let outputs = self.engine.run(&f.file, &inputs)?;
        store.absorb(&outputs)?;
        let loss = outputs
            .last()
            .ok_or_else(|| crate::Error::Runtime("empty train outputs".into()))?
            .first_f32()?;
        self.step_times.add(t.elapsed().as_secs_f64());
        self.losses.push(loss);
        Ok(loss)
    }
}

/// Greedy autoregressive decode over a batch (seq2seq eval).
///
/// Runs `encode` once, then `decode_step` up to `max_len` times, harvesting
/// token ids until EOS per row. Returns one id sequence per batch row.
pub fn greedy_decode(
    engine: &Engine,
    variant: &VariantInfo,
    store: &ParamStore,
    batch: &Batch,
    max_len: usize,
) -> Result<Vec<Vec<usize>>> {
    let enc_f = variant.function("encode")?;
    let dec_f = variant.function("decode_step")?;
    let b = batch.batch_size;

    let mut enc_inputs = store.param_values();
    enc_inputs.push(Value::I32(
        batch.src.iter().map(|&x| x as i32).collect(),
        vec![b, batch.src_len],
    ));
    let enc_out = engine.run(&enc_f.file, &enc_inputs)?;
    let (enc_proj, src_mask, mut h) = (
        enc_out[0].clone(),
        enc_out[1].clone(),
        enc_out[2].clone(),
    );

    let params = store.param_values();
    let mut prev: Vec<i32> = vec![crate::text::BOS as i32; b];
    let mut seqs: Vec<Vec<usize>> = vec![Vec::new(); b];
    let mut done = vec![false; b];
    for _ in 0..max_len {
        let mut inputs = params.clone();
        inputs.push(enc_proj.clone());
        inputs.push(src_mask.clone());
        inputs.push(Value::I32(prev.clone(), vec![b]));
        inputs.push(h.clone());
        let out = engine.run(&dec_f.file, &inputs)?;
        let next = out[0].as_i32()?.to_vec();
        h = out[1].clone();
        for i in 0..b {
            if !done[i] {
                if next[i] as usize == EOS {
                    done[i] = true;
                } else {
                    seqs[i].push(next[i] as usize);
                }
            }
        }
        if done.iter().all(|&d| d) {
            break;
        }
        prev = next;
    }
    Ok(seqs)
}

/// QA span prediction over a batch; returns (start, end_inclusive) per row.
pub fn predict_spans(
    engine: &Engine,
    variant: &VariantInfo,
    store: &ParamStore,
    batch: &QaBatch,
) -> Result<Vec<(usize, usize)>> {
    let f = variant.function("predict")?;
    let mut inputs = store.param_values();
    inputs.push(Value::I32(
        batch.context.iter().map(|&x| x as i32).collect(),
        vec![batch.batch_size, batch.ctx_len],
    ));
    inputs.push(Value::I32(
        batch.question.iter().map(|&x| x as i32).collect(),
        vec![batch.batch_size, batch.q_len],
    ));
    let out = engine.run(&f.file, &inputs)?;
    let starts = out[0].as_i32()?;
    let ends = out[1].as_i32()?;
    Ok(starts
        .iter()
        .zip(ends.iter())
        .map(|(&s, &e)| (s.max(0) as usize, e.max(0) as usize))
        .collect())
}
