//! Task preparation: corpus synthesis → vocabulary → encoding → batchers,
//! driven by the artifact manifest's dims (shapes are the contract).

use crate::config::{ExperimentConfig, TaskKind};
use crate::corpus::{self, QaExample, SeqPair};
use crate::data::{
    encode_pairs, encode_qa, truncate_pairs, truncate_qa, Batcher, QaBatcher,
};
use crate::error::Result;
use crate::runtime::VariantInfo;
use crate::text::Vocab;

/// Prepared data for a sequence-to-sequence task (summarization, MT).
pub struct Seq2SeqData {
    pub vocab: Vocab,
    pub train: Batcher,
    pub valid: Batcher,
    pub test: Batcher,
    /// Reference target token strings for valid/test (metric ground truth).
    pub valid_refs: Vec<Vec<String>>,
    pub test_refs: Vec<Vec<String>>,
}

/// Prepared data for the QA task.
pub struct QaData {
    pub vocab: Vocab,
    pub train: QaBatcher,
    pub valid: QaBatcher,
    pub test: QaBatcher,
    /// Raw examples (for span → token answers at eval).
    pub valid_examples: Vec<QaExample>,
    pub test_examples: Vec<QaExample>,
}

fn build_vocab_pairs(pairs: &[SeqPair], max_size: usize) -> Vocab {
    let mut seqs: Vec<&[String]> = Vec::with_capacity(pairs.len() * 2);
    for p in pairs {
        seqs.push(&p.src);
        seqs.push(&p.tgt);
    }
    Vocab::build(seqs.into_iter(), max_size, 1)
}

fn build_vocab_qa(examples: &[QaExample], max_size: usize) -> Vocab {
    let mut seqs: Vec<&[String]> = Vec::with_capacity(examples.len() * 2);
    for e in examples {
        seqs.push(&e.context);
        seqs.push(&e.question);
    }
    Vocab::build(seqs.into_iter(), max_size, 1)
}

/// Build seq2seq data with shapes taken from the manifest variant.
pub fn prepare_seq2seq(cfg: &ExperimentConfig, var: &VariantInfo) -> Result<Seq2SeqData> {
    let vocab_cap = var.dim("vocab")?;
    let batch = var.dim("batch")?;
    let src_len = var.dim("src_len")?;
    let tgt_len = var.dim("tgt_len")?;

    let splits = match cfg.task {
        TaskKind::Summarization => corpus::summarization::generate(&cfg.corpus, vocab_cap),
        TaskKind::Translation => corpus::translation::generate(&cfg.corpus, vocab_cap / 2),
        TaskKind::Qa => {
            return Err(crate::Error::Config("QA task needs prepare_qa".into()));
        }
    };
    let vocab = build_vocab_pairs(&splits.train, vocab_cap);

    let enc = |pairs: &[SeqPair]| {
        let mut e = encode_pairs(pairs, &vocab, &vocab);
        truncate_pairs(&mut e, src_len, tgt_len);
        e
    };
    let valid_refs = splits.valid.iter().map(|p| p.tgt.clone()).collect();
    let test_refs = splits.test.iter().map(|p| p.tgt.clone()).collect();
    Ok(Seq2SeqData {
        train: Batcher::new(enc(&splits.train), batch, src_len, tgt_len),
        valid: Batcher::new(enc(&splits.valid), batch, src_len, tgt_len),
        test: Batcher::new(enc(&splits.test), batch, src_len, tgt_len),
        vocab,
        valid_refs,
        test_refs,
    })
}

/// Build QA data with shapes taken from the manifest variant.
pub fn prepare_qa(cfg: &ExperimentConfig, var: &VariantInfo) -> Result<QaData> {
    let vocab_cap = var.dim("vocab")?;
    let batch = var.dim("batch")?;
    let ctx_len = var.dim("ctx_len")?;
    let q_len = var.dim("q_len")?;

    let splits = corpus::qa::generate(&cfg.corpus, vocab_cap);
    let vocab = build_vocab_qa(&splits.train, vocab_cap);

    let enc = |ex: &[QaExample]| {
        let mut e = encode_qa(ex, &vocab);
        truncate_qa(&mut e, ctx_len, q_len);
        e
    };
    // Keep raw examples aligned with encodable ones (drop the same ones).
    let keep = |ex: &[QaExample]| -> Vec<QaExample> {
        ex.iter().filter(|e| e.span.1 <= ctx_len).cloned().collect()
    };
    Ok(QaData {
        train: QaBatcher::new(enc(&splits.train), batch, ctx_len, q_len),
        valid: QaBatcher::new(enc(&splits.valid), batch, ctx_len, q_len),
        test: QaBatcher::new(enc(&splits.test), batch, ctx_len, q_len),
        vocab,
        valid_examples: keep(&splits.valid),
        test_examples: keep(&splits.test),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn fake_variant(task: &str) -> VariantInfo {
        let json = format!(
            r#"{{"variants": {{"x": {{
              "dims": {{"task": "{task}", "batch": 4, "vocab": 512, "hidden": 8,
                        "src_len": 16, "tgt_len": 8, "ctx_len": 32, "q_len": 8,
                        "emb_dim": 16}},
              "embedding": {{"kind": "regular", "order": 1, "rank": 1, "q": 16,
                            "t": 512, "num_params": 8192}},
              "params": [], "functions": {{}}
            }}}}}}"#
        );
        Manifest::parse(&json).unwrap().variants["x"].clone()
    }

    #[test]
    fn seq2seq_preparation_shapes() {
        let mut cfg = ExperimentConfig::default();
        cfg.corpus.train = 40;
        cfg.corpus.valid = 8;
        cfg.corpus.test = 8;
        let var = fake_variant("sum");
        let d = prepare_seq2seq(&cfg, &var).unwrap();
        assert_eq!(d.train.len_examples(), 40);
        assert_eq!(d.valid_refs.len(), 8);
        assert!(d.vocab.len() <= 512);
        let mut rng = crate::util::Rng::new(0);
        let (batch, real) = d.train.epoch(&mut rng).remove(0);
        assert_eq!(batch.src.len(), 4 * 16);
        assert!(real <= 4);
    }

    #[test]
    fn qa_preparation_spans_fit() {
        let mut cfg = ExperimentConfig::default();
        cfg.task = TaskKind::Qa;
        cfg.corpus.train = 30;
        cfg.corpus.valid = 6;
        cfg.corpus.test = 6;
        let var = fake_variant("qa");
        let d = prepare_qa(&cfg, &var).unwrap();
        assert!(d.train.len_examples() > 0);
        assert_eq!(d.valid.len_examples(), d.valid_examples.len());
        for (b, _) in d.test.eval_batches() {
            for i in 0..b.batch_size {
                assert!(b.start[i] >= 0 && (b.end[i] as usize) < 32);
            }
        }
    }
}
