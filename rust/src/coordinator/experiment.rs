//! One-call experiment runner: config → corpus → training → evaluation →
//! report. This is what `w2k train`, the examples, and every table/figure
//! bench drive.

use super::schedule::LrSchedule;
use super::tasks::{self, QaData, Seq2SeqData};
use super::trainer::{greedy_decode, predict_spans, Trainer};
use crate::config::{ExperimentConfig, TaskKind};
use crate::error::Result;
use crate::metrics::{corpus_bleu, qa_corpus, rouge_corpus, QaScore};
use crate::runtime::{Engine, Manifest, ParamStore, VariantInfo};
use crate::util::{fmt_count, Json, Rng, Summary, Table, Timer};
use std::path::Path;

/// Metric snapshot at one evaluation point.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    /// Task-dependent primary metric: RG-L (sum), BLEU (mt), F1 (qa).
    pub primary: f64,
    /// All named metrics at this point.
    pub metrics: Vec<(String, f64)>,
}

/// Everything an experiment produces.
#[derive(Debug, Clone)]
pub struct Report {
    pub name: String,
    pub variant: String,
    pub task: &'static str,
    pub emb_params: usize,
    pub total_params: usize,
    pub space_saving: f64,
    pub steps: usize,
    pub losses: Vec<f32>,
    pub curve: Vec<EvalPoint>,
    pub final_metrics: Vec<(String, f64)>,
    pub step_time_mean_ms: f64,
    pub step_time_p99_ms: f64,
    pub wall_seconds: f64,
}

impl Report {
    pub fn primary(&self) -> f64 {
        self.final_metrics.first().map(|(_, v)| *v).unwrap_or(0.0)
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(vec!["Field", "Value"]).with_title(format!(
            "experiment '{}' — variant {} ({})",
            self.name, self.variant, self.task
        ));
        t.add_row(vec!["embedding params".to_string(), fmt_count(self.emb_params as u64)]);
        t.add_row(vec!["total params".to_string(), fmt_count(self.total_params as u64)]);
        t.add_row(vec!["space saving (embedding)".to_string(), format!("{:.0}×", self.space_saving)]);
        t.add_row(vec!["train steps".to_string(), self.steps.to_string()]);
        if let (Some(first), Some(last)) = (self.losses.first(), self.losses.last()) {
            t.add_row(vec!["loss first→last".to_string(), format!("{first:.3} → {last:.3}")]);
        }
        for (k, v) in &self.final_metrics {
            t.add_row(vec![format!("test {k}"), format!("{v:.2}")]);
        }
        t.add_row(vec![
            "step time".to_string(),
            format!("{:.1}ms (p99 {:.1}ms)", self.step_time_mean_ms, self.step_time_p99_ms),
        ]);
        t.add_row(vec!["wall time".to_string(), format!("{:.1}s", self.wall_seconds)]);
        t.render()
    }

    /// JSON for EXPERIMENTS.md tooling.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("variant", Json::str(self.variant.clone())),
            ("task", Json::str(self.task)),
            ("emb_params", Json::num(self.emb_params as f64)),
            ("total_params", Json::num(self.total_params as f64)),
            ("space_saving", Json::num(self.space_saving)),
            ("steps", Json::num(self.steps as f64)),
            (
                "final_metrics",
                Json::Obj(
                    self.final_metrics
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v)))
                        .collect(),
                ),
            ),
            (
                "curve",
                Json::arr(self.curve.iter().map(|p| {
                    Json::obj(vec![
                        ("step", Json::num(p.step as f64)),
                        ("primary", Json::num(p.primary)),
                    ])
                })),
            ),
            ("step_time_mean_ms", Json::num(self.step_time_mean_ms)),
            ("wall_seconds", Json::num(self.wall_seconds)),
        ])
    }
}

/// Resolve the manifest variant for a config.
pub fn resolve_variant<'m>(cfg: &ExperimentConfig, manifest: &'m Manifest) -> Result<&'m VariantInfo> {
    let prefix = cfg.artifact_prefix();
    manifest.variants.get(&prefix).ok_or_else(|| {
        crate::Error::Artifact(format!(
            "no artifact variant '{prefix}' — available: {:?}",
            manifest.variants.keys().collect::<Vec<_>>()
        ))
    })
}

/// Train + evaluate per the config; the main entry point.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<Report> {
    let engine = Engine::cpu(Path::new(&cfg.artifacts_dir))?;
    let manifest = Manifest::load(Path::new(&cfg.artifacts_dir))?;
    let variant = resolve_variant(cfg, &manifest)?;
    let mut store = ParamStore::init(&variant.params, cfg.train.seed);
    run_with(cfg, &engine, variant, &mut store, true)
}

/// Evaluate a saved checkpoint without training.
pub fn eval_checkpoint(cfg: &ExperimentConfig, ckpt: &Path) -> Result<Report> {
    let engine = Engine::cpu(Path::new(&cfg.artifacts_dir))?;
    let manifest = Manifest::load(Path::new(&cfg.artifacts_dir))?;
    let variant = resolve_variant(cfg, &manifest)?;
    let mut store = ParamStore::load(&variant.params, ckpt)?;
    let mut cfg2 = cfg.clone();
    cfg2.train.steps = 0;
    run_with(&cfg2, &engine, variant, &mut store, false)
}

/// Core loop shared by train and eval paths. Exposed for benches that need
/// to reuse one Engine across variants.
pub fn run_with(
    cfg: &ExperimentConfig,
    engine: &Engine,
    variant: &VariantInfo,
    store: &mut ParamStore,
    save_checkpoint: bool,
) -> Result<Report> {
    let wall = Timer::start();
    match cfg.task {
        TaskKind::Summarization | TaskKind::Translation => {
            let data = tasks::prepare_seq2seq(cfg, variant)?;
            run_seq2seq(cfg, engine, variant, store, data, save_checkpoint, wall)
        }
        TaskKind::Qa => {
            let data = tasks::prepare_qa(cfg, variant)?;
            run_qa(cfg, engine, variant, store, data, save_checkpoint, wall)
        }
    }
}

fn finish_report(
    cfg: &ExperimentConfig,
    variant: &VariantInfo,
    trainer_losses: Vec<f32>,
    step_times: &Summary,
    curve: Vec<EvalPoint>,
    final_metrics: Vec<(String, f64)>,
    wall: Timer,
) -> Report {
    let dp = variant.dims.get("vocab").copied().unwrap_or(0)
        * variant.dims.get("emb_dim").copied().unwrap_or(0);
    let emb_params = variant.embedding.num_params;
    Report {
        name: cfg.name.clone(),
        variant: variant.name.clone(),
        task: match cfg.task {
            TaskKind::Summarization => "summarization",
            TaskKind::Translation => "translation",
            TaskKind::Qa => "qa",
        },
        emb_params,
        total_params: variant.total_params(),
        space_saving: if emb_params > 0 { dp as f64 / emb_params as f64 } else { 1.0 },
        steps: trainer_losses.len(),
        losses: trainer_losses,
        curve,
        final_metrics,
        step_time_mean_ms: step_times.mean() * 1e3,
        step_time_p99_ms: step_times.p99() * 1e3,
        wall_seconds: wall.elapsed().as_secs_f64(),
    }
}

// ---------------------------------------------------------------------------
// seq2seq
// ---------------------------------------------------------------------------

fn eval_seq2seq(
    engine: &Engine,
    variant: &VariantInfo,
    store: &ParamStore,
    data: &Seq2SeqData,
    batcher: &crate::data::Batcher,
    refs: &[Vec<String>],
    task: TaskKind,
) -> Result<Vec<(String, f64)>> {
    let max_len = variant.dim("tgt_len")?;
    let mut pairs: Vec<(Vec<String>, Vec<String>)> = Vec::with_capacity(refs.len());
    for (batch, real_idx) in batcher.eval_batches() {
        let seqs = greedy_decode(engine, variant, store, &batch, max_len)?;
        for (row, &orig) in real_idx.iter().enumerate() {
            let hyp = data.vocab.decode(&seqs[row]);
            pairs.push((hyp, refs[orig].clone()));
        }
    }
    Ok(match task {
        TaskKind::Summarization => vec![
            ("RG-L".to_string(), rouge_corpus(&pairs, 1, true)),
            ("RG-1".to_string(), rouge_corpus(&pairs, 1, false)),
            ("RG-2".to_string(), rouge_corpus(&pairs, 2, false)),
        ],
        _ => {
            let bleu = corpus_bleu(&pairs);
            vec![
                ("BLEU".to_string(), bleu.bleu),
                ("BP".to_string(), bleu.brevity_penalty),
            ]
        }
    })
}

fn run_seq2seq(
    cfg: &ExperimentConfig,
    engine: &Engine,
    variant: &VariantInfo,
    store: &mut ParamStore,
    data: Seq2SeqData,
    save_checkpoint: bool,
    wall: Timer,
) -> Result<Report> {
    let mut trainer = Trainer::new(
        engine,
        variant,
        LrSchedule::new(cfg.train.lr, cfg.train.warmup),
    );
    let mut rng = Rng::new(cfg.train.seed ^ 0xba7c4);
    let mut curve = Vec::new();
    let mut epoch_batches = Vec::new();

    for step in 0..cfg.train.steps {
        if epoch_batches.is_empty() {
            epoch_batches = data.train.epoch(&mut rng);
            epoch_batches.reverse(); // pop from the back
        }
        let (batch, _real) = epoch_batches.pop().unwrap();
        let loss = trainer.step_seq2seq(store, &batch)?;
        if step % 20 == 0 {
            crate::info!("step {step}: loss {loss:.4}");
        }
        if cfg.train.eval_every > 0
            && (step + 1) % cfg.train.eval_every == 0
            && step + 1 < cfg.train.steps
        {
            let m = eval_seq2seq(engine, variant, store, &data, &data.valid, &data.valid_refs, cfg.task)?;
            crate::info!("eval @{}: {:?}", step + 1, m);
            curve.push(EvalPoint { step: step + 1, primary: m[0].1, metrics: m });
        }
    }
    let final_metrics =
        eval_seq2seq(engine, variant, store, &data, &data.test, &data.test_refs, cfg.task)?;
    curve.push(EvalPoint {
        step: cfg.train.steps,
        primary: final_metrics[0].1,
        metrics: final_metrics.clone(),
    });
    if save_checkpoint && cfg.train.steps > 0 {
        let path = Path::new(&cfg.train.checkpoint_dir)
            .join(format!("{}.ckpt", variant.name));
        store.save(&path)?;
        crate::info!("checkpoint → {}", path.display());
    }
    let losses = std::mem::take(&mut trainer.losses);
    let times = trainer.step_times.clone();
    Ok(finish_report(cfg, variant, losses, &times, curve, final_metrics, wall))
}

// ---------------------------------------------------------------------------
// QA
// ---------------------------------------------------------------------------

fn eval_qa(
    engine: &Engine,
    variant: &VariantInfo,
    store: &ParamStore,
    batcher: &crate::data::QaBatcher,
    examples: &[crate::corpus::QaExample],
) -> Result<QaScore> {
    let mut items: Vec<(Vec<String>, Vec<Vec<String>>)> = Vec::with_capacity(examples.len());
    let mut offset = 0usize;
    for (batch, real) in batcher.eval_batches() {
        let spans = predict_spans(engine, variant, store, &batch)?;
        for row in 0..real {
            let ex = &examples[offset + row];
            let (s, e) = spans[row];
            let e = e.min(ex.context.len().saturating_sub(1));
            let s = s.min(e);
            let pred: Vec<String> = ex.context[s..=e].to_vec();
            items.push((pred, ex.answers.clone()));
        }
        offset += real;
    }
    Ok(qa_corpus(&items))
}

fn run_qa(
    cfg: &ExperimentConfig,
    engine: &Engine,
    variant: &VariantInfo,
    store: &mut ParamStore,
    data: QaData,
    save_checkpoint: bool,
    wall: Timer,
) -> Result<Report> {
    let mut trainer = Trainer::new(
        engine,
        variant,
        LrSchedule::new(cfg.train.lr, cfg.train.warmup),
    );
    let mut rng = Rng::new(cfg.train.seed ^ 0x9a11);
    let mut curve = Vec::new();
    let mut epoch_batches = Vec::new();

    for step in 0..cfg.train.steps {
        if epoch_batches.is_empty() {
            epoch_batches = data.train.epoch(&mut rng);
            epoch_batches.reverse();
        }
        let (batch, _real) = epoch_batches.pop().unwrap();
        let loss = trainer.step_qa(store, &batch)?;
        if step % 20 == 0 {
            crate::info!("step {step}: loss {loss:.4}");
        }
        if cfg.train.eval_every > 0
            && (step + 1) % cfg.train.eval_every == 0
            && step + 1 < cfg.train.steps
        {
            let s = eval_qa(engine, variant, store, &data.valid, &data.valid_examples)?;
            crate::info!("eval @{}: F1 {:.2} EM {:.2}", step + 1, s.f1, s.em);
            curve.push(EvalPoint {
                step: step + 1,
                primary: s.f1,
                metrics: vec![("F1".to_string(), s.f1), ("EM".to_string(), s.em)],
            });
        }
    }
    let s = eval_qa(engine, variant, store, &data.test, &data.test_examples)?;
    let final_metrics = vec![("F1".to_string(), s.f1), ("EM".to_string(), s.em)];
    curve.push(EvalPoint { step: cfg.train.steps, primary: s.f1, metrics: final_metrics.clone() });
    if save_checkpoint && cfg.train.steps > 0 {
        let path = Path::new(&cfg.train.checkpoint_dir)
            .join(format!("{}.ckpt", variant.name));
        store.save(&path)?;
    }
    let losses = std::mem::take(&mut trainer.losses);
    let times = trainer.step_times.clone();
    Ok(finish_report(cfg, variant, losses, &times, curve, final_metrics, wall))
}
