//! L3 coordinator: the training / evaluation / serving orchestration around
//! the AOT-compiled compute artifacts. Pure Rust on the request path.

pub mod beam;
pub mod experiment;
pub mod schedule;
pub mod server;
pub mod tasks;
pub mod trainer;

pub use experiment::{eval_checkpoint, run_experiment, Report};
pub use schedule::LrSchedule;
pub use trainer::Trainer;
