//! Vocabulary-sharded multi-node serving: a topology-aware routing tier
//! that turns N single-node embedding servers into one logical service.
//!
//! The paper's argument scales *out*, not just down: a 100×-smaller
//! embedding table is cheap to replicate and cheap to partition, so a huge
//! vocabulary can be served by many small nodes. This subsystem adds the
//! distribution layer over everything built so far — the shard servers are
//! stock `serving/` + `snapshot/` single-node servers, booted from per-
//! shard snapshot files; the cluster logic lives entirely in the router.
//!
//! ```text
//!                         clients (text or binary wire)
//!                                    │
//!                        ┌───────────▼───────────┐
//!                        │   Router (cluster/)   │  scatter-gather,
//!                        │  ┌─────────────────┐  │  failover, health,
//!                        │  │ Topology        │  │  STATS roll-up,
//!                        │  │ HealthBoard     │  │  rolling reload
//!                        │  └─────────────────┘  │
//!                        └──┬─────────┬───────┬──┘
//!             OP_LOOKUP │ OP_KNN_VEC │ OP_PING │ OP_RELOAD (downstream wire)
//!                ┌──────▼───┐  ┌─────▼────┐  ┌─▼────────┐
//!                │ shard 0  │  │ shard 1  │  │ shard N-1│   each: replicas
//!                │ r0 r1 …  │  │ r0 r1 …  │  │ r0 r1 …  │   serving one
//!                └──────────┘  └──────────┘  └──────────┘   vocab slice
//!                 shard0.snap   shard1.snap    shardN-1.snap
//! ```
//!
//! * [`Topology`] — how the vocabulary splits (range or hash), O(1) id
//!   mapping in both directions, replica address book; parsed from a
//!   `[cluster]` TOML section and embedded per shard in the snapshot
//!   manifest ([`crate::snapshot::ShardRange`]).
//! * [`save_shard_snapshots`] — slice a global store into per-shard
//!   snapshot files (word2ket slices stay factored).
//! * [`Router`] — pooled downstream
//!   [`BinaryClient`](crate::serving::BinaryClient) connections,
//!   scatter-gather requests, replica failover, background health probing,
//!   cluster STATS, rolling zero-downtime reload.
//! * [`server`] — the router as a listener: the same text + binary
//!   protocols upstream, so clients cannot tell a router from a node.

pub mod health;
pub mod router;
pub mod server;
pub mod shard;
pub mod topology;

pub use health::HealthBoard;
pub use router::{ClusterStats, ReplicaReport, Router, RouterConfig, RouterError};
pub use server::RouterState;
pub use shard::{save_shard_snapshots, shard_snapshot_path, shard_store};
pub use topology::{ShardStrategy, Topology};
