//! Shard preparation: slice one global embedding store into per-shard
//! stores and persist them as snapshot files the existing single-node
//! server boots from unchanged.
//!
//! A shard server is just `serve_embeddings`/`w2k serve` pointed at
//! `shard<i>.snap` — the cluster layer adds no new server binary. Each
//! shard file carries a [`ShardRange`](crate::snapshot::ShardRange) section
//! ([`Topology::shard_range`]) so the file itself records which global ids
//! it owns.
//!
//! Slicing keeps the factored representation where the math allows it:
//! word2ket stores per-word leaf tensors, so any subset of words is again a
//! word2ket store (the slice stays ~100× smaller than dense rows). The
//! other kinds share parameters *across* the whole vocabulary (word2ketXS
//! factors address global-id digits, hashing buckets are global), so their
//! slices materialize to dense regular rows — still small in absolute
//! terms, because a shard holds only `vocab/n` rows, and bit-identical to
//! the global store's reconstruction by construction.

use super::topology::Topology;
use crate::embedding::{EmbeddingStore, RegularEmbedding, Word2Ket};
use crate::error::{Error, Result};
use crate::repr::Repr;
use crate::snapshot::{save_store, SaveOptions, SnapshotInfo};
use std::path::{Path, PathBuf};

/// Canonical shard file name inside a snapshot directory: `shard<i>.snap`.
/// The router's rolling `RELOAD <dir>` resolves per-shard paths with this,
/// so writers and the reload path cannot disagree on naming.
pub fn shard_snapshot_path(dir: &Path, shard: usize) -> PathBuf {
    dir.join(format!("shard{shard}.snap"))
}

/// Build the store shard `s` serves: the global store's rows for exactly
/// the ids `topo` assigns to `s`, re-indexed by local id.
pub fn shard_store(
    store: &dyn EmbeddingStore,
    topo: &Topology,
    s: usize,
) -> Result<Box<dyn EmbeddingStore>> {
    if store.vocab_size() != topo.vocab() {
        return Err(Error::Config(format!(
            "store holds {} words but the topology describes {}",
            store.vocab_size(),
            topo.vocab()
        )));
    }
    let ids: Vec<usize> = topo.shard_ids(s).collect();
    // word2ket: per-word leaves make any word subset a word2ket store.
    if let Repr::Word2Ket(e) = store.repr() {
        let per_word = e.rank() * e.order() * e.leaf_dim();
        let mut leaves = Vec::with_capacity(ids.len() * per_word);
        for &id in &ids {
            leaves.extend_from_slice(e.word(id).leaves());
        }
        return Ok(Box::new(Word2Ket::from_leaves(
            ids.len(),
            e.dim(),
            e.order(),
            e.rank(),
            e.leaf_dim(),
            e.layernorm(),
            &leaves,
        )?));
    }
    // Everything else: materialize the slice (see module docs).
    let mut rows = Vec::with_capacity(ids.len() * store.dim());
    store.lookup_batch_into(&ids, &mut rows);
    Ok(Box::new(RegularEmbedding::new(ids.len(), store.dim(), rows)))
}

/// Slice `store` per `topo` and write `shard<i>.snap` files (atomic, like
/// every snapshot write) into `dir`, each carrying its shard-range section.
/// Returns the per-shard paths in shard order.
pub fn save_shard_snapshots(
    store: &dyn EmbeddingStore,
    topo: &Topology,
    dir: &Path,
    opts: &SaveOptions,
) -> Result<Vec<(PathBuf, SnapshotInfo)>> {
    std::fs::create_dir_all(dir)
        .map_err(|e| Error::Snapshot(format!("create {}: {e}", dir.display())))?;
    let mut out = Vec::with_capacity(topo.n_shards());
    for s in 0..topo.n_shards() {
        let sub = shard_store(store, topo, s)?;
        let path = shard_snapshot_path(dir, s);
        let shard_opts = SaveOptions { shard_range: Some(topo.shard_range(s)), ..*opts };
        let info = save_store(sub.as_ref(), &path, &shard_opts)?;
        out.push((path, info));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ShardStrategy;
    use crate::embedding::Word2KetXS;
    use crate::snapshot::{Snapshot, SnapshotStore};
    use crate::util::Rng;
    use std::sync::Arc;

    fn topo(vocab: usize, strategy: ShardStrategy, shards: usize) -> Topology {
        let addrs = (0..shards).map(|s| vec![format!("127.0.0.1:{}", 7200 + s)]).collect();
        Topology::new(vocab, strategy, addrs).unwrap()
    }

    fn tmp_dir(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("w2k_shard_{}_{name}", std::process::id()))
    }

    /// Every shard row must be bit-identical to the global store's row for
    /// the same global id — through slicing, save and (mmap) load.
    #[test]
    fn shard_snapshots_serve_bit_identical_rows() {
        for strategy in [ShardStrategy::Range, ShardStrategy::Hash] {
            let mut rng = Rng::new(41);
            let store = Word2KetXS::random(53, 16, 2, 2, &mut rng);
            let t = topo(53, strategy, 3);
            let dir = tmp_dir(&format!("rows_{}", strategy.name()));
            let saved = save_shard_snapshots(&store, &t, &dir, &SaveOptions::default()).unwrap();
            assert_eq!(saved.len(), 3);
            for (s, (path, info)) in saved.iter().enumerate() {
                assert!(info.bytes > 0);
                let snap = Arc::new(Snapshot::open(path, true).unwrap());
                let sr = snap.shard_range().expect("shard file must carry its range");
                assert_eq!(sr.shard as usize, s);
                assert_eq!(sr.global_vocab as usize, 53);
                let loaded = SnapshotStore::open(snap).unwrap();
                assert_eq!(loaded.vocab_size(), t.local_count(s));
                for (local, global) in t.shard_ids(s).enumerate() {
                    assert_eq!(
                        loaded.lookup(local),
                        store.lookup(global),
                        "{strategy:?} shard {s} local {local}"
                    );
                }
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    /// word2ket slices stay factored (tiny on disk); shared-parameter kinds
    /// materialize.
    #[test]
    fn word2ket_slices_stay_factored() {
        let mut rng = Rng::new(42);
        let mut w2k = Word2Ket::random(40, 16, 2, 2, &mut rng);
        w2k.set_layernorm(false);
        let t = topo(40, ShardStrategy::Range, 4);
        let sub = shard_store(&w2k, &t, 1).unwrap();
        assert!(matches!(sub.repr(), Repr::Word2Ket(_)), "{}", sub.describe());
        for (local, global) in t.shard_ids(1).enumerate() {
            assert_eq!(sub.lookup(local), w2k.lookup(global));
        }

        let xs = Word2KetXS::random(40, 16, 2, 2, &mut rng);
        let sub = shard_store(&xs, &t, 1).unwrap();
        assert!(matches!(sub.repr(), Repr::Regular(_)), "{}", sub.describe());
    }

    #[test]
    fn rejects_vocab_mismatch() {
        let mut rng = Rng::new(43);
        let store = Word2KetXS::random(10, 16, 2, 1, &mut rng);
        let t = topo(11, ShardStrategy::Range, 2);
        assert!(shard_store(&store, &t, 0).is_err());
        let dir = tmp_dir("mismatch");
        assert!(save_shard_snapshots(&store, &t, &dir, &SaveOptions::default()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The canonical naming used by rolling reload matches what the writer
    /// produced.
    #[test]
    fn snapshot_paths_are_canonical() {
        let mut rng = Rng::new(44);
        let store = Word2KetXS::random(12, 16, 2, 1, &mut rng);
        let t = topo(12, ShardStrategy::Range, 2);
        let dir = tmp_dir("paths");
        let saved = save_shard_snapshots(&store, &t, &dir, &SaveOptions::default()).unwrap();
        for (s, (path, _)) in saved.iter().enumerate() {
            assert_eq!(path, &shard_snapshot_path(&dir, s));
            assert!(Snapshot::open(path, false).is_ok());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
