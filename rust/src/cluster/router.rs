//! Scatter-gather router: N binary-protocol shard servers behind one
//! client-facing façade.
//!
//! The router owns one pooled [`BinaryClient`] connection per replica
//! (lazily established, transparently re-established) and speaks the
//! existing downstream wire protocol — shard servers are stock single-node
//! servers, unaware they are part of a cluster. Per request:
//!
//! * **LOOKUP** — ids are bucketed by owning shard ([`Topology::locate`]),
//!   one `OP_LOOKUP` per involved shard fans out on scoped threads, and
//!   rows are scattered back into request positions — reassembly is in
//!   request order regardless of shard reply order.
//! * **DOT** — co-routed to the owning shard when both words live there
//!   (one `OP_DOT`, factored server-side); otherwise the two rows are
//!   fetched from their shards and the dot runs router-side.
//! * **KNN** — the query row is fetched from its owning shard, scattered to
//!   every shard as `OP_KNN_VEC`, and the per-shard top-(k+1) heaps are
//!   merged with [`merge_top_k`] into an exact global top-k (ties by global
//!   id). When shards score the same dense rows a single node would (the
//!   materialized slices `save_shard_snapshots` writes for every kind but
//!   word2ket), the merged answer is *bit-identical* to the unsharded
//!   scan; factored word2ket slices agree within float ulps, so exact-tie
//!   neighbors can swap order — the same noise the single node's own
//!   factored-vs-dense paths exhibit.
//! * **STATS** — fanned to every replica and rolled up (sums for counters,
//!   max for latency percentiles, min for the cluster generation).
//! * **RELOAD** — rolled across the cluster one replica at a time, each
//!   swap verified against `STATS` generation counters, so a snapshot
//!   deploys with zero downtime ([`Router::rolling_reload`]).
//!
//! Failover: replica selection rotates round-robin over *healthy* replicas
//! (see [`HealthBoard`]); a transport error drops the pooled connection,
//! records the failure, and moves to the next replica — a killed replica
//! costs latency, never a failed client request, as long as one replica of
//! each shard survives. A background prober `OP_PING`s every replica (on
//! dedicated connections) so ejected nodes are re-admitted when they
//! return.
//!
//! Connection model: **one pooled connection per replica**, so concurrent
//! requests routed to the same replica serialize on it (probes, STATS
//! fan-out, and rolling reload deliberately use short-lived dedicated
//! connections and never touch the slot). For the target deployment —
//! many shards, R small — request concurrency spreads across shards; a
//! per-replica connection *pool* is the natural next scaling step if one
//! replica must absorb many concurrent routers' worth of traffic.

use super::health::HealthBoard;
use super::shard::shard_snapshot_path;
use super::topology::Topology;
use crate::config::TomlDoc;
use crate::error::Error;
use crate::index::{merge_top_k, Neighbor};
use crate::net::{NetConfig, NetDriver};
use crate::obs::{relabel_exposition, Obs, ObsConfig, Span, Stage, TraceContext};
use crate::serving::wire::{self, WireError, WireStats};
use crate::serving::BinaryClient;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

/// Router knobs, parsed from the same `[cluster]` section as the topology.
/// (`PartialEq` only: [`ObsConfig`] carries the float `trace_sample`.)
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouterConfig {
    /// Downstream TCP connect deadline.
    pub connect_timeout: Duration,
    /// Downstream per-operation read/write deadline.
    pub io_timeout: Duration,
    /// Health-probe period; zero disables the prober (requests still
    /// record failures, but ejected replicas are only re-admitted by the
    /// last-resort retry pass).
    pub probe_interval: Duration,
    /// Consecutive failures before a replica is ejected.
    pub eject_after: u32,
    /// The router's own listener driver plus multiplexed-fan-out toggle:
    /// under `driver = "epoll"`, multi-shard scatter-gather runs as
    /// concurrent in-flight exchanges on one poller instead of one scoped
    /// thread per shard.
    pub net: NetConfig,
    /// The router's own metrics plane (`[obs]` section): route/fan-out/
    /// merge stage histograms, per-shard failover counters, slow ring.
    pub obs: ObsConfig,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            connect_timeout: Duration::from_millis(1000),
            io_timeout: Duration::from_millis(5000),
            probe_interval: Duration::from_millis(1000),
            eject_after: 3,
            net: NetConfig::default(),
            obs: ObsConfig::default(),
        }
    }
}

impl RouterConfig {
    /// Read overrides from a `[cluster]` section (`connect_timeout_ms`,
    /// `io_timeout_ms`, `probe_interval_ms`, `eject_after`) plus the shared
    /// `[net]` section.
    pub fn from_doc(doc: &TomlDoc) -> RouterConfig {
        let d = RouterConfig::default();
        let ms = |key: &str, dflt: Duration| {
            Duration::from_millis(doc.usize_or(key, dflt.as_millis() as usize) as u64)
        };
        RouterConfig {
            connect_timeout: ms("cluster.connect_timeout_ms", d.connect_timeout),
            io_timeout: ms("cluster.io_timeout_ms", d.io_timeout),
            probe_interval: ms("cluster.probe_interval_ms", d.probe_interval),
            eject_after: doc.usize_or("cluster.eject_after", d.eject_after as usize) as u32,
            net: NetConfig::from_doc(doc),
            obs: ObsConfig::from_doc(doc),
        }
    }
}

/// Why a routed request failed.
#[derive(Debug)]
pub enum RouterError {
    /// A global id is outside the topology's vocabulary.
    OutOfRange,
    /// Malformed request (empty lookup, zero k).
    BadQuery,
    /// Every replica of a shard failed; `last` is the final transport
    /// error observed.
    ShardDown { shard: usize, last: String },
    /// A downstream server answered with an error status, or the transport
    /// failed in a non-failover context.
    Wire(WireError),
    /// A rolling reload step failed or verified wrong.
    Reload { shard: usize, replica: usize, message: String },
}

impl std::fmt::Display for RouterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouterError::OutOfRange => write!(f, "id outside the cluster vocabulary"),
            RouterError::BadQuery => write!(f, "bad query"),
            RouterError::ShardDown { shard, last } => {
                write!(f, "shard {shard}: every replica failed (last: {last})")
            }
            RouterError::Wire(e) => write!(f, "downstream: {e}"),
            RouterError::Reload { shard, replica, message } => {
                write!(f, "rolling reload at shard {shard} replica {replica}: {message}")
            }
        }
    }
}

impl std::error::Error for RouterError {}

impl From<WireError> for RouterError {
    fn from(e: WireError) -> Self {
        RouterError::Wire(e)
    }
}

impl From<RouterError> for Error {
    fn from(e: RouterError) -> Self {
        Error::Server(e.to_string())
    }
}

impl RouterError {
    /// The upstream wire status the router's own listener answers with.
    pub fn status_code(&self) -> u32 {
        match self {
            RouterError::OutOfRange => wire::STATUS_RANGE,
            RouterError::BadQuery => wire::STATUS_BAD_REQUEST,
            // A fully-down shard is indistinguishable from overload from
            // the client's seat: retry later, possibly elsewhere.
            RouterError::ShardDown { .. } => wire::STATUS_OVERLOADED,
            RouterError::Wire(WireError::Status(s)) => *s,
            RouterError::Wire(WireError::TimedOut) => wire::STATUS_TIMEOUT,
            RouterError::Wire(_) => wire::STATUS_TIMEOUT,
            RouterError::Reload { .. } => wire::STATUS_RELOAD_FAILED,
        }
    }

    /// Short status label stamped onto a routed span that ends in this
    /// error (mirrors the single-node `LookupError` tags).
    fn trace_tag(&self) -> &'static str {
        match self {
            RouterError::OutOfRange => "range",
            RouterError::BadQuery => "bad_query",
            RouterError::ShardDown { .. } => "shard_down",
            RouterError::Wire(_) => "wire",
            RouterError::Reload { .. } => "reload",
        }
    }
}

/// One replica's view in a [`ClusterStats`] report.
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub shard: usize,
    pub replica: usize,
    pub addr: String,
    pub healthy: bool,
    /// `None` when the replica did not answer STATS.
    pub stats: Option<WireStats>,
}

/// Cluster-wide STATS roll-up.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    /// Counters summed across replicas; latency percentiles are the
    /// cluster-wide maximum (the conservative tail); `model_generation` is
    /// the *minimum* across replicas — the generation every node has
    /// reached; `snapshot_bytes` sums.
    pub aggregate: WireStats,
    pub replicas: Vec<ReplicaReport>,
    pub healthy_replicas: usize,
    pub total_replicas: usize,
    /// Requests that succeeded only after failing over off a replica.
    pub failovers: u64,
    pub min_generation: u64,
    pub max_generation: u64,
}

/// One pooled downstream connection, lazily established.
type Slot = Mutex<Option<BinaryClient>>;

struct Inner {
    topo: Topology,
    cfg: RouterConfig,
    /// Pooled downstream connections, `[shard][replica]`; `None` until the
    /// first request (or probe) needs one.
    slots: Vec<Vec<Slot>>,
    health: HealthBoard,
    next: Vec<AtomicUsize>,
    dim: AtomicUsize,
    stop: AtomicBool,
    failovers: AtomicU64,
    /// Requests that succeeded only after failing over, per shard
    /// (`w2k_router_shard_failovers_total{shard=...}`).
    shard_failovers: Vec<AtomicU64>,
    /// Downstream deadline expiries observed per shard, whether or not the
    /// request eventually succeeded elsewhere.
    shard_timeouts: Vec<AtomicU64>,
    /// The router's own metrics registry: route/fan-out/merge stage
    /// histograms, end-to-end latency, slow ring, plus whatever transport
    /// stages the router's listener driver records.
    obs: Arc<Obs>,
}

/// The cluster router (cheaply cloneable handle; see the module docs).
#[derive(Clone)]
pub struct Router {
    inner: Arc<Inner>,
}

impl Router {
    /// Build a router over `topo`; spawns the health-probe loop unless
    /// `cfg.probe_interval` is zero. No connections are opened yet.
    pub fn new(topo: Topology, cfg: RouterConfig) -> Router {
        let shape: Vec<usize> = (0..topo.n_shards()).map(|s| topo.replicas(s).len()).collect();
        let inner = Arc::new(Inner {
            slots: shape
                .iter()
                .map(|&n| (0..n).map(|_| Slot::new(None)).collect())
                .collect(),
            health: HealthBoard::new(&shape, cfg.eject_after),
            next: shape.iter().map(|_| AtomicUsize::new(0)).collect(),
            dim: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            failovers: AtomicU64::new(0),
            shard_failovers: shape.iter().map(|_| AtomicU64::new(0)).collect(),
            shard_timeouts: shape.iter().map(|_| AtomicU64::new(0)).collect(),
            obs: Arc::new(Obs::new(&cfg.obs)),
            topo,
            cfg,
        });
        if !inner.cfg.probe_interval.is_zero() {
            spawn_prober(&inner);
        }
        Router { inner }
    }

    pub fn topology(&self) -> &Topology {
        &self.inner.topo
    }

    pub fn config(&self) -> &RouterConfig {
        &self.inner.cfg
    }

    pub fn health(&self) -> &HealthBoard {
        &self.inner.health
    }

    /// Requests that succeeded only after a replica failover.
    pub fn failovers(&self) -> u64 {
        self.inner.failovers.load(Ordering::Relaxed)
    }

    /// Stop the probe loop. Pooled connections close as the router drops.
    pub fn shutdown(&self) {
        self.inner.stop.store(true, Ordering::SeqCst);
    }

    /// Embedding dimensionality served by the cluster (from the first
    /// downstream hello; forces a connection if none exists yet).
    pub fn dim(&self) -> Result<usize, RouterError> {
        let d = self.inner.dim.load(Ordering::Relaxed);
        if d != 0 {
            return Ok(d);
        }
        self.inner.with_replica(0, |c| Ok(c.dim))
    }

    /// Fetch rows for global `ids`, one `dim`-length vector per id, in
    /// request order (scatter by shard, gather by position).
    pub fn lookup(&self, ids: &[u32]) -> Result<Vec<Vec<f32>>, RouterError> {
        self.lookup_traced(ids, None)
    }

    /// [`Self::lookup`] carrying an optional propagated trace context plus
    /// the listener's parse time: the routed span (a child of the client's
    /// span, or a head-sampled root when `trace` is `None`) parents every
    /// shard-side span via the fan-out's trace-context extension.
    pub fn lookup_traced(
        &self,
        ids: &[u32],
        trace: Option<(TraceContext, u64)>,
    ) -> Result<Vec<Vec<f32>>, RouterError> {
        let span = self.inner.edge_span("lookup", trace);
        self.lookup_with_span(ids, span)
    }

    /// The real lookup: `span` (when sampled) collects the route/fan-out/
    /// merge stage split and its context rides every downstream frame.
    fn lookup_with_span(
        &self,
        ids: &[u32],
        mut span: Option<Span>,
    ) -> Result<Vec<Vec<f32>>, RouterError> {
        let inner = &*self.inner;
        let t_start = Instant::now();
        let sampled = span.is_some();
        let ctx = span.as_ref().map(|s| s.context());
        let result = (|| {
            if ids.is_empty() {
                return Err(RouterError::BadQuery);
            }
            // Stage boundaries (one Instant read each, only when obs is on):
            // route = bucketing ids by owning shard, fanout = downstream
            // round-trips, merge = scattering rows back into request order.
            let t0 = inner.obs.enabled().then(Instant::now);
            let vocab = inner.topo.vocab();
            let n = inner.topo.n_shards();
            // positions[s] / locals[s]: which request slots shard s fills,
            // and with which shard-local ids.
            let mut positions: Vec<Vec<usize>> = vec![Vec::new(); n];
            let mut locals: Vec<Vec<u32>> = vec![Vec::new(); n];
            for (pos, &gid) in ids.iter().enumerate() {
                if gid as usize >= vocab {
                    return Err(RouterError::OutOfRange);
                }
                let (s, local) = inner.topo.locate(gid as usize);
                positions[s].push(pos);
                locals[s].push(local as u32);
            }
            let mut out: Vec<Vec<f32>> = vec![Vec::new(); ids.len()];
            let involved: Vec<usize> = (0..n).filter(|&s| !positions[s].is_empty()).collect();
            let t_route = t0.map(|_| Instant::now());
            if let [s] = involved[..] {
                // Single-shard fast path: no scatter threads for the common
                // small request.
                let rows = inner.with_replica(s, |c| c.lookup_traced(&locals[s], ctx))?;
                let t_fan = t0.map(|_| Instant::now());
                for (row, &pos) in rows.into_iter().zip(&positions[s]) {
                    out[pos] = row;
                }
                if let (Some(t0), Some(t_route), Some(t_fan)) = (t0, t_route, t_fan) {
                    inner.record_route("lookup", t0, t_route, t_fan, &mut span);
                }
                return Ok(out);
            }
            let gathered = if inner.multiplexed() {
                inner.fan_lookup(&involved, &locals, ctx)?
            } else {
                scatter(&involved, |s| {
                    inner.with_replica(s, |c| c.lookup_traced(&locals[s], ctx))
                })?
            };
            let t_fan = t0.map(|_| Instant::now());
            for (s, rows) in involved.iter().zip(gathered) {
                for (row, &pos) in rows.into_iter().zip(&positions[*s]) {
                    out[pos] = row;
                }
            }
            if let (Some(t0), Some(t_route), Some(t_fan)) = (t0, t_route, t_fan) {
                inner.record_route("lookup", t0, t_route, t_fan, &mut span);
            }
            Ok(out)
        })();
        let err_tag = result.as_ref().err().map(RouterError::trace_tag);
        inner.close_route_span("lookup", span.take(), sampled, err_tag, t_start);
        result
    }

    /// Inner product of two global ids: co-routed when one shard owns both
    /// words (factored server-side), computed router-side from the two
    /// fetched rows otherwise.
    pub fn dot(&self, a: u32, b: u32) -> Result<f32, RouterError> {
        let inner = &*self.inner;
        let vocab = inner.topo.vocab();
        if a as usize >= vocab || b as usize >= vocab {
            return Err(RouterError::OutOfRange);
        }
        let (sa, la) = inner.topo.locate(a as usize);
        let (sb, lb) = inner.topo.locate(b as usize);
        if sa == sb {
            return inner.with_replica(sa, |c| c.dot(la as u32, lb as u32));
        }
        let rows = self.lookup(&[a, b])?;
        Ok(crate::tensor::dot(&rows[0], &rows[1]))
    }

    /// Exact global top-`k` neighbors of word `id` (excluded from its own
    /// results), scatter-gathered across every shard and merged with the
    /// single-node selection rule — bit-identical ids *and* scores to the
    /// unsharded scan for dense shard stores (see the module docs for the
    /// factored-word2ket ulp caveat).
    pub fn knn(&self, id: u32, k: u32) -> Result<Vec<(u32, f32)>, RouterError> {
        self.knn_traced(id, k, None)
    }

    /// [`Self::knn`] carrying an optional propagated trace context: the
    /// routed span parents both the query row's own lookup span and every
    /// shard's scatter span, so one client request yields one cross-node
    /// span tree.
    pub fn knn_traced(
        &self,
        id: u32,
        k: u32,
        trace: Option<(TraceContext, u64)>,
    ) -> Result<Vec<(u32, f32)>, RouterError> {
        let inner = &*self.inner;
        let t_start = Instant::now();
        let mut span = inner.edge_span("knn", trace);
        let sampled = span.is_some();
        let ctx = span.as_ref().map(|s| s.context());
        let result = (|| {
            if id as usize >= inner.topo.vocab() {
                return Err(RouterError::OutOfRange);
            }
            if k == 0 {
                return Err(RouterError::BadQuery);
            }
            // The query row comes from its owning shard like any lookup —
            // traced as a child span of this knn (never a fresh root: an
            // unsampled knn must not mint an unrelated lookup trace).
            let child = ctx.and_then(|c| inner.obs.tracer().start_child(c, "lookup", 0));
            let query = self.lookup_with_span(&[id], child)?.remove(0);
            // ...then every shard scores it. Shards cannot exclude the query
            // word (they see only a vector), so each is asked for k+1 and
            // the gather filters the query id out before the merge.
            let merged =
                self.scatter_knn(&query, k.saturating_add(1), Some(id), ctx, &mut span)?;
            Ok(take_k(merged, k as usize))
        })();
        let err_tag = result.as_ref().err().map(RouterError::trace_tag);
        inner.close_route_span("knn", span.take(), sampled, err_tag, t_start);
        result
    }

    /// Exact global top-`k` for an external query vector (no exclusion).
    pub fn knn_vec(&self, query: &[f32], k: u32) -> Result<Vec<(u32, f32)>, RouterError> {
        self.knn_vec_traced(query, k, None)
    }

    /// [`Self::knn_vec`] carrying an optional propagated trace context.
    pub fn knn_vec_traced(
        &self,
        query: &[f32],
        k: u32,
        trace: Option<(TraceContext, u64)>,
    ) -> Result<Vec<(u32, f32)>, RouterError> {
        let inner = &*self.inner;
        let t_start = Instant::now();
        let mut span = inner.edge_span("knn", trace);
        let sampled = span.is_some();
        let ctx = span.as_ref().map(|s| s.context());
        let result = (|| {
            if k == 0 || query.is_empty() {
                return Err(RouterError::BadQuery);
            }
            let merged = self.scatter_knn(query, k, None, ctx, &mut span)?;
            Ok(take_k(merged, k as usize))
        })();
        let err_tag = result.as_ref().err().map(RouterError::trace_tag);
        inner.close_route_span("knn", span.take(), sampled, err_tag, t_start);
        result
    }

    /// Scatter `OP_KNN_VEC` to every shard, map local ids to global, drop
    /// `exclude`, and merge the partial heaps exactly. `ctx` rides every
    /// downstream frame; `span` (the caller's routed span, when sampled)
    /// is finished by [`Inner::record_route`] on success.
    fn scatter_knn(
        &self,
        query: &[f32],
        per_shard_k: u32,
        exclude: Option<u32>,
        ctx: Option<TraceContext>,
        span: &mut Option<Span>,
    ) -> Result<Vec<Neighbor>, RouterError> {
        let inner = &*self.inner;
        let shards: Vec<usize> = (0..inner.topo.n_shards()).collect();
        let t0 = inner.obs.enabled().then(Instant::now);
        let per_shard = if inner.multiplexed() && shards.len() > 1 {
            inner.fan_knn(&shards, query, per_shard_k, ctx)?
        } else {
            scatter(&shards, |s| {
                inner.with_replica(s, |c| c.knn_vec_traced(query, per_shard_k, ctx))
            })?
        };
        let t_fan = t0.map(|_| Instant::now());
        let lists = shards.iter().zip(per_shard).map(|(&s, locals)| {
            locals
                .into_iter()
                .map(|(local, score)| Neighbor {
                    id: inner.topo.global_id(s, local as usize),
                    score,
                })
                .filter(|n| Some(n.id as u32) != exclude)
                .collect()
        });
        // Clamp before sizing the merge heap: shards clamp hostile ks to
        // their own vocabularies, and the router must do the same rather
        // than let a u32::MAX k from the wire size an eager allocation.
        let cap = (per_shard_k as usize).min(inner.topo.vocab());
        let merged = merge_top_k(cap, lists);
        if let (Some(t0), Some(t_fan)) = (t0, t_fan) {
            // No routing decision for a scatter-to-all: the route span is
            // empty by construction (the query row's own lookup recorded
            // its routing separately).
            inner.record_route("knn", t0, t0, t_fan, span);
        }
        Ok(merged)
    }

    /// Every (shard, replica) coordinate, shard-major.
    fn replica_pairs(&self) -> Vec<(usize, usize)> {
        let topo = &self.inner.topo;
        (0..topo.n_shards())
            .flat_map(|s| (0..topo.replicas(s).len()).map(move |r| (s, r)))
            .collect()
    }

    /// Liveness-probe every replica once (the probe loop's body; callable
    /// directly in tests): success re-admits, failure advances the
    /// ejection streak. Probes fan out on scoped threads — serially, each
    /// dead replica would add a full connect timeout to the cycle,
    /// stretching re-admission latency for the nodes that *did* recover.
    pub fn probe_once(&self) {
        let inner = &*self.inner;
        let pairs = self.replica_pairs();
        std::thread::scope(|scope| {
            for &(s, r) in &pairs {
                scope.spawn(move || inner.probe_replica(s, r));
            }
        });
    }

    /// Fan `STATS` to every replica (in parallel — a dead replica must
    /// cost the caller one connect timeout, not one per corpse) and roll
    /// the answers up.
    pub fn stats(&self) -> ClusterStats {
        let inner = &*self.inner;
        let pairs = self.replica_pairs();
        let replicas: Vec<ReplicaReport> = std::thread::scope(|scope| {
            let handles: Vec<_> = pairs
                .iter()
                .map(|&(s, r)| {
                    scope.spawn(move || {
                        // Dedicated connection: a wedged replica must not
                        // hold its request slot's mutex hostage for an
                        // io_timeout while clients queue behind it. Health
                        // accounting belongs to the prober and the request
                        // path, not to observability fetches.
                        let stats = inner.with_admin_connection(s, r, |c| c.stats()).ok();
                        ReplicaReport {
                            shard: s,
                            replica: r,
                            addr: inner.topo.replicas(s)[r].clone(),
                            healthy: inner.health.is_healthy(s, r),
                            stats,
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("stats thread")).collect()
        });
        let mut agg = WireStats::default();
        let (mut min_generation, mut max_generation) = (u64::MAX, 0u64);
        let mut min_simd = u64::MAX;
        let mut probes_weighted = 0.0f64;
        for rep in replicas.iter().filter_map(|r| r.stats.as_ref()) {
            agg.p50_us = agg.p50_us.max(rep.p50_us);
            agg.p99_us = agg.p99_us.max(rep.p99_us);
            agg.served += rep.served;
            agg.cache_hits += rep.cache_hits;
            agg.cache_misses += rep.cache_misses;
            agg.rejected += rep.rejected;
            agg.knn_queries += rep.knn_queries;
            agg.knn_candidates += rep.knn_candidates;
            probes_weighted += rep.knn_mean_probes * rep.knn_queries as f64;
            agg.snapshot_bytes += rep.snapshot_bytes;
            min_generation = min_generation.min(rep.model_generation);
            max_generation = max_generation.max(rep.model_generation);
            // The fleet is only as vectorized as its slowest member: the
            // roll-up reports the minimum dispatch level across replicas.
            min_simd = min_simd.min(rep.simd_level);
            // Payload precision rolls up as the *maximum*: the fleet is
            // only as compressed as its least-quantized serving payload
            // (0 only when no replica answered).
            agg.payload_bits = agg.payload_bits.max(rep.payload_bits);
        }
        if min_generation == u64::MAX {
            min_generation = 0;
        }
        agg.simd_level = if min_simd == u64::MAX { 0 } else { min_simd };
        agg.knn_mean_probes =
            if agg.knn_queries == 0 { 0.0 } else { probes_weighted / agg.knn_queries as f64 };
        agg.model_generation = min_generation;
        ClusterStats {
            aggregate: agg,
            replicas,
            healthy_replicas: inner.health.healthy_count(),
            total_replicas: inner.health.total(),
            failovers: self.failovers(),
            min_generation,
            max_generation,
        }
    }

    /// Deploy new shard snapshots with zero downtime: one replica at a
    /// time, `paths[s]` reloaded on every replica of shard `s`, each swap
    /// verified via `STATS` (`model_generation` must step by exactly one
    /// and the post-swap STATS must agree). While one replica swaps, its
    /// siblings keep serving — and the swapping replica itself never drops
    /// a request (single-node hot swap). Aborts on the first failure,
    /// leaving untouched replicas on the old generation for the operator
    /// to retry. Returns each shard's final generation.
    pub fn rolling_reload(&self, paths: &[String]) -> Result<Vec<u64>, RouterError> {
        let inner = &*self.inner;
        if paths.len() != inner.topo.n_shards() {
            return Err(RouterError::BadQuery);
        }
        let mut generations = Vec::with_capacity(paths.len());
        for (s, path) in paths.iter().enumerate() {
            let mut shard_generation = 0u64;
            for r in 0..inner.topo.replicas(s).len() {
                let step = |m: String| RouterError::Reload { shard: s, replica: r, message: m };
                // A dedicated admin connection, NOT the pooled request
                // slot: a snapshot load can take seconds, and holding the
                // slot mutex for that long would stall every client
                // request round-robined to this replica — exactly the
                // downtime a rolling reload exists to avoid.
                let (before, swapped, after) = inner
                    .with_admin_connection(s, r, |c| {
                        let before = c.stats()?.model_generation;
                        let swapped = c.reload(path)? as u64;
                        let after = c.stats()?.model_generation;
                        Ok((before, swapped, after))
                    })
                    .map_err(|e| step(e.to_string()))?;
                if swapped != before + 1 {
                    return Err(step(format!(
                        "generation stepped {before} -> {swapped}, expected {}",
                        before + 1
                    )));
                }
                if after != swapped {
                    return Err(step(format!(
                        "post-swap STATS reports generation {after}, reload said {swapped}"
                    )));
                }
                shard_generation = after;
            }
            generations.push(shard_generation);
        }
        Ok(generations)
    }

    /// [`rolling_reload`](Self::rolling_reload) over a directory of
    /// canonical `shard<i>.snap` files (what
    /// [`save_shard_snapshots`](super::save_shard_snapshots) wrote) — the
    /// form the router's own `RELOAD <dir>` wire op uses.
    pub fn rolling_reload_dir(&self, dir: &Path) -> Result<Vec<u64>, RouterError> {
        let paths: Vec<String> = (0..self.inner.topo.n_shards())
            .map(|s| shard_snapshot_path(dir, s).to_string_lossy().into_owned())
            .collect();
        self.rolling_reload(&paths)
    }

    /// The router's metrics registry — its own listener records transport
    /// stages (parse/flush, reactor loop) into it via [`net::Service::obs`]
    /// (see `cluster::server`).
    pub fn obs(&self) -> Arc<Obs> {
        self.inner.obs.clone()
    }

    /// Cluster-wide METRICS roll-up: the router's own families first
    /// (total and per-shard failover counters, per-shard downstream
    /// timeout counters, route/fan-out/merge stage histograms), then every
    /// replica's full exposition scraped over `OP_METRICS` and re-emitted
    /// with `shard`/`replica` labels injected into each sample. A
    /// `w2k_scrape_ok{shard,replica}` marker precedes each replica's
    /// section (0 when the replica did not answer — its samples are simply
    /// absent, so one dead node never hides the rest of the cluster).
    pub fn metrics(&self) -> String {
        use std::fmt::Write as _;
        let inner = &*self.inner;
        let mut out = String::new();
        let _ = writeln!(out, "w2k_router_failovers_total {}", self.failovers());
        for (s, c) in inner.shard_failovers.iter().enumerate() {
            let _ = writeln!(
                out,
                "w2k_router_shard_failovers_total{{shard=\"{s}\"}} {}",
                c.load(Ordering::Relaxed)
            );
        }
        for (s, c) in inner.shard_timeouts.iter().enumerate() {
            let _ = writeln!(
                out,
                "w2k_router_shard_timeouts_total{{shard=\"{s}\"}} {}",
                c.load(Ordering::Relaxed)
            );
        }
        let _ = writeln!(out, "w2k_router_healthy_replicas {}", inner.health.healthy_count());
        let _ = writeln!(out, "w2k_router_total_replicas {}", inner.health.total());
        inner.obs.render_into(&mut out);
        // Scrape every replica in parallel on dedicated admin connections —
        // a dead replica costs one connect timeout, not one per corpse, and
        // the pooled request slots are never held across a scrape.
        let pairs = self.replica_pairs();
        let scraped: Vec<(usize, usize, Option<String>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = pairs
                .iter()
                .map(|&(s, r)| {
                    scope.spawn(move || {
                        (s, r, inner.with_admin_connection(s, r, |c| c.metrics()).ok())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("metrics scrape thread")).collect()
        });
        for (s, r, text) in scraped {
            let _ = writeln!(
                out,
                "w2k_scrape_ok{{shard=\"{s}\",replica=\"{r}\"}} {}",
                u32::from(text.is_some())
            );
            if let Some(text) = text {
                out.push_str(&relabel_exposition(
                    &text,
                    &format!("shard=\"{s}\",replica=\"{r}\""),
                ));
            }
        }
        out.push_str("# EOF\n");
        out
    }

    /// The router's own slow-query ring (`METRICS?slow` on the router
    /// listener) — slow routed requests with their route/fan-out/merge
    /// split, not the shards' rings (scrape a shard directly for those).
    pub fn metrics_slow_text(&self) -> String {
        self.inner.obs.render_slow()
    }

    /// Cluster-assembled trace dump (`TRACE <id>` / `OP_TRACE` on the
    /// router listener): the router's own spans for `trace_id` first, then
    /// every replica's spans for it scraped over `OP_TRACE` on dedicated
    /// admin connections and re-emitted with `shard`/`replica` labels —
    /// the same roll-up pattern as [`Self::metrics`]. A
    /// `w2k_trace_scrape_ok{shard,replica}` marker per replica keeps dead
    /// shards *visible* (marker 0, spans absent) instead of silently
    /// hiding them from the assembled tree.
    pub fn trace_text(&self, trace_id: u128) -> String {
        use std::fmt::Write as _;
        let inner = &*self.inner;
        let mut out = String::new();
        inner.obs.tracer().render_trace(trace_id, &mut out);
        let pairs = self.replica_pairs();
        let scraped: Vec<(usize, usize, Option<String>)> = std::thread::scope(|scope| {
            let handles: Vec<_> = pairs
                .iter()
                .map(|&(s, r)| {
                    scope.spawn(move || {
                        (s, r, inner.with_admin_connection(s, r, |c| c.trace(trace_id)).ok())
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("trace scrape thread")).collect()
        });
        for (s, r, text) in scraped {
            let _ = writeln!(
                out,
                "w2k_trace_scrape_ok{{shard=\"{s}\",replica=\"{r}\"}} {}",
                u32::from(text.is_some())
            );
            if let Some(text) = text {
                out.push_str(&relabel_exposition(
                    &text,
                    &format!("shard=\"{s}\",replica=\"{r}\""),
                ));
            }
        }
        out.push_str("# EOF\n");
        out
    }

    /// The router's own completed-trace ring (`TRACE?slow` on the router
    /// listener): head-sampled plus tail-captured routed requests. Shard
    /// rings are one `TRACE <id>` away via the assembled dump.
    pub fn trace_slow_text(&self) -> String {
        let mut out = String::new();
        self.inner.obs.tracer().render_ring(&mut out);
        out.push_str("# EOF\n");
        out
    }
}

/// Run `f(shard)` for every listed shard on scoped threads and gather the
/// results in listing order; the first error wins.
fn scatter<T: Send>(
    shards: &[usize],
    f: impl Fn(usize) -> Result<T, RouterError> + Sync,
) -> Result<Vec<T>, RouterError> {
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> =
            shards.iter().map(|&s| scope.spawn(move || f(s))).collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("scatter thread panicked"))
            .collect::<Result<Vec<T>, RouterError>>()
    })
}

/// Merged lists may hold `per_shard_k` entries; the client asked for `k`.
fn take_k(mut merged: Vec<Neighbor>, k: usize) -> Vec<(u32, f32)> {
    merged.truncate(k);
    merged.into_iter().map(|n| (n.id as u32, n.score)).collect()
}

impl Inner {
    /// Record the route/fan-out/merge stage split of one routed request
    /// (merge ends now), its end-to-end latency, and a slow-ring entry.
    /// Callers only reach this when obs is enabled (the `Instant`s exist).
    /// A sampled routed span mirrors the same stage split and is finished
    /// here — ring-visible before the response is written.
    fn record_route(
        &self,
        op: &'static str,
        t0: Instant,
        route_done: Instant,
        fan_done: Instant,
        span: &mut Option<Span>,
    ) {
        let now = Instant::now();
        let route = route_done.duration_since(t0);
        let fan = fan_done.duration_since(route_done);
        let merge = now.duration_since(fan_done);
        self.obs.record_stage(Stage::Route, route);
        self.obs.record_stage(Stage::Fanout, fan);
        self.obs.record_stage(Stage::Merge, merge);
        self.obs.record_e2e(now.duration_since(t0));
        self.obs.note_slow(
            op,
            now.duration_since(t0),
            vec![
                (Stage::Route, route.as_micros() as u64),
                (Stage::Fanout, fan.as_micros() as u64),
                (Stage::Merge, merge.as_micros() as u64),
            ],
        );
        if let Some(mut s) = span.take() {
            s.stage(Stage::Route, route.as_micros() as u64);
            s.stage(Stage::Fanout, fan.as_micros() as u64);
            s.stage(Stage::Merge, merge.as_micros() as u64);
            self.obs.tracer().finish(s);
        }
    }

    /// Mint the routed span for one request at the router's edge: adopt a
    /// propagated client context as a child span (stamping the listener's
    /// parse time) or head-sample a fresh root.
    fn edge_span(&self, op: &'static str, trace: Option<(TraceContext, u64)>) -> Option<Span> {
        let tracer = self.obs.tracer();
        let mut span = match trace {
            Some((ctx, pre_us)) => tracer.start_child(ctx, op, pre_us),
            None => tracer.maybe_start_root(op),
        };
        if let (Some(s), Some((_, pre_us))) = (span.as_mut(), trace) {
            if pre_us > 0 {
                s.stage(Stage::Parse, pre_us);
            }
        }
        span
    }

    /// Close out a routed request's span. A span still alive here ended in
    /// an error (success finishes it inside [`Self::record_route`]);
    /// unsampled or errored requests fall through to tail-capture so slow
    /// and failing routes stay ring-visible at any sampling rate.
    fn close_route_span(
        &self,
        op: &'static str,
        span: Option<Span>,
        sampled: bool,
        err: Option<&'static str>,
        t0: Instant,
    ) {
        let tracer = self.obs.tracer();
        if let Some(mut s) = span {
            if let Some(tag) = err {
                s.set_status(tag);
            }
            tracer.finish(s);
        } else if err.is_some() || !sampled {
            tracer.tail_capture(op, t0.elapsed().as_micros() as u64, err.is_some());
        }
    }

    /// Lock a replica slot, (re)connecting if needed, and run `op` on it.
    /// On transport failure the pooled connection is dropped and the
    /// failure recorded; server status errors are *answers* and count as
    /// replica health successes.
    fn try_slot<T>(
        &self,
        s: usize,
        r: usize,
        op: &mut dyn FnMut(&mut BinaryClient) -> Result<T, WireError>,
    ) -> Result<T, WireError> {
        let mut slot = self.slots[s][r].lock().unwrap();
        if slot.is_none() {
            let addr = &self.topo.replicas(s)[r];
            let client = BinaryClient::connect_with_timeouts(
                addr,
                self.cfg.connect_timeout,
                self.cfg.io_timeout,
            );
            match client {
                Ok(c) => {
                    self.dim.store(c.dim, Ordering::Relaxed);
                    *slot = Some(c);
                }
                Err(e) => {
                    self.health.record_failure(s, r);
                    return Err(e);
                }
            }
        }
        let c = slot.as_mut().expect("connected above");
        match op(c) {
            Ok(v) => {
                self.health.record_success(s, r);
                Ok(v)
            }
            Err(WireError::Status(code)) => {
                // The server answered; the replica is fine.
                self.health.record_success(s, r);
                Err(WireError::Status(code))
            }
            Err(e) => {
                *slot = None;
                self.health.record_failure(s, r);
                Err(e)
            }
        }
    }

    /// Probe one replica on a fresh dedicated connection (never the pooled
    /// request slot: a hung replica would hold the slot mutex for a full
    /// io_timeout with client requests queued behind it) and record the
    /// outcome on the health board. Probing the full accept path also
    /// means a server whose listener died but whose old sockets linger is
    /// correctly detected as down.
    fn probe_replica(&self, s: usize, r: usize) {
        let addr = &self.topo.replicas(s)[r];
        let result = BinaryClient::connect_with_timeouts(
            addr,
            self.cfg.connect_timeout,
            self.cfg.io_timeout,
        )
        .and_then(|mut c| {
            let out = c.ping();
            c.quit().ok();
            out
        });
        match result {
            Ok(()) => self.health.record_success(s, r),
            Err(_) => {
                self.health.record_failure(s, r);
            }
        }
    }

    /// A short-lived dedicated connection for administrative exchanges
    /// (rolling reload, STATS fan-out): long or slow server-side work must
    /// never run while the pooled request slot's mutex is held. No health
    /// recording — that is the prober's and the request path's job.
    fn with_admin_connection<T>(
        &self,
        s: usize,
        r: usize,
        mut op: impl FnMut(&mut BinaryClient) -> Result<T, WireError>,
    ) -> Result<T, WireError> {
        let addr = &self.topo.replicas(s)[r];
        let mut client = BinaryClient::connect_with_timeouts(
            addr,
            self.cfg.connect_timeout,
            self.cfg.io_timeout,
        )?;
        let out = op(&mut client);
        client.quit().ok();
        out
    }

    /// Run `op` against shard `s` with automatic failover: round-robin over
    /// healthy replicas first, then — if every healthy replica failed — one
    /// last-resort pass over the ejected ones (ejection degrades, it must
    /// never blackhole a shard whose last replica flapped).
    ///
    /// What fails over: transport errors, and the two *capacity* statuses
    /// (`overloaded`, `timeout`) — a replica drowning in backpressure (or
    /// mid-shutdown with drained workers) answered, so its health streak is
    /// untouched, but a sibling may well have room. Every other non-zero
    /// status is a final answer about the request itself (bad id, bad
    /// frame): retrying it elsewhere would just repeat the answer.
    fn with_replica<T>(
        &self,
        s: usize,
        mut op: impl FnMut(&mut BinaryClient) -> Result<T, WireError>,
    ) -> Result<T, RouterError> {
        let n = self.topo.replicas(s).len();
        let start = self.next[s].fetch_add(1, Ordering::Relaxed);
        let mut last = String::from("no replicas");
        let mut attempts = 0u32;
        for pass in 0..2 {
            for off in 0..n {
                let r = (start + off) % n;
                if (pass == 0) != self.health.is_healthy(s, r) {
                    continue;
                }
                attempts += 1;
                match self.try_slot(s, r, &mut op) {
                    Ok(v) => {
                        if attempts > 1 {
                            self.failovers.fetch_add(1, Ordering::Relaxed);
                            self.shard_failovers[s].fetch_add(1, Ordering::Relaxed);
                        }
                        return Ok(v);
                    }
                    Err(WireError::Status(code))
                        if code == wire::STATUS_OVERLOADED
                            || code == wire::STATUS_TIMEOUT =>
                    {
                        if code == wire::STATUS_TIMEOUT {
                            self.shard_timeouts[s].fetch_add(1, Ordering::Relaxed);
                        }
                        last = format!("status {code}: {}", wire::status_name(code));
                    }
                    // Any other status is a final answer about the request;
                    // it is not a successful failover, so the counter
                    // (documented as successes-after-failover) stays put.
                    Err(WireError::Status(code)) => {
                        return Err(RouterError::Wire(WireError::Status(code)));
                    }
                    Err(e) => {
                        if matches!(e, WireError::TimedOut) {
                            self.shard_timeouts[s].fetch_add(1, Ordering::Relaxed);
                        }
                        last = e.to_string();
                    }
                }
            }
        }
        Err(RouterError::ShardDown { shard: s, last })
    }

    /// Should multi-shard fan-out run as concurrent in-flight exchanges on
    /// one poller (`[net] driver = "epoll"`) instead of one scoped thread
    /// per shard? Off unix there is no poller, so never.
    fn multiplexed(&self) -> bool {
        cfg!(unix) && self.cfg.net.driver == NetDriver::Epoll
    }

    /// Multiplexed LOOKUP fan-out; shards whose concurrent attempt could
    /// not run or did not answer fall back to the blocking failover path.
    #[cfg(unix)]
    fn fan_lookup(
        &self,
        involved: &[usize],
        locals: &[Vec<u32>],
        ctx: Option<TraceContext>,
    ) -> Result<Vec<Vec<Vec<f32>>>, RouterError> {
        let attempts = self.scatter_multiplexed(
            involved,
            &|s| wire::encode_ids_frame_traced(wire::OP_LOOKUP, &locals[s], ctx),
            true,
        );
        let mut out = Vec::with_capacity(involved.len());
        for (&s, attempt) in involved.iter().zip(attempts) {
            out.push(match attempt {
                FanAttempt::Rows(rows) => rows,
                FanAttempt::Neighbors(_) => unreachable!("rows exchange answered neighbors"),
                other => self.refan(s, other, |c| c.lookup_traced(&locals[s], ctx))?,
            });
        }
        Ok(out)
    }

    #[cfg(not(unix))]
    fn fan_lookup(
        &self,
        involved: &[usize],
        locals: &[Vec<u32>],
        ctx: Option<TraceContext>,
    ) -> Result<Vec<Vec<Vec<f32>>>, RouterError> {
        scatter(involved, |s| self.with_replica(s, |c| c.lookup_traced(&locals[s], ctx)))
    }

    /// Multiplexed KNN_VEC fan-out with the same per-shard fallback.
    #[cfg(unix)]
    fn fan_knn(
        &self,
        shards: &[usize],
        query: &[f32],
        per_shard_k: u32,
        ctx: Option<TraceContext>,
    ) -> Result<Vec<Vec<(u32, f32)>>, RouterError> {
        let attempts = self.scatter_multiplexed(
            shards,
            &|_| wire::encode_knn_vec_frame_traced(query, per_shard_k, ctx),
            false,
        );
        let mut out = Vec::with_capacity(shards.len());
        for (&s, attempt) in shards.iter().zip(attempts) {
            out.push(match attempt {
                FanAttempt::Neighbors(ns) => ns,
                FanAttempt::Rows(_) => unreachable!("neighbors exchange answered rows"),
                other => self.refan(s, other, |c| c.knn_vec_traced(query, per_shard_k, ctx))?,
            });
        }
        Ok(out)
    }

    #[cfg(not(unix))]
    fn fan_knn(
        &self,
        shards: &[usize],
        query: &[f32],
        per_shard_k: u32,
        ctx: Option<TraceContext>,
    ) -> Result<Vec<Vec<(u32, f32)>>, RouterError> {
        scatter(shards, |s| {
            self.with_replica(s, |c| c.knn_vec_traced(query, per_shard_k, ctx))
        })
    }

    /// Resolve a non-answer fan-out attempt through the blocking failover
    /// path ([`with_replica`](Self::with_replica)). A success after a
    /// failed concurrent attempt counts as a failover, same as the
    /// blocking path's own retries; capacity statuses (overloaded,
    /// timeout) retry elsewhere, every other status is a final answer.
    #[cfg(unix)]
    fn refan<T>(
        &self,
        s: usize,
        attempt: FanAttempt,
        mut op: impl FnMut(&mut BinaryClient) -> Result<T, WireError>,
    ) -> Result<T, RouterError> {
        let failed = !matches!(attempt, FanAttempt::Skipped);
        if let FanAttempt::Status(code) = attempt {
            if code != wire::STATUS_OVERLOADED && code != wire::STATUS_TIMEOUT {
                return Err(RouterError::Wire(WireError::Status(code)));
            }
        }
        let v = self.with_replica(s, &mut op)?;
        if failed {
            self.failovers.fetch_add(1, Ordering::Relaxed);
            self.shard_failovers[s].fetch_add(1, Ordering::Relaxed);
        }
        Ok(v)
    }

    /// One concurrent exchange per listed shard: pick a healthy replica
    /// (round-robin, pooled connection locked for the whole exchange —
    /// the same exclusivity the blocking path has), write every request
    /// frame, then multiplex all the response reads on one poller via
    /// [`fanout::exchange_all`](crate::net::fanout::exchange_all). Wall
    /// time is one downstream round-trip instead of thread-spawn + the
    /// slowest sequential pieces. Never errors: each shard reports a
    /// [`FanAttempt`] and the caller decides how to settle failures.
    #[cfg(unix)]
    fn scatter_multiplexed(
        &self,
        shards: &[usize],
        frame_for: &dyn Fn(usize) -> Vec<u8>,
        rows_shape: bool,
    ) -> Vec<FanAttempt> {
        use crate::net::fanout::{exchange_all, Exchange, Payload, Shape};
        // Phase 1: pick + lock one replica slot per shard. Connect failures
        // advance the ejection streak exactly like the blocking path; a
        // slot with buffered response bytes (a previous exchange died
        // mid-read) is unusable for framed fan-out and is skipped.
        let mut picks: Vec<Option<(usize, std::sync::MutexGuard<'_, Option<BinaryClient>>)>> =
            Vec::with_capacity(shards.len());
        for &s in shards {
            let n = self.topo.replicas(s).len();
            let start = self.next[s].fetch_add(1, Ordering::Relaxed);
            let mut picked = None;
            for off in 0..n {
                let r = (start + off) % n;
                if !self.health.is_healthy(s, r) {
                    continue;
                }
                let mut slot = self.slots[s][r].lock().unwrap();
                if slot.is_none() {
                    match BinaryClient::connect_with_timeouts(
                        &self.topo.replicas(s)[r],
                        self.cfg.connect_timeout,
                        self.cfg.io_timeout,
                    ) {
                        Ok(c) => {
                            self.dim.store(c.dim, Ordering::Relaxed);
                            *slot = Some(c);
                        }
                        Err(_) => {
                            self.health.record_failure(s, r);
                            continue;
                        }
                    }
                }
                if !slot.as_ref().is_some_and(|c| c.fanout_ready()) {
                    continue;
                }
                picked = Some((r, slot));
                break;
            }
            picks.push(picked);
        }
        // Phase 2: build the exchanges over the locked slots and run them.
        let mut jobs = Vec::new();
        for (i, pick) in picks.iter_mut().enumerate() {
            if let Some((_, guard)) = pick {
                let client = guard.as_mut().expect("picked slots are connected");
                let frame = frame_for(shards[i]);
                let shape =
                    if rows_shape { Shape::Rows { dim: client.dim } } else { Shape::Neighbors };
                jobs.push(Exchange { client, frame, shape });
            }
        }
        let mut results = exchange_all(jobs, self.cfg.io_timeout).into_iter();
        // Phase 3: settle health + pooled slots per shard, in shard order
        // (jobs were built in pick order, so the iterator lines up).
        let mut out = Vec::with_capacity(shards.len());
        for (&s, pick) in shards.iter().zip(picks) {
            let Some((r, mut guard)) = pick else {
                out.push(FanAttempt::Skipped);
                continue;
            };
            out.push(match results.next().expect("one result per picked shard") {
                Ok(Payload::Rows(rows)) => {
                    self.health.record_success(s, r);
                    FanAttempt::Rows(rows)
                }
                Ok(Payload::Neighbors(ns)) => {
                    self.health.record_success(s, r);
                    FanAttempt::Neighbors(ns)
                }
                // The server answered; the replica is fine, the
                // connection's framing is clean.
                Err(WireError::Status(code)) => {
                    self.health.record_success(s, r);
                    FanAttempt::Status(code)
                }
                Err(_) => {
                    *guard = None;
                    self.health.record_failure(s, r);
                    FanAttempt::TransportFailed
                }
            });
        }
        out
    }
}

/// Outcome of one shard's concurrent fan-out exchange.
#[cfg(unix)]
enum FanAttempt {
    /// No usable replica pick (unhealthy, connect failed, or a dirty
    /// pooled connection): the blocking path owns the retry, and it is
    /// not counted as a failover.
    Skipped,
    /// The exchange's transport failed; the pooled connection was dropped.
    TransportFailed,
    /// The server answered a non-OK status.
    Status(u32),
    Rows(Vec<Vec<f32>>),
    Neighbors(Vec<(u32, f32)>),
}

/// Background PING loop; holds only a `Weak`, so dropping every router
/// handle (or calling [`Router::shutdown`]) ends it.
fn spawn_prober(inner: &Arc<Inner>) {
    let weak: Weak<Inner> = Arc::downgrade(inner);
    let interval = inner.cfg.probe_interval;
    std::thread::Builder::new()
        .name("cluster-prober".into())
        .spawn(move || loop {
            let Some(inner) = weak.upgrade() else { return };
            if inner.stop.load(Ordering::SeqCst) {
                return;
            }
            Router { inner }.probe_once();
            std::thread::sleep(interval);
        })
        .ok();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ShardStrategy;

    fn topo2() -> Topology {
        // Ports in TEST-NET ranges nothing listens on: connection attempts
        // fail fast-ish and deterministically.
        Topology::new(
            100,
            ShardStrategy::Range,
            vec![vec!["127.0.0.1:1".into()], vec!["127.0.0.1:1".into()]],
        )
        .unwrap()
    }

    fn no_probe_cfg() -> RouterConfig {
        RouterConfig {
            connect_timeout: Duration::from_millis(50),
            io_timeout: Duration::from_millis(50),
            probe_interval: Duration::ZERO,
            eject_after: 1,
            ..RouterConfig::default()
        }
    }

    #[test]
    fn config_defaults_and_doc_overrides() {
        let d = RouterConfig::default();
        assert_eq!(d.eject_after, 3);
        let doc = TomlDoc::parse(
            "[cluster]\nprobe_interval_ms = 50\neject_after = 1\nio_timeout_ms = 100\n",
        )
        .unwrap();
        let cfg = RouterConfig::from_doc(&doc);
        assert_eq!(cfg.probe_interval, Duration::from_millis(50));
        assert_eq!(cfg.eject_after, 1);
        assert_eq!(cfg.io_timeout, Duration::from_millis(100));
        assert_eq!(cfg.connect_timeout, d.connect_timeout);
        assert_eq!(cfg.net, crate::net::NetConfig::default());

        // [net] rides along in the same doc.
        let doc = TomlDoc::parse("[net]\ndriver = \"epoll\"\nhandlers = 8\n").unwrap();
        let cfg = RouterConfig::from_doc(&doc);
        assert_eq!(cfg.net.driver, crate::net::NetDriver::Epoll);
        assert_eq!(cfg.net.handlers, 8);
        assert_eq!(cfg.net.drain_ms, crate::net::NetConfig::default().drain_ms);
    }

    #[test]
    fn validation_precedes_any_connection() {
        // Bad requests fail before the router ever dials a socket — no
        // listening servers exist here.
        let router = Router::new(topo2(), no_probe_cfg());
        assert!(matches!(router.lookup(&[]), Err(RouterError::BadQuery)));
        assert!(matches!(router.lookup(&[100]), Err(RouterError::OutOfRange)));
        assert!(matches!(router.dot(0, 100), Err(RouterError::OutOfRange)));
        assert!(matches!(router.knn(100, 5), Err(RouterError::OutOfRange)));
        assert!(matches!(router.knn(0, 0), Err(RouterError::BadQuery)));
        assert!(matches!(router.knn_vec(&[], 5), Err(RouterError::BadQuery)));
        assert!(matches!(
            router.rolling_reload(&["one.snap".into()]),
            Err(RouterError::BadQuery)
        ));
        router.shutdown();
    }

    #[test]
    fn unreachable_cluster_reports_shard_down_and_ejects() {
        let router = Router::new(topo2(), no_probe_cfg());
        match router.lookup(&[1]) {
            Err(RouterError::ShardDown { shard: 0, .. }) => {}
            other => panic!("expected ShardDown, got {other:?}"),
        }
        // eject_after = 1: the first failed connect ejected the replica.
        assert!(!router.health().is_healthy(0, 0));
        let stats = router.stats();
        assert_eq!(stats.total_replicas, 2);
        assert_eq!(stats.aggregate.served, 0);
        assert_eq!(stats.min_generation, 0);
        assert!(stats.replicas.iter().all(|r| r.stats.is_none()));
        router.shutdown();
    }

    #[test]
    fn error_status_mapping() {
        assert_eq!(RouterError::OutOfRange.status_code(), wire::STATUS_RANGE);
        assert_eq!(RouterError::BadQuery.status_code(), wire::STATUS_BAD_REQUEST);
        let down = RouterError::ShardDown { shard: 0, last: "x".into() };
        assert_eq!(down.status_code(), wire::STATUS_OVERLOADED);
        let status = RouterError::Wire(WireError::Status(wire::STATUS_RANGE));
        assert_eq!(status.status_code(), wire::STATUS_RANGE);
        let reload = RouterError::Reload { shard: 0, replica: 0, message: "x".into() };
        assert_eq!(reload.status_code(), wire::STATUS_RELOAD_FAILED);
    }
}
